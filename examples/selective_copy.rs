//! Selective Copying (paper Appendix F.1, Table 5, Figure 5).
//!
//! Trains the 2-layer Appendix-F model on the selective copying task and
//! reports exact-match accuracy over training — reproducing the paper's
//! observation that the model "suddenly learns" the task at some point and
//! that polysketch attention solves it like softmax does.
//!
//! ```bash
//! cargo run --release --example selective_copy -- [artifact] [steps]
//! # artifacts: copy_softmax | copy_poly4 | copy_psk
//! ```

use polysketchformer::coordinator::{run_task, TaskRunnerConfig};
use polysketchformer::runtime::{self, LoadOpts};
use polysketchformer::tasks::selective_copy::SelectiveCopyTask;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().cloned().unwrap_or_else(|| "copy_psk".to_string());
    let steps: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(600);

    println!("== Selective Copying (Appendix F.1) ==");
    let mut model = runtime::load_model(&name, LoadOpts::default())?;
    let task = SelectiveCopyTask::standard(model.ctx());
    println!(
        "artifact {name}: ctx={} vocab={} ({} colors, {} to memorize)",
        model.ctx(),
        model.vocab(),
        task.n_colors,
        task.n_memorize,
    );

    let cfg = TaskRunnerConfig {
        steps,
        eval_every: 50,
        eval_examples: 64,
        echo_every: 25,
        seed: 0,
        stop_at_accuracy: 0.995,
    };
    let summary = run_task(&mut model, &task, &cfg)?;

    println!("\n== accuracy curve (Figure 5 analog) ==");
    println!("{:>8} {:>10} {:>10}", "step", "exact", "token");
    for &(step, acc) in &summary.curve {
        println!("{step:>8} {:>9.1}% {:>9.1}%", acc.exact * 100.0, acc.token * 100.0);
    }
    println!(
        "\nfinal: {:.1}% exact-match / {:.1}% token after {} steps (Table 5 analog)",
        summary.final_accuracy.exact * 100.0,
        summary.final_accuracy.token * 100.0,
        summary.steps_run,
    );
    Ok(())
}
