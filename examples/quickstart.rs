//! Quickstart: load a PolySketchFormer model artifact, run a few train
//! steps and an eval — the smallest end-to-end trip through all three
//! layers (Pallas kernel -> JAX model -> HLO -> rust PJRT runtime).
//!
//! Run `make artifacts` first, then:
//!
//! ```bash
//! cargo run --release --example quickstart [-- <artifact-name>]
//! ```

use polysketchformer::data::{self, batcher::Batcher, corpus::Flavor};
use polysketchformer::runtime::{self, LoadOpts};

fn main() -> anyhow::Result<()> {
    let name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "psk4_r16_learned_local_v512_d128_l4_h4x32_c256".to_string());
    println!("loading artifact bundle `{name}` ...");
    let mut model = runtime::load_model(&name, LoadOpts::default())?;
    let (batch, ctx, vocab) = (model.batch(), model.ctx(), model.vocab());
    println!(
        "  {} — {} params, batch={batch} ctx={ctx} vocab={vocab}",
        model.manifest.name, model.manifest.nparams,
    );

    // Synthetic PG19-like corpus -> BPE tokens -> packed batches.
    let ds = data::load_corpus_tokens(Flavor::Books, 400_000, vocab, 7, None)?;
    let mut train = Batcher::new(&ds.train, batch, ctx + 1, 7);
    let mut test = Batcher::new(&ds.test, batch, ctx + 1, 7);

    println!("training 5 steps:");
    for _ in 0..5 {
        let tokens = train.next_batch();
        let stats = model.train_step(&tokens.tokens)?;
        println!("  step {:>2}  loss {:.4}", stats.step, stats.loss);
    }

    let nll = model.eval_loss(&test.next_batch().tokens)?;
    println!("eval: nll {:.4}  perplexity {:.2}", nll, nll.exp());
    println!("quickstart OK");
    Ok(())
}
