//! Serving-gateway quickstart: start `serve::Gateway` on an ephemeral
//! port, talk to it over real HTTP, and watch the prompt-prefix cache
//! erase the prefill from the second request's TTFT.
//!
//! ```bash
//! cargo run --release --example serve
//! ```
//!
//! The full-featured entry point is `cargo run --release -- serve --help`
//! (same gateway, every knob exposed), which pairs with plain curl:
//!
//! ```bash
//! curl -N -X POST http://127.0.0.1:8080/v1/generate \
//!      -d '{"prompt":"The polynomial kernel","max_tokens":32,"seed":7}'
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use polysketchformer::attn::Mechanism;
use polysketchformer::infer::LmConfig;
use polysketchformer::serve::{Gateway, GatewayConfig};

fn main() -> anyhow::Result<()> {
    let mech = Mechanism::Polysketch { r: 16, p: 4, block: 32, local: true };
    let model = polysketchformer::infer::NativeLm::new(LmConfig::default(), mech);
    let gateway = Arc::new(Gateway::new(model, GatewayConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_requests: 3, // healthz is free; the gateway exits after 3 generates
        ..GatewayConfig::default()
    })?);

    let server = {
        let gateway = Arc::clone(&gateway);
        std::thread::spawn(move || gateway.run_http())
    };
    let addr = loop {
        if let Some(a) = gateway.http_addr() {
            break a;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    };

    println!("gateway up on http://{addr}\n");
    println!("GET /healthz\n  {}", request(&addr, "GET", "/healthz", "")?);

    let body = r#"{"prompt":"Sketching the polynomial kernel","max_tokens":32,"policy":"greedy","seed":7}"#;
    let generate = |label: &str| -> anyhow::Result<()> {
        let resp = request(&addr, "POST", "/v1/generate", body)?;
        let done = resp
            .lines()
            .find(|l| l.contains("\"done\":true"))
            .unwrap_or("<no terminal line>")
            .to_string();
        println!("POST /v1/generate [{label}]\n  {done}");
        Ok(())
    };
    generate("cold (full prefill)")?;
    generate("warm (prompt-cache hit)")?;
    println!("\nGET /metrics\n  {}", request(&addr, "GET", "/metrics", "")?);
    generate("warm again")?;
    // max_requests (3) reached -> the accept loop stops and workers drain.
    server.join().expect("server thread panicked")?;
    println!("\n(untrained weights — the text is noise; identical streams and the\n ttft_ms drop on the cache hits are the point)");
    Ok(())
}

/// Minimal HTTP/1.1 client: one request, read to EOF (the gateway closes
/// per connection), return the de-chunked body.
fn request(addr: &std::net::SocketAddr, method: &str, path: &str, body: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: psf\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    )?;
    let mut raw = String::new();
    s.read_to_string(&mut raw)?;
    let (head, payload) = raw.split_once("\r\n\r\n").unwrap_or(("", &raw));
    Ok(if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        dechunk(payload)
    } else {
        payload.to_string()
    })
}

/// Undo chunked transfer encoding (sizes are hex lines between chunks).
/// Byte-wise: chunk sizes count bytes, and a chunk boundary may fall
/// inside a multi-byte UTF-8 scalar.
fn dechunk(payload: &str) -> String {
    let mut out: Vec<u8> = Vec::new();
    let mut rest = payload.as_bytes();
    loop {
        let Some(eol) = rest.windows(2).position(|w| w == b"\r\n") else { break };
        let size_line = String::from_utf8_lossy(&rest[..eol]);
        let Ok(size) = usize::from_str_radix(size_line.trim(), 16) else { break };
        let data_start = eol + 2;
        if size == 0 || rest.len() < data_start + size {
            break;
        }
        out.extend_from_slice(&rest[data_start..data_start + size]);
        rest = &rest[data_start + size..];
        rest = rest.strip_prefix(b"\r\n").unwrap_or(rest);
    }
    String::from_utf8_lossy(&out).into_owned()
}
