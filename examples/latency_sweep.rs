//! Context-length latency sweep (Figure 1 / Figure 4 analog, interactive).
//!
//! Times one attention layer forward across mechanisms and context lengths
//! through TWO independent paths:
//!
//!   * the native rust kernels (reach 32k context — the interpreted Pallas
//!     kernels cannot), printing µs/token like Figure 1, and
//!   * the AOT Pallas attention artifacts via PJRT (proving the compiled
//!     path), at the sizes aot.py emits.
//!
//! The full bench-harness version with warmup/percentiles lives in
//! `rust/benches/fig1_latency.rs`; this example is the quick look.
//!
//! ```bash
//! cargo run --release --example latency_sweep -- [max_ctx] [head_dim]
//! ```

use std::time::Instant;

use polysketchformer::attn::Mechanism;
use polysketchformer::runtime;
use polysketchformer::tensor::Tensor;
use polysketchformer::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_ctx: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(8192);
    let head_dim: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(32);

    let mechanisms = [
        Mechanism::Flash { block: 256 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 16, p: 4, block: 256, local: true },
        Mechanism::Performer { m: 64, block: 256 },
    ];

    println!("== native kernels: µs/token, one attention head, h={head_dim} ==");
    print!("{:<22}", "mechanism");
    let mut ctxs = Vec::new();
    let mut ctx = 512;
    while ctx <= max_ctx {
        print!(" {ctx:>9}");
        ctxs.push(ctx);
        ctx *= 2;
    }
    println!();

    let mut rng = Pcg::seeded(0);
    for mech in &mechanisms {
        let attn = mech.build_kernel(head_dim, &mut rng);
        print!("{:<22}", mech.label());
        for &n in &ctxs {
            // Quadratic mechanisms above 16k take minutes on one core —
            // the paper marks these OOM; we mark them "-".
            if !mech.is_linear() && n > 16384 {
                print!(" {:>9}", "-");
                continue;
            }
            let q = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let k = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let v = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let t0 = Instant::now();
            let out = attn.forward(&q, &k, &v);
            let us_per_token = t0.elapsed().as_secs_f64() * 1e6 / n as f64;
            assert!(out.data().iter().all(|x| x.is_finite()));
            print!(" {us_per_token:>9.2}");
        }
        println!();
    }

    println!("\n== AOT Pallas artifacts via PJRT (compiled path) ==");
    let dir = runtime::artifacts_dir();
    let mans = runtime::discover(&dir)?;
    let mut names: Vec<&String> = mans
        .iter()
        .filter(|(_, m)| m.kind == "attn")
        .map(|(n, _)| n)
        .collect();
    names.sort();
    for name in names {
        let micro = runtime::load_attn(name)?;
        let numel = micro.numel();
        let mut rng = Pcg::seeded(1);
        let q: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();
        let k: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();
        let v: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();
        let t0 = Instant::now();
        let out = micro.run(&q, &k, &v)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        assert!(out.iter().all(|x| x.is_finite()));
        println!("  {name:<40} {ms:>8.2} ms ({} heads x n={})", micro.heads, micro.n);
    }
    println!("latency_sweep OK");
    Ok(())
}
