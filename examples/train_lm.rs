//! End-to-end training driver (the repository's headline example).
//!
//! Trains a GPT-2-style (CPU-scaled) PolySketchFormer language model for a
//! few hundred steps on the synthetic PG19-like corpus, logging the loss
//! curve and periodic test perplexity to `runs/<artifact>/train.jsonl`, and
//! closes with downstream multiple-choice evaluation — exercising every
//! layer of the stack: Pallas polysketch kernel -> JAX Transformer++ ->
//! AOT HLO -> rust PJRT runtime -> coordinator -> evaluator.
//!
//! ```bash
//! cargo run --release --example train_lm -- \
//!     [artifact-name] [steps] [corpus: books|wiki|web]
//! ```

use std::path::PathBuf;

use polysketchformer::coordinator::{self, Trainer, TrainerConfig};
use polysketchformer::data::{self, batcher::Batcher, corpus::Flavor};
use polysketchformer::runtime::{self, LoadOpts};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args
        .first()
        .cloned()
        .unwrap_or_else(|| "psk4_r16_learned_local_v512_d128_l4_h4x32_c256".to_string());
    let steps: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(300);
    let flavor = Flavor::parse(args.get(2).map(String::as_str).unwrap_or("books"))
        .expect("corpus must be books|wiki|web");

    println!("== PolySketchFormer end-to-end training driver ==");
    println!("artifact: {name}");
    let mut model = runtime::load_model(&name, LoadOpts::default())?;
    println!(
        "model: {} params, batch={} ctx={} vocab={}",
        model.manifest.nparams,
        model.batch(),
        model.ctx(),
        model.vocab(),
    );

    // Data: synthetic corpus -> BPE -> disjoint train/test streams.
    let ds = data::load_corpus_tokens(flavor, 4_000_000, model.vocab(), 7, None)?;
    println!(
        "data: {} corpus, {} train tokens, {} test tokens, {} BPE merges",
        ds.flavor.label(),
        ds.train.len(),
        ds.test.len(),
        ds.bpe.num_merges(),
    );
    let train = Batcher::new(&ds.train, model.batch(), model.ctx() + 1, 7);
    let test = Batcher::new(&ds.test, model.batch(), model.ctx() + 1, 7);

    let run_dir = PathBuf::from("runs").join(&name);
    let cfg = TrainerConfig {
        steps,
        eval_every: 50,
        eval_batches: 4,
        ckpt_every: 100,
        echo_every: 10,
        run_dir: Some(run_dir.clone()),
        nan_guard: true,
    };
    let summary = Trainer::new(&mut model, train, Some(test), cfg).run()?;

    println!("\n== loss curve (eval points) ==");
    println!("{:>8} {:>10} {:>12}", "step", "test NLL", "perplexity");
    for &(step, nll) in &summary.evals {
        println!("{step:>8} {nll:>10.4} {:>12.2}", (nll as f64).exp());
    }
    println!(
        "\ntrained {} steps in {:.1}s — {:.2} steps/s, {:.0} tokens/s",
        summary.steps_run,
        summary.wall_secs,
        summary.steps_per_sec(),
        summary.tokens_per_sec(),
    );
    println!("final test perplexity: {:.2}", summary.final_perplexity());
    println!("loss curve written to {}/train.jsonl", run_dir.display());

    // Downstream: synthetic multiple-choice cloze (Table 1 analog).
    for shots in [0usize, 5] {
        let qs = coordinator::gen_cloze_questions(
            &ds.test,
            model.ctx(),
            100,
            4,
            16,
            shots,
            11,
        );
        let acc = coordinator::score_mcq(&model, &qs)?;
        println!("downstream cloze MCQ {shots}-shot accuracy: {:.1}% (chance 25%)", acc * 100.0);
    }

    assert!(
        summary.final_loss < 6.0,
        "loss should drop below ln(vocab)≈6.24 after {steps} steps"
    );
    println!("train_lm OK");
    Ok(())
}
