//! Vendored, offline subset of the `anyhow` crate.
//!
//! This environment has no crates.io access, so the crate provides the
//! exact surface the repository uses — `Error`, `Result`, `anyhow!`,
//! `bail!`, `ensure!`, and the `Context` extension trait — with the same
//! semantics: any `std::error::Error` converts via `?`, `.context(...)`
//! wraps with an outer message, `{e}` prints the outermost message and
//! `{e:#}` prints the whole chain.

use std::fmt;

/// Error: an ordered context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirrors anyhow: Debug shows the message plus the cause chain, so
        // `fn main() -> anyhow::Result<()>` prints something useful.
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts via `?`. (Error itself intentionally does not
// implement std::error::Error, exactly like the real anyhow, so this
// blanket impl is coherent.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait: attach context to `Result`/`Option` errors.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_chains_and_alternate_prints_all() {
        let e: Result<()> = std::result::Result::<(), _>::Err(io_err()).context("loading cfg");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "loading cfg");
        assert_eq!(format!("{e:#}"), "loading cfg: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(5).is_err());
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
