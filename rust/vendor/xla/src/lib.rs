//! Offline stub of the `xla` (xla_extension 0.5.x / PJRT) bindings.
//!
//! The container this repository builds in has no xla_extension shared
//! library and no crates.io access, so this crate mirrors the *type and
//! method surface* the runtime layer uses — just enough for
//! `runtime/{exec,ops,model,attn_micro}.rs` and the coordinator to
//! compile.  Every device-touching call returns [`Error::Unavailable`];
//! callers that need real PJRT execution (the AOT-artifact paths behind
//! `make artifacts`) fail at run time with a clear message while the
//! native rust paths — attention kernels, the `infer` decoding subsystem,
//! data pipeline, benches — run fully.
//!
//! To execute AOT artifacts, point the `xla` dependency in
//! `rust/Cargo.toml` at the real bindings; no source change is needed.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: the backend is not linked into this build.
#[derive(Debug, Clone)]
pub struct Error {
    what: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: PJRT/XLA backend unavailable (offline `xla` stub; \
             link the real xla_extension bindings to run AOT artifacts)",
            self.what
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error { what: what.to_string() })
}

/// Element types transferable to/from device buffers.
pub trait ArrayElement: Copy + 'static {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u32 {}
impl ArrayElement for u8 {}

#[derive(Clone, Debug)]
pub struct PjRtClient;

#[derive(Debug)]
pub struct PjRtDevice;

#[derive(Debug)]
pub struct PjRtBuffer;

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

#[derive(Debug)]
pub struct Literal;

#[derive(Debug)]
pub struct HloModuleProto;

#[derive(Debug)]
pub struct XlaComputation;

#[derive(Debug)]
pub struct Shape;

#[derive(Debug)]
pub struct XlaBuilder;

#[derive(Clone, Debug)]
pub struct XlaOp;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }

    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

impl Literal {
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl Shape {
    pub fn array<T: ArrayElement>(_dims: Vec<i64>) -> Shape {
        Shape
    }
}

impl XlaBuilder {
    pub fn new(_name: &str) -> XlaBuilder {
        XlaBuilder
    }

    pub fn parameter_s(&self, _index: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        unavailable("XlaBuilder::parameter_s")
    }
}

impl XlaOp {
    pub fn add_(&self, _other: &XlaOp) -> Result<XlaOp> {
        unavailable("XlaOp::add_")
    }

    pub fn mul_(&self, _other: &XlaOp) -> Result<XlaOp> {
        unavailable("XlaOp::mul_")
    }

    pub fn broadcast(&self, _dims: &[i64]) -> Result<XlaOp> {
        unavailable("XlaOp::broadcast")
    }

    pub fn build(&self) -> Result<XlaComputation> {
        unavailable("XlaOp::build")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(XlaBuilder::new("b").parameter_s(0, &Shape::array::<f32>(vec![4]), "x").is_err());
    }
}
