//! NOTE: every test here is `#[ignore]`d for tier-1 runs: they exercise
//! AOT artifacts through PJRT, which needs `make artifacts` (Python/JAX
//! toolchain) and the real xla_extension bindings in place of the offline
//! stub under rust/vendor/xla.  Run with `cargo test -- --ignored` once
//! both are available.

//! Integration tests for the synthetic-task path: task generators ->
//! AOT train/fwd artifacts -> accuracy evaluation (Appendix F protocol).

use polysketchformer::coordinator::{eval_accuracy, run_task, TaskRunnerConfig};
use polysketchformer::runtime::{self, LoadOpts};
use polysketchformer::tasks::induction::InductionTask;
use polysketchformer::tasks::selective_copy::SelectiveCopyTask;

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn untrained_model_scores_near_zero_on_selective_copy() {
    let model = runtime::load_model("tiny_softmax", LoadOpts::fwd_only())
        .expect("run `make artifacts` first");
    // ctx 32 is tight: 4 colors, 4 to memorize fits (needs ctx > 2*4+2).
    let task = SelectiveCopyTask::new(model.ctx(), 4, 4);
    let acc = eval_accuracy(&model, &task, 32, 0).unwrap();
    // Exact match of 4 positions from 4 colors at random: (1/4)^4 ~ 0.4%.
    assert!(acc.exact < 0.2, "untrained exact accuracy {}", acc.exact);
    assert!((0.0..=1.0).contains(&acc.token));
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn task_runner_trains_induction_on_tiny_model() {
    let mut model = runtime::load_model("tiny_softmax", LoadOpts::default()).unwrap();
    let task = InductionTask::standard(model.ctx());
    assert!(model.vocab() >= task.vocab());
    let cfg = TaskRunnerConfig {
        steps: 8,
        eval_every: 4,
        eval_examples: 16,
        echo_every: 0,
        seed: 0,
        stop_at_accuracy: 0.0,
    };
    let summary = run_task(&mut model, &task, &cfg).unwrap();
    assert_eq!(summary.steps_run, 8);
    assert!(summary.final_loss.is_finite());
    assert_eq!(summary.curve.len(), 2);
    for (_, acc) in summary.curve {
        assert!((0.0..=1.0).contains(&acc.exact));
        assert!((0.0..=1.0).contains(&acc.token));
        assert!(acc.token >= acc.exact - 1e-9, "token acc dominates exact");
    }
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn induction_loss_starts_near_uniform_over_answers() {
    // With every non-answer target masked, the first-step loss is the NLL
    // of one answer token: ~ln(vocab_task) not ln(vocab_model) after any
    // training, but at init it is ~ln(model vocab) since logits are flat.
    let mut model = runtime::load_model("tiny_softmax", LoadOpts::train_only()).unwrap();
    let task = InductionTask::standard(model.ctx());
    let (tokens, _) = {
        let mut rng = polysketchformer::Pcg::seeded(0);
        task.batch(model.batch(), &mut rng)
    };
    let stats = model.train_step(&tokens).unwrap();
    let ln_v = (model.vocab() as f32).ln();
    assert!(
        (stats.loss - ln_v).abs() < 1.0,
        "masked init loss {} should be near ln(vocab)={}",
        stats.loss,
        ln_v
    );
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn selective_copy_trains_loss_down() {
    let mut model = runtime::load_model("tiny_psk", LoadOpts::train_only()).unwrap();
    let task = SelectiveCopyTask::new(model.ctx(), 4, 4);
    let mut rng = polysketchformer::Pcg::seeded(1);
    let (tokens, _) = task.batch(model.batch(), &mut rng);
    let first = model.train_step(&tokens).unwrap();
    let mut last = first;
    for _ in 0..20 {
        last = model.train_step(&tokens).unwrap(); // memorize one batch
    }
    assert!(
        last.loss < first.loss,
        "task loss should decrease: {} -> {}",
        first.loss,
        last.loss
    );
}
