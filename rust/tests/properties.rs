//! Property tests over the coordinator substrates' invariants (batching,
//! sharding, checkpoint integrity, tokenizer round-trips) and the native
//! attention kernels' algebraic properties — generative, deterministic,
//! shrinking to a minimal-ish failing size (crate::prop, no proptest in
//! this environment).  No PJRT involvement: everything here is host math.

use polysketchformer::attn::sketch::PolySketch;
use polysketchformer::attn::Mechanism;
use polysketchformer::checkpoint::Checkpoint;
use polysketchformer::coordinator::dataparallel::shard_stream;
use polysketchformer::coordinator::gen_cloze_questions;
use polysketchformer::data::batcher::{split_stream, Batcher};
use polysketchformer::data::bpe::Bpe;
use polysketchformer::infer::{GenRequest, SamplePolicy};
use polysketchformer::mem::{quant, QuantMatrix};
use polysketchformer::prop::{check, close, ensure};
use polysketchformer::shard::proto::{
    decode_generate, encode_generate, Frame, FrameKind, ProtoError, MAX_PAYLOAD, VERSION,
};
use polysketchformer::shard::{hash_key, HashRing};
use polysketchformer::tensor::{layernorm_rows, micro, Tensor};
use polysketchformer::util::rng::Pcg;

// ------------------------------------------------------------- batching

#[test]
fn prop_batcher_epoch_is_a_permutation_of_segments() {
    check("batcher epoch permutation", 40, |rng, size| {
        let batch = 1 + rng.usize_below(4);
        let seq = 2 + rng.usize_below(16);
        let segments = batch * (1 + size % 8);
        let stream: Vec<u32> = (0..segments * seq as usize)
            .map(|i| (i % 251) as u32 + 1)
            .collect();
        let mut b = Batcher::new(&stream, batch, seq, rng.next_u64());
        let mut seen: Vec<Vec<i32>> = Vec::new();
        for _ in 0..b.batches_per_epoch() {
            let batch_out = b.next_batch();
            for r in 0..batch_out.batch {
                seen.push(batch_out.row(r).to_vec());
            }
        }
        let mut want: Vec<Vec<i32>> = stream
            .chunks_exact(seq)
            .map(|c| c.iter().map(|&t| t as i32).collect())
            .collect();
        seen.sort();
        want.sort();
        ensure(seen == want, "epoch must emit every segment exactly once")
    });
}

#[test]
fn prop_split_stream_partitions() {
    check("split partitions", 60, |rng, size| {
        let n = 10 + size * 7;
        let stream: Vec<u32> = (0..n as u32).collect();
        let frac = rng.f64() * 0.9;
        let (a, b) = split_stream(&stream, frac);
        ensure(a.len() + b.len() == n, "lengths must sum")?;
        ensure(
            a.iter().chain(b.iter()).copied().eq(0..n as u32),
            "order preserved, disjoint",
        )
    });
}

#[test]
fn prop_shards_disjoint_equal() {
    check("shards disjoint", 60, |rng, size| {
        let n = 16 + size * 13;
        let workers = 1 + rng.usize_below(7);
        let stream: Vec<u32> = (0..n as u32).collect();
        let shards = shard_stream(&stream, workers);
        ensure(shards.len() == workers, "one shard per worker")?;
        let per = n / workers;
        for (w, s) in shards.iter().enumerate() {
            ensure(s.len() == per, "equal shard sizes")?;
            ensure(
                s.first() == Some(&((w * per) as u32)),
                "shards contiguous and disjoint",
            )?;
        }
        Ok(())
    });
}

// ------------------------------------------------------------ tokenizer

#[test]
fn prop_bpe_roundtrip() {
    check("bpe encode/decode roundtrip", 25, |rng, size| {
        // Train on synthetic-ish text, then round-trip arbitrary bytes
        // drawn from the same alphabet.
        let alphabet = b"abcdefgh ij.\n";
        let text: Vec<u8> = (0..400 + size * 40)
            .map(|_| alphabet[rng.usize_below(alphabet.len())])
            .collect();
        let vocab = 260 + rng.usize_below(100);
        let bpe = Bpe::train(&text, vocab);
        ensure(bpe.vocab_size() <= vocab, "vocab bound respected")?;
        let sample: Vec<u8> = (0..size * 5)
            .map(|_| alphabet[rng.usize_below(alphabet.len())])
            .collect();
        let ids = bpe.encode(&sample);
        for &id in &ids {
            ensure((id as usize) < bpe.vocab_size(), "ids in range")?;
            ensure(id != 0, "id 0 is reserved for PAD")?;
        }
        ensure(bpe.decode(&ids) == sample, "decode(encode(x)) == x")
    });
}

// ----------------------------------------------------------- checkpoint

#[test]
fn prop_checkpoint_roundtrip_and_corruption_detection() {
    let dir = std::env::temp_dir().join("psf_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    check("checkpoint roundtrip", 25, |rng, size| {
        let mut ck = Checkpoint::new(rng.next_u64());
        let sections = 1 + rng.usize_below(4);
        for s in 0..sections {
            let data: Vec<f32> = (0..size * 3 + 1).map(|_| rng.gaussian()).collect();
            ck = ck.with(&format!("sec{s}"), data);
        }
        let path = dir.join(format!("ck_{}.bin", rng.next_u64()));
        ck.save(&path).map_err(|e| e.to_string())?;
        let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
        ensure(back == ck, "roundtrip equality")?;

        // Flip one payload byte -> CRC must catch it.
        let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
        let idx = 20 + rng.usize_below(bytes.len().saturating_sub(25));
        bytes[idx] ^= 0x40;
        let tmp = dir.join("corrupt.bin");
        std::fs::write(&tmp, &bytes).map_err(|e| e.to_string())?;
        let corrupted = Checkpoint::load(&tmp);
        let _ = std::fs::remove_file(&path);
        ensure(corrupted.is_err(), "corruption must be detected")
    });
}

// ------------------------------------------------------------ evaluator

#[test]
fn prop_cloze_questions_well_formed() {
    check("cloze question invariants", 30, |rng, size| {
        let vocab = 50 + rng.usize_below(200);
        let stream: Vec<u32> = (0..3000 + size * 50)
            .map(|_| 1 + rng.below(vocab as u64 - 1) as u32)
            .collect();
        let ctx = 32 + 8 * rng.usize_below(8);
        let span = 4 + rng.usize_below(8);
        let choices = 2 + rng.usize_below(3);
        let shots = rng.usize_below(3);
        if ctx / (shots + 1) <= span + 1 {
            return Ok(()); // generator precondition
        }
        let qs = gen_cloze_questions(&stream, ctx, 5, choices, span, shots,
                                     rng.next_u64());
        for q in &qs {
            ensure(q.choices.len() == choices, "choice count")?;
            ensure(q.answer < choices, "answer index")?;
            ensure(q.span_start == ctx - span, "span at tail")?;
            for c in &q.choices {
                ensure(c.len() == ctx, "row length")?;
                ensure(
                    c[..q.span_start] == q.choices[0][..q.span_start],
                    "shared prefix",
                )?;
            }
        }
        Ok(())
    });
}

// ----------------------------------------------------- mechanism labels

#[test]
fn prop_mechanism_label_parse_roundtrip() {
    // `Mechanism::parse` is the exact inverse of `label` over the whole
    // valid parameter space, not just the handful of spellings the unit
    // tests pin: random valid mechanisms must survive label -> parse ->
    // label unchanged.
    check("mechanism label/parse roundtrip", 80, |rng, _size| {
        let mech = match rng.usize_below(5) {
            0 => Mechanism::Softmax,
            1 => Mechanism::Flash { block: 1 + rng.usize_below(1024) },
            2 => Mechanism::Poly { p: 2 * (1 + rng.usize_below(8) as u32) },
            3 => Mechanism::Polysketch {
                r: 1 + rng.usize_below(128),
                p: 1u32 << (1 + rng.usize_below(3)),
                block: 1 + rng.usize_below(2048),
                local: rng.usize_below(2) == 1,
            },
            _ => Mechanism::Performer {
                m: 1 + rng.usize_below(256),
                block: 1 + rng.usize_below(2048),
            },
        };
        let label = mech.label();
        let back = Mechanism::parse(&label)
            .map_err(|e| format!("`{label}` failed to re-parse: {e}"))?;
        ensure(back == mech, format!("`{label}` round-tripped to {back:?}"))?;
        ensure(back.label() == label, "label must be stable under re-parse")
    });
}

#[test]
fn prop_mechanism_parse_rejects_degenerate_labels() {
    // Degenerate parameters that `label` can never emit (zero sizes, odd
    // or non-power-of-two degrees) must be rejected at the parse
    // boundary rather than panicking inside a kernel — `psk4_r0_b8` and
    // friends are the canonical offenders.
    for bad in [
        "psk4_r0_b8", "psk4_r4_b0", "psk0_r4_b8", "psk1_r4_b8", "psk3_r4_b8",
        "psk6_r4_b8", "flash_b0", "poly0", "poly1", "poly3", "poly7",
        "performer0_b8", "performer16_b0", "psk4_r16_b64_localx",
        "psk4_r16_b64_local_local", "psk4_r-1_b8", "performer16_b-2",
    ] {
        assert!(Mechanism::parse(bad).is_err(), "`{bad}` should not parse");
    }
    // Degenerate-but-valid extremes parse and round-trip.
    for ok in ["flash_b1", "psk2_r1_b1", "psk2_r1_b1_local", "performer1_b1"] {
        let m = Mechanism::parse(ok).unwrap_or_else(|e| panic!("`{ok}`: {e}"));
        assert_eq!(m.label(), ok);
    }
}

// ------------------------------------------------------- attention math

#[test]
fn prop_polysketch_block_size_invariance() {
    check("block-lt b-invariance", 12, |rng, size| {
        let n = [32usize, 64, 128][size % 3];
        let h = 8;
        let q = Tensor::gaussian(rng, &[n, h]);
        let k = Tensor::gaussian(rng, &[n, h]);
        let v = Tensor::gaussian(rng, &[n, h]);
        let mk = |block| {
            let mech = Mechanism::Polysketch { r: 8, p: 4, block, local: false };
            mech.build_kernel(h, &mut Pcg::seeded(7)).forward(&q, &k, &v)
        };
        let a = mk(n);
        for &b in &[16usize, 32] {
            if b >= n {
                continue;
            }
            let o = mk(b);
            for (x, y) in o.data().iter().zip(a.data()) {
                ensure(close(*x, *y, 1e-3), format!("b={b}: {x} vs {y}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_attention_causality() {
    // Changing v (and k) at positions > i must never change output row i.
    check("causality", 10, |rng, size| {
        let n = 32 + (size % 3) * 16; // multiples of the block sizes used
        let h = 8;
        let cut = n / 2;
        let q = Tensor::gaussian(rng, &[n, h]);
        let k = Tensor::gaussian(rng, &[n, h]);
        let v = Tensor::gaussian(rng, &[n, h]);
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for i in cut..n {
            for j in 0..h {
                k2.set2(i, j, rng.gaussian());
                v2.set2(i, j, rng.gaussian());
            }
        }
        for mech in [
            Mechanism::Flash { block: 16 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 8, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 16, block: 8 },
        ] {
            let attn = mech.build_kernel(h, &mut Pcg::seeded(3));
            let a = attn.forward(&q, &k, &v);
            let b = attn.forward(&q, &k2, &v2);
            for i in 0..cut {
                for (x, y) in a.row(i).iter().zip(b.row(i)) {
                    ensure(
                        close(*x, *y, 1e-4),
                        format!("{}: row {i} changed by future edit", mech.label()),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nonnegative_sketch_weights() {
    check("Thm 1.1 nonnegativity", 20, |rng, size| {
        let n = 8 + size % 24;
        let h = 8;
        let r = [4usize, 8, 16][size % 3];
        let q = layernorm_rows(&Tensor::gaussian(rng, &[n, h]));
        let k = layernorm_rows(&Tensor::gaussian(rng, &[n, h]));
        let sk = PolySketch::sample(rng, h, r, 4);
        let w = sk.nonnegative(&q).matmul_t(&sk.nonnegative(&k));
        let max_abs = w.data().iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let floor = -1e-5 * (max_abs + 1.0);
        for &x in w.data() {
            ensure(x >= floor, format!("weight {x} < fp floor {floor}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_poly_attention_rows_form_subprobability() {
    // With the `1 +` denominator, each output row is a subconvex
    // combination of value rows: |out_i| <= max_j |v_j| elementwise.
    check("poly rows subconvex", 20, |rng, size| {
        let n = 8 + size % 24;
        let h = 8;
        let q = Tensor::gaussian(rng, &[n, h]);
        let k = Tensor::gaussian(rng, &[n, h]);
        let v = Tensor::gaussian(rng, &[n, h]);
        let out = polysketchformer::attn::poly::poly_attention(&q, &k, &v, 4);
        let vmax = v.data().iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for &x in out.data() {
            ensure(x.abs() <= vmax + 1e-4, format!("out {x} exceeds vmax {vmax}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_flash_matches_naive_softmax() {
    check("flash == naive softmax", 15, |rng, size| {
        let n = [16usize, 32, 64][size % 3];
        let h = 4 + (size % 3) * 4;
        let block = [8usize, 16][size % 2]; // n is a multiple of both
        let q = Tensor::gaussian(rng, &[n, h]);
        let k = Tensor::gaussian(rng, &[n, h]);
        let v = Tensor::gaussian(rng, &[n, h]);
        let a = polysketchformer::attn::softmax::softmax_attention(&q, &k, &v);
        let b = polysketchformer::attn::softmax::flash_attention(&q, &k, &v, block);
        for (x, y) in a.data().iter().zip(b.data()) {
            ensure(close(*x, *y, 1e-4), format!("{x} vs {y}"))?;
        }
        Ok(())
    });
}

// ------------------------------------------------------- microkernel layer

/// Independent transcription of the documented reduction spec: element i
/// feeds lane i % 8 in increasing-i order, lanes combine as the fixed
/// balanced tree.  Deliberately *not* calling into `micro` — this is the
/// oracle the lane-tree invariant is checked against.
fn spec_dot(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; micro::LANES];
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        lanes[i % micro::LANES] += x * y;
    }
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
}

fn spec_sum(a: &[f32]) -> f32 {
    let mut lanes = [0.0f32; micro::LANES];
    for (i, x) in a.iter().enumerate() {
        lanes[i % micro::LANES] += x;
    }
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
}

#[test]
fn prop_lane_tree_reductions_match_spec_for_ragged_lengths() {
    // Every length 1..=33 — full 8-wide bodies, every ragged tail length
    // (including the canonical n = 13: one full chunk + a 5-tail), and
    // the 32/33 boundary where the AVX2 4x-unrolled tile turns over —
    // must produce the spec bytes under whatever backend is active.
    check("lane-tree reduction spec", 20, |rng, _size| {
        for n in 1..=33usize {
            let a: Vec<f32> = rng.gaussians(n);
            let b: Vec<f32> = rng.gaussians(n);
            let (got, want) = (micro::dot(&a, &b), spec_dot(&a, &b));
            ensure(
                got.to_bits() == want.to_bits(),
                format!("dot n={n}: {got} vs spec {want}"),
            )?;
            let (got, want) = (micro::sum(&a), spec_sum(&a));
            ensure(
                got.to_bits() == want.to_bits(),
                format!("sum n={n}: {got} vs spec {want}"),
            )?;
        }
        Ok(())
    });
}

/// Draw a vector whose entries are mostly Gaussian but sprinkled with the
/// IEEE edge cases the bitwise-parity contract must survive: NaN, both
/// infinities, subnormals, and exact zeros (the zero-skip path).
fn edge_case_vec(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| match rng.usize_below(16) {
            0 => f32::NAN,
            1 => f32::INFINITY,
            2 => f32::NEG_INFINITY,
            3 => 1.0e-42,  // subnormal
            4 => -1.0e-42, // subnormal
            5 => 0.0,
            6 => -0.0,
            _ => rng.gaussian(),
        })
        .collect()
}

/// Run every micro primitive once and collect all output bits.  The
/// parity property is that this entire transcript is identical across
/// backends.
fn micro_battery_bits(a: &[f32], b: &[f32], mat: &[f32], rows: usize) -> Vec<u32> {
    let n = a.len();
    debug_assert_eq!(mat.len(), rows * n);
    let mut bits: Vec<u32> = Vec::new();
    bits.push(micro::dot(a, b).to_bits());
    bits.push(micro::sum(a).to_bits());
    bits.push(micro::sq_dev_sum(a, 0.25).to_bits());
    let mut out = vec![0.0f32; rows];
    micro::dot_rows(a, mat, &mut out);
    bits.extend(out.iter().map(|v| v.to_bits()));
    let mut o = b.to_vec();
    micro::axpy(&mut o, a, 1.5);
    bits.extend(o.iter().map(|v| v.to_bits()));
    let mut o = vec![0.0f32; n];
    micro::scale(&mut o, a, -0.75);
    bits.extend(o.iter().map(|v| v.to_bits()));
    let mut o = b.to_vec();
    micro::scale_inplace(&mut o, 3.0);
    bits.extend(o.iter().map(|v| v.to_bits()));
    let mut o = b.to_vec();
    micro::mul_inplace(&mut o, a);
    bits.extend(o.iter().map(|v| v.to_bits()));
    let mut o = vec![0.0f32; n];
    micro::norm_scale(&mut o, a, 0.1, 2.0);
    bits.extend(o.iter().map(|v| v.to_bits()));
    let mut c = vec![0.0f32; rows];
    micro::gemm_row(&mut c, a, mat);
    bits.extend(c.iter().map(|v| v.to_bits()));
    let mut z = vec![0.0f32; n * n];
    micro::outer(&mut z, a, b);
    bits.extend(z.iter().map(|v| v.to_bits()));
    micro::outer_accum(&mut z, b, a);
    bits.extend(z.iter().map(|v| v.to_bits()));
    let mut e = vec![0.0f32; n];
    micro::exp_sub(&mut e, a, 0.5);
    bits.extend(e.iter().map(|v| v.to_bits()));
    let mut g = b.to_vec();
    micro::gelu_rows(&mut g);
    bits.extend(g.iter().map(|v| v.to_bits()));
    bits
}

#[test]
fn prop_micro_backends_bitwise_identical_under_edge_cases() {
    // The tentpole invariant: every primitive, every backend, the same
    // bytes — including NaN/inf/subnormal inputs and ragged lengths.
    // (Flipping the backend mid-process is benign precisely *because* of
    // this property; other tests racing micro calls see identical bytes.)
    let best = micro::best_available();
    check("micro scalar/simd bitwise parity", 30, |rng, size| {
        let n = 1 + size % 40;
        let rows = 1 + size % 5;
        let a = edge_case_vec(rng, n);
        let b = edge_case_vec(rng, n);
        let mat = edge_case_vec(rng, rows * n);
        micro::force_backend(micro::Backend::Scalar)?;
        let scalar_bits = micro_battery_bits(&a, &b, &mat, rows);
        micro::force_backend(best)?;
        let simd_bits = micro_battery_bits(&a, &b, &mat, rows);
        micro::reset_backend();
        ensure(
            scalar_bits == simd_bits,
            format!(
                "scalar vs {} diverged at bit index {:?} (n={n}, rows={rows})",
                best.label(),
                scalar_bits.iter().zip(&simd_bits).position(|(x, y)| x != y),
            ),
        )
    });
}

// ------------------------------------------------------ quantized storage

/// Brute-force f16 nearest-even oracle: scan every non-NaN code and keep
/// the closest decoded value (f64 distances are exact for f32 inputs and
/// f16 candidates), breaking exact ties toward the even significand — an
/// independent transcription of IEEE 754 roundTiesToEven that shares no
/// bit tricks with `quant::f16_encode`.
fn f16_oracle(x: f32) -> u16 {
    if x == 0.0 {
        return if x.is_sign_negative() { 0x8000 } else { 0x0000 };
    }
    let mut best_code = 0u16;
    let mut best_dist = f64::INFINITY;
    for code in 0..=u16::MAX {
        let v = quant::f16_decode(code);
        if v.is_nan() {
            continue;
        }
        let dist = if x.is_infinite() {
            if v == x {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            // The infinity codes stand in for ±2^16, the next value the
            // exponent ladder would produce — that is exactly how RTNE
            // overflow behaves (65520 ties to even = inf).
            let vv = if v.is_infinite() { (v as f64).signum() * 65536.0 } else { v as f64 };
            (vv - x as f64).abs()
        };
        if dist < best_dist || (dist == best_dist && code & 1 == 0 && best_code & 1 == 1) {
            best_dist = dist;
            best_code = code;
        }
    }
    best_code
}

#[test]
fn prop_f16_encode_is_round_to_nearest_even() {
    check("f16 RTNE vs brute-force oracle", 10, |rng, _size| {
        // Magnitudes spanning subnormal, normal, and near-overflow f16.
        for _ in 0..8 {
            let scale = [1e-7f32, 1e-4, 1.0, 100.0, 3.0e4][rng.usize_below(5)];
            let x = rng.gaussian() * scale;
            let got = quant::f16_encode(x);
            let want = f16_oracle(x);
            ensure(got == want, format!("encode({x:e}) = {got:#06x}, oracle {want:#06x}"))?;
        }
        // Exact halfway points between adjacent finite f16 values (the
        // midpoint needs one extra significand bit, so it is exact in
        // f32) must round to the even code.
        for _ in 0..4 {
            let c = rng.usize_below(0x7bff) as u16;
            let v0 = quant::f16_decode(c) as f64;
            let v1 = quant::f16_decode(c + 1) as f64;
            let mid = ((v0 + v1) * 0.5) as f32;
            let got = quant::f16_encode(mid);
            ensure(
                got == f16_oracle(mid),
                format!("tie at {mid:e}: {got:#06x} vs oracle"),
            )?;
            ensure(got & 1 == 0, format!("tie at {mid:e} landed on odd code {got:#06x}"))?;
        }
        Ok(())
    });
}

#[test]
fn f16_specials_and_code_roundtrip_are_exact() {
    assert!(quant::f16_decode(quant::f16_encode(f32::NAN)).is_nan());
    assert_eq!(quant::f16_decode(quant::f16_encode(f32::INFINITY)), f32::INFINITY);
    assert_eq!(quant::f16_decode(quant::f16_encode(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    assert_eq!(quant::f16_encode(1.0e6), 0x7c00, "overflow rounds to +inf");
    assert_eq!(quant::f16_encode(-1.0e6), 0xfc00, "overflow rounds to -inf");
    assert_eq!(quant::f16_encode(0.0), 0x0000);
    assert_eq!(quant::f16_encode(-0.0), 0x8000, "zero sign is preserved");
    // The smallest f16 subnormal (2^-24) decodes exactly and encodes back.
    let tiny = f32::from_bits(0x3380_0000);
    assert_eq!(quant::f16_decode(0x0001), tiny);
    assert_eq!(quant::f16_encode(tiny), 0x0001);
    // f16 is a subset of f32, so decode -> encode is the identity on
    // every non-NaN code, and NaN codes stay NaN.
    for code in 0..=u16::MAX {
        let v = quant::f16_decode(code);
        if v.is_nan() {
            assert!(quant::f16_decode(quant::f16_encode(v)).is_nan());
        } else {
            assert_eq!(quant::f16_encode(v), code, "code {code:#06x} decoded to {v:e}");
        }
    }
}

#[test]
fn prop_int8_rows_reconstruct_within_half_scale() {
    // Per-row absmax quantization: every reconstructed entry sits within
    // half a quantization step of the original, and all-zero rows get a
    // zero scale (the downstream zero-skip path).
    check("int8 per-row error bound", 30, |rng, size| {
        let cols = 1 + size % 40;
        let rows = 1 + rng.usize_below(5);
        let mut data: Vec<f32> = (0..rows * cols).map(|_| rng.gaussian() * 3.0).collect();
        if rows > 1 {
            data[(rows - 1) * cols..].iter_mut().for_each(|x| *x = 0.0);
        }
        let q = QuantMatrix::from_rows(&data, rows, cols);
        for r in 0..rows {
            let row = &data[r * cols..(r + 1) * cols];
            let scale = q.scales[r];
            let amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            if amax == 0.0 {
                ensure(scale == 0.0, "all-zero row must have zero scale")?;
            }
            let bound = scale as f64 * 0.5 * (1.0 + 1e-5) + 1e-12;
            for (c, &x) in row.iter().enumerate() {
                let back = q.qrow(r)[c] as f32 * scale;
                ensure(
                    ((back as f64) - (x as f64)).abs() <= bound,
                    format!("row {r} col {c}: {x} -> {back} exceeds scale/2 = {bound}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_q8_micro_primitives_bitwise_identical_across_backends() {
    // Same contract as the f32 battery above, for the int8 primitives:
    // every ragged length through the 32/33 SIMD-tile boundary, scalar
    // vs best backend, identical output bits.  Int-to-float conversion
    // is exact in every backend, so parity is achievable and required.
    let best = micro::best_available();
    check("q8 micro scalar/simd parity", 20, |rng, _size| {
        for n in 1..=33usize {
            let a: Vec<f32> = rng.gaussians(n);
            let q: Vec<i8> = (0..n).map(|_| rng.usize_below(256) as u8 as i8).collect();
            let k = 3usize;
            let qmat: Vec<i8> = (0..k * n).map(|_| rng.usize_below(256) as u8 as i8).collect();
            let coeff: Vec<f32> = rng.gaussians(k);
            let scales = [0.031_25f32, 0.0, 1.5]; // zero scale: skip path
            let battery = |bits: &mut Vec<u32>| {
                bits.push(micro::dot_q8(&a, &q, 0.062_5).to_bits());
                let mut c = vec![0.0f32; n];
                micro::gemm_row_q8(&mut c, &coeff, &qmat, &scales);
                bits.extend(c.iter().map(|v| v.to_bits()));
                let mut d = vec![0.0f32; n];
                micro::dequant_row(&mut d, &q, 0.25);
                bits.extend(d.iter().map(|v| v.to_bits()));
            };
            micro::force_backend(micro::Backend::Scalar)?;
            let mut scalar_bits = Vec::new();
            battery(&mut scalar_bits);
            micro::force_backend(best)?;
            let mut simd_bits = Vec::new();
            battery(&mut simd_bits);
            micro::reset_backend();
            ensure(
                scalar_bits == simd_bits,
                format!("n={n}: scalar vs {} diverged", best.label()),
            )?;
        }
        Ok(())
    });
}

// ------------------------------------------------------------- sampling

#[test]
fn prop_top_p_never_samples_outside_nucleus() {
    // Recompute the nucleus with an independent oracle (same tie-breaking
    // rule: probability-descending, stop at the first crossing of p) and
    // check every draw lands inside it.
    check("top-p stays in nucleus", 40, |rng, size| {
        let n = 2 + size % 30;
        let logits: Vec<f32> = (0..n).map(|_| rng.gaussian() * 3.0).collect();
        let p = 0.05 + rng.f32() * 0.9;
        let t = 0.2 + rng.f32() * 1.5;
        // Oracle softmax at temperature t.
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&l| ((l - mx) / t).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|e| e / sum).collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let mut nucleus = vec![false; n];
        let mut mass = 0.0f32;
        for &i in &order {
            nucleus[i] = true;
            mass += probs[i];
            if mass >= p {
                break;
            }
        }
        let policy = SamplePolicy::TopP { p, temperature: t };
        let mut draw_rng = Pcg::seeded(rng.next_u64());
        for _ in 0..64 {
            let s = policy.sample(&logits, &mut draw_rng);
            ensure(nucleus[s], format!("sampled {s} outside nucleus (p={p}, t={t})"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_top_k_never_samples_outside_k_best() {
    check("top-k stays in k best", 40, |rng, size| {
        let n = 2 + size % 30;
        let logits: Vec<f32> = (0..n).map(|_| rng.gaussian() * 3.0).collect();
        let k = 1 + rng.usize_below(n);
        let mut sorted = logits.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let thresh = sorted[k - 1];
        let policy = SamplePolicy::TopK { k, temperature: 0.7 };
        let mut draw_rng = Pcg::seeded(rng.next_u64());
        for _ in 0..64 {
            let s = policy.sample(&logits, &mut draw_rng);
            ensure(
                logits[s] >= thresh,
                format!("sampled logit {} below k-th best {thresh} (k={k})", logits[s]),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_sampling_is_seed_deterministic_across_policies() {
    // The serving determinism contract at the sampler level: a (seed,
    // logits, policy) triple replays the identical draw sequence.
    check("sampler seed determinism", 30, |rng, size| {
        let n = 2 + size % 40;
        let logits: Vec<f32> = (0..n).map(|_| rng.gaussian() * 2.0).collect();
        let seed = rng.next_u64();
        let policies = [
            SamplePolicy::Greedy,
            SamplePolicy::Temperature(0.8),
            SamplePolicy::TopK { k: 1 + n / 2, temperature: 0.9 },
            SamplePolicy::TopP { p: 0.85, temperature: 1.1 },
        ];
        for policy in policies {
            let draw = |seed: u64| {
                let mut r = Pcg::seeded(seed);
                (0..16).map(|_| policy.sample(&logits, &mut r)).collect::<Vec<_>>()
            };
            ensure(draw(seed) == draw(seed), format!("{policy:?} not replayable"))?;
        }
        Ok(())
    });
}

// ----------------------------------------------------- shard IPC protocol

#[test]
fn prop_frame_roundtrip() {
    check("frame encode/decode roundtrip", 60, |rng, size| {
        let kind = FrameKind::from_u8(rng.usize_below(14) as u8).expect("all kinds covered");
        let stream = rng.next_u64();
        let payload: Vec<u8> = (0..size * 9).map(|_| rng.usize_below(256) as u8).collect();
        let frame = Frame::new(kind, stream, payload);
        let buf = frame.encode();
        let (back, consumed) = Frame::decode(&buf).map_err(|e| format!("decode: {e}"))?;
        ensure(consumed == buf.len(), "decode must consume the whole encoding")?;
        ensure(back == frame, "frame must survive the wire byte-identically")?;
        // The stream path must agree with the slice path.
        let streamed = Frame::read_from(&mut &buf[..]).map_err(|e| format!("read_from: {e}"))?;
        ensure(streamed == Some(frame), "read_from must match decode")?;
        // A clean EOF right after the frame is Ok(None), not an error.
        let mut r = &buf[buf.len()..];
        ensure(
            Frame::read_from(&mut r).ok() == Some(None),
            "EOF at a frame boundary must be a clean end-of-stream",
        )
    });
}

#[test]
fn prop_frame_strict_prefixes_are_truncated() {
    check("frame truncation detection", 40, |rng, size| {
        let kind = FrameKind::from_u8(rng.usize_below(14) as u8).expect("all kinds covered");
        let payload: Vec<u8> = (0..1 + size * 5).map(|_| rng.usize_below(256) as u8).collect();
        let buf = Frame::new(kind, rng.next_u64(), payload).encode();
        // Every strict prefix must be rejected as Truncated — never
        // misparsed as a shorter valid frame.
        let cut = rng.usize_below(buf.len());
        ensure(
            Frame::decode(&buf[..cut]) == Err(ProtoError::Truncated),
            format!("prefix of {cut}/{} bytes must be Truncated", buf.len()),
        )?;
        // Mid-frame EOF on the stream path is an error, not a clean end.
        if cut > 0 {
            ensure(
                Frame::read_from(&mut &buf[..cut]).is_err(),
                "mid-frame EOF must surface as an io::Error",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_frame_rejects_corrupted_headers() {
    check("frame header validation", 40, |rng, _| {
        let buf = Frame::new(FrameKind::Ping, rng.next_u64(), vec![7u8; 3]).encode();

        // Version skew: peers from different builds must fail loudly
        // (the protocol has no negotiation — one binary ships both ends).
        let mut skewed = buf.clone();
        let bad_version = VERSION + 1 + rng.usize_below(100) as u16;
        skewed[4..6].copy_from_slice(&bad_version.to_le_bytes());
        ensure(
            Frame::decode(&skewed)
                == Err(ProtoError::VersionMismatch { got: bad_version, want: VERSION }),
            "version skew must be VersionMismatch",
        )?;

        // Corrupted magic.
        let mut garbled = buf.clone();
        garbled[0] ^= 0xff;
        ensure(
            matches!(Frame::decode(&garbled), Err(ProtoError::BadMagic(_))),
            "corrupted magic must be BadMagic",
        )?;

        // Unknown frame kind.
        let mut unknown = buf.clone();
        unknown[6] = 14 + rng.usize_below(200) as u8;
        ensure(
            Frame::decode(&unknown) == Err(ProtoError::BadKind(unknown[6])),
            "unknown kind must be BadKind",
        )?;

        // Oversized length claim: bounded before any allocation.
        let mut huge = buf;
        let len = MAX_PAYLOAD + 1 + rng.usize_below(1 << 20) as u32;
        huge[16..20].copy_from_slice(&len.to_le_bytes());
        ensure(
            Frame::decode(&huge) == Err(ProtoError::Oversize { len, max: MAX_PAYLOAD }),
            "over-limit length must be Oversize",
        )
    });
}

#[test]
fn prop_generate_payload_roundtrip() {
    check("generate payload roundtrip", 50, |rng, size| {
        let policy = match rng.usize_below(4) {
            0 => SamplePolicy::Greedy,
            1 => SamplePolicy::Temperature(0.05 + rng.f64() as f32 * 2.0),
            2 => SamplePolicy::TopK {
                k: 1 + rng.usize_below(300),
                temperature: 0.05 + rng.f64() as f32 * 2.0,
            },
            _ => SamplePolicy::TopP {
                p: rng.f64() as f32,
                temperature: 0.05 + rng.f64() as f32 * 2.0,
            },
        };
        let req = GenRequest {
            prompt: (0..1 + size * 3).map(|_| rng.usize_below(257) as u32).collect(),
            max_new_tokens: rng.usize_below(4096),
            policy,
            seed: rng.next_u64(),
        };
        let trace_id = rng.next_u64();
        let bytes = encode_generate(&req, trace_id);
        let (back, back_trace) =
            decode_generate(&bytes).map_err(|e| format!("decode: {e}"))?;
        ensure(back.prompt == req.prompt, "prompt tokens must round-trip")?;
        ensure(back.max_new_tokens == req.max_new_tokens, "max_new must round-trip")?;
        ensure(back.seed == req.seed, "seed must round-trip")?;
        ensure(back.policy == req.policy, "policy must round-trip (f32 knobs bit-exact)")?;
        ensure(back_trace == trace_id, "trace id must round-trip")?;
        // Re-encoding is byte-identical: f32 knobs crossed the wire as
        // raw bits, never through a lossy text form.
        ensure(encode_generate(&back, back_trace) == bytes, "re-encode must be byte-identical")
    });
}

// -------------------------------------------------- shard routing ring

#[test]
fn prop_ring_removal_only_moves_victims_keys() {
    check("ring rebalance stability", 30, |rng, size| {
        let runners = 2 + rng.usize_below(6) as u32;
        let mut ring = HashRing::new();
        for r in 0..runners {
            ring.add(r);
        }
        let keys: Vec<u64> = (0..20 + size * 10)
            .map(|i| hash_key("psk4_r16_b32_local", &[i as u32, rng.usize_below(257) as u32]))
            .collect();
        let before: Vec<u32> = keys.iter().map(|&k| ring.route(k).unwrap()).collect();

        // Remove one runner: only its keys may move.
        let victim = rng.usize_below(runners as usize) as u32;
        ring.remove(victim);
        ensure(ring.len_runners() == runners as usize - 1, "runner count drops by one")?;
        for (&k, &owner) in keys.iter().zip(&before) {
            let now = ring.route(k).unwrap();
            if owner != victim {
                ensure(
                    now == owner,
                    format!("key moved {owner} -> {now} though {victim} was removed"),
                )?;
            } else {
                ensure(now != victim, "victim's keys must be re-homed")?;
            }
        }

        // Re-adding restores the original assignment exactly (vnode
        // points are a pure function of the runner id).
        ring.add(victim);
        let after: Vec<u32> = keys.iter().map(|&k| ring.route(k).unwrap()).collect();
        ensure(after == before, "re-add must restore the original routing")
    });
}
