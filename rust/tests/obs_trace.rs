//! Observability integration: span lifecycle/nesting over the real
//! thread-local ring buffers, histogram boundary semantics through the
//! Prometheus exposition, the tracing-on/off determinism contract on the
//! serving path, and one end-to-end sharded run — a spawned
//! `psf serve --runners 2 --trace` process whose exported trace must
//! parse as valid Chrome trace-event JSON with gateway and runner spans
//! stitched by one trace id.
//!
//! The in-process tests toggle the global tracing flag, so they
//! serialize on [`OBS_LOCK`]; the spawned-process test has its own
//! address space and runs freely.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use polysketchformer::infer::{GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::obs;
use polysketchformer::serve::{collect_stream, Gateway, GatewayConfig};

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_lock() -> MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

// ------------------------------------------------------------ span tests

#[test]
fn span_records_nesting_depth_and_trace_id() {
    let _g = obs_lock();
    obs::set_tracing(true);
    obs::span::drain_all(); // discard anything a prior test buffered
    obs::set_trace_id(0xbeef);
    {
        let _outer = obs::span("outer", "test");
        let _inner = obs::span("inner", "test");
    }
    obs::set_trace_id(0);
    obs::set_tracing(false);
    let (events, dropped) = obs::span::drain_all();
    assert_eq!(dropped, 0);
    let outer = events.iter().find(|e| e.name == "outer").expect("outer span recorded");
    let inner = events.iter().find(|e| e.name == "inner").expect("inner span recorded");
    assert_eq!(outer.depth, 0);
    assert_eq!(inner.depth, 1);
    assert_eq!(outer.trace_id, 0xbeef);
    assert_eq!(inner.trace_id, 0xbeef);
    assert_eq!(outer.tid, inner.tid, "same thread, same tid");
    assert!(inner.ts_us >= outer.ts_us, "child starts within parent");
    assert!(
        inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us,
        "child ends within parent (RAII nesting)"
    );
}

/// Property-style sweep: for every depth d, a stack of d nested spans
/// yields exactly d events with depths 0..d, properly contained, and the
/// thread-local depth counter returns to zero (no leak across
/// iterations).
#[test]
fn span_nesting_property_across_depths() {
    let _g = obs_lock();
    obs::set_tracing(true);
    obs::span::drain_all();
    for d in 1..=8usize {
        let mut spans: Vec<obs::Span> =
            (0..d).map(|i| obs::span(&format!("lvl{i}"), "test")).collect();
        // Unwind innermost-first (Vec drops front-to-back, which would
        // close the parent before its children).
        while let Some(s) = spans.pop() {
            drop(s);
        }
        let (events, _) = obs::span::drain_all();
        assert_eq!(events.len(), d, "depth {d}: one event per span");
        let mut depths: Vec<u32> = events.iter().map(|e| e.depth).collect();
        depths.sort_unstable();
        assert_eq!(depths, (0..d as u32).collect::<Vec<_>>(), "depth {d}: depths are 0..d");
        for w in 1..d {
            let outer = events.iter().find(|e| e.depth == (w - 1) as u32).unwrap();
            let inner = events.iter().find(|e| e.depth == w as u32).unwrap();
            assert!(
                inner.ts_us >= outer.ts_us
                    && inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us,
                "depth {d}: level {w} not contained in level {}",
                w - 1
            );
        }
        // Next span opens at depth 0 again: the counter unwound fully.
        {
            let _probe = obs::span("probe", "test");
        }
        let (probe, _) = obs::span::drain_all();
        assert_eq!(probe[0].depth, 0, "depth counter leaked after iteration {d}");
    }
    obs::set_tracing(false);
}

#[test]
fn trace_id_does_not_leak_across_threads() {
    let _g = obs_lock();
    obs::set_tracing(true);
    obs::span::drain_all();
    obs::set_trace_id(0x111);
    let handle = std::thread::spawn(|| {
        // Fresh thread: no inherited trace id.
        assert_eq!(obs::current_trace_id(), 0);
        obs::set_trace_id(0x222);
        let _s = obs::span("worker", "test");
    });
    handle.join().unwrap();
    {
        let _s = obs::span("main", "test");
    }
    obs::set_trace_id(0);
    obs::set_tracing(false);
    let (events, _) = obs::span::drain_all();
    let worker = events.iter().find(|e| e.name == "worker").unwrap();
    let main = events.iter().find(|e| e.name == "main").unwrap();
    assert_eq!(worker.trace_id, 0x222);
    assert_eq!(main.trace_id, 0x111);
    assert_ne!(worker.tid, main.tid, "distinct threads get distinct tids");
}

// ------------------------------------------------- histogram boundaries

#[test]
fn histogram_bucket_boundaries_are_le_inclusive() {
    // Prometheus `le` semantics: a sample exactly on a bound counts into
    // that bound's bucket.
    let h = obs::Hist::new(&[1.0, 2.0]);
    h.observe(1.0); // == first bound -> le="1" bucket
    h.observe(1.5); // -> le="2"
    h.observe(2.0000001); // just past last bound -> +Inf only
    let mut text = String::new();
    h.prometheus_into("psf_boundary_seconds", "t", &mut text);
    assert!(text.contains("psf_boundary_seconds_bucket{le=\"1\"} 1"), "{text}");
    assert!(text.contains("psf_boundary_seconds_bucket{le=\"2\"} 2"), "{text}");
    assert!(text.contains("psf_boundary_seconds_bucket{le=\"+Inf\"} 3"), "{text}");
    assert!(text.contains("psf_boundary_seconds_count 3"), "{text}");
}

// ------------------------------------------- determinism with tracing on

fn serve_once(req: &GenRequest) -> Vec<u32> {
    let cfg = LmConfig { d_model: 32, layers: 2, heads: 2, seed: 1, ..LmConfig::default() };
    let model = NativeLm::new(cfg, polysketchformer::attn::Mechanism::parse("psk4_r4_b8_local").unwrap());
    let gw = Gateway::new(model, GatewayConfig::default()).expect("gateway");
    let rx = gw.submit(req.clone()).expect("admission");
    let (tokens, stats) = collect_stream(rx);
    gw.finish().expect("drain");
    assert!(stats.is_some(), "request must complete");
    tokens
}

#[test]
fn token_stream_identical_with_tracing_on_and_off() {
    let _g = obs_lock();
    let req = GenRequest {
        prompt: (0..32u32).map(|i| 1 + (i * 37) % 256).collect(),
        max_new_tokens: 16,
        policy: SamplePolicy::Greedy,
        seed: 11,
    };
    obs::set_tracing(false);
    obs::set_phases(false);
    let off = serve_once(&req);
    obs::set_tracing(true);
    obs::set_phases(true);
    let on = serve_once(&req);
    obs::set_tracing(false);
    obs::set_phases(false);
    obs::span::drain_all();
    obs::phase::reset();
    assert_eq!(off, on, "tracing must never change a token (write-only telemetry)");
}

// --------------------------------------- sharded end-to-end trace export

struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn http_roundtrip(addr: &str, request: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect to psf serve");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    stream.write_all(request.as_bytes()).expect("send request");
    let mut response = String::new();
    // Server closes the connection at end of response (streaming chunks).
    let _ = stream.read_to_string(&mut response);
    response
}

#[test]
fn sharded_serve_exports_one_stitched_perfetto_trace() {
    let dir = std::env::temp_dir().join(format!("psf_obs_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("trace.json");
    let _ = std::fs::remove_file(&trace_path);

    let child = Command::new(env!("CARGO_BIN_EXE_psf"))
        .args([
            "serve",
            "--addr", "127.0.0.1:0",
            "--mech", "psk4_r4_b8_local",
            "--d-model", "32",
            "--layers", "2",
            "--heads", "2",
            "--seed", "1",
            "--runners", "2",
            "--workers", "1",
            "--threads", "1",
            "--max-requests", "1",
            "--trace", trace_path.to_str().unwrap(),
        ])
        .env_remove("PSF_TRACE")
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn psf serve");
    let mut child = KillOnDrop(child);

    // Drain stderr in the background so the child can't block on a full
    // pipe; scrape the bound address off the stdout banner.
    let stderr = child.0.stderr.take().unwrap();
    let stderr_thread = std::thread::spawn(move || {
        let mut text = String::new();
        let _ = BufReader::new(stderr).read_to_string(&mut text);
        text
    });
    let stdout = BufReader::new(child.0.stdout.take().unwrap());
    let mut addr = None;
    let mut lines = stdout.lines();
    let deadline = Instant::now() + Duration::from_secs(120);
    for line in &mut lines {
        let line = line.expect("serve stdout");
        if let Some(rest) = line.split("listening on http://").nth(1) {
            addr = Some(rest.split_whitespace().next().unwrap().to_string());
            break;
        }
        assert!(Instant::now() < deadline, "no listening banner within 120s");
    }
    let addr = addr.expect("psf serve exited before printing its address");
    // Keep draining stdout so the gateway never blocks writing to it.
    let stdout_thread = std::thread::spawn(move || for _ in &mut lines {});

    // Prometheus exposition must be live before the drain (the generate
    // below is the max-requests stop trigger).
    let metrics = http_roundtrip(
        &addr,
        &format!(
            "GET /metrics?format=prometheus HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
        ),
    );
    assert!(metrics.contains("200"), "metrics status: {metrics}");
    for want in
        ["psf_ttft_seconds_bucket", "psf_queue_wait_seconds_bucket", "le=\"+Inf\"", "_count"]
    {
        assert!(metrics.contains(want), "prometheus exposition missing `{want}`:\n{metrics}");
    }

    let body = "{\"prompt\": \"observability end to end\", \"max_tokens\": 8}";
    let response = http_roundtrip(
        &addr,
        &format!(
            "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    );
    assert!(response.contains("\"done\":true"), "generate stream incomplete:\n{response}");

    // --max-requests 1 drains the fleet and flushes + merges the traces.
    let deadline = Instant::now() + Duration::from_secs(120);
    let status = loop {
        if let Some(status) = child.0.try_wait().expect("try_wait") {
            break status;
        }
        assert!(Instant::now() < deadline, "psf serve did not exit after --max-requests 1");
        std::thread::sleep(Duration::from_millis(50));
    };
    stdout_thread.join().unwrap();
    let stderr_text = stderr_thread.join().unwrap();
    assert!(status.success(), "psf serve failed: {status:?}\nstderr:\n{stderr_text}");

    let text = std::fs::read_to_string(&trace_path).unwrap_or_else(|e| {
        panic!("trace file missing at {}: {e}\nstderr:\n{stderr_text}", trace_path.display())
    });
    let tf = obs::trace::parse(&text).expect("exported trace must be valid trace-event JSON");
    assert!(!tf.events.is_empty(), "trace has no events");

    let pids: std::collections::BTreeSet<u64> = tf.events.iter().map(|e| e.pid).collect();
    assert!(
        pids.len() >= 2,
        "want gateway + runner processes in one timeline, got pids {pids:?}\nstderr:\n{stderr_text}"
    );
    assert!(
        tf.events.iter().any(|e| e.name == "serve_request"),
        "gateway serve_request span missing"
    );

    // The acceptance criterion: one request's gateway and runner spans
    // share a trace id.
    let stitched = tf
        .events
        .iter()
        .filter(|e| e.trace_id != 0)
        .map(|e| e.trace_id)
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .find(|id| {
            let span_pids: std::collections::BTreeSet<u64> = tf
                .events
                .iter()
                .filter(|e| e.trace_id == *id)
                .map(|e| e.pid)
                .collect();
            span_pids.len() >= 2
        });
    assert!(
        stitched.is_some(),
        "no trace id spans both the gateway and a runner process\nstderr:\n{stderr_text}"
    );

    // Runner trace files were merged into the main file and removed.
    for slot in 0..2 {
        let runner_file = PathBuf::from(format!("{}.runner{slot}", trace_path.display()));
        assert!(!runner_file.exists(), "{} not merged/removed", runner_file.display());
    }

    let _ = std::fs::remove_dir_all(&dir);
}
