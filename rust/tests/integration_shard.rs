//! Integration tests over multi-process sharded serving: a real
//! supervisor spawning real `psf runner` child processes (the binary
//! cargo builds for this test run), driven through the in-process
//! `ShardGateway` API — no HTTP in the loop, but everything else is the
//! production path: Unix-socket IPC, framed protocol, hash-ring
//! routing, crash detection, respawn.
//!
//! The determinism contract under test: a request served by a runner
//! replica is byte-identical to the same request served by the
//! single-process gateway, before AND after the runner serving it was
//! SIGKILLed and respawned.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use polysketchformer::attn::Mechanism;
use polysketchformer::infer::{GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::serve::{collect_stream, Gateway, GatewayConfig};
use polysketchformer::shard::{
    hash_key, partition_heads, run_tp_session, LocalCombine, ShardConfig, ShardEvent,
    ShardGateway, Supervisor, SupervisorConfig,
};

const MECH: &str = "psk4_r4_b8_local";

fn model_args() -> Vec<String> {
    ["--mech", MECH, "--d-model", "32", "--layers", "2", "--heads", "2", "--seed", "1"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

/// The same model the runners build from `model_args` (vocab 257 is the
/// `LmConfig` default, matching `psf runner`'s flag-built path).
fn oracle_model() -> NativeLm {
    let cfg = LmConfig { d_model: 32, layers: 2, heads: 2, seed: 1, ..LmConfig::default() };
    NativeLm::new(cfg, Mechanism::parse(MECH).expect("test mechanism label"))
}

fn sup_config(runners: usize, tp: bool) -> SupervisorConfig {
    SupervisorConfig {
        runners,
        runner_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_psf")),
        model_args: model_args(),
        threads_per_runner: 1,
        tp,
        heads: 2,
        ..SupervisorConfig::default()
    }
}

fn prompt(tag: u32) -> Vec<u32> {
    std::iter::once(0u32)
        .chain((0..24u32).map(|i| 1 + (tag.wrapping_mul(97) + i * 13) % 256))
        .collect()
}

fn request(tag: u32, max_new: usize) -> GenRequest {
    GenRequest {
        prompt: prompt(tag),
        max_new_tokens: max_new,
        policy: SamplePolicy::Greedy,
        seed: 7 + tag as u64,
    }
}

/// Drain a submit receiver with a hang guard (never `iter()` in tests:
/// a wedged gateway thread must fail the test, not freeze CI).
fn drain(rx: &Receiver<ShardEvent>) -> (Vec<u32>, bool, Option<(bool, String)>) {
    let mut tokens = Vec::new();
    let mut done = false;
    let mut error = None;
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        match rx.recv_timeout(Duration::from_secs(5)) {
            Ok(ShardEvent::Token { token, .. }) => tokens.push(token),
            Ok(ShardEvent::Done { .. }) => done = true,
            Ok(ShardEvent::Failed { retriable, msg, .. }) => error = Some((retriable, msg)),
            Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {
                assert!(Instant::now() < deadline, "stream hung: no event within 60s");
            }
        }
    }
    (tokens, done, error)
}

/// What the single-process serving path generates for `req`.
fn single_process_tokens(req: &GenRequest) -> Vec<u32> {
    let gw = Arc::new(
        Gateway::new(oracle_model(), GatewayConfig::default()).expect("oracle gateway"),
    );
    let rx = gw.submit(req.clone()).expect("oracle admission");
    let (tokens, stats) = collect_stream(rx);
    gw.finish().expect("oracle drain");
    assert!(stats.is_some(), "oracle request must complete");
    tokens
}

fn wait_all_healthy(sup: &Supervisor, within: Duration) {
    let deadline = Instant::now() + within;
    loop {
        let (total, healthy) = sup.health();
        if healthy == total {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "runners did not recover within {within:?}: {healthy}/{total} healthy"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn replica_serving_matches_single_process_gateway_byte_identically() {
    let sup = Supervisor::start(sup_config(2, false)).expect("supervisor start");
    let gw = Arc::new(
        ShardGateway::new(
            Arc::clone(&sup),
            Mechanism::parse(MECH).unwrap(),
            ShardConfig::default(),
        )
        .expect("shard gateway"),
    );

    // Distinct prompts spread over the ring: both runners serve some.
    for tag in 0..4u32 {
        let req = request(tag, 12);
        let rx = gw.submit(req.clone()).expect("admission");
        let (tokens, done, error) = drain(&rx);
        assert!(error.is_none(), "request {tag} failed: {error:?}");
        assert!(done, "request {tag} never completed");
        assert_eq!(
            tokens,
            single_process_tokens(&req),
            "runner replica diverged from the single-process path (tag {tag})"
        );
    }
    gw.finish().expect("drain");
}

#[test]
fn runner_crash_fails_fast_then_respawn_serves_identically() {
    let sup = Supervisor::start(sup_config(2, false)).expect("supervisor start");
    let gw = Arc::new(
        ShardGateway::new(
            Arc::clone(&sup),
            Mechanism::parse(MECH).unwrap(),
            ShardConfig::default(),
        )
        .expect("shard gateway"),
    );

    // Find a prompt routed to runner 0's ring slice so the kill target
    // is the runner actually serving the stream.
    let tag = (0..u32::MAX)
        .find(|&t| sup.route(hash_key(MECH, &prompt(t))) == Some(0))
        .expect("some prompt routes to runner 0");
    let victim = 0u32;

    // Long-running stream: enough decode steps that the SIGKILL lands
    // mid-stream (tiny model, but 4000 steps is hundreds of ms).
    let rx = gw.submit(request(tag, 4000)).expect("admission");
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(ShardEvent::Token { .. }) => {}
        other => panic!("expected first token, got {other:?}"),
    }
    sup.kill_runner(victim);
    let (_, done, error) = drain(&rx);
    assert!(!done, "stream must not complete after its runner was killed");
    let (retriable, msg) = error.expect("killed stream must end in a Failed event");
    assert!(retriable, "mid-stream runner death must be retriable: {msg}");

    // Graceful degradation: the gateway lives, the supervisor noticed,
    // and the runner comes back within the recovery window.
    assert!(sup.was_ever_degraded());
    wait_all_healthy(&sup, Duration::from_secs(30));
    assert!(sup.respawn_count() >= 1, "dead runner must have been respawned");

    // The retried request — same routing key, now served by the respawned
    // replica — is byte-identical to a cold single-process run.
    let req = request(tag, 12);
    let rx = gw.submit(req.clone()).expect("admission after recovery");
    let (tokens, done, error) = drain(&rx);
    assert!(error.is_none(), "retried request failed: {error:?}");
    assert!(done, "retried request never completed");
    assert_eq!(
        tokens,
        single_process_tokens(&req),
        "respawned runner diverged from the cold single-process run"
    );
    gw.finish().expect("drain");
}

#[test]
fn tp_over_ipc_matches_local_combine_bitwise() {
    let req = request(9, 10);

    // In-process reference: two shard threads over LocalCombine.
    let model = Arc::new(oracle_model());
    let ranges = partition_heads(2, 2);
    let mut handles = Vec::new();
    for (range, mut combine) in ranges.into_iter().zip(LocalCombine::world(2)) {
        let model = Arc::clone(&model);
        let req = req.clone();
        handles.push(std::thread::spawn(move || {
            run_tp_session(&model, range, &req, &mut combine, &mut |_| Ok(())).unwrap()
        }));
    }
    let runs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(runs[0].generated, runs[1].generated, "local shards must agree");
    let want = runs[0].generated.clone();

    // Production path: the same two shards as separate processes, the
    // gateway as combine hub over the framed protocol.
    let sup = Supervisor::start(sup_config(2, true)).expect("tp supervisor start");
    assert!(sup.is_tp());
    let gw = Arc::new(
        ShardGateway::new(
            Arc::clone(&sup),
            Mechanism::parse(MECH).unwrap(),
            ShardConfig::default(),
        )
        .expect("shard gateway"),
    );
    let rx = gw.submit(req).expect("admission");
    let (tokens, done, error) = drain(&rx);
    assert!(error.is_none(), "tp request failed: {error:?}");
    assert!(done, "tp request never completed");
    assert_eq!(tokens, want, "IPC combine must be bitwise-identical to LocalCombine");
    gw.finish().expect("drain");
}
