//! Golden fixtures pinning the kernel core's exact bytes.
//!
//! For all six mechanisms this captures, to `tests/fixtures/kernel_golden/`:
//!
//! * forward logits of a full-context (ragged-length) prefill,
//! * the decode token stream + final logits of a sampled session,
//! * the served token stream through the gateway (worker pool + prompt
//!   cache), cold and cache-hit.
//!
//! Every value is serialized as raw f32 bit patterns, so equality is
//! *byte* equality, not tolerance.  The first run (or `PSF_BLESS=1`)
//! writes the fixtures; thereafter any refactor that changes a single
//! bit of any mechanism's forward/decode/serve behavior fails here.
//!
//! Provenance: the fixtures are blessed by the first toolchain run at
//! the kernel-core refactor commit that introduced this test (the
//! growth container has no cargo, so a literal pre-refactor capture was
//! impossible).  The pre-vs-post anchor is therefore indirect but
//! strong: the engines reproduce the historical per-mechanism kernels'
//! operation order op for op — `block_lt`'s ragged-vs-padded test pins
//! that bitwise, and `attn::kernel::state` pins capture-vs-absorb —
//! with the one documented exception (performer decode now follows the
//! blocked recurrence, see CHANGES.md).  The serial-vs-pooled
//! cross-check below and the `PSF_THREADS=2` CI rerun keep the
//! fixtures thread-count independent from then on.
//!
//! Re-bless (microkernel refactor): moving every inner loop onto
//! `tensor::micro` replaced the historical sequential `sum += a[i]*b[i]`
//! folds with the fixed lane-width-8 reduction tree, which rounds
//! differently, so these fixtures were re-blessed exactly once at that
//! commit.  The lane tree is now *the spec* (DESIGN.md, invariant #11):
//! it is what makes scalar and SIMD backends byte-identical, so it can
//! never change again — any future bit movement here is a bug, not a
//! candidate for re-blessing.  CI reruns this suite under `PSF_SIMD=off`
//! to pin both backends to the same fixtures.

use std::fmt::Write as _;
use std::path::PathBuf;

use polysketchformer::attn::Mechanism;
use polysketchformer::exec::pool;
use polysketchformer::infer::{DecodeSession, GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::serve::{collect_stream, Gateway, GatewayConfig};

fn mechanisms() -> Vec<Mechanism> {
    vec![
        Mechanism::Softmax,
        Mechanism::Flash { block: 8 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
        Mechanism::Performer { m: 16, block: 8 },
    ]
}

fn lm(mech: Mechanism) -> NativeLm {
    let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 17 };
    NativeLm::new(cfg, mech)
}

fn prompt(n: usize) -> Vec<u32> {
    std::iter::once(0u32).chain((1..n as u32).map(|i| i.wrapping_mul(23) % 64)).collect()
}

fn hex_f32s(xs: &[f32]) -> String {
    let mut s = String::with_capacity(xs.len() * 9);
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            s.push(' ');
        }
        let _ = write!(s, "{:08x}", x.to_bits());
    }
    s
}

fn ints(xs: &[u32]) -> String {
    xs.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
}

/// Capture forward/decode/serve behavior of one mechanism as a stable,
/// byte-exact text artifact.
fn capture(mech: &Mechanism) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "mechanism {}", mech.label());

    // ---- forward logits (ragged length: 13 straddles block 8) --------
    let model = lm(mech.clone());
    let toks = prompt(13);
    let logits = model.forward(&toks);
    let _ = writeln!(out, "forward {}x{}", logits.rows(), logits.cols());
    for i in 0..logits.rows() {
        let _ = writeln!(out, "{}", hex_f32s(logits.row(i)));
    }

    // ---- decode token stream + final logits --------------------------
    let req = GenRequest {
        prompt: prompt(5),
        max_new_tokens: 12,
        policy: SamplePolicy::Temperature(0.8),
        seed: 99,
    };
    let mut session = DecodeSession::new(&model, 0, req);
    session.run_to_completion(&model);
    let _ = writeln!(out, "decode {}", ints(session.generated()));
    let _ = writeln!(out, "decode_logits {}", hex_f32s(&session.snapshot().last_logits));

    // ---- served stream: cold, then cache hit -------------------------
    let gw = Gateway::new(
        lm(mech.clone()),
        GatewayConfig { workers: 2, ..GatewayConfig::default() },
    )
    .expect("gateway");
    let serve_req = || GenRequest {
        prompt: prompt(9),
        max_new_tokens: 8,
        policy: SamplePolicy::TopP { p: 0.9, temperature: 0.7 },
        seed: 41,
    };
    let (cold, _) = collect_stream(gw.submit(serve_req()).expect("cold submit"));
    let (cached, _) = collect_stream(gw.submit(serve_req()).expect("cached submit"));
    gw.finish().expect("gateway finish");
    assert_eq!(cold, cached, "{}: cache hit diverged from cold serve", mech.label());
    let _ = writeln!(out, "serve {}", ints(&cold));
    out
}

fn fixture_path(mech: &Mechanism) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/kernel_golden")
        .join(format!("{}.golden", mech.label()))
}

#[test]
fn golden_outputs_byte_identical_for_all_mechanisms() {
    let bless = std::env::var("PSF_BLESS").is_ok_and(|v| v == "1");
    let mut blessed = Vec::new();
    for mech in mechanisms() {
        let got = capture(&mech);
        // The pooled capture must already be thread-count independent;
        // cross-check against the forced single-thread execution before
        // trusting it as (or comparing it to) a fixture.
        let serial = pool::serial(|| capture(&mech));
        assert_eq!(got, serial, "{}: capture depends on thread count", mech.label());

        let path = fixture_path(&mech);
        match std::fs::read_to_string(&path) {
            Ok(want) if !bless => {
                assert_eq!(
                    got,
                    want,
                    "{}: outputs changed vs golden fixture {} — a refactor moved bytes; \
                     rerun with PSF_BLESS=1 only if the change is intended",
                    mech.label(),
                    path.display()
                );
            }
            _ => {
                std::fs::create_dir_all(path.parent().expect("fixture dir")).expect("mkdir");
                std::fs::write(&path, &got).expect("write fixture");
                blessed.push(path);
            }
        }
    }
    for p in &blessed {
        eprintln!("blessed golden fixture {}", p.display());
    }
}
