//! Numeric-health sentinel integration: the byte-identity contract and
//! fault attribution on the real model paths.
//!
//! The sentinel invariant is that telemetry never feeds computation:
//! with `PSF_SENTINEL=1` the kernel/train hooks scan activations and
//! gradients, but every computed value — forward logits, sampled token
//! streams, per-section gradients — must be **bitwise identical** to a
//! sentinels-off run, for all six mechanisms.  A healthy run must also
//! never trip.  The poisoned-model tests then check the other half of
//! the bargain: a genuine NaN is caught and attributed (site, layer,
//! step) rather than silently propagated.
//!
//! Every test toggles the process-global sentinel flag, so they all
//! serialize on one lock.

use std::sync::{Mutex, MutexGuard};

use polysketchformer::attn::Mechanism;
use polysketchformer::infer::{DecodeSession, GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::obs::{self, sentinel};
use polysketchformer::train::{compute_grads, TrainExample};

static SENTINEL_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    SENTINEL_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn mechanisms() -> Vec<Mechanism> {
    vec![
        Mechanism::Softmax,
        Mechanism::Flash { block: 8 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
        Mechanism::Performer { m: 16, block: 8 },
    ]
}

fn lm(mech: Mechanism) -> NativeLm {
    // 4 heads + a 77-token prompt engages the pooled head fan-out, the
    // blocked fold (block 8 < 77), and the padded layer tail.
    let cfg = LmConfig { vocab: 64, d_model: 64, layers: 2, heads: 4, ff_mult: 2, seed: 33 };
    NativeLm::new(cfg, mech)
}

fn prompt(n: usize) -> Vec<u32> {
    std::iter::once(0u32).chain((1..n as u32).map(|i| i.wrapping_mul(23) % 64)).collect()
}

fn generate(model: &NativeLm, seed: u64) -> Vec<u32> {
    let req = GenRequest {
        prompt: prompt(77),
        max_new_tokens: 12,
        policy: SamplePolicy::Temperature(0.9),
        seed,
    };
    let mut s = DecodeSession::new(model, 0, req);
    s.run_to_completion(model);
    s.generated().to_vec()
}

/// f32 slices compared at the bit level — `==` on floats would already
/// fail on a NaN, but the contract is *byte* identity.
fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn token_streams_byte_identical_sentinels_on_vs_off() {
    let _g = lock();
    for mech in mechanisms() {
        let label = mech.label();
        let model = lm(mech);
        obs::set_sentinels(false);
        let off = generate(&model, 7);
        obs::set_sentinels(true);
        sentinel::reset();
        let on = generate(&model, 7);
        let tripped = sentinel::tripped();
        obs::set_sentinels(false);
        sentinel::reset();
        assert_eq!(off, on, "{label}: token stream moved under sentinels");
        assert!(!tripped, "{label}: healthy generation tripped a sentinel");
    }
}

#[test]
fn forward_logits_byte_identical_sentinels_on_vs_off() {
    let _g = lock();
    let tokens = prompt(77);
    for mech in mechanisms() {
        let label = mech.label();
        let model = lm(mech);
        obs::set_sentinels(false);
        let off = model.forward(&tokens);
        obs::set_sentinels(true);
        sentinel::reset();
        let on = model.forward(&tokens);
        obs::set_sentinels(false);
        sentinel::reset();
        assert_eq!(bits(off.data()), bits(on.data()), "{label}: logits moved under sentinels");
    }
}

#[test]
fn gradients_byte_identical_sentinels_on_vs_off() {
    let _g = lock();
    let ex = || TrainExample {
        tokens: (0..=32u32).map(|i| (i * 7) % 32).collect(),
        mask: vec![true; 32],
    };
    for mech in mechanisms() {
        let label = mech.label();
        let model = lm(mech);
        obs::set_sentinels(false);
        let (g_off, s_off) = compute_grads(&model, &[ex(), ex()]);
        obs::set_sentinels(true);
        sentinel::reset();
        let (g_on, s_on) = compute_grads(&model, &[ex(), ex()]);
        // Mirror the train loop's hook order: per-section scans feed
        // the watermarks, then the loss detector observes the batch.
        for (name, t) in g_on.named() {
            sentinel::scan_named(sentinel::Site::Grad, &name, t.data());
        }
        sentinel::observe_loss(0, s_on.loss);
        let tripped = sentinel::tripped();
        obs::set_sentinels(false);
        sentinel::reset();
        assert_eq!(g_off, g_on, "{label}: gradients moved under sentinels");
        assert_eq!(
            s_off.loss.to_bits(),
            s_on.loss.to_bits(),
            "{label}: loss moved under sentinels"
        );
        assert!(!tripped, "{label}: healthy gradients tripped a sentinel");
    }
}

#[test]
fn healthy_run_populates_watermarks_without_faults() {
    let _g = lock();
    obs::set_sentinels(true);
    sentinel::reset();
    let model = lm(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
    let _ = generate(&model, 3);
    let marks = sentinel::watermarks();
    let tripped = sentinel::tripped();
    obs::set_sentinels(false);
    sentinel::reset();
    assert!(!tripped, "healthy decode must not trip");
    // The logits scan runs unsampled sites every stride; a 77-token
    // prefill + 12 decode steps crosses every stride boundary, so at
    // least the logits watermark must be live.
    let logits_mark = marks
        .iter()
        .find(|(site, _)| *site == "logits")
        .map(|(_, v)| *v)
        .expect("logits watermark present");
    assert!(logits_mark > 0.0, "logits watermark never rose: {marks:?}");
}

#[test]
fn poisoned_gradient_trips_with_grad_site_attribution() {
    let _g = lock();
    obs::set_sentinels(true);
    sentinel::reset();
    sentinel::set_step(41);
    let mut grad = vec![0.25f32; 64];
    grad[17] = f32::NAN;
    sentinel::scan_named(sentinel::Site::Grad, "layer0.wq", &grad);
    let fault = sentinel::fault().expect("NaN gradient must trip");
    let fatal = sentinel::tripped_fatal();
    obs::set_sentinels(false);
    sentinel::reset();
    assert!(fatal, "NaN is a fatal fault");
    assert_eq!(fault.site, sentinel::Site::Grad);
    assert_eq!(fault.step, 41);
    assert_eq!(fault.index, 17);
    assert_eq!(fault.detail, "layer0.wq");
}

#[test]
fn first_fault_wins_and_later_trips_only_count() {
    let _g = lock();
    obs::set_sentinels(true);
    sentinel::reset();
    sentinel::set_step(5);
    sentinel::scan_named(sentinel::Site::Grad, "first", &[f32::NAN]);
    sentinel::set_step(6);
    sentinel::scan_named(sentinel::Site::Grad, "second", &[f32::INFINITY]);
    let fault = sentinel::fault().expect("fault kept");
    let trips = sentinel::trip_count();
    obs::set_sentinels(false);
    sentinel::reset();
    assert_eq!(fault.detail, "first", "attribution must pin the FIRST fault");
    assert_eq!(fault.step, 5);
    assert_eq!(trips, 2, "later faults still counted");
}
