//! Prometheus text-exposition conformance for `/metrics?format=prometheus`.
//!
//! The scrape surface is hand-rolled (no client library in this
//! environment), so these tests pin the parts of the text format a real
//! Prometheus server is strict about: cumulative `le` buckets, the
//! `+Inf` bucket equalling `_count`, a `_sum` per histogram, label-value
//! escaping, and the `_info`-style build-identity gauge.
//!
//! The span-ring test toggles the process-global tracing flag, so the
//! flag-touching tests serialize on one lock (same idiom as
//! `tests/obs_trace.rs`).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard};

use polysketchformer::metrics::{prom_escape_label, ServeCounters};
use polysketchformer::obs;

static PROM_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    PROM_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Collect `(le, cumulative_count)` pairs for one histogram family, in
/// exposition order.
fn buckets(text: &str, family: &str) -> Vec<(String, u64)> {
    let prefix = format!("{family}_bucket{{le=\"");
    text.lines()
        .filter_map(|l| {
            let rest = l.strip_prefix(&prefix)?;
            let (le, count) = rest.split_once("\"} ")?;
            Some((le.to_string(), count.parse().ok()?))
        })
        .collect()
}

fn scalar(text: &str, name: &str) -> Option<f64> {
    let prefix = format!("{name} ");
    text.lines().find_map(|l| l.strip_prefix(&prefix)?.trim().parse().ok())
}

fn populated_counters() -> Arc<ServeCounters> {
    let c = Arc::new(ServeCounters::new());
    c.admitted.store(12, Ordering::Relaxed);
    c.completed.store(9, Ordering::Relaxed);
    c.cache_hits.store(5, Ordering::Relaxed);
    c.cache_misses.store(4, Ordering::Relaxed);
    // Spread samples across bucket bounds, including one past the last
    // bound so the +Inf bucket is exercised.
    for i in 0..200 {
        c.ttft.observe(1e-4 * (i + 1) as f64);
        c.token_latency.observe(5e-3);
    }
    c.ttft.observe(1e9);
    c.queue_wait.observe(0.002);
    c.ipc_rtt.observe(0.0004);
    c.cache_lookup.observe(2e-5);
    c
}

#[test]
fn histogram_buckets_are_cumulative_and_inf_matches_count() {
    let _g = lock();
    let c = populated_counters();
    let text = c.prometheus_text();
    for family in [
        "psf_ttft_seconds",
        "psf_token_latency_seconds",
        "psf_queue_wait_seconds",
        "psf_ipc_rtt_seconds",
        "psf_cache_lookup_seconds",
    ] {
        let bs = buckets(&text, family);
        assert!(bs.len() >= 2, "{family}: no buckets in:\n{text}");
        for w in bs.windows(2) {
            assert!(
                w[1].1 >= w[0].1,
                "{family}: bucket le=\"{}\" ({}) < le=\"{}\" ({}) — not cumulative",
                w[1].0,
                w[1].1,
                w[0].0,
                w[0].1,
            );
        }
        let (last_le, last_n) = bs.last().unwrap();
        assert_eq!(last_le, "+Inf", "{family}: final bucket must be +Inf");
        let count = scalar(&text, &format!("{family}_count"))
            .unwrap_or_else(|| panic!("{family}_count missing"));
        assert_eq!(*last_n, count as u64, "{family}: +Inf bucket != _count");
        let sum = scalar(&text, &format!("{family}_sum"))
            .unwrap_or_else(|| panic!("{family}_sum missing"));
        assert!(sum >= 0.0, "{family}_sum negative: {sum}");
        assert!(
            text.contains(&format!("# TYPE {family} histogram")),
            "{family}: TYPE line missing"
        );
    }
    // The sample past the last bound lands only in +Inf: its cumulative
    // count must exceed the last finite bound's.
    let ttft = buckets(&text, "psf_ttft_seconds");
    let finite_max = ttft[ttft.len() - 2].1;
    assert_eq!(ttft.last().unwrap().1, finite_max + 1, "overflow sample not in +Inf");
}

#[test]
fn counters_and_build_identity_present() {
    let _g = lock();
    let c = populated_counters();
    let text = c.prometheus_text();
    for needle in [
        "# TYPE psf_requests_admitted_total counter",
        "psf_requests_admitted_total 12",
        "psf_requests_completed_total 9",
        "psf_cache_hits_total 5",
        "psf_cache_misses_total 4",
        "# TYPE psf_build_info gauge",
        "# TYPE psf_uptime_seconds gauge",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    // psf_build_info: constant 1, identity in the labels.
    let info = text
        .lines()
        .find(|l| l.starts_with("psf_build_info{"))
        .expect("psf_build_info sample line");
    assert!(info.ends_with("} 1"), "build info must be the constant 1: {info}");
    for label in ["version=\"", "simd=\"", "quant=\""] {
        assert!(info.contains(label), "psf_build_info missing {label}: {info}");
    }
    let up = scalar(&text, "psf_uptime_seconds").expect("uptime sample");
    assert!(up >= 0.0, "uptime negative: {up}");
}

#[test]
fn label_values_escape_backslash_quote_newline() {
    let _g = lock();
    assert_eq!(prom_escape_label(r"a\b"), r"a\\b");
    assert_eq!(prom_escape_label("say \"hi\""), "say \\\"hi\\\"");
    assert_eq!(prom_escape_label("two\nlines"), "two\\nlines");
    assert_eq!(prom_escape_label("plain-1.2_3"), "plain-1.2_3");
    // Composed: every escaped label value stays on one exposition line.
    let v = prom_escape_label("x\\\"\ny");
    assert!(!v.contains('\n'), "escaped value leaked a raw newline: {v:?}");
    assert_eq!(v, "x\\\\\\\"\\ny");
}

#[test]
fn span_ring_series_appear_once_spans_flow() {
    let _g = lock();
    obs::set_tracing(true);
    {
        let _s = obs::span("prometheus-test-span", "serve");
    }
    obs::set_tracing(false);
    // This thread's ring is registered now whether or not other tests
    // ran first; the series must name it by tid.
    let rings = obs::span::ring_stats();
    assert!(!rings.is_empty(), "span emission must register a ring");
    let c = populated_counters();
    let text = c.prometheus_text();
    assert!(
        text.contains("# TYPE psf_span_ring_events gauge"),
        "ring occupancy gauge missing:\n{text}"
    );
    assert!(
        text.contains("# TYPE psf_span_ring_dropped_total counter"),
        "ring drop counter missing:\n{text}"
    );
    for (tid, occ, dropped) in rings {
        assert!(
            text.contains(&format!("psf_span_ring_events{{tid=\"{tid}\"}} {occ}")),
            "per-thread occupancy sample for tid {tid} missing:\n{text}"
        );
        assert!(
            text.contains(&format!("psf_span_ring_dropped_total{{tid=\"{tid}\"}} {dropped}")),
            "per-thread drop sample for tid {tid} missing:\n{text}"
        );
    }
}
