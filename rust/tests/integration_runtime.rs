//! NOTE: every test here is `#[ignore]`d for tier-1 runs: they exercise
//! AOT artifacts through PJRT, which needs `make artifacts` (Python/JAX
//! toolchain) and the real xla_extension bindings in place of the offline
//! stub under rust/vendor/xla.  Run with `cargo test -- --ignored` once
//! both are available.

//! Integration tests over the PJRT runtime + coordinator, exercising real
//! AOT artifacts end to end (requires `make artifacts`; uses the
//! second-scale `tiny_*` bundles so the whole file runs in ~a minute).
//!
//! All tests share one thread (PJRT objects are thread-confined), so this
//! file forces a single test thread via serial helpers per test — each test
//! creates its own runtime objects; the thread-local client is shared.

use polysketchformer::coordinator::{self, Trainer, TrainerConfig};
use polysketchformer::data::{batcher::Batcher, random_tokens};
use polysketchformer::runtime::{self, LoadOpts, ModelRuntime};

fn load(name: &str, opts: LoadOpts) -> ModelRuntime {
    runtime::load_model(name, opts).unwrap_or_else(|e| {
        panic!("cannot load artifact `{name}` — run `make artifacts` first: {e:#}")
    })
}

fn token_batch(model: &ModelRuntime, seed: u64) -> Vec<i32> {
    random_tokens(model.batch() * (model.ctx() + 1), model.vocab(), seed)
        .into_iter()
        .map(|t| t as i32)
        .collect()
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn train_step_decreases_loss_and_counts_steps() {
    let mut model = load("tiny_softmax", LoadOpts::train_only());
    let batch = token_batch(&model, 0);
    let first = model.train_step(&batch).unwrap();
    assert_eq!(first.step, 1);
    assert!(first.loss.is_finite());
    // ln(vocab=64) ~ 4.16 at init.
    assert!((3.0..5.5).contains(&first.loss), "init loss {}", first.loss);
    let mut last = first;
    for _ in 0..60 {
        last = model.train_step(&batch).unwrap();
    }
    assert_eq!(last.step, 61);
    // Repeating one batch must memorize it (lr is still in its 100-step
    // warmup ramp here, so require a solid but not dramatic drop).
    assert!(
        last.loss < first.loss - 0.3,
        "loss should drop on a repeated batch: {} -> {}",
        first.loss,
        last.loss
    );
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn eval_loss_matches_scale_and_is_deterministic() {
    let model = load("tiny_softmax", LoadOpts::eval_only());
    let batch = token_batch(&model, 1);
    let a = model.eval_loss(&batch).unwrap();
    let b = model.eval_loss(&batch).unwrap();
    assert_eq!(a, b, "eval must be deterministic");
    assert!((3.0..5.5).contains(&a), "init NLL ~ ln(64): {a}");
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn forward_shape_and_finiteness() {
    let model = load("tiny_softmax", LoadOpts::fwd_only());
    let tokens: Vec<i32> = random_tokens(model.batch() * model.ctx(), model.vocab(), 2)
        .into_iter()
        .map(|t| t as i32)
        .collect();
    let logits = model.forward(&tokens).unwrap();
    assert_eq!(logits.len(), model.batch() * model.ctx() * model.vocab());
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn state_roundtrip_preserves_training() {
    let mut model = load("tiny_softmax", LoadOpts::train_only());
    let batch = token_batch(&model, 3);
    model.train_step(&batch).unwrap();
    let saved = model.state_to_host().unwrap();
    assert_eq!(saved.len(), model.manifest.state_size());

    // Keep training, then restore: stats must rewind.
    model.train_step(&batch).unwrap();
    let s2 = model.read_stats().unwrap();
    assert_eq!(s2.step, 2);
    model.set_state(&saved).unwrap();
    let s1 = model.read_stats().unwrap();
    assert_eq!(s1.step, 1);

    // Restored state must continue identically (bitwise determinism).
    let a = model.train_step(&batch).unwrap();
    model.set_state(&saved).unwrap();
    let b = model.train_step(&batch).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.step, b.step);
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn reset_restores_init() {
    let mut model = load("tiny_softmax", LoadOpts::train_only());
    let batch = token_batch(&model, 4);
    let loss0 = model.train_step(&batch).unwrap().loss;
    for _ in 0..5 {
        model.train_step(&batch).unwrap();
    }
    model.reset().unwrap();
    let stats = model.read_stats().unwrap();
    assert_eq!(stats.step, 0);
    let loss_again = model.train_step(&batch).unwrap().loss;
    assert_eq!(loss0, loss_again, "reset must reproduce the first step");
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn gradstep_equals_fused_train_step() {
    // The factored grads -> gradstep path must produce the same update as
    // the fused train executable (same math, different artifact split).
    let mut fused = load("tiny_softmax", LoadOpts::train_only());
    let mut split = load("tiny_softmax", LoadOpts::grads_only());
    let batch = token_batch(&fused, 5);

    let a = fused.train_step(&batch).unwrap();
    let g = split.grad_loss(&batch).unwrap();
    let b = split.apply_gradvec(&g).unwrap();
    assert_eq!(a.step, b.step);
    assert!(
        (a.loss - b.loss).abs() < 1e-6,
        "fused {} vs split {}",
        a.loss,
        b.loss
    );

    let sa = fused.state_to_host().unwrap();
    let sb = split.state_to_host().unwrap();
    let max_dev = sa
        .iter()
        .zip(&sb)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dev < 1e-5, "state dev {max_dev}");
}

// NOTE: the DataParallel coordinator moved off the PJRT runtime onto the
// native training subsystem (`train/`); its single-worker-bitwise and
// multi-worker tests now live in `coordinator/dataparallel.rs` and run
// un-ignored in tier-1.

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn trainer_end_to_end_with_checkpointing() {
    let dir = std::env::temp_dir().join("psf_trainer_it");
    let _ = std::fs::remove_dir_all(&dir);

    let mut model = load("tiny_psk", LoadOpts::default());
    let stream = random_tokens(33 * 2 * 32, model.vocab(), 8);
    let train = Batcher::new(&stream[..33 * 2 * 24], model.batch(), model.ctx() + 1, 0);
    let test = Batcher::new(&stream[33 * 2 * 24..], model.batch(), model.ctx() + 1, 0);
    let cfg = TrainerConfig {
        steps: 6,
        eval_every: 3,
        eval_batches: 2,
        ckpt_every: 4,
        echo_every: 0,
        run_dir: Some(dir.clone()),
        nan_guard: true,
    };
    let summary = Trainer::new(&mut model, train, Some(test), cfg).run().unwrap();
    assert_eq!(summary.steps_run, 6);
    assert!(!summary.evals.is_empty());
    assert!(summary.final_perplexity().is_finite());
    assert!(dir.join("train.jsonl").exists());
    assert!(dir.join("ckpt_000004.bin").exists());

    // Restore the checkpoint into a fresh trainer and verify the step.
    let mut model2 = load("tiny_psk", LoadOpts::train_only());
    let train2 = Batcher::new(&stream, model2.batch(), model2.ctx() + 1, 0);
    let mut t2 = Trainer::new(&mut model2, train2, None, TrainerConfig::default());
    let step = t2.restore(&dir.join("ckpt_000004.bin")).unwrap();
    assert_eq!(step, 4);
    assert_eq!(t2.model.read_stats().unwrap().step, 4);
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn mcq_scoring_runs_above_chance_floor() {
    // An untrained model scores ~chance; the scorer itself must be sound
    // (probabilities normalized, batching correct). We only assert bounds.
    let model = load("tiny_softmax", LoadOpts::fwd_only());
    let stream = random_tokens(4000, model.vocab(), 10);
    let qs = coordinator::gen_cloze_questions(&stream, model.ctx(), 24, 4, 8, 0, 1);
    let acc = coordinator::score_mcq(&model, &qs).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn perplexity_of_untrained_model_near_uniform() {
    let model = load("tiny_softmax", LoadOpts::eval_only());
    let stream = random_tokens(33 * 2 * 8, model.vocab(), 11);
    let mut test = Batcher::new(&stream, model.batch(), model.ctx() + 1, 0);
    let ppl = coordinator::perplexity(&model, &mut test, 2).unwrap();
    // Uniform over 64-vocab => ppl ~ 64 (random tokens can't be learned).
    assert!((30.0..130.0).contains(&ppl), "ppl {ppl}");
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn rejects_wrong_token_shape() {
    let mut model = load("tiny_softmax", LoadOpts::train_only());
    let too_short = vec![1i32; 7];
    assert!(model.train_step(&too_short).is_err());
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn rejects_wrong_state_size() {
    let mut model = load("tiny_softmax", LoadOpts::train_only());
    assert!(model.set_state(&[0.0; 3]).is_err());
}
