//! Integration tests for the decoding/serving subsystem — no artifacts,
//! pure native path.
//!
//! The correctness anchor is prefill/decode parity: stepping a model
//! token-by-token through the per-head `KernelState`s must reproduce the
//! full-context forward logits within fp tolerance, for every mechanism,
//! at prompt lengths that do and do not align with block boundaries.

use polysketchformer::attn::Mechanism;
use polysketchformer::infer::{
    DecodeSession, GenRequest, LmConfig, NativeLm, SamplePolicy, Scheduler, SchedulerConfig,
};

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn mechanisms() -> Vec<(Mechanism, f32)> {
    // (mechanism, parity tolerance): flash accepts the online-softmax
    // reassociation; the rest are tight.
    vec![
        (Mechanism::Softmax, 1e-3),
        (Mechanism::Flash { block: 8 }, 5e-3),
        (Mechanism::Poly { p: 4 }, 1e-3),
        (Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false }, 2e-3),
        (Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true }, 2e-3),
        (Mechanism::Performer { m: 16, block: 8 }, 5e-3),
    ]
}

fn tiny(mech: Mechanism) -> NativeLm {
    let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 17 };
    NativeLm::new(cfg, mech)
}

fn tokens(n: usize) -> Vec<u32> {
    (0..n).map(|i| (i as u32).wrapping_mul(2654435761) % 64).collect()
}

#[test]
fn prefill_decode_parity_all_mechanisms() {
    // Decode from scratch: step every token through the kernel states and
    // compare each position's logits against the full-context forward.
    for (mech, tol) in mechanisms() {
        let model = tiny(mech.clone());
        for n in [7usize, 16, 27] {
            let toks = tokens(n);
            let want = model.forward(&toks);
            let mut states = model.new_states();
            for i in 0..n {
                let got = model.step(toks[i], i, &mut states);
                for (j, (g, w)) in got.iter().zip(want.row(i)).enumerate() {
                    assert!(
                        close(*g, *w, tol),
                        "{} n={n} pos={i} logit {j}: decode {g} vs prefill {w}",
                        mech.label()
                    );
                }
            }
        }
    }
}

#[test]
fn prefill_then_step_matches_pure_stepping() {
    // Absorbing the prompt via the full-context prefill must leave the
    // decode states equivalent to having stepped every prompt token.
    for (mech, tol) in mechanisms() {
        let model = tiny(mech.clone());
        let n = 21usize; // straddles the block-8 partition
        let toks = tokens(n);

        let mut prefilled = model.new_states();
        model.prefill(&toks, &mut prefilled);
        let mut stepped = model.new_states();
        for i in 0..n {
            model.step(toks[i], i, &mut stepped);
        }

        for (i, next) in tokens(n + 6)[n..].iter().enumerate() {
            let a = model.step(*next, n + i, &mut prefilled);
            let b = model.step(*next, n + i, &mut stepped);
            for (x, y) in a.iter().zip(&b) {
                assert!(close(*x, *y, tol), "{} continuation {i}: {x} vs {y}", mech.label());
            }
        }
    }
}

#[test]
fn generation_is_deterministic_through_the_scheduler() {
    // Fixed (seed, prompt, policy) => identical token output, independent
    // of the batching discipline — the `generate` CLI's contract.
    let model = tiny(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
    let run = |max_concurrent: usize, tick: usize| {
        let cfg = SchedulerConfig {
            max_concurrent,
            tick_tokens: tick,
            ..SchedulerConfig::default()
        };
        let mut sched = Scheduler::new(&model, cfg);
        for i in 0..3u64 {
            sched.submit(GenRequest {
                prompt: vec![0, 11, 29, 5],
                max_new_tokens: 9,
                policy: SamplePolicy::Temperature(0.7),
                seed: 1000 + i,
            });
        }
        let summary = sched.run().unwrap();
        assert_eq!(summary.total_new_tokens, 27);
        summary.reports.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    let a = run(1, 1);
    let b = run(3, 8);
    let c = run(2, 3);
    assert_eq!(a, b);
    assert_eq!(a, c);
}

#[test]
fn linear_state_is_constant_while_cache_grows() {
    let linear = tiny(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false });
    let cache = tiny(Mechanism::Softmax);
    let mem_at = |model: &NativeLm, n: usize| {
        let req = GenRequest {
            prompt: tokens(n),
            max_new_tokens: 8,
            policy: SamplePolicy::Greedy,
            seed: 0,
        };
        let mut s = DecodeSession::new(model, 0, req);
        s.run_to_completion(model);
        s.state_memory_floats()
    };
    // Block-aligned contexts so the sketch buffers compare like for like.
    assert_eq!(mem_at(&linear, 64), mem_at(&linear, 256));
    assert!(mem_at(&cache, 256) > 2 * mem_at(&cache, 64));
}

#[test]
fn greedy_decode_matches_forward_argmax_chain() {
    // End-to-end: greedy generation must follow the argmax chain of the
    // full-context forward pass recomputed from scratch each step — ties
    // between decode and prefill numerics are the only divergence risk,
    // so use the mechanism with exact parity.
    let model = tiny(Mechanism::Softmax);
    let prompt = vec![0u32, 3, 41, 8];
    let req = GenRequest {
        prompt: prompt.clone(),
        max_new_tokens: 6,
        policy: SamplePolicy::Greedy,
        seed: 0,
    };
    let mut session = DecodeSession::new(&model, 0, req);
    session.run_to_completion(&model);

    let mut oracle = prompt;
    for _ in 0..6 {
        let logits = model.forward(&oracle);
        let last = logits.row(oracle.len() - 1);
        let mut best = 0;
        for (i, &x) in last.iter().enumerate() {
            if x > last[best] {
                best = i;
            }
        }
        oracle.push(best as u32);
    }
    assert_eq!(session.tokens, oracle);
}
