//! End-to-end tests of the `PSF_QUANT` storage tiers: cached-session
//! resume through the frozen prompt-prefix cache (bitwise under `off`,
//! bounded drift under `f16`), int8 weight gating, and the arena's
//! generation-tag aliasing guarantee.
//!
//! These tests flip the process-global quant mode (`quant::force_mode`),
//! which is why they live in their own integration binary instead of the
//! lib unit tests: this process runs nothing else.  Tests inside the
//! binary still run on parallel threads, so every test serializes on
//! [`mode_lock`] and restores env-driven selection on drop.

use std::sync::{Mutex, MutexGuard, OnceLock};

use polysketchformer::attn::Mechanism;
use polysketchformer::infer::{DecodeSession, GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::mem::quant::{self, QuantMode};
use polysketchformer::mem::{FrozenRow, FrozenState, StateArena};
use polysketchformer::serve::cache::{CacheKey, PromptCache};
use polysketchformer::util::rng::Pcg;

/// Serialize quant-mode flips across this binary's test threads; the
/// guard drops the mode back to env-driven selection afterwards.
struct ModeGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for ModeGuard {
    fn drop(&mut self) {
        quant::reset_mode();
    }
}

fn mode_lock(mode: QuantMode) -> ModeGuard {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let guard = LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner());
    quant::force_mode(mode);
    ModeGuard(guard)
}

fn all_mechanisms() -> Vec<Mechanism> {
    vec![
        Mechanism::Softmax,
        Mechanism::Flash { block: 8 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
        Mechanism::Performer { m: 16, block: 8 },
    ]
}

fn model(mech: Mechanism) -> NativeLm {
    let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 7 };
    NativeLm::new(cfg, mech)
}

/// A prompt long enough to cross block boundaries (blocks of 8) so the
/// linear mechanisms carry both absorbed prefix moments and a ragged
/// in-progress tail into the freeze.
fn prompt() -> Vec<u32> {
    std::iter::once(0u32).chain((0..42u32).map(|i| 1 + (i * 13) % 60)).collect()
}

fn req(prompt: Vec<u32>, max_new: usize, seed: u64) -> GenRequest {
    GenRequest {
        prompt,
        max_new_tokens: max_new,
        policy: SamplePolicy::Temperature(0.8),
        seed,
    }
}

/// Resume a request through the prompt-prefix cache: prefill once with a
/// zero-token request, freeze into the cache, hit it, thaw, and decode.
fn resume_via_cache(m: &NativeLm, cache: &PromptCache, r: GenRequest) -> DecodeSession {
    let key = CacheKey { mech: m.mech.label(), prompt: r.prompt.clone() };
    let prefilled =
        DecodeSession::new(m, 0, GenRequest { max_new_tokens: 0, ..r.clone() });
    cache.insert(key.clone(), cache.freeze(&prefilled));
    let snap = cache.get(&key).expect("entry just inserted");
    let (states, logits) = snap.thaw(m);
    let mut session = DecodeSession::from_prefix(1, r, states, logits);
    session.run_to_completion(m);
    session
}

#[test]
fn off_mode_cached_resume_is_bitwise_for_every_mechanism() {
    let _mode = mode_lock(QuantMode::Off);
    for mech in all_mechanisms() {
        let m = model(mech.clone());
        let cache = PromptCache::new(32 << 20);
        let mut direct = DecodeSession::new(&m, 0, req(prompt(), 10, 99));
        direct.run_to_completion(&m);
        let cached = resume_via_cache(&m, &cache, req(prompt(), 10, 99));
        assert_eq!(
            cached.generated(),
            direct.generated(),
            "{}: off-mode cached resume diverged",
            mech.label()
        );
        // Down to the final logits, not just the sampled tokens.
        assert_eq!(
            cached.last_logits(),
            direct.last_logits(),
            "{}: off-mode final logits diverged",
            mech.label()
        );
    }
}

#[test]
fn f16_tier_resume_is_deterministic_and_tracks_f32() {
    let _mode = mode_lock(QuantMode::F16);
    for mech in all_mechanisms() {
        let m = model(mech.clone());

        // Oracle: freeze the same prefilled session by hand through the
        // spec'd f16 freeze/thaw (no cache involved) and decode.
        let prefilled = DecodeSession::new(&m, 0, req(prompt(), 0, 0));
        let arena = StateArena::new();
        let frozen: Vec<Vec<FrozenState>> = prefilled
            .states()
            .iter()
            .map(|l| {
                l.heads.iter().map(|h| FrozenState::freeze(h, QuantMode::F16, &arena)).collect()
            })
            .collect();
        let logits_row = FrozenRow::freeze(prefilled.last_logits(), QuantMode::F16, &arena);
        let states = prefilled
            .states()
            .iter()
            .enumerate()
            .map(|(li, _)| polysketchformer::infer::LayerState {
                heads: frozen[li]
                    .iter()
                    .zip(&m.kernels()[li])
                    .map(|(f, k)| f.thaw(k))
                    .collect(),
            })
            .collect();
        let thawed_logits = logits_row.thaw();
        // f16 narrowing of the logits row stays within half-ulp bounds.
        for (x, y) in thawed_logits.iter().zip(prefilled.last_logits()) {
            assert!(
                (x - y).abs() <= 1e-2 * (1.0 + y.abs()),
                "{}: f16 logits drift {x} vs {y}",
                mech.label()
            );
        }
        let mut oracle = DecodeSession::from_prefix(2, req(prompt(), 10, 99), states, thawed_logits);
        oracle.run_to_completion(&m);

        // Serving path: same request resumed through the cache's frozen
        // tier must match the hand-built oracle token for token (the
        // freeze is deterministic, so there is exactly one right answer).
        let cache = PromptCache::new(32 << 20);
        let cached = resume_via_cache(&m, &cache, req(prompt(), 10, 99));
        assert_eq!(
            cached.generated(),
            oracle.generated(),
            "{}: f16 cached resume diverged from the freeze/thaw oracle",
            mech.label()
        );
        assert_eq!(cached.last_logits(), oracle.last_logits(), "{}", mech.label());
    }
}

#[test]
fn f16_tier_compacts_subblock_linear_prefixes_by_3x() {
    // The admission-pressure payoff the memory sweep gates on: a linear
    // mechanism's sub-block prefix (Z still elided, tail stored as raw+v
    // halves) must freeze at least 3x smaller than the exact image.
    let mech = Mechanism::Polysketch { r: 4, p: 4, block: 32, local: true };
    let short: Vec<u32> = std::iter::once(0u32).chain((0..26u32).map(|i| 1 + i)).collect();
    let f32_bytes;
    let f16_bytes;
    {
        let _mode = mode_lock(QuantMode::Off);
        let m = model(mech.clone());
        let cache = PromptCache::new(32 << 20);
        f32_bytes = cache.freeze(&DecodeSession::new(&m, 0, req(short.clone(), 0, 0))).bytes();
    }
    {
        let _mode = mode_lock(QuantMode::F16);
        let m = model(mech);
        let cache = PromptCache::new(32 << 20);
        let snap = cache.freeze(&DecodeSession::new(&m, 0, req(short, 0, 0)));
        assert!(snap.is_f16());
        f16_bytes = snap.bytes();
    }
    let ratio = f32_bytes as f64 / f16_bytes as f64;
    assert!(ratio >= 3.0, "sub-block compact tier ratio {ratio:.2} < 3x");
}

#[test]
fn q8_weights_gate_on_mode_and_requantize() {
    // Baseline logits under `off`.
    let baseline = {
        let _mode = mode_lock(QuantMode::Off);
        let m = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let mut s = DecodeSession::new(&m, 0, req(prompt(), 3, 5));
        s.run_to_completion(&m);
        s.last_logits().to_vec()
    };

    let _mode = mode_lock(QuantMode::Q8);
    assert_eq!(quant::mode().label(), "q8");
    assert!(quant::mode().q8_weights());
    assert!(quant::mode().f16_cold_tier(), "q8 implies the f16 cold tier");
    let mut m = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
    // The constructor already quantized under the active mode; calling
    // again must be idempotent (the training loop calls it every step).
    m.requantize();
    let mut s = DecodeSession::new(&m, 0, req(prompt(), 3, 5));
    s.run_to_completion(&m);
    let q8_logits = s.last_logits().to_vec();

    // int8 decode tracks f32 closely but not bitwise.
    let (mut dist2, mut norm2) = (0.0f64, 0.0f64);
    for (x, y) in q8_logits.iter().zip(&baseline) {
        dist2 += ((x - y) as f64).powi(2);
        norm2 += (*y as f64).powi(2);
    }
    let (dist, norm) = (dist2.sqrt(), norm2.sqrt());
    assert!(dist <= 0.15 * norm + 0.05, "q8 drifted too far: {dist:.4} vs norm {norm:.4}");

    // Dropping back to `off` and requantizing clears the int8 twins:
    // decode returns to the bitwise f32 path.
    quant::force_mode(QuantMode::Off);
    m.requantize();
    let mut s = DecodeSession::new(&m, 0, req(prompt(), 3, 5));
    s.run_to_completion(&m);
    assert_eq!(s.last_logits(), &baseline[..], "off-mode decode must be bitwise again");
}

#[test]
fn generation_tags_kill_stale_handles_through_reuse() {
    // Pure arena level: a handle dies the moment its buffer drops, and
    // slot reuse can never resurrect it.
    let arena = StateArena::new();
    let a = arena.alloc_copy(&[1.0, 2.0, 3.0, 4.0]);
    let stale = a.handle();
    assert!(arena.is_live(stale));
    drop(a);
    assert!(!arena.is_live(stale), "dropped buffer left a live handle");
    let b = arena.alloc_zeroed(4);
    assert!(!arena.is_live(stale), "slot reuse resurrected a stale handle");
    assert!(arena.is_live(b.handle()));
    if b.handle().slot == stale.slot {
        assert_ne!(b.handle().gen, stale.gen, "reuse must bump the generation");
    }
    assert!(arena.stats().gen_bumps >= 1);

    // Frozen-state level: eviction (drop) of a cache entry invalidates
    // handles captured while it was resident.
    let mech = Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true };
    let kernel = mech.build_kernel(8, &mut Pcg::seeded(3));
    let mut rng = Pcg::seeded(4);
    let mut st = kernel.new_state();
    for _ in 0..11 {
        let (q, k, v) = (rng.gaussians(8), rng.gaussians(8), rng.gaussians(8));
        kernel.step(&q, &k, &v, &mut st);
    }
    let frozen = FrozenState::freeze(&st, QuantMode::Off, &arena);
    let h = frozen.handle();
    assert!(arena.is_live(h));
    drop(frozen);
    assert!(!arena.is_live(h), "evicted frozen state left a live handle");
}
