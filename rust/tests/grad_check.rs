//! Finite-difference gradient checks for the native training subsystem.
//!
//! Three levels, all at the repo's ragged-tail fixture shapes (n = 13
//! against block 8, head_dim 8) so every code path — full blocks, ragged
//! tail, local-exact diagonals — carries gradient:
//!
//! * **kernel level** — for all six mechanisms, `CausalKernel::vjp`'s
//!   dq/dk/dv against central differences of a linear functional of the
//!   forward output, at every input coordinate;
//! * **model level, directional** — the full `compute_grads` gradient
//!   projected on its own direction vs the central difference of the
//!   masked-CE loss along that direction, for all six mechanisms;
//! * **model level, elementwise** — a sample of individual parameter
//!   coordinates across every named tensor.
//!
//! Per-op checks (layernorm, GELU, matmul adjoints, RoPE, sketch
//! recursion, performer features, feature maps, cross-entropy) live next
//! to their implementations as unit tests; this file is the integration
//! gate.  Tolerance: relative error < 1e-2 (with a unit floor to keep
//! f32 forward noise from failing near-zero derivatives).

use polysketchformer::attn::kernel::Mechanism;
use polysketchformer::infer::{LmConfig, NativeLm};
use polysketchformer::tensor::Tensor;
use polysketchformer::train::grad::masked_cross_entropy;
use polysketchformer::train::{compute_grads, forward_tape, TrainExample};
use polysketchformer::util::rng::Pcg;

fn mechanisms() -> Vec<Mechanism> {
    vec![
        Mechanism::Softmax,
        Mechanism::Flash { block: 8 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
        Mechanism::Performer { m: 16, block: 8 },
    ]
}

fn fd_close(fd: f64, an: f64, ctx: &str) {
    assert!(
        (fd - an).abs() <= 1e-2 * (1.0 + fd.abs().max(an.abs())),
        "{ctx}: fd {fd} vs analytic {an}"
    );
}

#[test]
fn kernel_vjp_matches_finite_difference_all_mechanisms() {
    let (n, h) = (13usize, 8usize);
    let mut rng = Pcg::seeded(71);
    let q = Tensor::gaussian(&mut rng, &[n, h]);
    let k = Tensor::gaussian(&mut rng, &[n, h]);
    let v = Tensor::gaussian(&mut rng, &[n, h]);
    // Fixed probe: loss = Σ W ⊙ out.
    let w = Tensor::gaussian(&mut rng, &[n, h]);
    for mech in mechanisms() {
        let kernel = mech.build_kernel(h, &mut Pcg::seeded(17));
        let loss = |q: &Tensor, k: &Tensor, v: &Tensor| -> f64 {
            kernel
                .forward(q, k, v)
                .data()
                .iter()
                .zip(w.data())
                .map(|(&o, &c)| (o as f64) * (c as f64))
                .sum()
        };
        let mut dq = Tensor::zeros(&[n, h]);
        let mut dk = Tensor::zeros(&[n, h]);
        let mut dv = Tensor::zeros(&[n, h]);
        kernel.vjp(
            &q.view(),
            &k.view(),
            &v.view(),
            &w.view(),
            &mut dq.view_mut(),
            &mut dk.view_mut(),
            &mut dv.view_mut(),
        );
        let eps = 2e-3f32;
        let inputs: [(&Tensor, &Tensor, &str); 3] =
            [(&q, &dq, "dq"), (&k, &dk, "dk"), (&v, &dv, "dv")];
        for (x, dx, name) in inputs {
            for i in 0..n {
                for j in 0..h {
                    let mut xp = x.clone();
                    xp.set2(i, j, xp.at2(i, j) + eps);
                    let mut xm = x.clone();
                    xm.set2(i, j, xm.at2(i, j) - eps);
                    let (fp, fm) = match name {
                        "dq" => (loss(&xp, &k, &v), loss(&xm, &k, &v)),
                        "dk" => (loss(&q, &xp, &v), loss(&q, &xm, &v)),
                        _ => (loss(&q, &k, &xp), loss(&q, &k, &xm)),
                    };
                    let fd = (fp - fm) / (2.0 * eps as f64);
                    fd_close(
                        fd,
                        dx.at2(i, j) as f64,
                        &format!("{} {name}[{i},{j}]", mech.label()),
                    );
                }
            }
        }
    }
}

fn tiny_model(mech: Mechanism) -> NativeLm {
    let cfg = LmConfig { vocab: 32, d_model: 16, layers: 2, heads: 2, ff_mult: 2, seed: 5 };
    NativeLm::new(cfg, mech)
}

fn example() -> TrainExample {
    // n = 13 against block 8 — the ragged-tail fixture shape.
    let tokens: Vec<u32> = (0..14u32).map(|i| (i * 7 + 3) % 32).collect();
    TrainExample { tokens, mask: vec![true; 13] }
}

/// Mean masked CE of one example through the inference forward.
fn mean_loss(model: &NativeLm, ex: &TrainExample) -> f64 {
    let (logits, _) = forward_tape(model, ex.inputs());
    let ce = masked_cross_entropy(&logits, ex.targets(), &ex.mask);
    ce.loss_sum / ce.counted as f64
}

#[test]
fn model_gradient_directional_check_all_mechanisms() {
    let ex = example();
    for mech in mechanisms() {
        let mut model = tiny_model(mech.clone());
        let (grads, stats) = compute_grads(&model, std::slice::from_ref(&ex));
        assert!(stats.loss.is_finite());
        let gnorm = grads.l2_norm_sq().sqrt();
        assert!(gnorm > 0.0, "{}: zero gradient", mech.label());
        // Direction u = g / |g|; analytic directional derivative = |g|.
        let mut u = grads.clone();
        u.scale_in_place((1.0 / gnorm) as f32);
        let eps = 5e-3f32;
        let base = model.params().clone();
        let mut plus = base.clone();
        plus.add_scaled(&u, eps);
        let mut minus = base.clone();
        minus.add_scaled(&u, -eps);
        model.set_params(plus);
        let lp = mean_loss(&model, &ex);
        model.set_params(minus);
        let lm = mean_loss(&model, &ex);
        model.set_params(base);
        let fd = (lp - lm) / (2.0 * eps as f64);
        let rel = (fd - gnorm).abs() / gnorm.max(fd.abs()).max(1e-8);
        assert!(
            rel < 1e-2,
            "{}: directional derivative {fd} vs |g| {gnorm} (rel {rel})",
            mech.label()
        );
    }
}

#[test]
fn model_gradient_elementwise_spot_checks() {
    // A sample of coordinates from every named tensor, for one linear and
    // one quadratic mechanism (the directional test covers all six).
    let ex = example();
    for mech in [
        Mechanism::Softmax,
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
    ] {
        let mut model = tiny_model(mech.clone());
        let (grads, _) = compute_grads(&model, std::slice::from_ref(&ex));
        let names: Vec<String> =
            grads.named().into_iter().map(|(n, _)| n).collect();
        let mut rng = Pcg::seeded(99);
        for name in names {
            for _ in 0..3 {
                let (len, an, idx) = {
                    let named = grads.named();
                    let t = &named.iter().find(|(n, _)| n == &name).unwrap().1;
                    let len = t.len();
                    let idx = rng.usize_below(len);
                    (len, t.data()[idx] as f64, idx)
                };
                assert!(idx < len);
                let eps = 2e-3f32;
                let base = model.params().clone();
                let mut perturb = |delta: f32, model: &mut NativeLm| -> f64 {
                    let mut p = base.clone();
                    for (n, t) in p.named_mut() {
                        if n == name {
                            t.data_mut()[idx] += delta;
                        }
                    }
                    model.set_params(p);
                    mean_loss(model, &ex)
                };
                let lp = perturb(eps, &mut model);
                let lm = perturb(-eps, &mut model);
                model.set_params(base);
                let fd = (lp - lm) / (2.0 * eps as f64);
                fd_close(fd, an, &format!("{} {name}[{idx}]", mech.label()));
            }
        }
    }
}
