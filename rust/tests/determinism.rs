//! Determinism matrix for the parallel compute backend (`exec::pool`).
//!
//! The backend's contract is that results are **bitwise identical** at
//! every thread count.  Each test computes the same quantity twice — once
//! on the live pool (sized by `PSF_THREADS`, which CI additionally pins
//! to 2 in a dedicated job) and once under `pool::serial`, the forced
//! 1-thread inline execution — and asserts byte equality, for all six
//! mechanisms, at the three levels the serving stack exposes:
//!
//! * forward logits (prefill path: padded layers, parallel heads, tiled
//!   matmuls);
//! * full decode sessions (prefill + sampler + recurrent/KV stepping);
//! * a served request through the gateway (worker threads + prompt cache
//!   on top of the backend) against the single-threaded oracle.
//!
//! A final test flips the global pool size itself (1 → 2 → 8) and checks
//! the logits never move.

use polysketchformer::attn::Mechanism;
use polysketchformer::exec::pool;
use polysketchformer::infer::{
    DecodeSession, GenRequest, LmConfig, NativeLm, Params, SamplePolicy,
};
use polysketchformer::serve::{collect_stream, Gateway, GatewayConfig};
use polysketchformer::train::{compute_grads, AdamW, OptimConfig, TrainExample};

fn mechanisms() -> Vec<Mechanism> {
    vec![
        Mechanism::Softmax,
        Mechanism::Flash { block: 8 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
        Mechanism::Performer { m: 16, block: 8 },
    ]
}

fn lm(mech: Mechanism) -> NativeLm {
    // Large enough (64 x 77 prompt rows, 4 heads) that the matmul tiles,
    // row kernels, and head fan-out all actually engage the pool.
    let cfg = LmConfig { vocab: 64, d_model: 64, layers: 2, heads: 4, ff_mult: 2, seed: 33 };
    NativeLm::new(cfg, mech)
}

fn prompt(n: usize) -> Vec<u32> {
    std::iter::once(0u32).chain((1..n as u32).map(|i| i.wrapping_mul(23) % 64)).collect()
}

#[test]
fn forward_logits_bitwise_identical_serial_vs_parallel() {
    // 77 is odd on purpose: it exercises the padded tail partition too.
    let tokens = prompt(77);
    for mech in mechanisms() {
        let model = lm(mech.clone());
        let pooled = model.forward(&tokens);
        let inline = pool::serial(|| model.forward(&tokens));
        assert_eq!(pooled, inline, "{}: logits depend on thread count", mech.label());
    }
}

#[test]
fn decode_sessions_bitwise_identical_serial_vs_parallel() {
    let req = |seed| GenRequest {
        prompt: prompt(21),
        max_new_tokens: 12,
        policy: SamplePolicy::TopP { p: 0.9, temperature: 0.8 },
        seed,
    };
    for mech in mechanisms() {
        let model = lm(mech.clone());
        let mut pooled = DecodeSession::new(&model, 0, req(7));
        pooled.run_to_completion(&model);
        let inline = pool::serial(|| {
            let mut s = DecodeSession::new(&model, 1, req(7));
            s.run_to_completion(&model);
            s
        });
        assert_eq!(pooled.tokens, inline.tokens, "{}: token stream diverged", mech.label());
        assert_eq!(
            pooled.snapshot().last_logits,
            inline.snapshot().last_logits,
            "{}: final logits diverged",
            mech.label()
        );
    }
}

#[test]
fn served_request_matches_single_threaded_oracle() {
    // End to end: gateway (2 decode workers + prompt cache) over the live
    // pool vs a lone session stepped entirely inline.  Byte equality here
    // subsumes thread count, worker interleaving, and cache restore.
    let req = || GenRequest {
        prompt: prompt(33),
        max_new_tokens: 10,
        policy: SamplePolicy::Temperature(0.7),
        seed: 41,
    };
    for mech in mechanisms() {
        let g = Gateway::new(
            lm(mech.clone()),
            GatewayConfig { workers: 2, ..GatewayConfig::default() },
        )
        .unwrap();
        let (served, stats) = collect_stream(g.submit(req()).unwrap());
        assert_eq!(stats.expect("done event").generated, served);
        g.finish().unwrap();

        let model = lm(mech.clone());
        let oracle = pool::serial(|| {
            let mut s = DecodeSession::new(&model, 0, req());
            s.run_to_completion(&model);
            s.generated().to_vec()
        });
        assert_eq!(served, oracle, "{}: served stream != 1-thread oracle", mech.label());
    }
}

fn train_batch() -> Vec<TrainExample> {
    // Two ragged-length examples so the per-example fan-out has real work.
    [21usize, 13]
        .iter()
        .map(|&n| TrainExample {
            tokens: prompt(n + 1),
            mask: (0..n).map(|i| i % 3 != 0).collect(),
        })
        .collect()
}

/// One gradient computation + two AdamW steps; returns (grad bits of the
/// first step, post-update weight bits) for byte comparison.
fn train_step_bits(mech: Mechanism) -> (Vec<u32>, Vec<u32>) {
    let mut model = lm(mech);
    let mut opt = AdamW::new(
        OptimConfig { total_steps: 4, warmup: 1, ..OptimConfig::default() },
        model.params(),
    );
    let batch = train_batch();
    let (grads, _) = compute_grads(&model, &batch);
    let grad_bits = param_bits(&grads);
    opt.step(model.params_mut(), &grads);
    let (grads2, _) = compute_grads(&model, &batch);
    opt.step(model.params_mut(), &grads2);
    (grad_bits, param_bits(model.params()))
}

fn param_bits(p: &Params) -> Vec<u32> {
    p.named()
        .iter()
        .flat_map(|(_, t)| t.data().iter().map(|v| v.to_bits()))
        .collect()
}

#[test]
fn train_step_bitwise_identical_serial_vs_parallel() {
    // The PR 3 guarantee, extended to training: per-example gradients fan
    // out over the pool but reduce sequentially in example order, and the
    // optimizer is sequential scalar math — so gradient bytes and
    // post-AdamW weight bytes cannot depend on the thread count.
    for mech in mechanisms() {
        let pooled = train_step_bits(mech.clone());
        let inline = pool::serial(|| train_step_bits(mech.clone()));
        assert_eq!(pooled.0, inline.0, "{}: gradient bytes moved", mech.label());
        assert_eq!(pooled.1, inline.1, "{}: post-AdamW weights moved", mech.label());
    }
}

#[test]
fn train_step_invariant_across_pool_resizes() {
    let mech = Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true };
    let baseline = train_step_bits(mech.clone());
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let got = train_step_bits(mech.clone());
        assert_eq!(got.0, baseline.0, "threads={t}: gradient bytes moved");
        assert_eq!(got.1, baseline.1, "threads={t}: post-AdamW weights moved");
    }
    pool::set_threads(pool::default_threads());
}

#[test]
fn logits_invariant_across_pool_resizes() {
    // Resize the global pool through the PSF_THREADS matrix {1, 2, 8} and
    // back; the bytes must never move.  (Safe mid-suite: by contract a
    // resize only changes wall time, and in-flight calls on the old pool
    // self-complete.)
    let tokens = prompt(49);
    let model = lm(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
    let baseline = model.forward(&tokens);
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        assert_eq!(pool::threads(), t);
        let got = model.forward(&tokens);
        assert_eq!(got, baseline, "threads={t}: logits moved");
    }
    pool::set_threads(pool::default_threads());
}
