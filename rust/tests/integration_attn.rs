//! NOTE: every test here is `#[ignore]`d for tier-1 runs: they exercise
//! AOT artifacts through PJRT, which needs `make artifacts` (Python/JAX
//! toolchain) and the real xla_extension bindings in place of the offline
//! stub under rust/vendor/xla.  Run with `cargo test -- --ignored` once
//! both are available.

//! Cross-layer attention correctness: the AOT Pallas softmax kernel run
//! through PJRT must match the native rust implementation on the same
//! inputs — closing the loop L1 (Pallas) -> HLO -> rust against L3 native.
//!
//! (The polysketch artifacts bake *random sketch matrices* into the HLO, so
//! their outputs are only statistically comparable — covered by the
//! python-side pytest against the jnp oracle and by the AMM-error bench.)

use polysketchformer::attn::softmax::softmax_attention;
use polysketchformer::runtime;
use polysketchformer::tensor::Tensor;
use polysketchformer::util::rng::Pcg;

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn pallas_softmax_artifact_matches_native_rust() {
    let micro = runtime::load_attn("attn_softmax_pallas_n128").unwrap_or_else(|e| {
        panic!("run `make artifacts` first: {e:#}")
    });
    let (heads, n, hd) = (micro.heads, micro.n, micro.head_dim);
    let numel = heads * n * hd;

    let mut rng = Pcg::seeded(0);
    let q: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();
    let k: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();
    let v: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();

    let got = micro.run(&q, &k, &v).unwrap();
    assert_eq!(got.len(), numel);

    let mut max_dev = 0.0f32;
    for h in 0..heads {
        let slice = |x: &[f32]| {
            Tensor::from_vec(&[n, hd], x[h * n * hd..(h + 1) * n * hd].to_vec())
        };
        let want = softmax_attention(&slice(&q), &slice(&k), &slice(&v));
        for (g, w) in got[h * n * hd..(h + 1) * n * hd].iter().zip(want.data()) {
            max_dev = max_dev.max((g - w).abs());
        }
    }
    assert!(
        max_dev < 2e-4,
        "Pallas-softmax vs native-rust max deviation {max_dev}"
    );
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn pallas_poly_artifact_matches_native_rust() {
    let micro = runtime::load_attn("attn_poly_pallas_n128").unwrap();
    let (heads, n, hd) = (micro.heads, micro.n, micro.head_dim);
    let numel = heads * n * hd;
    let p = micro.manifest.cfg_usize("degree").unwrap() as u32;

    let mut rng = Pcg::seeded(1);
    let q: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();
    let k: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();
    let v: Vec<f32> = (0..numel).map(|_| rng.gaussian() * 0.5).collect();

    let got = micro.run(&q, &k, &v).unwrap();
    let mut max_dev = 0.0f32;
    for h in 0..heads {
        let slice = |x: &[f32]| {
            Tensor::from_vec(&[n, hd], x[h * n * hd..(h + 1) * n * hd].to_vec())
        };
        let want = polysketchformer::attn::poly::poly_attention(
            &slice(&q),
            &slice(&k),
            &slice(&v),
            p,
        );
        for (g, w) in got[h * n * hd..(h + 1) * n * hd].iter().zip(want.data()) {
            max_dev = max_dev.max((g - w).abs());
        }
    }
    assert!(
        max_dev < 2e-4,
        "Pallas-poly vs native-rust max deviation {max_dev}"
    );
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn polysketch_artifact_is_nonnegative_normalized() {
    // Even without bitwise comparison (random sketches live in the HLO),
    // the polysketch artifact's output must be a convex-ish combination of
    // value rows: bounded by value extrema row-wise per head.
    let micro = runtime::load_attn("attn_polysketch_pallas_n128").unwrap();
    let numel = micro.numel();
    let mut rng = Pcg::seeded(2);
    let q: Vec<f32> = (0..numel).map(|_| rng.gaussian()).collect();
    let k: Vec<f32> = (0..numel).map(|_| rng.gaussian()).collect();
    let v: Vec<f32> = (0..numel).map(|_| rng.gaussian()).collect();
    let out = micro.run(&q, &k, &v).unwrap();
    assert!(out.iter().all(|x| x.is_finite()));
    let vmax = v.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let vmin = v.iter().copied().fold(f32::INFINITY, f32::min);
    // The "1 +" denominator shrinks rows toward zero, so outputs stay
    // within the value range (slack for fp noise).
    for &o in &out {
        assert!(o <= vmax + 1e-3 && o >= vmin - 1e-3, "out {o} outside [{vmin},{vmax}]");
    }
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn distinct_mechanism_artifacts_produce_distinct_outputs() {
    // Regression test for the constant-elision bug: as_hlo_text() by
    // default prints large literals as `constant({...})`, which the
    // xla_extension 0.5.1 text parser silently reads as ZEROS — nulling
    // every baked static (RoPE tables, random sketches) and making all
    // polysketch variants compute the same attention-free function.
    // aot.py now lowers with print_large_constants=True; this test pins
    // the behavior: two different tiny psk artifacts must diverge.
    use polysketchformer::runtime::{self, LoadOpts};
    let a = runtime::load_model("tiny_psk", LoadOpts::fwd_only()).unwrap();
    let b = runtime::load_model("tiny_psk_random", LoadOpts::fwd_only()).unwrap();
    let toks: Vec<i32> = (0..a.batch() * a.ctx()).map(|i| 1 + (i as i32 * 7) % 63).collect();
    let oa = a.forward(&toks).unwrap();
    let ob = b.forward(&toks).unwrap();
    let max_dev = oa
        .iter()
        .zip(&ob)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_dev > 1e-4,
        "learned vs random sketch artifacts are bit-identical (max dev {max_dev}) — \
         baked constants are being elided from the HLO text again"
    );
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla_extension backend"]
fn rope_tables_survive_the_hlo_text_roundtrip() {
    // Second regression angle: the model's attention must actually depend
    // on token *positions* (RoPE + sinusoidal tables are baked statics).
    // With zeroed tables, swapping two distant input tokens changes logits
    // only at those positions' own rows through token identity, not
    // through attention distance — in particular the LAST row (which
    // attends to everything) must change when an early token moves.
    use polysketchformer::runtime::{self, LoadOpts};
    let m = runtime::load_model("tiny_softmax", LoadOpts::fwd_only()).unwrap();
    let (bsz, ctx, vocab) = (m.batch(), m.ctx(), m.vocab());
    let base: Vec<i32> = (0..bsz * ctx).map(|i| 1 + (i as i32 * 11) % 63).collect();
    // Swap positions 2 and 3 in row 0 (same multiset of tokens).
    let mut swapped = base.clone();
    swapped.swap(2, 3);
    let oa = m.forward(&base).unwrap();
    let ob = m.forward(&swapped).unwrap();
    // Compare the final position's logits of row 0.
    let last = &oa[(ctx - 1) * vocab..ctx * vocab];
    let last_b = &ob[(ctx - 1) * vocab..ctx * vocab];
    let dev = last
        .iter()
        .zip(last_b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(
        dev > 1e-6,
        "swapping early tokens does not reach the last position ({dev}) — \
         positional statics look dead"
    );
}
