//! Integration tests for the serving gateway (`rust/src/serve/`) — no
//! artifacts, pure native path, real threads, and (for the last test) a
//! real TCP socket.
//!
//! The two serving-level contracts pinned here, for every mechanism:
//!
//! * cache parity — a request served from the prompt-prefix cache returns
//!   a byte-identical token stream to the cold-path request at matched
//!   (seed, policy): restoring a constant-size state is indistinguishable
//!   from re-running the prefill;
//! * scheduling independence — concurrent multi-worker serving returns
//!   the same completions as sequential single-slot scheduling: requests
//!   own their sessions, so thread interleaving can never leak between
//!   token streams.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use polysketchformer::attn::Mechanism;
use polysketchformer::infer::{
    GenRequest, LmConfig, NativeLm, SamplePolicy, Scheduler, SchedulerConfig,
};
use polysketchformer::serve::{collect_stream, Gateway, GatewayConfig, Rejected};

fn mechanisms() -> Vec<Mechanism> {
    vec![
        Mechanism::Softmax,
        Mechanism::Flash { block: 8 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
        Mechanism::Performer { m: 16, block: 8 },
    ]
}

fn lm(mech: Mechanism) -> NativeLm {
    let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 21 };
    NativeLm::new(cfg, mech)
}

#[test]
fn cache_hit_stream_is_byte_identical_for_every_mechanism() {
    for mech in mechanisms() {
        let g = Gateway::new(
            lm(mech.clone()),
            GatewayConfig { workers: 2, ..GatewayConfig::default() },
        )
        .unwrap();
        let req = |seed| GenRequest {
            prompt: vec![0, 5, 9, 3, 27, 14, 60, 2, 8, 19, 44],
            max_new_tokens: 8,
            policy: SamplePolicy::TopP { p: 0.9, temperature: 0.8 },
            seed,
        };
        let (cold, cold_stats) = collect_stream(g.submit(req(7)).unwrap());
        let cold_stats = cold_stats.expect("cold done event");
        assert!(!cold_stats.cache_hit, "{}: first request cannot hit", mech.label());
        assert_eq!(cold_stats.generated, cold);

        let (warm, warm_stats) = collect_stream(g.submit(req(7)).unwrap());
        let warm_stats = warm_stats.expect("warm done event");
        assert!(warm_stats.cache_hit, "{}: repeat prompt must hit", mech.label());
        assert_eq!(warm_stats.prefill_secs, 0.0, "{}: hit must skip prefill", mech.label());
        assert_eq!(cold, warm, "{}: cache-hit stream diverged from cold path", mech.label());

        // Same cached prefix, different sampling seed: still a hit, and
        // the stream is the seed's own, not a replay of the cold one.
        let (other, other_stats) = collect_stream(g.submit(req(8)).unwrap());
        assert!(other_stats.expect("done").cache_hit);
        assert_ne!(other, cold, "{}: seed must drive the stream", mech.label());
        g.finish().unwrap();
    }
}

#[test]
fn concurrent_multiworker_serving_matches_sequential_scheduling() {
    // Identical weights (same LmConfig seed), identical requests: the
    // single-threaded tick-by-tick scheduler is the oracle for the
    // multi-threaded worker pool.
    for mech in [
        Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
        Mechanism::Softmax,
    ] {
        let reqs: Vec<GenRequest> = (0..6u64)
            .map(|i| GenRequest {
                // Distinct prompts (and two repeats to also exercise the
                // cache mid-traffic).
                prompt: match i {
                    4 => vec![0, 11, 7],
                    5 => vec![0, 11, 7],
                    _ => vec![0, 11, 7 + i as u32 * 5, 30 - i as u32],
                },
                max_new_tokens: 7 + (i as usize % 3),
                policy: SamplePolicy::Temperature(0.85),
                seed: 500 + i,
            })
            .collect();

        let oracle_model = lm(mech.clone());
        let mut sched = Scheduler::new(
            &oracle_model,
            SchedulerConfig { max_concurrent: 1, tick_tokens: 1, ..Default::default() },
        );
        for r in &reqs {
            sched.submit(r.clone());
        }
        let summary = sched.run().unwrap();
        let oracle: Vec<Vec<u32>> = summary
            .reports
            .iter()
            .map(|r| r.tokens[r.prompt_len..].to_vec())
            .collect();

        let g = Gateway::new(
            lm(mech.clone()),
            GatewayConfig { workers: 3, slice_tokens: 2, ..GatewayConfig::default() },
        )
        .unwrap();
        // Submit everything up front so sessions genuinely interleave
        // across the three workers, then drain the streams.
        let rxs: Vec<_> = reqs.iter().map(|r| g.submit(r.clone()).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let (tokens, stats) = collect_stream(rx);
            let stats = stats.expect("done event");
            assert_eq!(stats.id as usize, i);
            assert_eq!(
                tokens,
                oracle[i],
                "{}: request {i} diverged between 3-worker serving and sequential scheduling",
                mech.label()
            );
        }
        g.finish().unwrap();
    }
}

#[test]
fn admission_overflow_rejects_with_queue_full() {
    let g = Gateway::new(
        lm(Mechanism::Softmax),
        GatewayConfig { workers: 1, queue_cap: 1, max_resident: 1, ..GatewayConfig::default() },
    )
    .unwrap();
    // Long prompts make admission slow enough that a burst must overflow
    // the depth-1 queue; every admitted request still completes.
    let req = |seed| GenRequest {
        prompt: (0..200u32).map(|i| i % 60).collect(),
        max_new_tokens: 4,
        policy: SamplePolicy::Greedy,
        seed,
    };
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..8u64 {
        match g.submit(req(i)) {
            Ok(rx) => accepted.push(rx),
            Err(Rejected::QueueFull) => rejected += 1,
            Err(Rejected::Draining) => panic!("gateway is not draining"),
        }
    }
    assert!(rejected > 0, "burst of 8 into a depth-1 queue must reject");
    assert!(!accepted.is_empty());
    for rx in accepted {
        let (tokens, stats) = collect_stream(rx);
        assert_eq!(tokens.len(), 4);
        assert!(stats.is_some());
    }
    g.finish().unwrap();
    let rej = g.counters.rejected.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(rej as usize, rejected);
}

// ------------------------------------------------------------ HTTP layer

/// Minimal HTTP client: one request, read to EOF (server closes per
/// connection), return the raw response (headers + chunked body).
fn http_request(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    )
    .unwrap();
    let mut out = Vec::new();
    s.read_to_end(&mut out).expect("read response");
    String::from_utf8_lossy(&out).into_owned()
}

/// Extract the `"token":N` stream from a (possibly chunked) response body.
/// Each token line is emitted as one complete chunk, so the pattern is
/// never split across chunk framing.
fn token_stream(resp: &str) -> Vec<u32> {
    let mut out = Vec::new();
    let mut rest = resp;
    while let Some(pos) = rest.find("\"token\":") {
        rest = &rest[pos + "\"token\":".len()..];
        let digits: String = rest.chars().take_while(char::is_ascii_digit).collect();
        if let Ok(v) = digits.parse() {
            out.push(v);
        }
    }
    out
}

#[test]
fn http_end_to_end_cached_equals_uncached() {
    let g = Arc::new(
        Gateway::new(
            lm(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true }),
            GatewayConfig {
                addr: "127.0.0.1:0".into(),
                workers: 2,
                max_requests: 2,
                ..GatewayConfig::default()
            },
        )
        .unwrap(),
    );
    let server = {
        let g = Arc::clone(&g);
        std::thread::spawn(move || g.run_http())
    };
    let t0 = Instant::now();
    let addr = loop {
        if let Some(a) = g.http_addr() {
            break a;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "server did not bind");
        std::thread::sleep(Duration::from_millis(5));
    };

    let health = http_request(addr, "GET", "/healthz", "");
    assert!(health.starts_with("HTTP/1.1 200"), "{health}");
    assert!(health.contains("\"ok\":true"), "{health}");
    // The active microkernel backend is part of the liveness identity.
    assert!(health.contains("\"simd\":"), "{health}");

    let body = r#"{"prompt":"the polynomial kernel","max_tokens":12,"policy":"greedy","seed":3}"#;
    let cold = http_request(addr, "POST", "/v1/generate", body);
    assert!(cold.starts_with("HTTP/1.1 200"), "{cold}");
    assert!(cold.contains("Transfer-Encoding: chunked"), "{cold}");
    assert!(cold.contains("\"cache_hit\":false"), "{cold}");
    let warm = http_request(addr, "POST", "/v1/generate", body);
    assert!(warm.contains("\"cache_hit\":true"), "{warm}");

    let cold_tokens = token_stream(&cold);
    let warm_tokens = token_stream(&warm);
    assert_eq!(cold_tokens.len(), 12);
    assert_eq!(cold_tokens, warm_tokens, "cached and uncached streams must be identical");

    // max_requests = 2 -> the server drains and the thread joins cleanly.
    server.join().expect("server thread panicked").expect("run_http failed");
}

#[test]
fn http_error_paths() {
    let g = Arc::new(
        Gateway::new(
            lm(Mechanism::Performer { m: 16, block: 8 }),
            GatewayConfig {
                addr: "127.0.0.1:0".into(),
                workers: 1,
                max_requests: 1,
                ..GatewayConfig::default()
            },
        )
        .unwrap(),
    );
    let server = {
        let g = Arc::clone(&g);
        std::thread::spawn(move || g.run_http())
    };
    let t0 = Instant::now();
    let addr = loop {
        if let Some(a) = g.http_addr() {
            break a;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "server did not bind");
        std::thread::sleep(Duration::from_millis(5));
    };

    assert!(http_request(addr, "GET", "/nope", "").starts_with("HTTP/1.1 404"));
    assert!(http_request(addr, "DELETE", "/v1/generate", "").starts_with("HTTP/1.1 405"));
    assert!(http_request(addr, "POST", "/v1/generate", "{}").starts_with("HTTP/1.1 400"));
    assert!(http_request(addr, "POST", "/v1/generate", "not json").starts_with("HTTP/1.1 400"));
    let metrics = http_request(addr, "GET", "/metrics", "");
    assert!(metrics.contains("\"kind\":\"serve_metrics\""), "{metrics}");

    // One successful generate trips max_requests and shuts the server down.
    let ok = http_request(
        addr,
        "POST",
        "/v1/generate",
        r#"{"prompt":"x","max_tokens":3}"#,
    );
    assert!(ok.contains("\"done\":true"), "{ok}");
    server.join().expect("server thread panicked").expect("run_http failed");
}
