//! Small device-side elementwise computations built in rust via XlaBuilder.
//!
//! The data-parallel coordinator accumulates gradient vectors on-device
//! (`add`) and rescales the sum by 1/workers (`scale`) before the optimizer
//! update, so simulated allreduce never round-trips P floats to the host.
//! Compiled executables are cached per (op, length) on this thread.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{anyhow, Result};
use xla::{PjRtBuffer, Shape, XlaBuilder};

use super::exec::client;

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum OpKind {
    Add,
    Scale,
}

thread_local! {
    static CACHE: RefCell<HashMap<(OpKind, usize), std::rc::Rc<xla::PjRtLoadedExecutable>>> =
        RefCell::new(HashMap::new());
}

fn cached(kind: OpKind, n: usize) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
    CACHE.with(|c| {
        if let Some(exe) = c.borrow().get(&(kind, n)) {
            return Ok(exe.clone());
        }
        let builder = XlaBuilder::new(&format!("{kind:?}_{n}"));
        let shape = Shape::array::<f32>(vec![n as i64]);
        let x = builder
            .parameter_s(0, &shape, "x")
            .map_err(|e| anyhow!("builder param x: {e}"))?;
        let root = match kind {
            OpKind::Add => {
                let y = builder
                    .parameter_s(1, &shape, "y")
                    .map_err(|e| anyhow!("builder param y: {e}"))?;
                x.add_(&y).map_err(|e| anyhow!("builder add: {e}"))?
            }
            OpKind::Scale => {
                let c = builder
                    .parameter_s(1, &Shape::array::<f32>(vec![]), "c")
                    .map_err(|e| anyhow!("builder param c: {e}"))?;
                let cb = c
                    .broadcast(&[n as i64])
                    .map_err(|e| anyhow!("builder broadcast: {e}"))?;
                x.mul_(&cb).map_err(|e| anyhow!("builder mul: {e}"))?
            }
        };
        let comp = root.build().map_err(|e| anyhow!("builder build: {e}"))?;
        let exe = client()?
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {kind:?}[{n}]: {e}"))?;
        let exe = std::rc::Rc::new(exe);
        c.borrow_mut().insert((kind, n), exe.clone());
        Ok(exe)
    })
}

fn run1(exe: &std::rc::Rc<xla::PjRtLoadedExecutable>, args: &[&PjRtBuffer]) -> Result<PjRtBuffer> {
    exe.execute_b(args)
        .map_err(|e| anyhow!("elementwise exec: {e}"))?
        .into_iter()
        .next()
        .and_then(|r| r.into_iter().next())
        .ok_or_else(|| anyhow!("elementwise exec: empty result"))
}

/// Device-side `x + y` for two f32[n] buffers.
pub fn add(x: &PjRtBuffer, y: &PjRtBuffer, n: usize) -> Result<PjRtBuffer> {
    run1(&cached(OpKind::Add, n)?, &[x, y])
}

/// Device-side `x * c` for an f32[n] buffer and host scalar.
pub fn scale(x: &PjRtBuffer, c: f32, n: usize) -> Result<PjRtBuffer> {
    let cbuf = super::exec::to_device_f32(&[c], &[])?;
    run1(&cached(OpKind::Scale, n)?, &[x, &cbuf])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::exec::{to_device_f32, to_host_f32};

    #[test]
    fn add_and_scale_roundtrip() {
        let x = to_device_f32(&[1.0, 2.0, 3.0], &[3]).unwrap();
        let y = to_device_f32(&[10.0, 20.0, 30.0], &[3]).unwrap();
        let s = add(&x, &y, 3).unwrap();
        assert_eq!(to_host_f32(&s).unwrap(), vec![11.0, 22.0, 33.0]);
        let h = scale(&s, 0.5, 3).unwrap();
        assert_eq!(to_host_f32(&h).unwrap(), vec![5.5, 11.0, 16.5]);
    }

    #[test]
    fn cache_reuses_executables() {
        // Two calls with the same n must not recompile (observable only as
        // not-crashing + correctness; the cache is internal).
        for _ in 0..3 {
            let x = to_device_f32(&[2.0; 8], &[8]).unwrap();
            let out = scale(&x, 2.0, 8).unwrap();
            assert_eq!(to_host_f32(&out).unwrap(), vec![4.0; 8]);
        }
    }
}
