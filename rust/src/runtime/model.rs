//! ModelRuntime: a loaded model-artifact bundle with device-resident state.
//!
//! Wraps the four executables emitted per model config (train / stats /
//! evalloss / fwd) plus `init.bin`.  The fused state vector
//! `[theta | m | v | step | loss]` lives on the device; each train step
//! feeds the previous output buffer straight back in, and per-step metrics
//! come from the 8-byte `stats` output — the hot loop never moves
//! parameters over the host bridge.

use std::path::Path;

use anyhow::{anyhow, bail, Result};
use xla::PjRtBuffer;

use super::exec::{self, Executable};
use super::manifest::Manifest;

/// Per-step training statistics extracted from the state vector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepStats {
    /// Optimizer step count after the update.
    pub step: u64,
    /// Mean masked NLL of the step's batch.
    pub loss: f32,
}

pub struct ModelRuntime {
    pub manifest: Manifest,
    train: Option<Executable>,
    stats: Option<Executable>,
    evalloss: Option<Executable>,
    fwd: Option<Executable>,
    grads: Option<Executable>,
    gradstep: Option<Executable>,
    /// Device-resident fused state vector, size `manifest.state_size()`.
    state: PjRtBuffer,
}

/// Which executables to compile (compiling everything is the default but a
/// latency bench that only needs `fwd` can skip the rest).
#[derive(Clone, Copy, Debug)]
pub struct LoadOpts {
    pub train: bool,
    pub evalloss: bool,
    pub fwd: bool,
    /// grads + gradstep pair (data-parallel / microbatch accumulation).
    pub grads: bool,
}

impl Default for LoadOpts {
    fn default() -> Self {
        LoadOpts { train: true, evalloss: true, fwd: true, grads: false }
    }
}

impl LoadOpts {
    pub fn none() -> Self {
        LoadOpts { train: false, evalloss: false, fwd: false, grads: false }
    }

    pub fn train_only() -> Self {
        LoadOpts { train: true, ..Self::none() }
    }

    pub fn eval_only() -> Self {
        LoadOpts { evalloss: true, ..Self::none() }
    }

    pub fn fwd_only() -> Self {
        LoadOpts { fwd: true, ..Self::none() }
    }

    pub fn grads_only() -> Self {
        LoadOpts { grads: true, ..Self::none() }
    }

    pub fn with_grads(mut self) -> Self {
        self.grads = true;
        self
    }

    pub fn with_fwd(mut self) -> Self {
        self.fwd = true;
        self
    }

    pub fn with_evalloss(mut self) -> Self {
        self.evalloss = true;
        self
    }
}

impl ModelRuntime {
    /// Load a model bundle from its manifest, compiling the selected
    /// executables and initializing device state from `init.bin`.
    pub fn load(manifest: Manifest, opts: LoadOpts) -> Result<ModelRuntime> {
        if manifest.kind != "model" {
            bail!("{}: kind {} is not a model bundle", manifest.name, manifest.kind);
        }
        let compile = |role: &str| -> Result<Executable> {
            Executable::load(&manifest.file(role)?)
        };
        let train = if opts.train { Some(compile("train")?) } else { None };
        // stats is tiny; compile it whenever stepping (loss readback).
        let stats = if opts.train || opts.grads { Some(compile("stats")?) } else { None };
        let evalloss = if opts.evalloss { Some(compile("evalloss")?) } else { None };
        let fwd = if opts.fwd { Some(compile("fwd")?) } else { None };
        let grads = if opts.grads { Some(compile("grads")?) } else { None };
        let gradstep = if opts.grads { Some(compile("gradstep")?) } else { None };

        let theta = exec::read_f32_file(&manifest.file("init")?)?;
        if theta.len() != manifest.nparams {
            bail!(
                "{}: init.bin has {} params, manifest says {}",
                manifest.name,
                theta.len(),
                manifest.nparams
            );
        }
        let state = Self::state_from_theta(&manifest, &theta)?;
        Ok(ModelRuntime { manifest, train, stats, evalloss, fwd, grads, gradstep, state })
    }

    /// Convenience: load by manifest path.
    pub fn load_path(path: &Path, opts: LoadOpts) -> Result<ModelRuntime> {
        Self::load(Manifest::load(path)?, opts)
    }

    fn state_from_theta(man: &Manifest, theta: &[f32]) -> Result<PjRtBuffer> {
        let mut state = vec![0.0f32; man.state_size()];
        state[..man.nparams].copy_from_slice(theta);
        exec::to_device_f32(&state, &[man.state_size()])
    }

    // ------------------------------------------------------------- steps

    /// One optimizer step on a (batch, ctx+1) token batch; returns the
    /// post-step (step count, loss).
    pub fn train_step(&mut self, tokens: &[i32]) -> Result<StepStats> {
        let exe = self.train.as_ref().ok_or_else(|| anyhow!("train not compiled"))?;
        let toks = self.upload_tokens(tokens, self.manifest.ctx()? + 1)?;
        self.state = exe.run(&[&self.state, &toks])?;
        self.read_stats()
    }

    /// Read (step, loss) from the device state — an 8-byte transfer.
    pub fn read_stats(&self) -> Result<StepStats> {
        let exe = self.stats.as_ref().ok_or_else(|| anyhow!("stats not compiled"))?;
        let out = exe.run(&[&self.state])?;
        let v = exec::to_host_f32(&out)?;
        if v.len() != 2 {
            bail!("stats output has {} elements, want 2", v.len());
        }
        Ok(StepStats { step: v[0] as u64, loss: v[1] })
    }

    /// Mean masked NLL over a (batch, ctx+1) token batch (no update).
    pub fn eval_loss(&self, tokens: &[i32]) -> Result<f32> {
        let exe = self.evalloss.as_ref().ok_or_else(|| anyhow!("evalloss not compiled"))?;
        let toks = self.upload_tokens(tokens, self.manifest.ctx()? + 1)?;
        let out = exe.run(&[&self.state, &toks])?;
        Ok(exec::to_host_f32(&out)?[0])
    }

    /// Logits for a (batch, ctx) token batch, flattened (B * ctx * vocab).
    pub fn forward(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let exe = self.fwd.as_ref().ok_or_else(|| anyhow!("fwd not compiled"))?;
        let toks = self.upload_tokens(tokens, self.manifest.ctx()?)?;
        let out = exe.run(&[&self.state, &toks])?;
        exec::to_host_f32(&out)
    }

    /// Gradient+loss vector (P+1,) for one token batch, left on device.
    /// The coordinator accumulates these across shards / microbatches and
    /// applies them with [`Self::apply_gradvec`].
    pub fn grad_loss(&self, tokens: &[i32]) -> Result<PjRtBuffer> {
        let exe = self.grads.as_ref().ok_or_else(|| anyhow!("grads not compiled"))?;
        let toks = self.upload_tokens(tokens, self.manifest.ctx()? + 1)?;
        exe.run(&[&self.state, &toks])
    }

    /// One optimizer update from a (P+1,) grad vector (device buffer).
    pub fn apply_gradvec(&mut self, gradvec: &PjRtBuffer) -> Result<StepStats> {
        let exe = self.gradstep.as_ref().ok_or_else(|| anyhow!("gradstep not compiled"))?;
        self.state = exe.run(&[&self.state, gradvec])?;
        self.read_stats()
    }

    /// Gradient vector length: P + 1 (grads | loss).
    pub fn grad_dim(&self) -> usize {
        self.manifest.nparams + 1
    }

    fn upload_tokens(&self, tokens: &[i32], seq: usize) -> Result<PjRtBuffer> {
        let batch = self.manifest.batch;
        if tokens.len() != batch * seq {
            bail!(
                "token batch has {} elements, artifact wants {}x{}",
                tokens.len(),
                batch,
                seq
            );
        }
        exec::to_device_i32(tokens, &[batch, seq])
    }

    // ------------------------------------------------------------- state

    /// Download the full state vector (checkpointing).
    pub fn state_to_host(&self) -> Result<Vec<f32>> {
        exec::to_host_f32(&self.state)
    }

    /// Download just theta (the trained parameters).
    pub fn theta_to_host(&self) -> Result<Vec<f32>> {
        let mut full = self.state_to_host()?;
        full.truncate(self.manifest.nparams);
        Ok(full)
    }

    /// Replace device state wholesale (checkpoint restore).
    pub fn set_state(&mut self, state: &[f32]) -> Result<()> {
        if state.len() != self.manifest.state_size() {
            bail!(
                "state has {} elements, manifest wants {}",
                state.len(),
                self.manifest.state_size()
            );
        }
        self.state = exec::to_device_f32(state, &[state.len()])?;
        Ok(())
    }

    /// Reset to freshly-initialized parameters with zeroed optimizer state.
    pub fn reset(&mut self) -> Result<()> {
        let theta = exec::read_f32_file(&self.manifest.file("init")?)?;
        self.state = Self::state_from_theta(&self.manifest, &theta)?;
        Ok(())
    }

    pub fn batch(&self) -> usize {
        self.manifest.batch
    }

    pub fn ctx(&self) -> usize {
        self.manifest.ctx().unwrap_or(0)
    }

    pub fn vocab(&self) -> usize {
        self.manifest.vocab().unwrap_or(0)
    }
}
