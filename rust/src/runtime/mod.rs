//! Runtime layer: PJRT client + AOT-artifact loading and execution.
//!
//! The compile path (`make artifacts`) is the only place Python runs; this
//! module gives the rust coordinator everything it needs at run time:
//!
//! * [`manifest`] — parse the `*.manifest.txt` descriptors aot.py emits,
//! * [`exec`] — the PJRT CPU client singleton, HLO-text loading/compiling,
//!   and host<->device transfer helpers,
//! * [`model`] — [`ModelRuntime`]: a loaded model bundle with the fused
//!   train state held device-resident across steps,
//! * [`attn_micro`] — standalone attention-op artifacts for latency benches.
//!
//! Single-array-root convention: every artifact returns exactly one array
//! (xla_extension 0.5.1 cannot transfer tuple literals), so outputs feed
//! straight back in as inputs — see aot.py's module docstring.

pub mod attn_micro;
pub mod exec;
pub mod manifest;
pub mod model;
pub mod ops;

pub use attn_micro::AttnMicro;
pub use exec::{client, Executable};
pub use manifest::{discover, Leaf, Manifest};
pub use model::{LoadOpts, ModelRuntime, StepStats};

use std::path::PathBuf;

/// Default artifact directory: `$PSF_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("PSF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Load a model bundle by artifact name from the default directory.
pub fn load_model(name: &str, opts: LoadOpts) -> anyhow::Result<ModelRuntime> {
    let path = artifacts_dir().join(format!("{name}.manifest.txt"));
    ModelRuntime::load_path(&path, opts)
}

/// Load an attention micro-bundle by artifact name.
pub fn load_attn(name: &str) -> anyhow::Result<AttnMicro> {
    let path = artifacts_dir().join(format!("{name}.manifest.txt"));
    AttnMicro::load(Manifest::load(&path)?)
}
