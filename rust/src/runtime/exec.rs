//! PJRT execution: client singleton + compiled-artifact wrapper.
//!
//! Every artifact has a single non-tuple array root (see aot.py docstring),
//! so outputs transfer cleanly and can be fed straight back in as inputs —
//! the fused train-state vector stays device-resident across steps.

use std::cell::RefCell;
use std::path::Path;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

thread_local! {
    // PjRtClient is Rc-based (not Send/Sync): all PJRT objects are confined
    // to the thread that created them, so the client is thread-local.  Keep
    // every runtime object (executables, buffers) on one thread; worker
    // threads in `exec::pool` do host-side work only.
    static CLIENT: RefCell<Option<PjRtClient>> = const { RefCell::new(None) };
}

/// The thread's PJRT CPU client (created on first use; cheap Rc clone).
pub fn client() -> Result<PjRtClient> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if slot.is_none() {
            *slot = Some(
                PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?,
            );
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

/// A compiled single-root HLO artifact.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Load HLO text from `path` and compile it on the CPU client.
    pub fn load(path: &Path) -> Result<Executable> {
        let name = path
            .file_name()
            .and_then(|f| f.to_str())
            .unwrap_or("<artifact>")
            .to_string();
        let proto = HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let client = client()?;
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Executable { exe, name })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with device-resident buffers; returns the single output buffer.
    pub fn run(&self, args: &[&PjRtBuffer]) -> Result<PjRtBuffer> {
        let out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        out.into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("executing {}: empty result", self.name))
    }

    /// Execute with host literals; returns the single output buffer.
    pub fn run_literals(&self, args: &[Literal]) -> Result<PjRtBuffer> {
        let out = self
            .exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        out.into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("executing {}: empty result", self.name))
    }
}

// ---------------------------------------------------------------- host I/O

/// Upload an f32 slice as a device buffer of the given dims.
pub fn to_device_f32(data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
    client()?
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("host->device f32 {dims:?}: {e}"))
}

/// Upload an i32 slice as a device buffer of the given dims.
pub fn to_device_i32(data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
    client()?
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("host->device i32 {dims:?}: {e}"))
}

/// Download a device buffer as a flat f32 vec.
pub fn to_host_f32(buf: &PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow!("device->host transfer: {e}"))?;
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e}"))
}

/// Read a little-endian f32 binary file (e.g. `<name>.init.bin`).
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        anyhow::bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("psf_exec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("vals.bin");
        let vals = [1.5f32, -2.25, 0.0, 1e-7];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(read_f32_file(&path).unwrap(), vals);
    }

    #[test]
    fn read_f32_file_rejects_ragged() {
        let dir = std::env::temp_dir().join("psf_exec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ragged.bin");
        std::fs::write(&path, [0u8; 7]).unwrap();
        assert!(read_f32_file(&path).is_err());
    }
}
