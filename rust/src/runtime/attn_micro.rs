//! Standalone attention-op artifacts (kind "attn") for latency benches.
//!
//! Each bundle holds one executable mapping (H, n, hd) q/k/v tensors to the
//! attention output — the L1 Pallas kernel lowered through HLO, runnable
//! from rust without Python (Figures 1 and 4, Table 4).

use anyhow::{bail, Result};

use super::exec::{self, Executable};
use super::manifest::Manifest;

pub struct AttnMicro {
    pub manifest: Manifest,
    exe: Executable,
    pub heads: usize,
    pub n: usize,
    pub head_dim: usize,
}

impl AttnMicro {
    pub fn load(manifest: Manifest) -> Result<AttnMicro> {
        if manifest.kind != "attn" {
            bail!("{}: kind {} is not an attn bundle", manifest.name, manifest.kind);
        }
        let exe = Executable::load(&manifest.file("attn")?)?;
        let heads = manifest.cfg_usize("heads")?;
        let n = manifest.cfg_usize("n")?;
        let head_dim = manifest.cfg_usize("head_dim")?;
        Ok(AttnMicro { manifest, exe, heads, n, head_dim })
    }

    pub fn numel(&self) -> usize {
        self.heads * self.n * self.head_dim
    }

    /// Run attention on flat (H*n*hd) q/k/v; returns the flat output.
    pub fn run(&self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let dims = [self.heads, self.n, self.head_dim];
        let qb = exec::to_device_f32(q, &dims)?;
        let kb = exec::to_device_f32(k, &dims)?;
        let vb = exec::to_device_f32(v, &dims)?;
        let out = self.exe.run(&[&qb, &kb, &vb])?;
        exec::to_host_f32(&out)
    }

    /// Run with pre-uploaded device buffers (hot-loop benchmarking: upload
    /// once, execute many times).
    pub fn run_buffers(
        &self,
        q: &xla::PjRtBuffer,
        k: &xla::PjRtBuffer,
        v: &xla::PjRtBuffer,
    ) -> Result<xla::PjRtBuffer> {
        self.exe.run(&[q, k, v])
    }
}
