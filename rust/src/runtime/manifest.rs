//! Artifact manifest parser (`*.manifest.txt`, format "psf-manifest v1").
//!
//! The AOT pipeline (python/compile/aot.py) writes one manifest per emitted
//! artifact bundle.  Line-oriented key/value format:
//!
//! ```text
//! psf-manifest v1
//! name psk4_r16_learned_local_v512_d128_l4_h4x32_c256
//! kind model                     # model | attn
//! cfg vocab 512                  # ModelConfig fields
//! tc peak_lr 0.0003              # TrainConfig fields (model kind only)
//! batch 8
//! nparams 1180672
//! leaf ['layers'][0]['attn_q'] 0 128x128
//! file train psk4....train.hlo.txt
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// One parameter leaf: pytree path, flat offset into theta, shape.
#[derive(Clone, Debug)]
pub struct Leaf {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl Leaf {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Parsed manifest for one artifact bundle.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub name: String,
    pub kind: String,
    pub cfg: BTreeMap<String, String>,
    pub tc: BTreeMap<String, String>,
    pub batch: usize,
    pub nparams: usize,
    pub leaves: Vec<Leaf>,
    pub files: BTreeMap<String, String>,
    /// Directory the manifest was loaded from (artifact files live here).
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let mut man = Self::parse(&text)?;
        man.dir = path
            .parent()
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| PathBuf::from("."));
        Ok(man)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut lines = text.lines();
        match lines.next() {
            Some("psf-manifest v1") => {}
            other => bail!("bad manifest header: {other:?}"),
        }
        let mut man = Manifest::default();
        for (lno, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, rest) = line
                .split_once(' ')
                .ok_or_else(|| anyhow!("manifest line {}: no value: {line}", lno + 2))?;
            match key {
                "name" => man.name = rest.to_string(),
                "kind" => man.kind = rest.to_string(),
                "batch" => man.batch = rest.parse().context("batch")?,
                "nparams" => man.nparams = rest.parse().context("nparams")?,
                "cfg" | "tc" => {
                    let (k, v) = rest
                        .split_once(' ')
                        .ok_or_else(|| anyhow!("manifest line {}: bad {key}", lno + 2))?;
                    let map = if key == "cfg" { &mut man.cfg } else { &mut man.tc };
                    map.insert(k.to_string(), v.to_string());
                }
                "leaf" => {
                    // leaf <name> <offset> <dims>; name has no spaces.
                    let mut it = rest.rsplitn(3, ' ');
                    let dims = it.next().ok_or_else(|| anyhow!("leaf dims"))?;
                    let off = it.next().ok_or_else(|| anyhow!("leaf offset"))?;
                    let name = it.next().ok_or_else(|| anyhow!("leaf name"))?;
                    let shape = if dims == "scalar" {
                        vec![]
                    } else {
                        dims.split('x')
                            .map(|d| d.parse::<usize>().context("leaf dim"))
                            .collect::<Result<Vec<_>>>()?
                    };
                    man.leaves.push(Leaf {
                        name: name.to_string(),
                        offset: off.parse().context("leaf offset")?,
                        shape,
                    });
                }
                "file" => {
                    let (k, v) = rest
                        .split_once(' ')
                        .ok_or_else(|| anyhow!("manifest line {}: bad file", lno + 2))?;
                    man.files.insert(k.to_string(), v.to_string());
                }
                other => bail!("manifest line {}: unknown key {other}", lno + 2),
            }
        }
        if man.name.is_empty() {
            bail!("manifest missing name");
        }
        Ok(man)
    }

    /// Absolute path of a role's artifact file ("train", "fwd", ...).
    pub fn file(&self, role: &str) -> Result<PathBuf> {
        let f = self
            .files
            .get(role)
            .ok_or_else(|| anyhow!("manifest {}: no file role {role}", self.name))?;
        Ok(self.dir.join(f))
    }

    pub fn has_file(&self, role: &str) -> bool {
        self.files.contains_key(role)
    }

    // Typed cfg accessors -------------------------------------------------

    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.cfg
            .get(key)
            .ok_or_else(|| anyhow!("manifest {}: no cfg {key}", self.name))?
            .parse()
            .with_context(|| format!("cfg {key}"))
    }

    pub fn cfg_f64(&self, key: &str) -> Result<f64> {
        self.cfg
            .get(key)
            .ok_or_else(|| anyhow!("manifest {}: no cfg {key}", self.name))?
            .parse()
            .with_context(|| format!("cfg {key}"))
    }

    pub fn cfg_str(&self, key: &str) -> Option<&str> {
        self.cfg.get(key).map(|s| s.as_str())
    }

    pub fn tc_f64(&self, key: &str) -> Result<f64> {
        self.tc
            .get(key)
            .ok_or_else(|| anyhow!("manifest {}: no tc {key}", self.name))?
            .parse()
            .with_context(|| format!("tc {key}"))
    }

    pub fn tc_usize(&self, key: &str) -> Result<usize> {
        self.tc
            .get(key)
            .ok_or_else(|| anyhow!("manifest {}: no tc {key}", self.name))?
            .parse()
            .with_context(|| format!("tc {key}"))
    }

    /// Context length (model kind).
    pub fn ctx(&self) -> Result<usize> {
        self.cfg_usize("ctx")
    }

    /// Vocabulary size (model kind).
    pub fn vocab(&self) -> Result<usize> {
        self.cfg_usize("vocab")
    }

    /// Fused state-vector size: 3P + 2 (theta | m | v | step | loss).
    pub fn state_size(&self) -> usize {
        3 * self.nparams + 2
    }
}

/// Discover every manifest in a directory, keyed by artifact name.
pub fn discover(dir: &Path) -> Result<BTreeMap<String, Manifest>> {
    let mut out = BTreeMap::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading artifact dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let fname = match path.file_name().and_then(|f| f.to_str()) {
            Some(f) => f,
            None => continue,
        };
        if fname.ends_with(".manifest.txt") {
            let man = Manifest::load(&path)?;
            out.insert(man.name.clone(), man);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "psf-manifest v1\n\
        name psk_test\n\
        kind model\n\
        cfg vocab 512\n\
        cfg ctx 256\n\
        cfg attn polysketch\n\
        tc peak_lr 0.0003\n\
        tc total_steps 600\n\
        batch 8\n\
        nparams 1000\n\
        leaf ['tok_emb'] 0 512x128\n\
        leaf ['ln_f']['scale'] 65536 128\n\
        leaf ['scalar_leaf'] 65664 scalar\n\
        file train psk_test.train.hlo.txt\n\
        file init psk_test.init.bin\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "psk_test");
        assert_eq!(m.kind, "model");
        assert_eq!(m.batch, 8);
        assert_eq!(m.nparams, 1000);
        assert_eq!(m.state_size(), 3002);
        assert_eq!(m.cfg_usize("vocab").unwrap(), 512);
        assert_eq!(m.ctx().unwrap(), 256);
        assert_eq!(m.tc_f64("peak_lr").unwrap(), 0.0003);
        assert_eq!(m.tc_usize("total_steps").unwrap(), 600);
        assert_eq!(m.leaves.len(), 3);
        assert_eq!(m.leaves[0].shape, vec![512, 128]);
        assert_eq!(m.leaves[0].numel(), 512 * 128);
        assert_eq!(m.leaves[2].shape, Vec::<usize>::new());
        assert_eq!(m.leaves[2].numel(), 1);
        assert!(m.has_file("train"));
        assert!(!m.has_file("fwd"));
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Manifest::parse("nope\nname x\n").is_err());
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(Manifest::parse("psf-manifest v1\nname x\nbogus 1\n").is_err());
    }

    #[test]
    fn rejects_missing_name() {
        assert!(Manifest::parse("psf-manifest v1\nkind model\n").is_err());
    }

    #[test]
    fn file_role_resolution() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        m.dir = PathBuf::from("/tmp/arts");
        assert_eq!(
            m.file("train").unwrap(),
            PathBuf::from("/tmp/arts/psk_test.train.hlo.txt")
        );
        assert!(m.file("nonexistent").is_err());
    }
}
