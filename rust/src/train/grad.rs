//! Gradient building blocks for the native trainer: the tensor-level
//! VJPs (matmul adjoints, row layernorm backward) and the masked
//! cross-entropy LM loss.
//!
//! Everything here is deterministic by construction: the matmul adjoints
//! reuse the pooled-but-bitwise-stable `tensor` primitives, and the
//! row-wise ops run the identical sequential inner loop per row.  Loss
//! sums accumulate in f64 so the finite-difference gradient checks are
//! not dominated by f32 summation noise.

use crate::tensor::{micro, ln_row_vjp, softmax_rows, Tensor};

/// C = Aᵀ·B for A (n, a), B (n, b) → (a, b): the weight-gradient adjoint
/// of `x.matmul(w)` (dW = xᵀ·dy).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    a.transpose2().matmul(b)
}

/// acc += Aᵀ·B — weight-gradient accumulation into a Params tensor
/// (axpy with unit scale: y·1.0 == y bitwise, so this is a pure add).
pub fn add_matmul_tn(acc: &mut Tensor, a: &Tensor, b: &Tensor) {
    let g = matmul_tn(a, b);
    assert_eq!(acc.shape(), g.shape());
    micro::axpy(acc.data_mut(), g.data(), 1.0);
}

/// Row-wise backward of `layernorm_rows`: `x` is the raw input, `dy` the
/// gradient w.r.t. the normalized output.
pub fn layernorm_rows_vjp(x: &Tensor, dy: &Tensor) -> Tensor {
    assert_eq!(x.shape(), dy.shape());
    let (n, d) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[n, d]);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&ln_row_vjp(x.row(i), dy.row(i)));
    }
    out
}

/// a += b elementwise (same shape).
pub fn add_into(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    micro::axpy(a.data_mut(), b.data(), 1.0);
}

/// Masked cross-entropy statistics of one example.
pub struct CeStats {
    /// Σ −ln p(target) over counted positions, in f64.
    pub loss_sum: f64,
    /// Number of positions that carried loss (mask true).
    pub counted: usize,
    /// Counted positions where the greedy argmax equals the target.
    pub correct: usize,
    /// ∂(Σ loss)/∂logits: `softmax − onehot` at counted rows, zero
    /// elsewhere.  *Unscaled* — the batch driver divides the reduced
    /// gradient by the batch-wide counted total, keeping the reduction
    /// order (and therefore the bytes) independent of the thread count.
    pub d_logits: Tensor,
}

/// Masked next-token cross-entropy: `logits` is (n, vocab) for inputs
/// `tokens[..n]`, `targets` is `tokens[1..]` (length n), and `mask[i]`
/// says whether target position i carries loss (answer positions for the
/// synthetic tasks, non-pad targets for LM corpora).
pub fn masked_cross_entropy(logits: &Tensor, targets: &[u32], mask: &[bool]) -> CeStats {
    let (n, vocab) = (logits.rows(), logits.cols());
    assert_eq!(targets.len(), n);
    assert_eq!(mask.len(), n);
    let probs = softmax_rows(logits);
    let mut d_logits = Tensor::zeros(&[n, vocab]);
    let mut loss_sum = 0.0f64;
    let mut counted = 0usize;
    let mut correct = 0usize;
    for i in 0..n {
        if !mask[i] {
            continue;
        }
        let t = targets[i] as usize;
        assert!(t < vocab, "target {t} out of vocab {vocab}");
        let p = probs.row(i);
        loss_sum += -((p[t] as f64).max(1e-30).ln());
        counted += 1;
        let mut best = 0usize;
        for (j, &v) in p.iter().enumerate() {
            if v > p[best] {
                best = j;
            }
        }
        if best == t {
            correct += 1;
        }
        let drow = d_logits.row_mut(i);
        drow.copy_from_slice(p);
        drow[t] -= 1.0;
    }
    CeStats { loss_sum, counted, correct, d_logits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let mut rng = Pcg::seeded(1);
        let a = Tensor::gaussian(&mut rng, &[7, 3]);
        let b = Tensor::gaussian(&mut rng, &[7, 5]);
        let got = matmul_tn(&a, &b);
        assert_eq!(got.shape(), &[3, 5]);
        for i in 0..3 {
            for j in 0..5 {
                let want: f32 = (0..7).map(|r| a.at2(r, i) * b.at2(r, j)).sum();
                assert!((got.at2(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn masked_ce_grad_matches_finite_difference() {
        let mut rng = Pcg::seeded(2);
        let logits = Tensor::gaussian(&mut rng, &[4, 6]);
        let targets = [1u32, 5, 0, 3];
        let mask = [true, false, true, true];
        let st = masked_cross_entropy(&logits, &targets, &mask);
        assert_eq!(st.counted, 3);
        // Masked rows carry no gradient.
        assert!(st.d_logits.row(1).iter().all(|&v| v == 0.0));
        let eps = 1e-3f32;
        for i in 0..4 {
            for j in 0..6 {
                let mut lp = logits.clone();
                lp.set2(i, j, lp.at2(i, j) + eps);
                let mut lm = logits.clone();
                lm.set2(i, j, lm.at2(i, j) - eps);
                let fp = masked_cross_entropy(&lp, &targets, &mask).loss_sum;
                let fm = masked_cross_entropy(&lm, &targets, &mask).loss_sum;
                let fd = (fp - fm) / (2.0 * eps as f64);
                let an = st.d_logits.at2(i, j) as f64;
                assert!(
                    (fd - an).abs() <= 1e-2 * (1.0 + fd.abs().max(an.abs())),
                    "({i},{j}): fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn masked_ce_counts_greedy_correct() {
        // Put all the mass on the target for row 0 and off-target for row 1.
        let logits = Tensor::from_vec(&[2, 3], vec![0.0, 9.0, 0.0, 9.0, 0.0, 0.0]);
        let st = masked_cross_entropy(&logits, &[1, 2], &[true, true]);
        assert_eq!(st.counted, 2);
        assert_eq!(st.correct, 1);
        assert!(st.loss_sum.is_finite());
    }
}
