//! Native training subsystem: backprop through the kernel core.
//!
//! The paper's headline claim is a *training* one — polysketch attention
//! trains 2.5–4× faster than FlashAttention at long context — and this
//! module makes that claim reproducible natively: a std-only,
//! pool-parallel, bitwise-deterministic trainer with hand-written
//! backward passes through the whole `NativeLm` stack.
//!
//! * [`grad`] — tensor-level adjoints (matmul transposes, row layernorm
//!   backward) and the masked cross-entropy LM loss;
//! * [`backprop`] — the activation tape + reverse pass; attention
//!   gradients go through `CausalKernel::vjp`, so the quadratic engines
//!   pay the recompute-softmax O(n²) backward and the linear engine runs
//!   the reverse-direction blocked recurrence over suffix sums of
//!   feature outer-products (the transpose of the paper's block-based
//!   causal masking algorithm, still O(n·r²) per head);
//! * [`optim`] — AdamW with global-norm clipping and a warmup + cosine
//!   schedule, moments serialized into checkpoints for exact resume;
//! * [`driver`] (`loop.rs`) — the training loop over
//!   `tasks::{induction, selective_copy}` and `data::Batcher` corpora
//!   with JSONL metrics and `psf train-native` as its CLI face.
//!
//! Determinism contract: per-example gradients are computed in parallel
//! into private accumulators and reduced sequentially in example order,
//! and the optimizer is sequential scalar math — so gradients and
//! post-AdamW weights are bitwise identical at every thread count
//! (pinned by `tests/determinism.rs`).  Gradient correctness is pinned
//! against central finite differences for every layer op and all six
//! mechanisms in `tests/grad_check.rs`.

pub mod backprop;
#[path = "loop.rs"]
pub mod driver;
pub mod grad;
pub mod optim;

pub use backprop::{compute_grads, forward_tape, BatchStats, TrainExample};
pub use driver::{EvalPoint, TrainConfig, TrainSource, TrainSummary, Trainer};
pub use optim::{AdamW, OptimConfig, StepInfo};
