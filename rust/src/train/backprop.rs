//! Hand-written backward pass through the whole [`NativeLm`] stack.
//!
//! [`forward_tape`] runs exactly the arithmetic of `NativeLm::forward`
//! (same ops, same order — the logits are the inference logits) while
//! caching per-layer activations; [`backward_tape`] walks the tape in
//! reverse, routing attention gradients through the kernel core's
//! `CausalKernel::vjp` (the single dispatch stays in `attn::kernel`) and
//! everything else through the closed-form adjoints in [`super::grad`].
//!
//! Batching: examples are independent, so [`compute_grads`] fans them
//! over the deterministic pool, each into its own `Params`-shaped
//! accumulator, and reduces **sequentially in example order** — gradient
//! bytes can never depend on the thread count.  The per-example gradient
//! rows (`softmax − onehot`) are left unscaled until the batch-wide
//! masked-position count is known, so normalization is one exact scalar
//! multiply at the end.

use crate::attn::kernel;
use crate::exec::pool;
use crate::infer::model::{add_sinusoidal, rope_heads, rope_row_inv};
use crate::infer::{NativeLm, Params};
use crate::tensor::{axpy, gelu_grad, layernorm_rows, micro, Tensor};
use crate::train::grad::{
    add_into, add_matmul_tn, layernorm_rows_vjp, masked_cross_entropy,
};

/// One training sequence: `tokens` of length ctx+1 (inputs = `[..ctx]`,
/// targets = `[1..]`) and a per-target loss mask of length ctx.
#[derive(Clone, Debug)]
pub struct TrainExample {
    pub tokens: Vec<u32>,
    pub mask: Vec<bool>,
}

impl TrainExample {
    pub fn inputs(&self) -> &[u32] {
        &self.tokens[..self.tokens.len() - 1]
    }

    pub fn targets(&self) -> &[u32] {
        &self.tokens[1..]
    }
}

/// Cached activations of one transformer block.
struct LayerTape {
    x_in: Tensor,
    xn: Tensor,
    /// Post-RoPE fused projections.
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Concatenated head outputs (pre-W_o).
    ao: Tensor,
    x_mid: Tensor,
    xn2: Tensor,
    g_pre: Tensor,
    g: Tensor,
    u: Tensor,
}

/// Activation tape of one example's forward pass.
pub struct Tape {
    layers: Vec<LayerTape>,
    x_last: Tensor,
    xf: Tensor,
}

/// Forward pass with activation capture: identical math to
/// `NativeLm::forward` (the returned logits *are* the inference logits).
pub fn forward_tape(model: &NativeLm, inputs: &[u32]) -> (Tensor, Tape) {
    let n = inputs.len();
    assert!(n > 0, "empty token sequence");
    let d = model.cfg.d_model;
    let hd = model.head_dim();
    let params = model.params();
    let mut x = Tensor::zeros(&[n, d]);
    for (i, &t) in inputs.iter().enumerate() {
        let row = x.row_mut(i);
        row.copy_from_slice(params.embed.row(t as usize));
        add_sinusoidal(row, i);
    }
    let mut layers = Vec::with_capacity(params.layers.len());
    for (li, layer) in params.layers.iter().enumerate() {
        let x_in = x;
        let xn = layernorm_rows(&x_in);
        let mut q = xn.matmul(&layer.wq);
        let mut k = xn.matmul(&layer.wk);
        let v = xn.matmul(&layer.wv);
        rope_heads(&mut q, hd);
        rope_heads(&mut k, hd);
        let mut ao = Tensor::zeros(&[n, d]);
        kernel::prefill_heads(&model.kernels()[li], &q, &k, &v, None, &mut ao);
        let x_mid = x_in.add(&ao.matmul(&layer.wo));
        let xn2 = layernorm_rows(&x_mid);
        let g_pre = xn2.matmul(&layer.ffn_gate);
        let mut g = g_pre.clone();
        micro::gelu_rows(g.data_mut());
        let u = xn2.matmul(&layer.ffn_up);
        x = x_mid.add(&g.hadamard(&u).matmul(&layer.ffn_down));
        layers.push(LayerTape { x_in, xn, q, k, v, ao, x_mid, xn2, g_pre, g, u });
    }
    let x_last = x;
    let xf = layernorm_rows(&x_last);
    let logits = xf.matmul(&params.readout);
    (logits, Tape { layers, x_last, xf })
}

/// Reverse pass: accumulate ∂loss/∂θ into `grads` given ∂loss/∂logits.
pub fn backward_tape(
    model: &NativeLm,
    inputs: &[u32],
    tape: &Tape,
    d_logits: &Tensor,
    grads: &mut Params,
) {
    let n = inputs.len();
    let d = model.cfg.d_model;
    let hd = model.head_dim();
    let params = model.params();

    // Readout head.
    add_matmul_tn(&mut grads.readout, &tape.xf, d_logits);
    let dxf = d_logits.matmul_t(&params.readout);
    let mut dx = layernorm_rows_vjp(&tape.x_last, &dxf);

    for li in (0..params.layers.len()).rev() {
        let layer = &params.layers[li];
        let t = &tape.layers[li];
        let glayer = &mut grads.layers[li];

        // FFN: x_out = x_mid + (g ⊙ u) W_down.
        let hprod = t.g.hadamard(&t.u);
        add_matmul_tn(&mut glayer.ffn_down, &hprod, &dx);
        let dhprod = dx.matmul_t(&layer.ffn_down);
        let dg = dhprod.hadamard(&t.u);
        let du = dhprod.hadamard(&t.g);
        let mut dg_pre = dg;
        for (v, &pre) in dg_pre.data_mut().iter_mut().zip(t.g_pre.data()) {
            *v *= gelu_grad(pre);
        }
        add_matmul_tn(&mut glayer.ffn_gate, &t.xn2, &dg_pre);
        add_matmul_tn(&mut glayer.ffn_up, &t.xn2, &du);
        let mut dxn2 = dg_pre.matmul_t(&layer.ffn_gate);
        add_into(&mut dxn2, &du.matmul_t(&layer.ffn_up));
        let mut dx_mid = dx; // residual branch
        add_into(&mut dx_mid, &layernorm_rows_vjp(&t.x_mid, &dxn2));

        // Attention: x_mid = x_in + ao W_o.
        add_matmul_tn(&mut glayer.wo, &t.ao, &dx_mid);
        let dao = dx_mid.matmul_t(&layer.wo);
        let mut dq = Tensor::zeros(&[n, d]);
        let mut dk = Tensor::zeros(&[n, d]);
        let mut dv = Tensor::zeros(&[n, d]);
        kernel::vjp_heads(
            &model.kernels()[li],
            &t.q,
            &t.k,
            &t.v,
            &dao,
            &mut dq,
            &mut dk,
            &mut dv,
        );
        // RoPE is orthogonal: pull gradients back with the inverse
        // rotation, per head segment, per position.
        for i in 0..n {
            for seg in dq.row_mut(i).chunks_mut(hd) {
                rope_row_inv(seg, i);
            }
            for seg in dk.row_mut(i).chunks_mut(hd) {
                rope_row_inv(seg, i);
            }
        }
        add_matmul_tn(&mut glayer.wq, &t.xn, &dq);
        add_matmul_tn(&mut glayer.wk, &t.xn, &dk);
        add_matmul_tn(&mut glayer.wv, &t.xn, &dv);
        let mut dxn = dq.matmul_t(&layer.wq);
        add_into(&mut dxn, &dk.matmul_t(&layer.wk));
        add_into(&mut dxn, &dv.matmul_t(&layer.wv));
        dx = dx_mid;
        add_into(&mut dx, &layernorm_rows_vjp(&t.x_in, &dxn));
    }

    // Embedding scatter (the sinusoidal table is a constant).
    for (i, &tok) in inputs.iter().enumerate() {
        axpy(grads.embed.row_mut(tok as usize), dx.row(i), 1.0);
    }
}

/// Aggregate loss/accuracy statistics of one batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Mean cross-entropy per counted position.
    pub loss: f64,
    /// Counted (masked-in) positions across the batch.
    pub counted: usize,
    /// Greedy-correct counted positions.
    pub correct: usize,
}

impl BatchStats {
    pub fn accuracy(&self) -> f64 {
        if self.counted == 0 {
            0.0
        } else {
            self.correct as f64 / self.counted as f64
        }
    }
}

/// One full gradient computation over a batch: per-example forward tape +
/// backward in parallel (each example owns a private accumulator), then a
/// sequential in-order reduction and one exact `1/counted` scale.
pub fn compute_grads(model: &NativeLm, examples: &[TrainExample]) -> (Params, BatchStats) {
    assert!(!examples.is_empty(), "empty training batch");
    let mut slots: Vec<Option<(Params, f64, usize, usize)>> = vec![None; examples.len()];
    pool::par_map_mut(&mut slots, 1, |i, slot| {
        let ex = &examples[i];
        let (logits, tape) = forward_tape(model, ex.inputs());
        let ce = masked_cross_entropy(&logits, ex.targets(), &ex.mask);
        let mut g = model.params().zeros_like();
        backward_tape(model, ex.inputs(), &tape, &ce.d_logits, &mut g);
        *slot = Some((g, ce.loss_sum, ce.counted, ce.correct));
    });
    let mut total = model.params().zeros_like();
    let mut stats = BatchStats::default();
    let mut loss_sum = 0.0f64;
    for slot in slots {
        let (g, loss, counted, correct) = slot.expect("example gradient missing");
        total.add_scaled(&g, 1.0);
        loss_sum += loss;
        stats.counted += counted;
        stats.correct += correct;
    }
    assert!(stats.counted > 0, "batch has no loss-carrying positions");
    total.scale_in_place(1.0 / stats.counted as f32);
    stats.loss = loss_sum / stats.counted as f64;
    (total, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::LmConfig;

    fn tiny(mech: Mechanism) -> NativeLm {
        let cfg = LmConfig { vocab: 32, d_model: 16, layers: 2, heads: 2, ff_mult: 2, seed: 5 };
        NativeLm::new(cfg, mech)
    }

    fn example(n: usize) -> TrainExample {
        let tokens: Vec<u32> = (0..=n as u32).map(|i| (i * 7) % 32).collect();
        TrainExample { tokens, mask: vec![true; n] }
    }

    #[test]
    fn forward_tape_logits_match_inference_forward() {
        // The drift guard for the tape: training must differentiate
        // exactly the function serving runs, so the taped forward is
        // pinned **bitwise** against `NativeLm::forward` for every
        // mechanism, at a ragged (13 vs block 8) and a block-aligned
        // (16) length.  Any edit to either forward that is not mirrored
        // in the other fails here.
        let mechs = [
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 16, block: 8 },
        ];
        for mech in mechs {
            for n in [13usize, 16] {
                let lm = tiny(mech.clone());
                let ex = example(n);
                let (logits, _) = forward_tape(&lm, ex.inputs());
                assert_eq!(logits, lm.forward(ex.inputs()), "{} n={n}", mech.label());
            }
        }
    }

    #[test]
    fn compute_grads_shapes_and_finiteness() {
        let lm = tiny(Mechanism::Performer { m: 8, block: 8 });
        let (g, stats) = compute_grads(&lm, &[example(13), example(9)]);
        assert!(stats.loss.is_finite() && stats.loss > 0.0);
        assert_eq!(stats.counted, 22);
        for (name, t) in g.named() {
            assert!(t.data().iter().all(|v| v.is_finite()), "{name} has non-finite grads");
        }
        // Something actually flowed everywhere.
        assert!(g.l2_norm_sq() > 0.0);
    }

    #[test]
    fn masked_positions_do_not_leak_gradient() {
        // With every mask bit off except position 0, only tokens at
        // positions <= 1 can receive embedding gradient (causality).
        let lm = tiny(Mechanism::Softmax);
        let mut ex = example(8);
        ex.mask = vec![false; 8];
        ex.mask[0] = true;
        let (g, _) = compute_grads(&lm, &[ex.clone()]);
        let touched: Vec<u32> = (0..32u32)
            .filter(|&t| g.embed.row(t as usize).iter().any(|&v| v != 0.0))
            .collect();
        for t in &touched {
            assert!(
                ex.tokens[..2].contains(t),
                "token {t} got gradient but only positions 0..2 are live"
            );
        }
    }
}
