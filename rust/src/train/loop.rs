//! The native training loop: batches → gradients → AdamW, with JSONL
//! metrics, periodic accuracy/loss evals, checkpointing, and exact
//! resume.  Drives the synthetic tasks (`tasks::{induction,
//! selective_copy}`) and byte-level LM corpora (`data::Batcher`) through
//! one [`TrainSource`] enum — no trait objects, no per-task trainers.

use std::path::PathBuf;
use std::time::Instant;

use crate::data::batcher::Batcher;
use crate::infer::NativeLm;
use crate::metrics::{JsonlWriter, Record};
use crate::tasks::induction::InductionTask;
use crate::tasks::selective_copy::SelectiveCopyTask;
use crate::tasks::Example;
use crate::train::backprop::{compute_grads, TrainExample};
use crate::train::optim::{AdamW, OptimConfig};
use crate::util::rng::Pcg;

/// Where training sequences come from.
pub enum TrainSource {
    /// Induction heads (Appendix F.2): loss only at the answer position.
    Induction(InductionTask),
    /// Selective copying (Appendix F.1): loss on the answer span.
    Copy(SelectiveCopyTask),
    /// Byte-level LM over packed token streams: loss at every non-pad
    /// target.  `eval` is the held-out split's batcher; when the test
    /// split is too short for one batch, evals fall back to a *clone* of
    /// the training batcher (upcoming segments — still unseen, but not
    /// disjoint per epoch) so the training stream itself never advances
    /// on eval and exact resume stays exact.
    Corpus { train: Batcher, eval: Option<Batcher> },
}

fn corpus_examples(b: &mut Batcher) -> Vec<TrainExample> {
    let bt = b.next_batch();
    (0..bt.batch)
        .map(|r| {
            let tokens: Vec<u32> = bt.row(r).iter().map(|&t| t as u32).collect();
            let mask = tokens[1..].iter().map(|&t| t != 0).collect();
            TrainExample { tokens, mask }
        })
        .collect()
}

impl TrainSource {
    fn task_example(ex: &Example) -> TrainExample {
        let ctx = ex.tokens.len() - 1;
        let mut mask = vec![false; ctx];
        for &p in &ex.answer_positions {
            mask[p] = true;
        }
        TrainExample { tokens: ex.tokens.clone(), mask }
    }

    /// Next training batch, deterministic in `rng` (the corpus batcher
    /// carries its own deterministic shuffle and ignores `rng`).
    fn next_batch(&mut self, batch: usize, rng: &mut Pcg) -> Vec<TrainExample> {
        match self {
            TrainSource::Induction(t) => {
                (0..batch).map(|_| Self::task_example(&t.sample(rng))).collect()
            }
            TrainSource::Copy(t) => {
                (0..batch).map(|_| Self::task_example(&t.sample(rng))).collect()
            }
            TrainSource::Corpus { train, .. } => corpus_examples(train),
        }
    }

    /// Held-out eval batch of `count` examples: fresh task examples from
    /// an eval-only RNG stream keyed by the step (never overlaps
    /// training draws, identical across resume), or — for the corpus — a
    /// throwaway *clone* of the eval (or, fallback, training) batcher.
    /// The clone makes every eval score the same fixed validation
    /// batches: the curve is comparable across steps, no batcher cursor
    /// ever moves on eval, and resumed runs report the same metrics an
    /// uninterrupted run would.
    fn eval_batch(&mut self, count: usize, seed: u64, tag: u64) -> Vec<TrainExample> {
        let mut rng = Pcg::new(seed ^ 0xe7a1, tag);
        match self {
            TrainSource::Induction(_) | TrainSource::Copy(_) => {
                self.next_batch(count, &mut rng)
            }
            TrainSource::Corpus { train, eval } => {
                let mut b = eval.as_ref().unwrap_or(&*train).clone();
                let mut out = Vec::with_capacity(count);
                while out.len() < count {
                    out.extend(corpus_examples(&mut b));
                }
                out.truncate(count);
                out
            }
        }
    }

    /// Fast-forward a resumed corpus stream past the batches the
    /// interrupted run already consumed; task sources resume via their
    /// per-resume-point RNG stream instead.
    fn fast_forward(&mut self, steps: u64) {
        if let TrainSource::Corpus { train, .. } = self {
            train.skip_batches(steps);
        }
    }
}

/// Training-loop configuration (`psf train-native` maps its flags 1:1).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: u64,
    pub batch: usize,
    pub optim: OptimConfig,
    /// Data/eval seed (weights have their own seed in `LmConfig`).
    pub seed: u64,
    /// Eval cadence in steps (0 = only at the end).
    pub eval_every: u64,
    pub eval_examples: usize,
    /// Early-stop when eval accuracy reaches this (0 = off).
    pub stop_at_accuracy: f64,
    /// Echo a progress line every N steps (0 = silent).
    pub echo_every: u64,
    pub log_path: Option<PathBuf>,
    pub ckpt_path: Option<PathBuf>,
    /// Checkpoint cadence in steps (0 = only at the end, if a path is set).
    pub ckpt_every: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            batch: 16,
            optim: OptimConfig::default(),
            seed: 0,
            eval_every: 50,
            eval_examples: 64,
            stop_at_accuracy: 0.0,
            echo_every: 10,
            log_path: None,
            ckpt_path: None,
            ckpt_every: 0,
        }
    }
}

/// One point of the accuracy-vs-steps curve.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub step: u64,
    pub loss: f64,
    pub accuracy: f64,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    pub steps_run: u64,
    /// Loss of the very first batch (pre-update).
    pub initial_loss: f64,
    /// Loss of the last batch trained on.
    pub final_loss: f64,
    /// Last eval's answer-position accuracy.
    pub final_accuracy: f64,
    pub curve: Vec<EvalPoint>,
    pub wall_secs: f64,
    pub tokens_seen: u64,
}

/// The training driver: owns the optimizer and the data source, borrows
/// the model.  `psf train-native`, the task benches, and the train-smoke
/// CI job all run through here.
pub struct Trainer<'a> {
    model: &'a mut NativeLm,
    source: TrainSource,
    cfg: TrainConfig,
    opt: AdamW,
}

impl<'a> Trainer<'a> {
    pub fn new(model: &'a mut NativeLm, source: TrainSource, cfg: TrainConfig) -> Trainer<'a> {
        let opt = AdamW::new(cfg.optim.clone(), model.params());
        Trainer { model, source, cfg, opt }
    }

    /// Restore optimizer moments + step from a resume checkpoint (the
    /// caller already rebuilt the model itself via
    /// `NativeLm::from_checkpoint`).  Returns the step to continue from.
    ///
    /// Exact resume additionally requires the *run configuration* to
    /// match the interrupted run — batch, data seed, peak lr, schedule
    /// length are recorded in the checkpoint's `train.meta` section and
    /// compared here: mismatches warn loudly (they are sometimes
    /// intentional, e.g. extending `--steps` continues the cosine
    /// schedule on a longer horizon) instead of failing.
    pub fn resume_from(&mut self, ck: &crate::checkpoint::Checkpoint) -> anyhow::Result<u64> {
        self.opt.restore_from_checkpoint(ck)?;
        if let Some(tm) = ck.get("train.meta") {
            anyhow::ensure!(tm.len() == 3 + 8, "train.meta has {} entries, want 11", tm.len());
            let mut warn = |what: &str, saved: String, now: String| {
                eprintln!(
                    "warning: --resume with different {what} (checkpoint: {saved}, now: {now}) \
                     — the run will not match an uninterrupted one"
                );
            };
            if tm[0] as usize != self.cfg.batch {
                warn("--batch", format!("{}", tm[0] as usize), format!("{}", self.cfg.batch));
            }
            if tm[1] != self.cfg.optim.lr {
                warn("--lr", format!("{}", tm[1]), format!("{}", self.cfg.optim.lr));
            }
            if tm[2] as u64 != self.cfg.optim.total_steps {
                warn(
                    "--steps (schedule length)",
                    format!("{}", tm[2] as u64),
                    format!("{}", self.cfg.optim.total_steps),
                );
            }
            let mut seed_bytes = [0u8; 8];
            for (b, &v) in seed_bytes.iter_mut().zip(&tm[3..]) {
                *b = v as u8;
            }
            let saved_seed = u64::from_le_bytes(seed_bytes);
            if saved_seed != self.cfg.seed {
                warn("--seed (data stream)", format!("{saved_seed}"), format!("{}", self.cfg.seed));
            }
        }
        Ok(ck.step)
    }

    fn save_checkpoint(&self, step: u64) -> anyhow::Result<()> {
        if let Some(path) = &self.cfg.ckpt_path {
            let mut ck = self.model.to_checkpoint(step);
            self.opt.add_to_checkpoint(&mut ck);
            // Run configuration, so resume can detect divergent flags.
            let mut tm = vec![
                self.cfg.batch as f32,
                self.cfg.optim.lr,
                self.cfg.optim.total_steps as f32,
            ];
            tm.extend(self.cfg.seed.to_le_bytes().iter().map(|&b| b as f32));
            ck.sections.insert("train.meta".into(), tm);
            ck.save(path).map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        Ok(())
    }

    /// Evaluate answer-position accuracy + loss on fresh held-out data
    /// through the *inference* forward path (same params, no tape).
    pub fn evaluate(&mut self, step: u64) -> EvalPoint {
        let n = self.cfg.eval_examples.max(1);
        let batch = self.source.eval_batch(n, self.cfg.seed, step);
        let mut loss_sum = 0.0f64;
        let mut counted = 0usize;
        let mut correct = 0usize;
        for ex in &batch {
            let logits = self.model.forward(ex.inputs());
            let ce = crate::train::grad::masked_cross_entropy(&logits, ex.targets(), &ex.mask);
            loss_sum += ce.loss_sum;
            counted += ce.counted;
            correct += ce.correct;
        }
        EvalPoint {
            step,
            loss: if counted == 0 { 0.0 } else { loss_sum / counted as f64 },
            accuracy: if counted == 0 { 0.0 } else { correct as f64 / counted as f64 },
        }
    }

    pub fn run(&mut self) -> anyhow::Result<TrainSummary> {
        let t0 = Instant::now();
        let start = self.opt.step_count();
        // Task sources draw from a distinct RNG stream per (seed, resume
        // point); the corpus batcher instead fast-forwards to the batch an
        // uninterrupted run would see next — either way a resumed run
        // never retrains on batches the interrupted run already consumed.
        let mut data_rng = Pcg::new(self.cfg.seed ^ 0x7a11, start);
        self.source.fast_forward(start);
        let mut log = match &self.cfg.log_path {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        let mut curve: Vec<EvalPoint> = Vec::new();
        let mut initial_loss = f64::NAN;
        let mut final_loss = f64::NAN;
        let mut tokens_seen = 0u64;
        let mut steps_run = 0u64;
        let mut stopped_early = false;
        // Test-only fault injection: PSF_TEST_POISON_STEP=N corrupts one
        // gradient value with NaN at step N, so CI can exercise the
        // sentinel-trip -> incident-dump path end to end.
        let poison_step: Option<u64> =
            std::env::var("PSF_TEST_POISON_STEP").ok().and_then(|s| s.parse().ok());
        for step in start..self.cfg.steps {
            let batch = self.source.next_batch(self.cfg.batch.max(1), &mut data_rng);
            // Per-step timing is telemetry only (JSONL + obs phase
            // accumulators); it never feeds the update itself.
            let t_grad = Instant::now();
            let (mut grads, stats) = compute_grads(self.model, &batch);
            let fwd_bwd_secs = t_grad.elapsed().as_secs_f64();
            crate::obs::phase::add(
                crate::obs::Phase::TrainGrad,
                (fwd_bwd_secs * 1e9) as u64,
            );
            if poison_step == Some(step) {
                if let Some((name, t)) = grads.named_mut().into_iter().next() {
                    eprintln!(
                        "psf train: poisoning grad {name} at step {step} (PSF_TEST_POISON_STEP)"
                    );
                    t.data_mut()[0] = f32::NAN;
                }
            }
            // Numeric-health sentinels: per-section grad scans + the
            // loss-spike detector.  Write-only — a healthy run's updates
            // are byte-identical with sentinels on or off; only a fatal
            // (non-finite) fault halts, *before* the poisoned update is
            // applied.
            if crate::obs::sentinels_on() {
                crate::obs::sentinel::set_step(step);
                for (name, t) in grads.named() {
                    crate::obs::sentinel::scan_named(
                        crate::obs::sentinel::Site::Grad,
                        &name,
                        t.data(),
                    );
                }
                crate::obs::sentinel::observe_loss(step, stats.loss);
                if crate::obs::sentinel::tripped_fatal() {
                    eprintln!(
                        "psf train: halting before step {step} update after fatal sentinel trip"
                    );
                    stopped_early = true;
                    break;
                }
            }
            // Snapshot weights for the update-ratio sentinel (|Δw|/|w|
            // per section).  Costs one params copy per step, so it only
            // runs with sentinels enabled.
            let snap: Option<Vec<(String, Vec<f32>)>> = if crate::obs::sentinels_on() {
                Some(
                    self.model
                        .params()
                        .named()
                        .into_iter()
                        .map(|(n, t)| (n, t.data().to_vec()))
                        .collect(),
                )
            } else {
                None
            };
            let t_opt = Instant::now();
            let info = self.opt.step(self.model.params_mut(), &grads);
            let opt_secs = t_opt.elapsed().as_secs_f64();
            crate::obs::phase::add(crate::obs::Phase::TrainOptim, (opt_secs * 1e9) as u64);
            if let Some(snap) = snap {
                for ((name, old), (_, new)) in
                    snap.iter().zip(self.model.params().named())
                {
                    let mut dn = 0.0f64;
                    let mut wn = 0.0f64;
                    for (a, b) in old.iter().zip(new.data()) {
                        let d = (*b - *a) as f64;
                        dn += d * d;
                        wn += (*a as f64) * (*a as f64);
                    }
                    let ratio = dn.sqrt() / (wn.sqrt() + 1e-12);
                    crate::obs::sentinel::observe_update_ratio(step, name, ratio);
                }
            }
            // Flight-recorder notes (inert unless the recorder runs).
            crate::obs::recorder::note("loss", stats.loss);
            crate::obs::recorder::note("grad_norm", info.grad_norm);
            // Weights moved: rebuild the int8 decode twins (no-op unless
            // PSF_QUANT=q8) so mid-training eval never decodes stale scales.
            self.model.requantize();
            tokens_seen += batch.iter().map(|e| e.mask.len() as u64).sum::<u64>();
            steps_run += 1;
            if initial_loss.is_nan() {
                initial_loss = stats.loss;
            }
            final_loss = stats.loss;
            if let Some(log) = &mut log {
                log.write(
                    &Record::new()
                        .str("kind", "train_step")
                        .i64("step", step as i64)
                        .f64("loss", stats.loss)
                        .f64("lr", info.lr as f64)
                        .f64("grad_norm", info.grad_norm)
                        .bool("clipped", info.clipped)
                        .f64("batch_accuracy", stats.accuracy())
                        .f64("fwd_bwd_secs", fwd_bwd_secs)
                        .f64("opt_secs", opt_secs),
                )?;
            }
            if self.cfg.echo_every > 0 && (step + 1) % self.cfg.echo_every == 0 {
                println!(
                    "step {:>6}  loss {:.4}  acc {:.1}%  lr {:.2e}  |g| {:.3}",
                    step + 1,
                    stats.loss,
                    stats.accuracy() * 100.0,
                    info.lr,
                    info.grad_norm,
                );
            }
            let due_eval =
                self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0;
            let last = step + 1 == self.cfg.steps;
            if due_eval || last {
                let point = self.evaluate(step + 1);
                if let Some(log) = &mut log {
                    log.write(
                        &Record::new()
                            .str("kind", "train_eval")
                            .i64("step", point.step as i64)
                            .f64("loss", point.loss)
                            .f64("accuracy", point.accuracy),
                    )?;
                }
                if self.cfg.echo_every > 0 {
                    println!(
                        "eval @ {:>6}: loss {:.4}, accuracy {:.2}%",
                        point.step,
                        point.loss,
                        point.accuracy * 100.0
                    );
                }
                let acc = point.accuracy;
                curve.push(point);
                if self.cfg.stop_at_accuracy > 0.0 && acc >= self.cfg.stop_at_accuracy {
                    stopped_early = true;
                }
            }
            let due_ckpt =
                self.cfg.ckpt_every > 0 && (step + 1) % self.cfg.ckpt_every == 0;
            if due_ckpt || last || stopped_early {
                self.save_checkpoint(step + 1)?;
            }
            if stopped_early {
                break;
            }
        }
        // A 0-step run (already-complete resume) still reports an eval.
        if curve.is_empty() {
            curve.push(self.evaluate(start));
        }
        if let Some(log) = &mut log {
            log.flush()?;
        }
        let last = curve.last().expect("eval curve");
        Ok(TrainSummary {
            steps_run,
            initial_loss: if initial_loss.is_nan() { last.loss } else { initial_loss },
            final_loss: if final_loss.is_nan() { last.loss } else { final_loss },
            final_accuracy: last.accuracy,
            curve,
            wall_secs: t0.elapsed().as_secs_f64(),
            tokens_seen,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::LmConfig;
    use crate::tasks::induction::InductionTask;

    #[test]
    fn a_few_steps_reduce_induction_loss() {
        // Not the convergence gate (CI's train-smoke job is) — just that
        // the loop runs end to end and the loss moves the right way.
        let task = InductionTask::standard(16);
        let cfg = LmConfig {
            vocab: task.vocab(),
            d_model: 32,
            layers: 2,
            heads: 2,
            ff_mult: 2,
            seed: 3,
        };
        let mut model = NativeLm::new(
            cfg,
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
        );
        let tcfg = TrainConfig {
            steps: 12,
            batch: 8,
            eval_every: 0,
            eval_examples: 16,
            echo_every: 0,
            optim: OptimConfig { lr: 1e-2, warmup: 2, total_steps: 12, ..Default::default() },
            ..Default::default()
        };
        let mut trainer = Trainer::new(&mut model, TrainSource::Induction(task), tcfg);
        let summary = trainer.run().unwrap();
        assert_eq!(summary.steps_run, 12);
        assert!(summary.final_loss.is_finite());
        assert!(
            summary.final_loss < summary.initial_loss,
            "loss did not improve: {} -> {}",
            summary.initial_loss,
            summary.final_loss
        );
    }

    #[test]
    fn resume_continues_from_saved_step() {
        let dir = std::env::temp_dir().join("psf_train_resume_test");
        let path = dir.join("resume.ckpt");
        let task = InductionTask::standard(16);
        let lm_cfg = LmConfig {
            vocab: task.vocab(),
            d_model: 16,
            layers: 1,
            heads: 2,
            ff_mult: 2,
            seed: 9,
        };
        let mech = Mechanism::Flash { block: 8 };
        let tcfg = TrainConfig {
            steps: 6,
            batch: 4,
            eval_every: 0,
            echo_every: 0,
            ckpt_path: Some(path.clone()),
            optim: OptimConfig { total_steps: 6, ..Default::default() },
            ..Default::default()
        };
        // Train 6 steps, checkpointing at the end.
        let mut model = NativeLm::new(lm_cfg.clone(), mech.clone());
        Trainer::new(&mut model, TrainSource::Induction(task), tcfg.clone())
            .run()
            .unwrap();
        // Resume: the checkpoint restores params + optimizer at step 6,
        // so a run with steps = 6 has nothing left to do.
        let ck = crate::checkpoint::Checkpoint::load(&path).unwrap();
        let mut resumed = NativeLm::from_checkpoint(&ck).unwrap();
        assert_eq!(resumed.cfg, lm_cfg);
        let mut trainer =
            Trainer::new(&mut resumed, TrainSource::Induction(task), tcfg.clone());
        let at = trainer.resume_from(&ck).unwrap();
        assert_eq!(at, 6);
        let summary = trainer.run().unwrap();
        assert_eq!(summary.steps_run, 0, "resume at the end trains no further");
        // And the resumed model's weights equal the saved ones bitwise.
        let (saved, _) = NativeLm::load_checkpoint(&path).unwrap();
        assert_eq!(saved.params(), resumed.params());
    }
}
