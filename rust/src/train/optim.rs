//! AdamW with global-norm gradient clipping and a warmup + cosine
//! learning-rate schedule — the standard recipe the paper's training
//! setup uses, specialized to the named-tensor [`Params`] layout.
//!
//! Everything is sequential scalar arithmetic in the fixed `named()`
//! order, so optimizer updates are bitwise deterministic at any thread
//! count; moments serialize into checkpoint sections (`opt.m.<name>`,
//! `opt.v.<name>`) for exact `--resume`.

use crate::checkpoint::Checkpoint;
use crate::infer::Params;

/// Optimizer + schedule hyperparameters.
#[derive(Clone, Debug)]
pub struct OptimConfig {
    /// Peak learning rate (after warmup).
    pub lr: f32,
    /// Linear warmup steps from 0 to `lr`.
    pub warmup: u64,
    /// Total schedule length; cosine decays from `lr` at warmup end to
    /// `min_lr_frac·lr` at `total_steps`.
    pub total_steps: u64,
    pub min_lr_frac: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Decoupled weight decay (AdamW; 0 disables).
    pub weight_decay: f32,
    /// Global-norm gradient clip (0 disables).
    pub clip: f32,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig {
            lr: 3e-3,
            warmup: 20,
            total_steps: 1000,
            min_lr_frac: 0.1,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            clip: 1.0,
        }
    }
}

/// Per-step diagnostics.
#[derive(Clone, Copy, Debug)]
pub struct StepInfo {
    pub lr: f32,
    /// Pre-clip global gradient L2 norm.
    pub grad_norm: f64,
    pub clipped: bool,
}

/// AdamW state: first/second moments in the same `Params` shape as the
/// weights, plus the step counter driving bias correction and the
/// schedule.
pub struct AdamW {
    pub cfg: OptimConfig,
    step: u64,
    m: Params,
    v: Params,
}

impl AdamW {
    pub fn new(cfg: OptimConfig, params: &Params) -> AdamW {
        AdamW { cfg, step: 0, m: params.zeros_like(), v: params.zeros_like() }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Learning rate at (0-based) step `s`: linear warmup, then cosine
    /// from peak down to `min_lr_frac` of peak at `total_steps`.
    pub fn lr_at(&self, s: u64) -> f32 {
        let c = &self.cfg;
        if c.warmup > 0 && s < c.warmup {
            return c.lr * (s + 1) as f32 / c.warmup as f32;
        }
        let span = c.total_steps.saturating_sub(c.warmup).max(1) as f64;
        let t = ((s.saturating_sub(c.warmup)) as f64 / span).min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos()) as f32;
        let floor = c.lr * c.min_lr_frac;
        floor + (c.lr - floor) * cos
    }

    /// One AdamW update in place.  `grads` is the already batch-averaged
    /// gradient; clipping rescales it by `clip / max(clip, ‖g‖₂)`.
    pub fn step(&mut self, params: &mut Params, grads: &Params) -> StepInfo {
        let grad_norm = grads.l2_norm_sq().sqrt();
        let c = self.cfg.clone();
        let clip_scale = if c.clip > 0.0 && grad_norm > c.clip as f64 {
            (c.clip as f64 / grad_norm) as f32
        } else {
            1.0
        };
        let lr = self.lr_at(self.step);
        self.step += 1;
        let t = self.step as f64;
        // Bias-corrected step size folded into one scalar.
        let bc1 = 1.0 - (c.beta1 as f64).powf(t);
        let bc2 = 1.0 - (c.beta2 as f64).powf(t);
        let alpha = (lr as f64 * bc2.sqrt() / bc1) as f32;
        let g_named = grads.named();
        let mut m_named = self.m.named_mut();
        let mut v_named = self.v.named_mut();
        for (pi, (_, p)) in params.named_mut().into_iter().enumerate() {
            let g = g_named[pi].1.data();
            let m = m_named[pi].1.data_mut();
            let v = v_named[pi].1.data_mut();
            let pd = p.data_mut();
            for i in 0..pd.len() {
                let gi = g[i] * clip_scale;
                m[i] = c.beta1 * m[i] + (1.0 - c.beta1) * gi;
                v[i] = c.beta2 * v[i] + (1.0 - c.beta2) * gi * gi;
                // Decoupled weight decay, then the Adam step.
                pd[i] -= lr * c.weight_decay * pd[i];
                pd[i] -= alpha * m[i] / (v[i].sqrt() + c.eps);
            }
        }
        StepInfo { lr, grad_norm, clipped: clip_scale != 1.0 }
    }

    /// Write moments + step into checkpoint sections (on top of the
    /// model's `param.*`/`meta`/`mech` sections).
    pub fn add_to_checkpoint(&self, ck: &mut Checkpoint) {
        let mut meta: Vec<f32> = Vec::with_capacity(8);
        meta.extend(self.step.to_le_bytes().iter().map(|&b| b as f32));
        ck.sections.insert("opt.meta".into(), meta);
        for (name, t) in self.m.named() {
            ck.sections.insert(format!("opt.m.{name}"), t.data().to_vec());
        }
        for (name, t) in self.v.named() {
            ck.sections.insert(format!("opt.v.{name}"), t.data().to_vec());
        }
    }

    /// Restore moments + step from a checkpoint; returns false (leaving
    /// fresh state) when the checkpoint has no optimizer sections.
    pub fn restore_from_checkpoint(&mut self, ck: &Checkpoint) -> anyhow::Result<bool> {
        let Some(meta) = ck.get("opt.meta") else {
            return Ok(false);
        };
        anyhow::ensure!(meta.len() == 8, "opt.meta has {} entries, want 8", meta.len());
        let mut bytes = [0u8; 8];
        for (b, &v) in bytes.iter_mut().zip(meta) {
            *b = v as u8;
        }
        self.step = u64::from_le_bytes(bytes);
        for (name, t) in self.m.named_mut() {
            let key = format!("opt.m.{name}");
            let data = ck
                .get(&key)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing section {key}"))?;
            anyhow::ensure!(data.len() == t.len(), "section {key} length mismatch");
            t.data_mut().copy_from_slice(data);
        }
        for (name, t) in self.v.named_mut() {
            let key = format!("opt.v.{name}");
            let data = ck
                .get(&key)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing section {key}"))?;
            anyhow::ensure!(data.len() == t.len(), "section {key} length mismatch");
            t.data_mut().copy_from_slice(data);
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn params_1d(vals: Vec<f32>) -> Params {
        Params {
            embed: Tensor::from_vec(&[vals.len(), 1], vals),
            readout: Tensor::zeros(&[1, 1]),
            layers: vec![],
        }
    }

    #[test]
    fn warmup_then_cosine_decay() {
        let cfg = OptimConfig { lr: 1.0, warmup: 10, total_steps: 110, ..Default::default() };
        let opt = AdamW::new(cfg, &params_1d(vec![0.0]));
        assert!(opt.lr_at(0) < 0.2);
        assert!((opt.lr_at(9) - 1.0).abs() < 1e-6);
        assert!(opt.lr_at(60) < 1.0);
        assert!((opt.lr_at(10_000) - 0.1).abs() < 1e-6, "decays to the floor");
    }

    #[test]
    fn adam_descends_a_quadratic() {
        // Minimize f(x) = Σ x² — Adam should monotonically shrink it.
        let mut p = params_1d(vec![1.0, -2.0, 0.5]);
        let cfg = OptimConfig {
            lr: 0.05,
            warmup: 0,
            total_steps: 200,
            weight_decay: 0.0,
            clip: 0.0,
            ..Default::default()
        };
        let mut opt = AdamW::new(cfg, &p);
        let f = |p: &Params| p.embed.data().iter().map(|x| x * x).sum::<f32>();
        let f0 = f(&p);
        for _ in 0..100 {
            let g = Params {
                embed: p.embed.clone().scale(2.0),
                readout: Tensor::zeros(&[1, 1]),
                layers: vec![],
            };
            opt.step(&mut p, &g);
        }
        assert!(f(&p) < 0.05 * f0, "{} -> {}", f0, f(&p));
    }

    #[test]
    fn clipping_reports_and_bounds() {
        let mut p = params_1d(vec![0.0; 4]);
        let cfg = OptimConfig { clip: 1.0, warmup: 0, ..Default::default() };
        let mut opt = AdamW::new(cfg, &p);
        let g = params_1d(vec![10.0; 4]);
        let info = opt.step(&mut p, &g);
        assert!(info.clipped);
        assert!((info.grad_norm - 20.0).abs() < 1e-3);
    }

    #[test]
    fn moments_round_trip_through_checkpoint() {
        let mut p = params_1d(vec![1.0, 2.0]);
        let mut opt = AdamW::new(OptimConfig { warmup: 0, ..Default::default() }, &p);
        let g = params_1d(vec![0.3, -0.7]);
        opt.step(&mut p, &g);
        opt.step(&mut p, &g);
        let mut ck = Checkpoint::new(2);
        opt.add_to_checkpoint(&mut ck);
        let mut fresh = AdamW::new(opt.cfg.clone(), &p);
        assert!(fresh.restore_from_checkpoint(&ck).unwrap());
        assert_eq!(fresh.step_count(), 2);
        // Continuing from restored state matches continuing the original.
        let mut pa = p.clone();
        let mut pb = p.clone();
        opt.step(&mut pa, &g);
        fresh.step(&mut pb, &g);
        assert_eq!(pa.embed, pb.embed);
    }
}
