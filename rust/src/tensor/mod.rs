//! Minimal row-major f32 tensor substrate.
//!
//! Backs the native attention implementations, data preparation, and
//! checkpoint math.  Deliberately small: dense f32, up to a handful of
//! dims, the ops the repo actually needs — not a general ndarray clone.
//!
//! The matmuls and row-wise normalizations here are the crate's compute
//! floor, so they run on the deterministic parallel backend
//! (`exec::pool`): outputs are partitioned into fixed row chunks and each
//! row is produced by exactly the sequential inner loop — results are
//! bitwise identical at every thread count, and small shapes (decode
//! steps are 1-row) never leave the calling thread.

use crate::exec::pool;
use crate::util::rng::Pcg;

/// Shapes below this many multiply-accumulates run inline: the dispatch
/// cost would exceed the work, and the decode hot path (m = 1) must never
/// touch the pool.  Purely a latency gate — both paths are bitwise equal.
const PAR_MIN_FLOPS: usize = 32 * 1024;

/// Minimum output rows per parallel chunk for the matmul family.
const PAR_MIN_ROWS: usize = 4;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} vs data len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn gaussian(rng: &mut Pcg, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.gaussians(n) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D accessors -------------------------------------------------

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// C = A @ B for 2-D tensors. Simple ikj loop with row-major access —
    /// the hot-path variants live in attn/ where tile sizes are known.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, ka) = (self.rows(), self.cols());
        let (kb, n) = (other.rows(), other.cols());
        assert_eq!(ka, kb, "matmul {}x{} @ {}x{}", m, ka, kb, n);
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into(self.data(), other.data(), out.data_mut(), m, ka, n);
        out
    }

    /// C = A @ B^T.  Row-parallel over C; each row runs the sequential
    /// dot loop, so results are thread-count independent bit for bit.
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        let (m, ka) = (self.rows(), self.cols());
        let (n, kb) = (other.rows(), other.cols());
        assert_eq!(ka, kb);
        let mut out = Tensor::zeros(&[m, n]);
        if out.is_empty() {
            return out;
        }
        let kernel = |row0: usize, chunk: &mut [f32]| {
            for (r, orow) in chunk.chunks_mut(n).enumerate() {
                let a = self.row(row0 + r);
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = dot(a, other.row(j));
                }
            }
        };
        if m.saturating_mul(ka).saturating_mul(n) < PAR_MIN_FLOPS {
            kernel(0, out.data_mut());
        } else {
            pool::par_row_chunks(out.data_mut(), n, PAR_MIN_ROWS, kernel);
        }
        out
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.set2(j, i, self.at2(i, j));
            }
        }
        out
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn hadamard(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn scale(self, s: f32) -> Tensor {
        self.map(|v| v * s)
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Max |a - b| between same-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Parameter-free layer normalization over the last axis of a 2-D tensor
/// (matches python/compile/common.py::layernorm, eps = 1e-6).
pub fn layernorm_rows(x: &Tensor) -> Tensor {
    let (m, n) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[m, n]);
    if out.is_empty() {
        return out;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let row = x.row(row0 + r);
            let mean: f32 = row.iter().sum::<f32>() / n as f32;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
            let inv = 1.0 / (var + 1e-6).sqrt();
            for (o, &v) in orow.iter_mut().zip(row) {
                *o = (v - mean) * inv;
            }
        }
    };
    if m * n < PAR_MIN_FLOPS {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_chunks(out.data_mut(), n, 16, kernel);
    }
    out
}

/// Numerically-stable softmax over each row.
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let (m, n) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[m, n]);
    if out.is_empty() {
        return out;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let row = x.row(row0 + r);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for (o, &v) in orow.iter_mut().zip(row) {
                let e = (v - mx).exp();
                *o = e;
                sum += e;
            }
            for v in orow.iter_mut() {
                *v /= sum;
            }
        }
    };
    if m * n < PAR_MIN_FLOPS {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_chunks(out.data_mut(), n, 16, kernel);
    }
    out
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    // 4-wide unroll: lets LLVM vectorize without unsafe.
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    for i in chunks * 4..a.len() {
        acc += a[i] * b[i];
    }
    acc + s0 + s1 + s2 + s3
}

/// out += a_row (x) scale — axpy helper used by the attention inner loops.
#[inline]
pub fn axpy(out: &mut [f32], a: &[f32], scale: f32) {
    debug_assert_eq!(out.len(), a.len());
    for i in 0..out.len() {
        out[i] += a[i] * scale;
    }
}

/// Plain row-major matmul into preallocated storage: C(m,n) = A(m,k) B(k,n).
/// Row-parallel above [`PAR_MIN_FLOPS`]; every C row is produced by the
/// same ikj loop (zero-skip included) regardless of thread count.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    if c.is_empty() {
        return;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        chunk.fill(0.0);
        for (r, crow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + r;
            for kk in 0..k {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                axpy(crow, &b[kk * n..(kk + 1) * n], av);
            }
        }
    };
    if m.saturating_mul(k).saturating_mul(n) < PAR_MIN_FLOPS {
        kernel(0, c);
    } else {
        pool::par_row_chunks(c, n, PAR_MIN_ROWS, kernel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_agrees_with_explicit_transpose() {
        let mut rng = Pcg::seeded(0);
        let a = Tensor::gaussian(&mut rng, &[5, 7]);
        let b = Tensor::gaussian(&mut rng, &[6, 7]);
        let got = a.matmul_t(&b);
        let want = a.matmul(&b.transpose2());
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn layernorm_rows_zero_mean_unit_var() {
        let mut rng = Pcg::seeded(1);
        let x = Tensor::gaussian(&mut rng, &[4, 64]).scale(3.0);
        let y = layernorm_rows(&x);
        for i in 0..4 {
            let row = y.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn softmax_rows_sum_one() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1000.0]);
        let y = softmax_rows(&x);
        for i in 0..2 {
            let s: f32 = y.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(y.at2(1, 2) > 0.999);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), naive);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        let _ = a.matmul(&b);
    }

    #[test]
    fn parallel_matmul_bitwise_matches_serial() {
        // Shapes chosen to clear PAR_MIN_FLOPS so the pooled path runs.
        let mut rng = Pcg::seeded(9);
        let a = Tensor::gaussian(&mut rng, &[96, 48]);
        let b = Tensor::gaussian(&mut rng, &[48, 80]);
        let bt = b.transpose2();
        let pooled = (a.matmul(&b), a.matmul_t(&bt));
        let inline = crate::exec::pool::serial(|| (a.matmul(&b), a.matmul_t(&bt)));
        assert_eq!(pooled.0, inline.0);
        assert_eq!(pooled.1, inline.1);
    }

    #[test]
    fn parallel_rowwise_ops_bitwise_match_serial() {
        let mut rng = Pcg::seeded(10);
        let x = Tensor::gaussian(&mut rng, &[512, 96]).scale(2.0);
        let pooled = (layernorm_rows(&x), softmax_rows(&x));
        let inline = crate::exec::pool::serial(|| (layernorm_rows(&x), softmax_rows(&x)));
        assert_eq!(pooled.0, inline.0);
        assert_eq!(pooled.1, inline.1);
    }
}
