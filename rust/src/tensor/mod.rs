//! Minimal row-major f32 tensor substrate.
//!
//! Backs the native attention implementations, data preparation, and
//! checkpoint math.  Deliberately small: dense f32, up to a handful of
//! dims, the ops the repo actually needs — not a general ndarray clone.
//!
//! The matmuls and row-wise normalizations here are the crate's compute
//! floor, so they run on the deterministic parallel backend
//! (`exec::pool`): outputs are partitioned into fixed row chunks and each
//! row is produced by exactly the sequential inner loop — results are
//! bitwise identical at every thread count, and small shapes (decode
//! steps are 1-row) never leave the calling thread.
//!
//! The inner loops themselves live one layer down, in [`micro`]: every
//! dot product, GEMM row tile, axpy, and row reduction dispatches to the
//! microkernel backend (scalar reference or runtime-detected SSE2/AVX2),
//! all of which share the fixed lane-width-8 reduction-tree order — so
//! "bitwise identical" extends across SIMD backends too.

pub mod micro;

use crate::exec::pool;
use crate::util::rng::Pcg;

pub use micro::{axpy, dot};

/// Shapes below this many multiply-accumulates run inline: the dispatch
/// cost would exceed the work, and the decode hot path (m = 1) must never
/// touch the pool.  Purely a latency gate — both paths are bitwise equal.
const PAR_MIN_FLOPS: usize = 32 * 1024;

/// Minimum output rows per parallel chunk for the matmul family.
const PAR_MIN_ROWS: usize = 4;

/// Read access to a row-major 2-D f32 matrix — implemented by [`Tensor`]
/// (stride == cols) and [`TensorView`] (arbitrary row stride).  The
/// attention kernels are generic over this trait so per-head column
/// stripes of a fused (n, n_heads·head_dim) projection can be consumed
/// in place instead of being copied into per-head tensors.
pub trait RowMat: Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn row(&self, i: usize) -> &[f32];
}

/// Borrowed strided view of a row-major matrix: `rows` rows of `cols`
/// elements, consecutive rows `stride` elements apart.  `Copy`, cheap to
/// construct, and `Sync` — safe to hand to the deterministic pool.
#[derive(Clone, Copy, Debug)]
pub struct TensorView<'a> {
    data: &'a [f32],
    rows: usize,
    cols: usize,
    stride: usize,
}

impl<'a> TensorView<'a> {
    /// View over `data` starting at its first element.  Requires the last
    /// row to fit: `(rows-1)*stride + cols <= data.len()`.
    pub fn new(data: &'a [f32], rows: usize, cols: usize, stride: usize) -> TensorView<'a> {
        assert!(cols <= stride || rows <= 1, "view cols {cols} exceed stride {stride}");
        assert!(
            rows == 0 || (rows - 1) * stride + cols <= data.len(),
            "view {rows}x{cols} (stride {stride}) exceeds buffer of {}",
            data.len()
        );
        TensorView { data, rows, cols, stride }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn row(&self, i: usize) -> &'a [f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.stride..i * self.stride + self.cols]
    }

    /// Materialize the view into an owned contiguous tensor.
    pub fn to_tensor(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(self.row(i));
        }
        out
    }
}

impl RowMat for TensorView<'_> {
    fn rows(&self) -> usize {
        TensorView::rows(self)
    }

    fn cols(&self) -> usize {
        TensorView::cols(self)
    }

    fn row(&self, i: usize) -> &[f32] {
        TensorView::row(self, i)
    }
}

/// Mutable strided view.  Built from a `&mut Tensor`, possibly several at
/// once over *disjoint column stripes* (`head_views_mut`), which is what
/// lets every head of a fused attention output be written in place, in
/// parallel, with no concat copy.
pub struct TensorViewMut<'a> {
    ptr: *mut f32,
    rows: usize,
    cols: usize,
    stride: usize,
    _marker: std::marker::PhantomData<&'a mut f32>,
}

// SAFETY: a TensorViewMut grants exclusive access to its own (disjoint)
// element set — see the constructors — so moving it to another thread is
// no different from moving a `&mut [f32]`.
unsafe impl Send for TensorViewMut<'_> {}

impl TensorViewMut<'_> {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        // SAFETY: constructor guarantees the row lies inside the buffer
        // and this view exclusively owns its element set.
        unsafe { std::slice::from_raw_parts(self.ptr.add(i * self.stride), self.cols) }
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        // SAFETY: as above, plus `&mut self` makes the access unique.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(i * self.stride), self.cols) }
    }

    /// Copy a same-shaped matrix into the view row by row.
    pub fn copy_from(&mut self, src: &impl RowMat) {
        assert_eq!((self.rows, self.cols), (src.rows(), src.cols()));
        for i in 0..self.rows {
            self.row_mut(i).copy_from_slice(src.row(i));
        }
    }
}

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl RowMat for Tensor {
    fn rows(&self) -> usize {
        Tensor::rows(self)
    }

    fn cols(&self) -> usize {
        Tensor::cols(self)
    }

    fn row(&self, i: usize) -> &[f32] {
        Tensor::row(self, i)
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} vs data len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn gaussian(rng: &mut Pcg, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.gaussians(n) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D accessors -------------------------------------------------

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// C = A @ B for 2-D tensors. Simple ikj loop with row-major access —
    /// the hot-path variants live in attn/ where tile sizes are known.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, ka) = (self.rows(), self.cols());
        let (kb, n) = (other.rows(), other.cols());
        assert_eq!(ka, kb, "matmul {}x{} @ {}x{}", m, ka, kb, n);
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into(self.data(), other.data(), out.data_mut(), m, ka, n);
        out
    }

    /// C = A @ B^T.  Row-parallel over C; each row runs the sequential
    /// dot loop, so results are thread-count independent bit for bit.
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        let (m, ka) = (self.rows(), self.cols());
        let (n, kb) = (other.rows(), other.cols());
        assert_eq!(ka, kb);
        let mut out = Tensor::zeros(&[m, n]);
        if out.is_empty() {
            return out;
        }
        let kernel = |row0: usize, chunk: &mut [f32]| {
            for (r, orow) in chunk.chunks_mut(n).enumerate() {
                // Fused dot-rows over B's packed rows (one tile call per
                // C row instead of n separate dots).
                micro::dot_rows(self.row(row0 + r), other.data(), orow);
            }
        };
        if m.saturating_mul(ka).saturating_mul(n) < PAR_MIN_FLOPS {
            kernel(0, out.data_mut());
        } else {
            pool::par_row_chunks(out.data_mut(), n, PAR_MIN_ROWS, kernel);
        }
        out
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.set2(j, i, self.at2(i, j));
            }
        }
        out
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn hadamard(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn scale(self, s: f32) -> Tensor {
        self.map(|v| v * s)
    }

    pub fn frob_norm(&self) -> f32 {
        micro::dot(&self.data, &self.data).sqrt()
    }

    /// Borrowed full view of a 2-D tensor.
    pub fn view(&self) -> TensorView<'_> {
        let (m, n) = (self.rows(), self.cols());
        TensorView::new(&self.data, m, n, n)
    }

    /// Mutable full view of a 2-D tensor.
    pub fn view_mut(&mut self) -> TensorViewMut<'_> {
        let (m, n) = (self.rows(), self.cols());
        TensorViewMut {
            ptr: self.data.as_mut_ptr(),
            rows: m,
            cols: n,
            stride: n,
            _marker: std::marker::PhantomData,
        }
    }

    /// Split a fused (n, heads·hd) matrix into one read view per head —
    /// column stripe `h*hd..(h+1)*hd` of every row, no copies.
    pub fn head_views(&self, heads: usize) -> Vec<TensorView<'_>> {
        let (m, n) = (self.rows(), self.cols());
        assert!(heads > 0 && n % heads == 0, "cols {n} not divisible into {heads} heads");
        let hd = n / heads;
        (0..heads)
            .map(|h| {
                let lo = h * hd;
                // Trim the slice so the view's last row ends inside it.
                let hi = if m == 0 { lo } else { (m - 1) * n + lo + hd };
                TensorView::new(&self.data[lo..hi.max(lo)], m, hd, n)
            })
            .collect()
    }

    /// Split a fused (n, heads·hd) matrix into one *mutable* view per
    /// head.  The stripes are disjoint element sets, so handing them to
    /// concurrent pool tasks is sound — this is how each head's attention
    /// output lands directly in the fused buffer with no concat copy.
    pub fn head_views_mut(&mut self, heads: usize) -> Vec<TensorViewMut<'_>> {
        let (m, n) = (self.rows(), self.cols());
        assert!(heads > 0 && n % heads == 0, "cols {n} not divisible into {heads} heads");
        let hd = n / heads;
        let base = self.data.as_mut_ptr();
        (0..heads)
            .map(|h| TensorViewMut {
                // SAFETY: stripe h covers elements {i*n + h*hd .. +hd} for
                // each row i — disjoint from every other stripe; the views
                // borrow `self` mutably for their whole lifetime.
                ptr: unsafe { base.add(h * hd) },
                rows: m,
                cols: hd,
                stride: n,
                _marker: std::marker::PhantomData,
            })
            .collect()
    }

    /// Max |a - b| between same-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Parameter-free layer normalization of one row — identical arithmetic
/// to [`layernorm_rows`] (eps 1e-6), applied per token on the decode hot
/// path.
pub fn ln_row(x: &[f32]) -> Vec<f32> {
    let (mean, inv) = micro::ln_stats(x, 1e-6);
    let mut out = vec![0.0f32; x.len()];
    micro::norm_scale(&mut out, x, mean, inv);
    out
}

/// VJP of [`ln_row`]: given the raw row `x` and the gradient `dy` w.r.t.
/// the normalized output, return the gradient w.r.t. `x`.
///
/// With μ = mean(x), σ² = var(x) + eps, y = (x − μ)/σ the closed form is
/// `dx = (dy − mean(dy) − y·mean(dy ⊙ y)) / σ` — the parameter-free
/// specialization of the usual layernorm backward.
pub fn ln_row_vjp(x: &[f32], dy: &[f32]) -> Vec<f32> {
    let n = x.len();
    debug_assert_eq!(dy.len(), n);
    let (mean, inv) = micro::ln_stats(x, 1e-6);
    let mut y = vec![0.0f32; n];
    micro::norm_scale(&mut y, x, mean, inv);
    let dy_mean = micro::sum(dy) / n as f32;
    let dyy_mean = micro::dot(dy, &y) / n as f32;
    y.iter()
        .zip(dy)
        .map(|(&yv, &dv)| (dv - dy_mean - yv * dyy_mean) * inv)
        .collect()
}

/// Tanh-approximation GELU (python/compile/common.py's activation).  Lives
/// here (not in the model) because both the forward model and the training
/// subsystem's backward need the identical scalar function.
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((2.0 / std::f32::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
}

/// d gelu(x) / dx for the tanh approximation above.
pub fn gelu_grad(x: f32) -> f32 {
    let c = (2.0 / std::f32::consts::PI).sqrt();
    let u = c * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * c * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Parameter-free layer normalization over the last axis of a 2-D matrix
/// (matches python/compile/common.py::layernorm, eps = 1e-6).
pub fn layernorm_rows(x: &impl RowMat) -> Tensor {
    let (m, n) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[m, n]);
    if out.is_empty() {
        return out;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let row = x.row(row0 + r);
            let (mean, inv) = micro::ln_stats(row, 1e-6);
            micro::norm_scale(orow, row, mean, inv);
        }
    };
    if m * n < PAR_MIN_FLOPS {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_chunks(out.data_mut(), n, 16, kernel);
    }
    out
}

/// Numerically-stable softmax over each row.
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let (m, n) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(&[m, n]);
    if out.is_empty() {
        return out;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let row = x.row(row0 + r);
            let mx = micro::row_max(row);
            micro::exp_sub(orow, row, mx);
            let sum = micro::sum(orow);
            for v in orow.iter_mut() {
                *v /= sum;
            }
        }
    };
    if m * n < PAR_MIN_FLOPS {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_chunks(out.data_mut(), n, 16, kernel);
    }
    out
}

/// Plain row-major matmul into preallocated storage: C(m,n) = A(m,k) B(k,n).
/// Row-parallel above [`PAR_MIN_FLOPS`]; every C row is one
/// [`micro::gemm_row`] tile (zero-skip included) regardless of thread
/// count or backend.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    if c.is_empty() {
        return;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        chunk.fill(0.0);
        for (r, crow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + r;
            micro::gemm_row(crow, &a[i * k..(i + 1) * k], b);
        }
    };
    if m.saturating_mul(k).saturating_mul(n) < PAR_MIN_FLOPS {
        kernel(0, c);
    } else {
        pool::par_row_chunks(c, n, PAR_MIN_ROWS, kernel);
    }
}

/// C = A @ B where A is any [`RowMat`] (possibly a strided view) and B
/// is an owned tensor.  Per-row operation order is identical to
/// [`matmul_into`]'s (the same [`micro::gemm_row`] tile), so a view and
/// its copied tensor produce the same bytes.
pub fn matmul_rowmat(a: &impl RowMat, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (kb, n) = (b.rows(), b.cols());
    assert_eq!(k, kb, "matmul {}x{} @ {}x{}", m, k, kb, n);
    let mut out = Tensor::zeros(&[m, n]);
    if out.is_empty() {
        return out;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        chunk.fill(0.0);
        for (r, crow) in chunk.chunks_mut(n).enumerate() {
            micro::gemm_row(crow, a.row(row0 + r), b.data());
        }
    };
    if m.saturating_mul(k).saturating_mul(n) < PAR_MIN_FLOPS {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_chunks(out.data_mut(), n, PAR_MIN_ROWS, kernel);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_rowmat_bitwise_matches_matmul() {
        let mut rng = Pcg::seeded(13);
        let a = Tensor::gaussian(&mut rng, &[9, 12]);
        let b = Tensor::gaussian(&mut rng, &[12, 7]);
        assert_eq!(matmul_rowmat(&a, &b), a.matmul(&b));
        // A strided head view agrees with its materialized copy.
        let fused = Tensor::gaussian(&mut rng, &[9, 24]);
        let view = fused.head_views(2)[1];
        let c = Tensor::gaussian(&mut rng, &[12, 5]);
        assert_eq!(matmul_rowmat(&view, &c), matmul_rowmat(&view.to_tensor(), &c));
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_agrees_with_explicit_transpose() {
        let mut rng = Pcg::seeded(0);
        let a = Tensor::gaussian(&mut rng, &[5, 7]);
        let b = Tensor::gaussian(&mut rng, &[6, 7]);
        let got = a.matmul_t(&b);
        let want = a.matmul(&b.transpose2());
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn layernorm_rows_zero_mean_unit_var() {
        let mut rng = Pcg::seeded(1);
        let x = Tensor::gaussian(&mut rng, &[4, 64]).scale(3.0);
        let y = layernorm_rows(&x);
        for i in 0..4 {
            let row = y.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn softmax_rows_sum_one() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1000.0]);
        let y = softmax_rows(&x);
        for i in 0..2 {
            let s: f32 = y.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(y.at2(1, 2) > 0.999);
    }

    #[test]
    fn dot_matches_naive() {
        // Small exact integers: every product and partial sum is exactly
        // representable, so the lane-tree reduction agrees with the
        // sequential sum bit for bit here.  (The tree order itself is
        // pinned by tensor::micro's own tests and tests/properties.rs.)
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), naive);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        let _ = a.matmul(&b);
    }

    #[test]
    fn ln_row_matches_layernorm_rows() {
        let mut rng = Pcg::seeded(3);
        let x = Tensor::gaussian(&mut rng, &[4, 16]).scale(2.5);
        let want = layernorm_rows(&x);
        for i in 0..4 {
            assert_eq!(ln_row(x.row(i)).as_slice(), want.row(i));
        }
    }

    #[test]
    fn head_views_cover_column_stripes() {
        let mut rng = Pcg::seeded(21);
        let t = Tensor::gaussian(&mut rng, &[5, 12]);
        let views = t.head_views(3);
        assert_eq!(views.len(), 3);
        for (h, v) in views.iter().enumerate() {
            assert_eq!((v.rows(), v.cols()), (5, 4));
            for i in 0..5 {
                assert_eq!(v.row(i), &t.row(i)[h * 4..(h + 1) * 4], "head {h} row {i}");
            }
        }
        // A view round-trips through to_tensor and layernorm_rows agrees
        // with the layernorm of the copied stripe.
        let copied = views[1].to_tensor();
        assert_eq!(layernorm_rows(&views[1]), layernorm_rows(&copied));
    }

    #[test]
    fn head_views_mut_write_disjoint_stripes() {
        let mut t = Tensor::zeros(&[4, 6]);
        {
            let mut views = t.head_views_mut(2);
            for (h, v) in views.iter_mut().enumerate() {
                for i in 0..v.rows() {
                    let c = v.cols();
                    v.row_mut(i).copy_from_slice(
                        &(0..c).map(|j| (h * 100 + i * 10 + j) as f32).collect::<Vec<_>>(),
                    );
                }
            }
        }
        for i in 0..4 {
            for j in 0..6 {
                let h = j / 3;
                assert_eq!(t.at2(i, j), (h * 100 + i * 10 + (j % 3)) as f32);
            }
        }
    }

    #[test]
    fn view_mut_copy_from_view() {
        let mut rng = Pcg::seeded(22);
        let src = Tensor::gaussian(&mut rng, &[6, 8]);
        let mut dst = Tensor::zeros(&[6, 16]);
        dst.head_views_mut(2)[1].copy_from(&src.view());
        for i in 0..6 {
            assert_eq!(&dst.row(i)[8..], src.row(i));
            assert!(dst.row(i)[..8].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn ln_row_vjp_matches_finite_difference() {
        let mut rng = Pcg::seeded(40);
        let x: Vec<f32> = rng.gaussians(12);
        let dy: Vec<f32> = rng.gaussians(12);
        let an = ln_row_vjp(&x, &dy);
        let loss = |x: &[f32]| -> f64 {
            ln_row(x).iter().zip(&dy).map(|(&y, &d)| (y as f64) * (d as f64)).sum()
        };
        let eps = 1e-3f32;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
            let a = an[i] as f64;
            assert!(
                (fd - a).abs() <= 1e-2 * (1.0 + fd.abs().max(a.abs())),
                "coord {i}: fd {fd} vs analytic {a}"
            );
        }
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-3.0f32, -1.0, -0.1, 0.0, 0.2, 1.5, 4.0] {
            let eps = 1e-3f32;
            let fd = ((gelu(x + eps) - gelu(x - eps)) / (2.0 * eps)) as f64;
            let an = gelu_grad(x) as f64;
            assert!((fd - an).abs() < 1e-3 * (1.0 + fd.abs()), "x={x}: {fd} vs {an}");
        }
    }

    #[test]
    fn parallel_matmul_bitwise_matches_serial() {
        // Shapes chosen to clear PAR_MIN_FLOPS so the pooled path runs.
        let mut rng = Pcg::seeded(9);
        let a = Tensor::gaussian(&mut rng, &[96, 48]);
        let b = Tensor::gaussian(&mut rng, &[48, 80]);
        let bt = b.transpose2();
        let pooled = (a.matmul(&b), a.matmul_t(&bt));
        let inline = crate::exec::pool::serial(|| (a.matmul(&b), a.matmul_t(&bt)));
        assert_eq!(pooled.0, inline.0);
        assert_eq!(pooled.1, inline.1);
    }

    #[test]
    fn parallel_rowwise_ops_bitwise_match_serial() {
        let mut rng = Pcg::seeded(10);
        let x = Tensor::gaussian(&mut rng, &[512, 96]).scale(2.0);
        let pooled = (layernorm_rows(&x), softmax_rows(&x));
        let inline = crate::exec::pool::serial(|| (layernorm_rows(&x), softmax_rows(&x)));
        assert_eq!(pooled.0, inline.0);
        assert_eq!(pooled.1, inline.1);
    }
}
