//! The microkernel layer: every f32 inner loop in the crate, behind one
//! trait with two interchangeable backends.
//!
//! Everything numeric above this module — the matmul family in
//! `tensor/`, the block lower-triangular linear engine, the sketch and
//! Performer feature maps, softmax/flash/poly attention, and the
//! training VJPs — is written against the free functions here ([`dot`],
//! [`gemm_row`], [`axpy`], [`outer_accum`], …).  They dispatch to one of
//! two [`MicroKernel`] backends:
//!
//! * [`scalar::Scalar`] — the portable reference implementation.  This
//!   *is* the numeric spec: what it computes, bit for bit, is what every
//!   other backend must compute.
//! * [`simd::Sse2`] / [`simd::Avx2`] — `std::arch` x86_64
//!   implementations behind the `simd` cargo feature, selected at
//!   runtime via CPU-feature detection.
//!
//! ## The lane-tree reduction order (determinism invariant #11)
//!
//! Every reduction (dot products, row sums, squared-deviation sums) uses
//! one fixed **lane-width-8 reduction tree**, regardless of backend:
//!
//! * 8 independent accumulator lanes; element `i` is accumulated into
//!   lane `i % 8`, in increasing-`i` order (so a ragged tail of length
//!   `r` lands in lanes `0..r`);
//! * the 8 lanes are combined in the fixed tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` ([`lane_tree`]).
//!
//! An 8-lane accumulator is exactly one AVX2 `ymm` register (or two SSE2
//! `xmm` registers), so the SIMD backends implement the spec natively
//! while the scalar backend walks the same lanes one element at a time.
//! Together with three more rules the result is bitwise identical across
//! backends, CPU features, and thread counts:
//!
//! * **no FMA** — every multiply-accumulate is a rounded multiply
//!   followed by a rounded add (`_mm256_mul_ps` + `_mm256_add_ps`, never
//!   `_mm256_fmadd_ps`), because fused rounding would diverge from SSE2
//!   and scalar;
//! * **transcendentals stay scalar** — `exp`/`tanh`(gelu) call libm per
//!   element in every backend; a vectorized polynomial would be a second
//!   numeric spec;
//! * **zero-skip is part of the spec** — accumulate primitives skip
//!   coefficients that compare `== 0.0` (so `0 × ∞` never manufactures a
//!   NaN in padded/ragged blocks), identically in every backend.
//!
//! Elementwise primitives (axpy, scale, outer product) are a single
//! rounded op sequence per element and are therefore backend-identical
//! by IEEE-754 semantics alone.  Max folds ([`row_max`]) stay a
//! sequential scalar fold because packed-max NaN semantics differ from
//! `f32::max`.
//!
//! ## Backend selection
//!
//! The active backend is chosen once per process from `PSF_SIMD`
//! (`auto` | `off` | `avx2` | `sse2`, default `auto`) clamped to what
//! the CPU and the `simd` cargo feature actually provide, and is
//! reported by serve `/healthz`.  [`force_backend`] exists for tests and
//! benches; flipping backends mid-run is benign *because* of the
//! invariant above — every backend produces the same bytes.

pub mod scalar;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub mod simd;

use std::sync::atomic::{AtomicU8, Ordering};

/// Width of the reduction tree: 8 lanes == one AVX2 register of f32.
pub const LANES: usize = 8;

/// Which [`MicroKernel`] implementation services the free functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable reference implementation — the numeric spec.
    Scalar,
    /// x86_64 SSE2 (baseline on every x86_64 CPU).
    Sse2,
    /// x86_64 AVX2 (runtime-detected).
    Avx2,
}

impl Backend {
    pub fn label(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Sse2 => "sse2",
            Backend::Avx2 => "avx2",
        }
    }

    fn code(self) -> u8 {
        match self {
            Backend::Scalar => 1,
            Backend::Sse2 => 2,
            Backend::Avx2 => 3,
        }
    }

    fn from_code(c: u8) -> Option<Backend> {
        match c {
            1 => Some(Backend::Scalar),
            2 => Some(Backend::Sse2),
            3 => Some(Backend::Avx2),
            _ => None,
        }
    }
}

/// The tiled f32 primitive set.  Two implementors: [`scalar::Scalar`]
/// (the spec) and the `std::arch` backends in [`simd`].  The
/// transcendental row primitives have default bodies that call scalar
/// libm per element — backends must **not** override them (that is the
/// spec: see the module docs).
pub trait MicroKernel {
    fn name(&self) -> &'static str;

    /// Lane-tree dot product of two equal-length rows.
    fn dot(&self, a: &[f32], b: &[f32]) -> f32;

    /// Fused dot-rows: `out[j] = dot(a, b[j*a.len() .. (j+1)*a.len()])`
    /// for each of the `out.len()` packed rows of `b`.
    fn dot_rows(&self, a: &[f32], b: &[f32], out: &mut [f32]);

    /// Lane-tree sum of a row.
    fn sum(&self, a: &[f32]) -> f32;

    /// Lane-tree sum of squared deviations `Σ (a[i]-mean)²`.
    fn sq_dev_sum(&self, a: &[f32], mean: f32) -> f32;

    /// `out[i] += a[i] * s`.
    fn axpy(&self, out: &mut [f32], a: &[f32], s: f32);

    /// `out[i] = a[i] * s`.
    fn scale(&self, out: &mut [f32], a: &[f32], s: f32);

    /// `out[i] *= s`.
    fn scale_inplace(&self, out: &mut [f32], s: f32);

    /// `out[i] *= a[i]`.
    fn mul_inplace(&self, out: &mut [f32], a: &[f32]);

    /// `out[i] = (a[i] - mean) * inv` — the layernorm normalize step.
    fn norm_scale(&self, out: &mut [f32], a: &[f32], mean: f32, inv: f32);

    /// Packed GEMM row tile: `c[j] += Σ_k a[k] · b[k*c.len() + j]`, the
    /// `k` additions in increasing-`k` order per element, coefficients
    /// `a[k] == 0.0` skipped.  `b` is `a.len()` packed rows of `c.len()`.
    fn gemm_row(&self, c: &mut [f32], a: &[f32], b: &[f32]);

    /// Outer product, overwrite: `out[i*b.len()+j] = a[i] * b[j]`.
    fn outer(&self, out: &mut [f32], a: &[f32], b: &[f32]);

    /// Outer-product accumulate: `z[i*b.len()+j] += a[i] * b[j]`, rows
    /// with `a[i] == 0.0` skipped.
    fn outer_accum(&self, z: &mut [f32], a: &[f32], b: &[f32]);

    /// Lane-tree dot of an f32 row against an int8 row sharing one
    /// scale: element `i` contributes `a[i] · (q[i] as f32 · scale)` —
    /// the int→float conversion is exact, then two rounded multiplies,
    /// so every backend produces identical bytes.
    fn dot_q8(&self, a: &[f32], q: &[i8], scale: f32) -> f32;

    /// Packed GEMM row tile over per-row-quantized int8 weights:
    /// `c[j] += Σ_k (a[k]·scales[k]) · (q[k*c.len()+j] as f32)`, the
    /// per-row weight `w = a[k]·scales[k]` computed once (one scalar
    /// rounding) and rows with `w == 0.0` skipped — the zero-skip rule,
    /// which also covers all-zero quantized rows (`scales[k] == 0`).
    /// Accumulation is f32 throughout.
    fn gemm_row_q8(&self, c: &mut [f32], a: &[f32], q: &[i8], scales: &[f32]);

    /// `out[i] = q[i] as f32 · scale` — dequantize one int8 row (exact
    /// conversion, one rounded multiply).
    fn dequant_row(&self, out: &mut [f32], q: &[i8], scale: f32);

    /// `out[i] = exp(x[i] - mx)` — scalar libm per element (spec).
    fn exp_sub(&self, out: &mut [f32], x: &[f32], mx: f32) {
        debug_assert_eq!(out.len(), x.len());
        for (o, &v) in out.iter_mut().zip(x) {
            *o = (v - mx).exp();
        }
    }

    /// In-place tanh-approximation GELU — scalar libm per element (spec).
    fn gelu_rows(&self, x: &mut [f32]) {
        for v in x.iter_mut() {
            *v = crate::tensor::gelu(*v);
        }
    }
}

/// The fixed combine order for the 8 accumulator lanes.  This exact
/// association is the spec — changing it re-blesses every golden.
#[inline]
pub fn lane_tree(l: &[f32; LANES]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Sequential scalar max fold (`f32::max`, NaN-ignoring) — shared by all
/// backends; packed-max NaN semantics differ, so this never vectorizes.
#[inline]
pub fn row_max(a: &[f32]) -> f32 {
    a.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
}

/// Layernorm row statistics via the lane-tree reductions: returns
/// `(mean, 1/sqrt(var + eps))`.
#[inline]
pub fn ln_stats(x: &[f32], eps: f32) -> (f32, f32) {
    let n = x.len() as f32;
    let mean = sum(x) / n;
    let var = sq_dev_sum(x, mean) / n;
    (mean, 1.0 / (var + eps).sqrt())
}

const UNINIT: u8 = 0;
static ACTIVE: AtomicU8 = AtomicU8::new(UNINIT);

/// The backend servicing the free functions, initialized on first use
/// from `PSF_SIMD` + CPU detection.
#[inline]
pub fn active() -> Backend {
    match Backend::from_code(ACTIVE.load(Ordering::Relaxed)) {
        Some(b) => b,
        None => init_active(),
    }
}

#[cold]
fn init_active() -> Backend {
    let b = detect_from_env();
    ACTIVE.store(b.code(), Ordering::Relaxed);
    b
}

/// `"scalar"` / `"sse2"` / `"avx2"` — surfaced by serve `/healthz`.
pub fn backend_label() -> &'static str {
    active().label()
}

/// Whether `b` can run on this build + CPU.
pub fn available(b: Backend) -> bool {
    match b {
        Backend::Scalar => true,
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Backend::Sse2 => true,
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Backend::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => false,
    }
}

/// Best backend this build + CPU supports (what `PSF_SIMD=auto` picks).
pub fn best_available() -> Backend {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
        return Backend::Sse2;
    }
    #[allow(unreachable_code)]
    Backend::Scalar
}

/// Clamp a requested backend to what is actually available: an
/// unavailable request falls back to the best available at or below it
/// (`avx2` → `sse2` → `scalar`), never silently above it.
fn clamp_to_available(req: Backend) -> Backend {
    if available(req) {
        return req;
    }
    match req {
        Backend::Avx2 if available(Backend::Sse2) => Backend::Sse2,
        _ => Backend::Scalar,
    }
}

/// Parse `PSF_SIMD` (`auto` | `off` | `avx2` | `sse2`; unset or unknown
/// values mean `auto`) and clamp to availability.
fn detect_from_env() -> Backend {
    let req = std::env::var("PSF_SIMD").unwrap_or_default();
    match req.trim().to_ascii_lowercase().as_str() {
        "off" | "scalar" | "0" => Backend::Scalar,
        "sse2" => clamp_to_available(Backend::Sse2),
        "avx2" => clamp_to_available(Backend::Avx2),
        _ => best_available(),
    }
}

/// Force the active backend (tests / benches / the parity gates).
/// Errors if `b` is not available on this build + CPU.  Safe to call
/// while other threads compute: every backend produces identical bytes,
/// so a mid-computation switch cannot change any result.
pub fn force_backend(b: Backend) -> Result<Backend, String> {
    if !available(b) {
        return Err(format!("micro backend `{}` not available on this build/CPU", b.label()));
    }
    ACTIVE.store(b.code(), Ordering::Relaxed);
    Ok(b)
}

/// Drop back to env + CPU detection on next use.
pub fn reset_backend() {
    ACTIVE.store(UNINIT, Ordering::Relaxed);
}

macro_rules! dispatch {
    ($(#[$doc:meta])* $name:ident ( $($arg:ident : $ty:ty),* ) $(-> $ret:ty)?) => {
        $(#[$doc])*
        #[inline]
        pub fn $name($($arg: $ty),*) $(-> $ret)? {
            match active() {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                Backend::Sse2 => MicroKernel::$name(&simd::Sse2, $($arg),*),
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                Backend::Avx2 => MicroKernel::$name(&simd::Avx2, $($arg),*),
                _ => MicroKernel::$name(&scalar::Scalar, $($arg),*),
            }
        }
    };
}

dispatch! {
    /// Lane-tree dot product — see [`MicroKernel::dot`].
    dot(a: &[f32], b: &[f32]) -> f32
}
dispatch! {
    /// Fused dot-rows — see [`MicroKernel::dot_rows`].
    dot_rows(a: &[f32], b: &[f32], out: &mut [f32])
}
dispatch! {
    /// Lane-tree row sum — see [`MicroKernel::sum`].
    sum(a: &[f32]) -> f32
}
dispatch! {
    /// Lane-tree squared-deviation sum — see [`MicroKernel::sq_dev_sum`].
    sq_dev_sum(a: &[f32], mean: f32) -> f32
}
dispatch! {
    /// `out += a · s` — see [`MicroKernel::axpy`].
    axpy(out: &mut [f32], a: &[f32], s: f32)
}
dispatch! {
    /// `out = a · s` — see [`MicroKernel::scale`].
    scale(out: &mut [f32], a: &[f32], s: f32)
}
dispatch! {
    /// `out *= s` — see [`MicroKernel::scale_inplace`].
    scale_inplace(out: &mut [f32], s: f32)
}
dispatch! {
    /// `out *= a` elementwise — see [`MicroKernel::mul_inplace`].
    mul_inplace(out: &mut [f32], a: &[f32])
}
dispatch! {
    /// `out = (a - mean) · inv` — see [`MicroKernel::norm_scale`].
    norm_scale(out: &mut [f32], a: &[f32], mean: f32, inv: f32)
}
dispatch! {
    /// Packed GEMM row tile — see [`MicroKernel::gemm_row`].
    gemm_row(c: &mut [f32], a: &[f32], b: &[f32])
}
dispatch! {
    /// Outer product (overwrite) — see [`MicroKernel::outer`].
    outer(out: &mut [f32], a: &[f32], b: &[f32])
}
dispatch! {
    /// Outer-product accumulate — see [`MicroKernel::outer_accum`].
    outer_accum(z: &mut [f32], a: &[f32], b: &[f32])
}
dispatch! {
    /// Lane-tree f32 × int8 dot — see [`MicroKernel::dot_q8`].
    dot_q8(a: &[f32], q: &[i8], scale: f32) -> f32
}
dispatch! {
    /// Packed GEMM row tile over int8 weights — see
    /// [`MicroKernel::gemm_row_q8`].
    gemm_row_q8(c: &mut [f32], a: &[f32], q: &[i8], scales: &[f32])
}
dispatch! {
    /// Dequantize one int8 row — see [`MicroKernel::dequant_row`].
    dequant_row(out: &mut [f32], q: &[i8], scale: f32)
}
dispatch! {
    /// `out = exp(x - mx)` rows, scalar libm — see [`MicroKernel::exp_sub`].
    exp_sub(out: &mut [f32], x: &[f32], mx: f32)
}
dispatch! {
    /// In-place GELU rows, scalar libm — see [`MicroKernel::gelu_rows`].
    gelu_rows(x: &mut [f32])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    // Spec reference, written independently of any backend: 8 lanes,
    // element i into lane i % 8, fixed combine tree.
    fn ref_dot(a: &[f32], b: &[f32]) -> f32 {
        let mut lanes = [0.0f32; LANES];
        for i in 0..a.len() {
            lanes[i % LANES] += a[i] * b[i];
        }
        lane_tree(&lanes)
    }

    #[test]
    fn dot_is_the_lane_tree_spec() {
        let mut rng = Pcg::seeded(77);
        for n in [0usize, 1, 7, 8, 9, 13, 16, 31, 32, 33, 100] {
            let a: Vec<f32> = rng.gaussians(n);
            let b: Vec<f32> = rng.gaussians(n);
            assert_eq!(dot(&a, &b).to_bits(), ref_dot(&a, &b).to_bits(), "n={n}");
        }
    }

    #[test]
    fn off_and_auto_backends_are_byte_identical() {
        // The satellite unit test: force `off` (scalar) and `auto`
        // (best available) and compare bytes across the primitive set.
        let prev = active();
        let mut rng = Pcg::seeded(78);
        let n = 37usize;
        let k = 5usize;
        let a: Vec<f32> = rng.gaussians(n);
        let b: Vec<f32> = rng.gaussians(n);
        let coeff: Vec<f32> = rng.gaussians(k);
        let packed: Vec<f32> = rng.gaussians(k * n);

        let run = |backend: Backend| -> Vec<u32> {
            force_backend(backend).unwrap();
            let mut bits = Vec::new();
            bits.push(dot(&a, &b).to_bits());
            bits.push(sum(&a).to_bits());
            bits.push(sq_dev_sum(&a, 0.25).to_bits());
            let mut c = vec![0.0f32; n];
            gemm_row(&mut c, &coeff, &packed);
            let mut o = b.clone();
            axpy(&mut o, &a, 1.5);
            let mut z = vec![0.0f32; k * n];
            outer_accum(&mut z, &coeff, &a);
            let mut d = vec![0.0f32; k];
            dot_rows(&a, &packed, &mut d);
            for v in c.iter().chain(&o).chain(&z).chain(&d) {
                bits.push(v.to_bits());
            }
            bits
        };

        let off = run(Backend::Scalar);
        let auto = run(best_available());
        assert_eq!(off, auto, "PSF_SIMD=off and auto must produce identical bytes");
        force_backend(prev).unwrap();
    }

    #[test]
    fn forced_backend_reports_label() {
        let prev = active();
        force_backend(Backend::Scalar).unwrap();
        assert_eq!(backend_label(), "scalar");
        force_backend(prev).unwrap();
        assert!(matches!(backend_label(), "scalar" | "sse2" | "avx2"));
    }

    #[test]
    fn dot_q8_is_the_lane_tree_spec() {
        // Independent transcription: lane i % 8, per element
        // `a[i] * (q[i] as f32 * scale)`, fixed combine tree.
        let mut rng = Pcg::seeded(79);
        for n in [0usize, 1, 7, 8, 9, 33] {
            let a: Vec<f32> = rng.gaussians(n);
            let q: Vec<i8> = (0..n).map(|i| ((i * 83 + 11) % 255) as i16 as i8).collect();
            let mut lanes = [0.0f32; LANES];
            for i in 0..n {
                lanes[i % LANES] += a[i] * (q[i] as f32 * 0.031_25);
            }
            let want = lane_tree(&lanes);
            assert_eq!(dot_q8(&a, &q, 0.031_25).to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn gemm_row_q8_matches_dequantized_gemm_row_on_zero_free_rows() {
        // With w = a[k]·scales[k] folded per row, the q8 tile must equal
        // gemm_row over `a[k]·scales[k]` coefficients and raw `q as f32`
        // rows — same op sequence, so bitwise, not approximately.
        let mut rng = Pcg::seeded(80);
        let (k, n) = (4usize, 13usize);
        let a: Vec<f32> = rng.gaussians(k);
        let scales = [0.5f32, 0.0, 1.25, 0.031_25];
        let q: Vec<i8> = (0..k * n).map(|i| ((i * 97 + 53) % 255) as i16 as i8).collect();
        let mut c1 = vec![0.2f32; n];
        gemm_row_q8(&mut c1, &a, &q, &scales);
        let coeff: Vec<f32> = a.iter().zip(&scales).map(|(&x, &s)| x * s).collect();
        let packed: Vec<f32> = q.iter().map(|&v| v as f32).collect();
        let mut c2 = vec![0.2f32; n];
        gemm_row(&mut c2, &coeff, &packed);
        assert_eq!(c1, c2);
    }

    #[test]
    fn dequant_row_is_exact_conversion_then_one_multiply() {
        let q: Vec<i8> = vec![-128, -127, -1, 0, 1, 2, 127];
        let mut out = vec![0.0f32; q.len()];
        dequant_row(&mut out, &q, 0.25);
        let want: Vec<f32> = q.iter().map(|&v| v as f32 * 0.25).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn zero_skip_never_manufactures_nan() {
        // 0-coefficients must skip rows even when those rows hold inf/NaN.
        let coeff = [0.0f32, 2.0];
        let packed = [f32::INFINITY, f32::NAN, 1.0, -1.0];
        let mut c = [1.0f32, 1.0];
        gemm_row(&mut c, &coeff, &packed);
        assert_eq!(c, [3.0, -1.0]);
        let mut z = [0.0f32; 4];
        outer_accum(&mut z, &coeff, &[1.0, 2.0]);
        assert_eq!(z, [0.0, 0.0, 2.0, 4.0]);
    }
}
