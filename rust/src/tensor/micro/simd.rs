//! `std::arch` x86_64 backends: [`Sse2`] (baseline) and [`Avx2`]
//! (runtime-detected).  Compiled only with the `simd` cargo feature on
//! x86_64; selected in `micro::detect_from_env` / `force_backend`.
//!
//! Bitwise parity with [`super::scalar::Scalar`] is not approximate —
//! it is the whole point.  The rules that make it hold:
//!
//! * reductions keep 8 accumulator lanes (one `ymm`, or two `xmm`) and
//!   spill them to an array so the ragged tail and the final
//!   [`super::lane_tree`] combine run the *scalar* spec code;
//! * every multiply-accumulate is `mul` then `add` — **no FMA** — so
//!   each lane performs the same two IEEE-754 roundings as the scalar
//!   backend (`mulss`/`addss` and `mulps`/`addps` round identically
//!   per lane, including NaN payloads, infinities, and subnormals;
//!   Rust never enables FTZ/DAZ);
//! * elementwise primitives vectorize freely because each output is a
//!   single rounded op sequence — lane position cannot change it;
//! * transcendentals ([`MicroKernel::exp_sub`], `gelu_rows`) keep the
//!   trait's default scalar-libm bodies — deliberately not overridden.
//!
//! Tail handling: vector loops cover `len / width * width` elements;
//! tails run the scalar spec loop starting at the same element index
//! and (for reductions) the same lane assignment `i % 8`.

#![allow(unsafe_code)]

use std::arch::x86_64::*;

use super::{lane_tree, scalar::Scalar, MicroKernel, LANES};

/// SSE2 backend: 8-lane reductions as two `__m128` accumulators.
pub struct Sse2;

/// AVX2 backend: 8-lane reductions as one `__m256` accumulator.
pub struct Avx2;

// ---------------------------------------------------------------- SSE2

// SSE2 is part of the x86_64 baseline, so these are sound to call on
// any CPU this module compiles for; `unsafe` is only for the raw
// pointer arithmetic of the unaligned loads/stores.

#[inline]
unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut lo = _mm_setzero_ps();
    let mut hi = _mm_setzero_ps();
    for c in 0..chunks {
        let i = c * LANES;
        lo = _mm_add_ps(lo, _mm_mul_ps(_mm_loadu_ps(ap.add(i)), _mm_loadu_ps(bp.add(i))));
        hi = _mm_add_ps(hi, _mm_mul_ps(_mm_loadu_ps(ap.add(i + 4)), _mm_loadu_ps(bp.add(i + 4))));
    }
    let mut lanes = [0.0f32; LANES];
    _mm_storeu_ps(lanes.as_mut_ptr(), lo);
    _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
    for i in chunks * LANES..n {
        lanes[i % LANES] += a[i] * b[i];
    }
    lane_tree(&lanes)
}

#[inline]
unsafe fn sum_sse2(a: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let mut lo = _mm_setzero_ps();
    let mut hi = _mm_setzero_ps();
    for c in 0..chunks {
        let i = c * LANES;
        lo = _mm_add_ps(lo, _mm_loadu_ps(ap.add(i)));
        hi = _mm_add_ps(hi, _mm_loadu_ps(ap.add(i + 4)));
    }
    let mut lanes = [0.0f32; LANES];
    _mm_storeu_ps(lanes.as_mut_ptr(), lo);
    _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
    for i in chunks * LANES..n {
        lanes[i % LANES] += a[i];
    }
    lane_tree(&lanes)
}

#[inline]
unsafe fn sq_dev_sum_sse2(a: &[f32], mean: f32) -> f32 {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let vm = _mm_set1_ps(mean);
    let mut lo = _mm_setzero_ps();
    let mut hi = _mm_setzero_ps();
    for c in 0..chunks {
        let i = c * LANES;
        let d0 = _mm_sub_ps(_mm_loadu_ps(ap.add(i)), vm);
        let d1 = _mm_sub_ps(_mm_loadu_ps(ap.add(i + 4)), vm);
        lo = _mm_add_ps(lo, _mm_mul_ps(d0, d0));
        hi = _mm_add_ps(hi, _mm_mul_ps(d1, d1));
    }
    let mut lanes = [0.0f32; LANES];
    _mm_storeu_ps(lanes.as_mut_ptr(), lo);
    _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
    for i in chunks * LANES..n {
        let d = a[i] - mean;
        lanes[i % LANES] += d * d;
    }
    lane_tree(&lanes)
}

#[inline]
unsafe fn axpy_sse2(out: &mut [f32], a: &[f32], s: f32) {
    debug_assert_eq!(out.len(), a.len());
    let n = out.len();
    let vs = _mm_set1_ps(s);
    let (op, ap) = (out.as_mut_ptr(), a.as_ptr());
    let mut i = 0;
    while i + 4 <= n {
        let r = _mm_add_ps(_mm_loadu_ps(op.add(i)), _mm_mul_ps(_mm_loadu_ps(ap.add(i)), vs));
        _mm_storeu_ps(op.add(i), r);
        i += 4;
    }
    while i < n {
        out[i] += a[i] * s;
        i += 1;
    }
}

#[inline]
unsafe fn scale_sse2(out: &mut [f32], a: &[f32], s: f32) {
    debug_assert_eq!(out.len(), a.len());
    let n = out.len();
    let vs = _mm_set1_ps(s);
    let (op, ap) = (out.as_mut_ptr(), a.as_ptr());
    let mut i = 0;
    while i + 4 <= n {
        _mm_storeu_ps(op.add(i), _mm_mul_ps(_mm_loadu_ps(ap.add(i)), vs));
        i += 4;
    }
    while i < n {
        out[i] = a[i] * s;
        i += 1;
    }
}

#[inline]
unsafe fn mul_inplace_sse2(out: &mut [f32], a: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    let n = out.len();
    let (op, ap) = (out.as_mut_ptr(), a.as_ptr());
    let mut i = 0;
    while i + 4 <= n {
        _mm_storeu_ps(op.add(i), _mm_mul_ps(_mm_loadu_ps(op.add(i)), _mm_loadu_ps(ap.add(i))));
        i += 4;
    }
    while i < n {
        out[i] *= a[i];
        i += 1;
    }
}

#[inline]
unsafe fn norm_scale_sse2(out: &mut [f32], a: &[f32], mean: f32, inv: f32) {
    debug_assert_eq!(out.len(), a.len());
    let n = out.len();
    let vm = _mm_set1_ps(mean);
    let vi = _mm_set1_ps(inv);
    let (op, ap) = (out.as_mut_ptr(), a.as_ptr());
    let mut i = 0;
    while i + 4 <= n {
        _mm_storeu_ps(op.add(i), _mm_mul_ps(_mm_sub_ps(_mm_loadu_ps(ap.add(i)), vm), vi));
        i += 4;
    }
    while i < n {
        out[i] = (a[i] - mean) * inv;
        i += 1;
    }
}

#[inline]
unsafe fn gemm_row_sse2(c: &mut [f32], a: &[f32], b: &[f32]) {
    let n = c.len();
    let k = a.len();
    debug_assert_eq!(b.len(), k * n);
    let (cp, bp) = (c.as_mut_ptr(), b.as_ptr());
    let mut j = 0;
    // 16-wide register tile: each c element still accumulates in
    // increasing-k order, identical to the scalar spec.
    while j + 16 <= n {
        let mut acc0 = _mm_loadu_ps(cp.add(j));
        let mut acc1 = _mm_loadu_ps(cp.add(j + 4));
        let mut acc2 = _mm_loadu_ps(cp.add(j + 8));
        let mut acc3 = _mm_loadu_ps(cp.add(j + 12));
        for (kk, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let vav = _mm_set1_ps(av);
            let base = bp.add(kk * n + j);
            acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(base), vav));
            acc1 = _mm_add_ps(acc1, _mm_mul_ps(_mm_loadu_ps(base.add(4)), vav));
            acc2 = _mm_add_ps(acc2, _mm_mul_ps(_mm_loadu_ps(base.add(8)), vav));
            acc3 = _mm_add_ps(acc3, _mm_mul_ps(_mm_loadu_ps(base.add(12)), vav));
        }
        _mm_storeu_ps(cp.add(j), acc0);
        _mm_storeu_ps(cp.add(j + 4), acc1);
        _mm_storeu_ps(cp.add(j + 8), acc2);
        _mm_storeu_ps(cp.add(j + 12), acc3);
        j += 16;
    }
    while j + 4 <= n {
        let mut acc = _mm_loadu_ps(cp.add(j));
        for (kk, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            acc = _mm_add_ps(acc, _mm_mul_ps(_mm_loadu_ps(bp.add(kk * n + j)), _mm_set1_ps(av)));
        }
        _mm_storeu_ps(cp.add(j), acc);
        j += 4;
    }
    for jj in j..n {
        let mut s = c[jj];
        for (kk, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            s += b[kk * n + jj] * av;
        }
        c[jj] = s;
    }
}

#[inline]
unsafe fn cvt_q8x4_sse2(p: *const i8) -> __m128 {
    // SSE2 has no byte→dword sign extension (that is SSE4.1): duplicate
    // each byte up to the high byte of its 32-bit lane, then
    // arithmetic-shift back down.  Both the extension and the
    // int→float conversion are exact, so parity with the scalar
    // backend's `q as f32` holds bit for bit.
    let raw = _mm_cvtsi32_si128((p as *const i32).read_unaligned());
    let w = _mm_unpacklo_epi8(raw, raw);
    let d = _mm_unpacklo_epi16(w, w);
    _mm_cvtepi32_ps(_mm_srai_epi32::<24>(d))
}

#[inline]
unsafe fn dot_q8_sse2(a: &[f32], q: &[i8], scale: f32) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    let n = a.len();
    let chunks = n / LANES;
    let (ap, qp) = (a.as_ptr(), q.as_ptr());
    let vs = _mm_set1_ps(scale);
    let mut lo = _mm_setzero_ps();
    let mut hi = _mm_setzero_ps();
    for c in 0..chunks {
        let i = c * LANES;
        // Same rounding sequence as scalar: exact convert, then
        // `* scale`, then `* a`, then lane add — no FMA.
        let d0 = _mm_mul_ps(cvt_q8x4_sse2(qp.add(i)), vs);
        let d1 = _mm_mul_ps(cvt_q8x4_sse2(qp.add(i + 4)), vs);
        lo = _mm_add_ps(lo, _mm_mul_ps(_mm_loadu_ps(ap.add(i)), d0));
        hi = _mm_add_ps(hi, _mm_mul_ps(_mm_loadu_ps(ap.add(i + 4)), d1));
    }
    let mut lanes = [0.0f32; LANES];
    _mm_storeu_ps(lanes.as_mut_ptr(), lo);
    _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
    for i in chunks * LANES..n {
        lanes[i % LANES] += a[i] * (q[i] as f32 * scale);
    }
    lane_tree(&lanes)
}

#[inline]
unsafe fn gemm_row_q8_sse2(c: &mut [f32], a: &[f32], q: &[i8], scales: &[f32]) {
    let n = c.len();
    debug_assert_eq!(q.len(), a.len() * n);
    debug_assert_eq!(scales.len(), a.len());
    let (cp, qp) = (c.as_mut_ptr(), q.as_ptr());
    let mut j = 0;
    // Same tiling as gemm_row_sse2; the per-row weight `w = a·scale` is
    // one scalar rounding, identical to the spec.
    while j + 16 <= n {
        let mut acc0 = _mm_loadu_ps(cp.add(j));
        let mut acc1 = _mm_loadu_ps(cp.add(j + 4));
        let mut acc2 = _mm_loadu_ps(cp.add(j + 8));
        let mut acc3 = _mm_loadu_ps(cp.add(j + 12));
        for (kk, &av) in a.iter().enumerate() {
            let w = av * scales[kk];
            if w == 0.0 {
                continue;
            }
            let vw = _mm_set1_ps(w);
            let base = qp.add(kk * n + j);
            acc0 = _mm_add_ps(acc0, _mm_mul_ps(cvt_q8x4_sse2(base), vw));
            acc1 = _mm_add_ps(acc1, _mm_mul_ps(cvt_q8x4_sse2(base.add(4)), vw));
            acc2 = _mm_add_ps(acc2, _mm_mul_ps(cvt_q8x4_sse2(base.add(8)), vw));
            acc3 = _mm_add_ps(acc3, _mm_mul_ps(cvt_q8x4_sse2(base.add(12)), vw));
        }
        _mm_storeu_ps(cp.add(j), acc0);
        _mm_storeu_ps(cp.add(j + 4), acc1);
        _mm_storeu_ps(cp.add(j + 8), acc2);
        _mm_storeu_ps(cp.add(j + 12), acc3);
        j += 16;
    }
    while j + 4 <= n {
        let mut acc = _mm_loadu_ps(cp.add(j));
        for (kk, &av) in a.iter().enumerate() {
            let w = av * scales[kk];
            if w == 0.0 {
                continue;
            }
            acc = _mm_add_ps(acc, _mm_mul_ps(cvt_q8x4_sse2(qp.add(kk * n + j)), _mm_set1_ps(w)));
        }
        _mm_storeu_ps(cp.add(j), acc);
        j += 4;
    }
    for jj in j..n {
        let mut s = c[jj];
        for (kk, &av) in a.iter().enumerate() {
            let w = av * scales[kk];
            if w == 0.0 {
                continue;
            }
            s += q[kk * n + jj] as f32 * w;
        }
        c[jj] = s;
    }
}

#[inline]
unsafe fn dequant_row_sse2(out: &mut [f32], q: &[i8], scale: f32) {
    debug_assert_eq!(out.len(), q.len());
    let n = out.len();
    let vs = _mm_set1_ps(scale);
    let (op, qp) = (out.as_mut_ptr(), q.as_ptr());
    let mut i = 0;
    while i + 4 <= n {
        _mm_storeu_ps(op.add(i), _mm_mul_ps(cvt_q8x4_sse2(qp.add(i)), vs));
        i += 4;
    }
    while i < n {
        out[i] = q[i] as f32 * scale;
        i += 1;
    }
}

impl MicroKernel for Sse2 {
    fn name(&self) -> &'static str {
        "sse2"
    }

    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: SSE2 is baseline on x86_64; slices bound all accesses.
        unsafe { dot_sse2(a, b) }
    }

    fn dot_rows(&self, a: &[f32], b: &[f32], out: &mut [f32]) {
        let k = a.len();
        debug_assert_eq!(b.len(), k * out.len());
        for (j, o) in out.iter_mut().enumerate() {
            // SAFETY: as above.
            *o = unsafe { dot_sse2(a, &b[j * k..(j + 1) * k]) };
        }
    }

    fn sum(&self, a: &[f32]) -> f32 {
        // SAFETY: as above.
        unsafe { sum_sse2(a) }
    }

    fn sq_dev_sum(&self, a: &[f32], mean: f32) -> f32 {
        // SAFETY: as above.
        unsafe { sq_dev_sum_sse2(a, mean) }
    }

    fn axpy(&self, out: &mut [f32], a: &[f32], s: f32) {
        // SAFETY: as above.
        unsafe { axpy_sse2(out, a, s) }
    }

    fn scale(&self, out: &mut [f32], a: &[f32], s: f32) {
        // SAFETY: as above.
        unsafe { scale_sse2(out, a, s) }
    }

    fn scale_inplace(&self, out: &mut [f32], s: f32) {
        // In-place scale is scale() aliased onto itself element by
        // element; reuse the scalar loop shape via a raw split.
        let n = out.len();
        // SAFETY: as above; reading and writing the same element of a
        // packed lane is fine (load happens before store).
        unsafe {
            let op = out.as_mut_ptr();
            let vs = _mm_set1_ps(s);
            let mut i = 0;
            while i + 4 <= n {
                _mm_storeu_ps(op.add(i), _mm_mul_ps(_mm_loadu_ps(op.add(i)), vs));
                i += 4;
            }
            while i < n {
                out[i] *= s;
                i += 1;
            }
        }
    }

    fn mul_inplace(&self, out: &mut [f32], a: &[f32]) {
        // SAFETY: as above.
        unsafe { mul_inplace_sse2(out, a) }
    }

    fn norm_scale(&self, out: &mut [f32], a: &[f32], mean: f32, inv: f32) {
        // SAFETY: as above.
        unsafe { norm_scale_sse2(out, a, mean, inv) }
    }

    fn gemm_row(&self, c: &mut [f32], a: &[f32], b: &[f32]) {
        // SAFETY: as above.
        unsafe { gemm_row_sse2(c, a, b) }
    }

    fn dot_q8(&self, a: &[f32], q: &[i8], scale: f32) -> f32 {
        // SAFETY: as above; cvt_q8x4_sse2's 4-byte unaligned read stays
        // inside the slice because every call site has >= 4 elements
        // remaining.
        unsafe { dot_q8_sse2(a, q, scale) }
    }

    fn gemm_row_q8(&self, c: &mut [f32], a: &[f32], q: &[i8], scales: &[f32]) {
        // SAFETY: as above.
        unsafe { gemm_row_q8_sse2(c, a, q, scales) }
    }

    fn dequant_row(&self, out: &mut [f32], q: &[i8], scale: f32) {
        // SAFETY: as above.
        unsafe { dequant_row_sse2(out, q, scale) }
    }

    fn outer(&self, out: &mut [f32], a: &[f32], b: &[f32]) {
        let n = b.len();
        debug_assert_eq!(out.len(), a.len() * n);
        for (i, &av) in a.iter().enumerate() {
            self.scale(&mut out[i * n..(i + 1) * n], b, av);
        }
    }

    fn outer_accum(&self, z: &mut [f32], a: &[f32], b: &[f32]) {
        let n = b.len();
        debug_assert_eq!(z.len(), a.len() * n);
        for (i, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            self.axpy(&mut z[i * n..(i + 1) * n], b, av);
        }
    }
}

// ---------------------------------------------------------------- AVX2

#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let i = c * LANES;
        acc = _mm256_add_ps(
            acc,
            _mm256_mul_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i))),
        );
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for i in chunks * LANES..n {
        lanes[i % LANES] += a[i] * b[i];
    }
    lane_tree(&lanes)
}

#[target_feature(enable = "avx2")]
unsafe fn sum_avx2(a: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        acc = _mm256_add_ps(acc, _mm256_loadu_ps(ap.add(c * LANES)));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for i in chunks * LANES..n {
        lanes[i % LANES] += a[i];
    }
    lane_tree(&lanes)
}

#[target_feature(enable = "avx2")]
unsafe fn sq_dev_sum_avx2(a: &[f32], mean: f32) -> f32 {
    let n = a.len();
    let chunks = n / LANES;
    let ap = a.as_ptr();
    let vm = _mm256_set1_ps(mean);
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let d = _mm256_sub_ps(_mm256_loadu_ps(ap.add(c * LANES)), vm);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for i in chunks * LANES..n {
        let d = a[i] - mean;
        lanes[i % LANES] += d * d;
    }
    lane_tree(&lanes)
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(out: &mut [f32], a: &[f32], s: f32) {
    debug_assert_eq!(out.len(), a.len());
    let n = out.len();
    let vs = _mm256_set1_ps(s);
    let (op, ap) = (out.as_mut_ptr(), a.as_ptr());
    let mut i = 0;
    while i + 8 <= n {
        let r = _mm256_add_ps(
            _mm256_loadu_ps(op.add(i)),
            _mm256_mul_ps(_mm256_loadu_ps(ap.add(i)), vs),
        );
        _mm256_storeu_ps(op.add(i), r);
        i += 8;
    }
    while i < n {
        out[i] += a[i] * s;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn scale_avx2(out: &mut [f32], a: &[f32], s: f32) {
    debug_assert_eq!(out.len(), a.len());
    let n = out.len();
    let vs = _mm256_set1_ps(s);
    let (op, ap) = (out.as_mut_ptr(), a.as_ptr());
    let mut i = 0;
    while i + 8 <= n {
        _mm256_storeu_ps(op.add(i), _mm256_mul_ps(_mm256_loadu_ps(ap.add(i)), vs));
        i += 8;
    }
    while i < n {
        out[i] = a[i] * s;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn scale_inplace_avx2(out: &mut [f32], s: f32) {
    let n = out.len();
    let vs = _mm256_set1_ps(s);
    let op = out.as_mut_ptr();
    let mut i = 0;
    while i + 8 <= n {
        _mm256_storeu_ps(op.add(i), _mm256_mul_ps(_mm256_loadu_ps(op.add(i)), vs));
        i += 8;
    }
    while i < n {
        out[i] *= s;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn mul_inplace_avx2(out: &mut [f32], a: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    let n = out.len();
    let (op, ap) = (out.as_mut_ptr(), a.as_ptr());
    let mut i = 0;
    while i + 8 <= n {
        _mm256_storeu_ps(
            op.add(i),
            _mm256_mul_ps(_mm256_loadu_ps(op.add(i)), _mm256_loadu_ps(ap.add(i))),
        );
        i += 8;
    }
    while i < n {
        out[i] *= a[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn norm_scale_avx2(out: &mut [f32], a: &[f32], mean: f32, inv: f32) {
    debug_assert_eq!(out.len(), a.len());
    let n = out.len();
    let vm = _mm256_set1_ps(mean);
    let vi = _mm256_set1_ps(inv);
    let (op, ap) = (out.as_mut_ptr(), a.as_ptr());
    let mut i = 0;
    while i + 8 <= n {
        _mm256_storeu_ps(
            op.add(i),
            _mm256_mul_ps(_mm256_sub_ps(_mm256_loadu_ps(ap.add(i)), vm), vi),
        );
        i += 8;
    }
    while i < n {
        out[i] = (a[i] - mean) * inv;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn gemm_row_avx2(c: &mut [f32], a: &[f32], b: &[f32]) {
    let n = c.len();
    let k = a.len();
    debug_assert_eq!(b.len(), k * n);
    let (cp, bp) = (c.as_mut_ptr(), b.as_ptr());
    let mut j = 0;
    // 32-wide register tile (4 ymm); each c element accumulates in
    // increasing-k order, identical to the scalar spec.
    while j + 32 <= n {
        let mut acc0 = _mm256_loadu_ps(cp.add(j));
        let mut acc1 = _mm256_loadu_ps(cp.add(j + 8));
        let mut acc2 = _mm256_loadu_ps(cp.add(j + 16));
        let mut acc3 = _mm256_loadu_ps(cp.add(j + 24));
        for (kk, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let vav = _mm256_set1_ps(av);
            let base = bp.add(kk * n + j);
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(base), vav));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_loadu_ps(base.add(8)), vav));
            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_loadu_ps(base.add(16)), vav));
            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_loadu_ps(base.add(24)), vav));
        }
        _mm256_storeu_ps(cp.add(j), acc0);
        _mm256_storeu_ps(cp.add(j + 8), acc1);
        _mm256_storeu_ps(cp.add(j + 16), acc2);
        _mm256_storeu_ps(cp.add(j + 24), acc3);
        j += 32;
    }
    while j + 8 <= n {
        let mut acc = _mm256_loadu_ps(cp.add(j));
        for (kk, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            acc = _mm256_add_ps(
                acc,
                _mm256_mul_ps(_mm256_loadu_ps(bp.add(kk * n + j)), _mm256_set1_ps(av)),
            );
        }
        _mm256_storeu_ps(cp.add(j), acc);
        j += 8;
    }
    for jj in j..n {
        let mut s = c[jj];
        for (kk, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            s += b[kk * n + jj] * av;
        }
        c[jj] = s;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn cvt_q8x8_avx2(p: *const i8) -> __m256 {
    // `_mm_loadl_epi64` reads exactly 8 bytes; `cvtepi8_epi32`
    // sign-extends the low 8 — both exact, so parity with the scalar
    // backend's `q as f32` holds bit for bit.
    _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(p as *const __m128i)))
}

#[target_feature(enable = "avx2")]
unsafe fn dot_q8_avx2(a: &[f32], q: &[i8], scale: f32) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    let n = a.len();
    let chunks = n / LANES;
    let (ap, qp) = (a.as_ptr(), q.as_ptr());
    let vs = _mm256_set1_ps(scale);
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let i = c * LANES;
        let d = _mm256_mul_ps(cvt_q8x8_avx2(qp.add(i)), vs);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_loadu_ps(ap.add(i)), d));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for i in chunks * LANES..n {
        lanes[i % LANES] += a[i] * (q[i] as f32 * scale);
    }
    lane_tree(&lanes)
}

#[target_feature(enable = "avx2")]
unsafe fn gemm_row_q8_avx2(c: &mut [f32], a: &[f32], q: &[i8], scales: &[f32]) {
    let n = c.len();
    debug_assert_eq!(q.len(), a.len() * n);
    debug_assert_eq!(scales.len(), a.len());
    let (cp, qp) = (c.as_mut_ptr(), q.as_ptr());
    let mut j = 0;
    // Same tiling as gemm_row_avx2; per-row weight `w = a·scale` is one
    // scalar rounding, identical to the spec.
    while j + 32 <= n {
        let mut acc0 = _mm256_loadu_ps(cp.add(j));
        let mut acc1 = _mm256_loadu_ps(cp.add(j + 8));
        let mut acc2 = _mm256_loadu_ps(cp.add(j + 16));
        let mut acc3 = _mm256_loadu_ps(cp.add(j + 24));
        for (kk, &av) in a.iter().enumerate() {
            let w = av * scales[kk];
            if w == 0.0 {
                continue;
            }
            let vw = _mm256_set1_ps(w);
            let base = qp.add(kk * n + j);
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(cvt_q8x8_avx2(base), vw));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(cvt_q8x8_avx2(base.add(8)), vw));
            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(cvt_q8x8_avx2(base.add(16)), vw));
            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(cvt_q8x8_avx2(base.add(24)), vw));
        }
        _mm256_storeu_ps(cp.add(j), acc0);
        _mm256_storeu_ps(cp.add(j + 8), acc1);
        _mm256_storeu_ps(cp.add(j + 16), acc2);
        _mm256_storeu_ps(cp.add(j + 24), acc3);
        j += 32;
    }
    while j + 8 <= n {
        let mut acc = _mm256_loadu_ps(cp.add(j));
        for (kk, &av) in a.iter().enumerate() {
            let w = av * scales[kk];
            if w == 0.0 {
                continue;
            }
            acc = _mm256_add_ps(
                acc,
                _mm256_mul_ps(cvt_q8x8_avx2(qp.add(kk * n + j)), _mm256_set1_ps(w)),
            );
        }
        _mm256_storeu_ps(cp.add(j), acc);
        j += 8;
    }
    for jj in j..n {
        let mut s = c[jj];
        for (kk, &av) in a.iter().enumerate() {
            let w = av * scales[kk];
            if w == 0.0 {
                continue;
            }
            s += q[kk * n + jj] as f32 * w;
        }
        c[jj] = s;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn dequant_row_avx2(out: &mut [f32], q: &[i8], scale: f32) {
    debug_assert_eq!(out.len(), q.len());
    let n = out.len();
    let vs = _mm256_set1_ps(scale);
    let (op, qp) = (out.as_mut_ptr(), q.as_ptr());
    let mut i = 0;
    while i + 8 <= n {
        _mm256_storeu_ps(op.add(i), _mm256_mul_ps(cvt_q8x8_avx2(qp.add(i)), vs));
        i += 8;
    }
    while i < n {
        out[i] = q[i] as f32 * scale;
        i += 1;
    }
}

impl MicroKernel for Avx2 {
    fn name(&self) -> &'static str {
        "avx2"
    }

    // SAFETY (all methods): the Avx2 backend is only selectable when
    // `is_x86_feature_detected!("avx2")` held at selection time
    // (micro::available), so the target-feature contract is met; slices
    // bound all pointer arithmetic.

    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_avx2(a, b) }
    }

    fn dot_rows(&self, a: &[f32], b: &[f32], out: &mut [f32]) {
        let k = a.len();
        debug_assert_eq!(b.len(), k * out.len());
        for (j, o) in out.iter_mut().enumerate() {
            *o = unsafe { dot_avx2(a, &b[j * k..(j + 1) * k]) };
        }
    }

    fn sum(&self, a: &[f32]) -> f32 {
        unsafe { sum_avx2(a) }
    }

    fn sq_dev_sum(&self, a: &[f32], mean: f32) -> f32 {
        unsafe { sq_dev_sum_avx2(a, mean) }
    }

    fn axpy(&self, out: &mut [f32], a: &[f32], s: f32) {
        unsafe { axpy_avx2(out, a, s) }
    }

    fn scale(&self, out: &mut [f32], a: &[f32], s: f32) {
        unsafe { scale_avx2(out, a, s) }
    }

    fn scale_inplace(&self, out: &mut [f32], s: f32) {
        unsafe { scale_inplace_avx2(out, s) }
    }

    fn mul_inplace(&self, out: &mut [f32], a: &[f32]) {
        unsafe { mul_inplace_avx2(out, a) }
    }

    fn norm_scale(&self, out: &mut [f32], a: &[f32], mean: f32, inv: f32) {
        unsafe { norm_scale_avx2(out, a, mean, inv) }
    }

    fn gemm_row(&self, c: &mut [f32], a: &[f32], b: &[f32]) {
        unsafe { gemm_row_avx2(c, a, b) }
    }

    fn dot_q8(&self, a: &[f32], q: &[i8], scale: f32) -> f32 {
        unsafe { dot_q8_avx2(a, q, scale) }
    }

    fn gemm_row_q8(&self, c: &mut [f32], a: &[f32], q: &[i8], scales: &[f32]) {
        unsafe { gemm_row_q8_avx2(c, a, q, scales) }
    }

    fn dequant_row(&self, out: &mut [f32], q: &[i8], scale: f32) {
        unsafe { dequant_row_avx2(out, q, scale) }
    }

    fn outer(&self, out: &mut [f32], a: &[f32], b: &[f32]) {
        let n = b.len();
        debug_assert_eq!(out.len(), a.len() * n);
        for (i, &av) in a.iter().enumerate() {
            self.scale(&mut out[i * n..(i + 1) * n], b, av);
        }
    }

    fn outer_accum(&self, z: &mut [f32], a: &[f32], b: &[f32]) {
        let n = b.len();
        debug_assert_eq!(z.len(), a.len() * n);
        for (i, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            self.axpy(&mut z[i * n..(i + 1) * n], b, av);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{best_available, Backend};
    use super::*;
    use crate::util::rng::Pcg;

    /// Every primitive, scalar vs the best SIMD backend, bit for bit.
    #[test]
    fn simd_backends_match_scalar_bitwise() {
        let mut rng = Pcg::seeded(91);
        let simd_kinds: Vec<&dyn MicroKernel> = match best_available() {
            Backend::Avx2 => vec![&Sse2, &Avx2],
            Backend::Sse2 => vec![&Sse2],
            Backend::Scalar => vec![],
        };
        for n in [1usize, 3, 4, 7, 8, 9, 13, 16, 17, 31, 32, 33, 64, 65] {
            let a: Vec<f32> = rng.gaussians(n);
            let b: Vec<f32> = rng.gaussians(n);
            let k = 5usize;
            let coeff: Vec<f32> = rng.gaussians(k);
            let packed: Vec<f32> = rng.gaussians(k * n);
            for kern in &simd_kinds {
                assert_eq!(kern.dot(&a, &b).to_bits(), Scalar.dot(&a, &b).to_bits(), "dot n={n}");
                assert_eq!(kern.sum(&a).to_bits(), Scalar.sum(&a).to_bits(), "sum n={n}");
                assert_eq!(
                    kern.sq_dev_sum(&a, 0.3).to_bits(),
                    Scalar.sq_dev_sum(&a, 0.3).to_bits(),
                    "sq_dev n={n}"
                );
                let (mut c1, mut c2) = (vec![0.1f32; n], vec![0.1f32; n]);
                kern.gemm_row(&mut c1, &coeff, &packed);
                Scalar.gemm_row(&mut c2, &coeff, &packed);
                assert_eq!(c1, c2, "gemm_row n={n} ({})", kern.name());
            }
        }
    }

    /// The int8 primitives, scalar vs every SIMD backend, bit for bit —
    /// including the -128 code the quantizer never emits.
    #[test]
    fn q8_primitives_match_scalar_bitwise() {
        let mut rng = Pcg::seeded(92);
        let simd_kinds: Vec<&dyn MicroKernel> = match best_available() {
            Backend::Avx2 => vec![&Sse2, &Avx2],
            Backend::Sse2 => vec![&Sse2],
            Backend::Scalar => vec![],
        };
        for n in [1usize, 3, 4, 7, 8, 9, 13, 16, 17, 31, 32, 33, 64, 65] {
            let a: Vec<f32> = rng.gaussians(n);
            let q: Vec<i8> = (0..n).map(|i| ((i * 71 + 5) % 256) as u8 as i8).collect();
            let k = 5usize;
            let coeff: Vec<f32> = rng.gaussians(k);
            let scales = [0.5f32, 0.031_25, 0.0, 1.0, 0.007_8];
            let qmat: Vec<i8> = (0..k * n).map(|i| ((i * 113 + 9) % 256) as u8 as i8).collect();
            for kern in &simd_kinds {
                assert_eq!(
                    kern.dot_q8(&a, &q, 0.062_5).to_bits(),
                    Scalar.dot_q8(&a, &q, 0.062_5).to_bits(),
                    "dot_q8 n={n} ({})",
                    kern.name()
                );
                let (mut c1, mut c2) = (vec![0.1f32; n], vec![0.1f32; n]);
                kern.gemm_row_q8(&mut c1, &coeff, &qmat, &scales);
                Scalar.gemm_row_q8(&mut c2, &coeff, &qmat, &scales);
                assert_eq!(c1, c2, "gemm_row_q8 n={n} ({})", kern.name());
                let (mut d1, mut d2) = (vec![0.0f32; n], vec![0.0f32; n]);
                kern.dequant_row(&mut d1, &q, 0.25);
                Scalar.dequant_row(&mut d2, &q, 0.25);
                assert_eq!(d1, d2, "dequant_row n={n} ({})", kern.name());
            }
        }
    }
}
