//! The portable reference backend — this file *is* the numeric spec.
//!
//! Every loop here walks the same 8 accumulator lanes and the same
//! per-element mul-then-add sequence the SIMD backends execute in
//! registers; see the module docs in [`super`] for the four rules
//! (lane tree, no FMA, scalar transcendentals, zero-skip).  Any change
//! to an operation order in this file is a golden-re-blessing event and
//! must be mirrored bit-for-bit in `simd.rs`.

use super::{lane_tree, MicroKernel, LANES};

/// The reference implementation of [`MicroKernel`].
pub struct Scalar;

impl MicroKernel for Scalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut lanes = [0.0f32; LANES];
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let i = c * LANES;
            for l in 0..LANES {
                lanes[l] += a[i + l] * b[i + l];
            }
        }
        for i in chunks * LANES..a.len() {
            lanes[i % LANES] += a[i] * b[i];
        }
        lane_tree(&lanes)
    }

    fn dot_rows(&self, a: &[f32], b: &[f32], out: &mut [f32]) {
        let k = a.len();
        debug_assert_eq!(b.len(), k * out.len());
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.dot(a, &b[j * k..(j + 1) * k]);
        }
    }

    fn sum(&self, a: &[f32]) -> f32 {
        let mut lanes = [0.0f32; LANES];
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let i = c * LANES;
            for l in 0..LANES {
                lanes[l] += a[i + l];
            }
        }
        for i in chunks * LANES..a.len() {
            lanes[i % LANES] += a[i];
        }
        lane_tree(&lanes)
    }

    fn sq_dev_sum(&self, a: &[f32], mean: f32) -> f32 {
        let mut lanes = [0.0f32; LANES];
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let i = c * LANES;
            for l in 0..LANES {
                let d = a[i + l] - mean;
                lanes[l] += d * d;
            }
        }
        for i in chunks * LANES..a.len() {
            let d = a[i] - mean;
            lanes[i % LANES] += d * d;
        }
        lane_tree(&lanes)
    }

    fn axpy(&self, out: &mut [f32], a: &[f32], s: f32) {
        debug_assert_eq!(out.len(), a.len());
        for (o, &v) in out.iter_mut().zip(a) {
            *o += v * s;
        }
    }

    fn scale(&self, out: &mut [f32], a: &[f32], s: f32) {
        debug_assert_eq!(out.len(), a.len());
        for (o, &v) in out.iter_mut().zip(a) {
            *o = v * s;
        }
    }

    fn scale_inplace(&self, out: &mut [f32], s: f32) {
        for o in out.iter_mut() {
            *o *= s;
        }
    }

    fn mul_inplace(&self, out: &mut [f32], a: &[f32]) {
        debug_assert_eq!(out.len(), a.len());
        for (o, &v) in out.iter_mut().zip(a) {
            *o *= v;
        }
    }

    fn norm_scale(&self, out: &mut [f32], a: &[f32], mean: f32, inv: f32) {
        debug_assert_eq!(out.len(), a.len());
        for (o, &v) in out.iter_mut().zip(a) {
            *o = (v - mean) * inv;
        }
    }

    fn gemm_row(&self, c: &mut [f32], a: &[f32], b: &[f32]) {
        let n = c.len();
        debug_assert_eq!(b.len(), a.len() * n);
        for (kk, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &v) in c.iter_mut().zip(brow) {
                *o += v * av;
            }
        }
    }

    fn dot_q8(&self, a: &[f32], q: &[i8], scale: f32) -> f32 {
        debug_assert_eq!(a.len(), q.len());
        let mut lanes = [0.0f32; LANES];
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let i = c * LANES;
            for l in 0..LANES {
                lanes[l] += a[i + l] * (q[i + l] as f32 * scale);
            }
        }
        for i in chunks * LANES..a.len() {
            lanes[i % LANES] += a[i] * (q[i] as f32 * scale);
        }
        lane_tree(&lanes)
    }

    fn gemm_row_q8(&self, c: &mut [f32], a: &[f32], q: &[i8], scales: &[f32]) {
        let n = c.len();
        debug_assert_eq!(q.len(), a.len() * n);
        debug_assert_eq!(scales.len(), a.len());
        for (kk, &av) in a.iter().enumerate() {
            let w = av * scales[kk];
            if w == 0.0 {
                continue;
            }
            let qrow = &q[kk * n..(kk + 1) * n];
            for (o, &qv) in c.iter_mut().zip(qrow) {
                *o += qv as f32 * w;
            }
        }
    }

    fn dequant_row(&self, out: &mut [f32], q: &[i8], scale: f32) {
        debug_assert_eq!(out.len(), q.len());
        for (o, &qv) in out.iter_mut().zip(q) {
            *o = qv as f32 * scale;
        }
    }

    fn outer(&self, out: &mut [f32], a: &[f32], b: &[f32]) {
        let n = b.len();
        debug_assert_eq!(out.len(), a.len() * n);
        for (i, &av) in a.iter().enumerate() {
            self.scale(&mut out[i * n..(i + 1) * n], b, av);
        }
    }

    fn outer_accum(&self, z: &mut [f32], a: &[f32], b: &[f32]) {
        let n = b.len();
        debug_assert_eq!(z.len(), a.len() * n);
        for (i, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            self.axpy(&mut z[i * n..(i + 1) * n], b, av);
        }
    }
}
