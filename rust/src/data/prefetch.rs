//! Batch prefetch: overlap host-side batch assembly with device execution.
//!
//! The PJRT train step blocks its thread, so a worker (from the exec
//! substrate's thread pool) assembles the next batches into a bounded
//! queue while the device computes.  With the synthetic corpora batch
//! assembly is cheap, but the overlap matters when the source is an
//! expensive generator (BPE-encoding fresh text, task example synthesis).

use std::sync::mpsc::{sync_channel, Receiver};

use super::batcher::{Batch, Batcher};
use crate::exec::ThreadPool;

/// A batch source running ahead of the consumer on a pool worker.
pub struct Prefetcher {
    rx: Receiver<Batch>,
    // Keeps the worker alive; dropped (and joined) after rx closes.
    _pool: ThreadPool,
}

impl Prefetcher {
    /// Wrap `batcher`, keeping up to `depth` batches ready.
    pub fn new(mut batcher: Batcher, depth: usize) -> Self {
        let (tx, rx) = sync_channel::<Batch>(depth.max(1));
        let pool = ThreadPool::new(1);
        pool.spawn(move || {
            loop {
                let batch = batcher.next_batch();
                // The consumer dropping its receiver is the shutdown signal.
                if tx.send(batch).is_err() {
                    break;
                }
            }
        });
        Prefetcher { rx, _pool: pool }
    }

    /// Next prefetched batch (blocks only if the producer is behind).
    pub fn next_batch(&mut self) -> Batch {
        self.rx.recv().expect("prefetch worker died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| 1 + i % 100).collect()
    }

    #[test]
    fn same_batches_as_direct_iteration() {
        let s = stream(33 * 16);
        let direct = {
            let mut b = Batcher::new(&s, 2, 33, 5);
            (0..10).map(|_| b.next_batch().tokens).collect::<Vec<_>>()
        };
        let mut pf = Prefetcher::new(Batcher::new(&s, 2, 33, 5), 4);
        for want in direct {
            assert_eq!(pf.next_batch().tokens, want);
        }
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let s = stream(33 * 8);
        let pf = Prefetcher::new(Batcher::new(&s, 2, 33, 0), 2);
        drop(pf); // must not hang on join
    }

    #[test]
    fn deep_queue_keeps_order_across_epochs() {
        let s = stream(33 * 4); // 4 segments, 2 per batch -> 2 batches/epoch
        let mut direct = Batcher::new(&s, 2, 33, 1);
        let mut pf = Prefetcher::new(Batcher::new(&s, 2, 33, 1), 8);
        for _ in 0..9 {
            assert_eq!(pf.next_batch().tokens, direct.next_batch().tokens);
        }
    }
}
