//! Byte-level BPE tokenizer — trainer + encoder/decoder, from scratch.
//!
//! Substitutes the paper's 32k SentencePiece vocabulary (DESIGN.md §4):
//! same representation class (subword units over raw bytes), vocabulary
//! scaled to the testbed models.  Id space: 0 = PAD (never produced by
//! encode), 1..=256 = raw bytes, 257.. = merges.

use std::collections::HashMap;

pub const PAD: u32 = 0;
const BYTE_BASE: u32 = 1;

/// A trained BPE model: ordered merge list + vocab size.
#[derive(Clone, Debug)]
pub struct Bpe {
    /// Merge rules in priority order: (left, right) -> new id.
    merges: Vec<(u32, u32)>,
    /// (left, right) -> rank for O(1) lookup during encode.
    ranks: HashMap<(u32, u32), usize>,
    vocab: usize,
}

impl Bpe {
    /// Train on `text`, producing a vocabulary of exactly `vocab` ids
    /// (PAD + 256 bytes + merges). `vocab` must be > 257.
    pub fn train(text: &[u8], vocab: usize) -> Self {
        assert!(vocab > 257, "vocab must exceed PAD + byte ids");
        let n_merges = vocab - 257;
        let mut ids: Vec<u32> = text.iter().map(|&b| BYTE_BASE + b as u32).collect();
        let mut merges = Vec::with_capacity(n_merges);
        let mut next_id = 257u32;

        for _ in 0..n_merges {
            let mut counts: HashMap<(u32, u32), u32> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // Deterministic argmax: highest count, then smallest pair.
            let best = counts
                .iter()
                .filter(|(_, &c)| c >= 2)
                .max_by_key(|(&pair, &c)| (c, std::cmp::Reverse(pair)));
            let (&pair, _) = match best {
                Some(kv) => kv,
                None => break, // corpus exhausted: no repeating pairs left
            };
            merges.push(pair);
            ids = merge_once(&ids, pair, next_id);
            next_id += 1;
        }

        let ranks = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i))
            .collect();
        Bpe { merges, ranks, vocab }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab
    }

    pub fn num_merges(&self) -> usize {
        self.merges.len()
    }

    /// Encode bytes to token ids (never emits PAD).
    pub fn encode(&self, text: &[u8]) -> Vec<u32> {
        let mut ids: Vec<u32> = text.iter().map(|&b| BYTE_BASE + b as u32).collect();
        // Repeatedly apply the lowest-rank merge present. O(n * merges_hit)
        // with early exit; fine at our corpus sizes.
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, position)
            for (i, w) in ids.windows(2).enumerate() {
                if let Some(&rank) = self.ranks.get(&(w[0], w[1])) {
                    if best.map_or(true, |(br, _)| rank < br) {
                        best = Some((rank, i));
                    }
                }
            }
            let Some((rank, _)) = best else { break };
            let pair = self.merges[rank];
            ids = merge_once(&ids, pair, 257 + rank as u32);
        }
        ids
    }

    /// Decode ids back to bytes (PAD decodes to nothing).
    pub fn decode(&self, ids: &[u32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            self.push_bytes(id, &mut out);
        }
        out
    }

    fn push_bytes(&self, id: u32, out: &mut Vec<u8>) {
        if id == PAD {
            return;
        }
        if id < 257 {
            out.push((id - BYTE_BASE) as u8);
            return;
        }
        let (l, r) = self.merges[(id - 257) as usize];
        self.push_bytes(l, out);
        self.push_bytes(r, out);
    }

    /// Serialize merges to a text format ("left right" per line).
    pub fn to_text(&self) -> String {
        let mut s = format!("psf-bpe v1 vocab {}\n", self.vocab);
        for (l, r) in &self.merges {
            s.push_str(&format!("{l} {r}\n"));
        }
        s
    }

    pub fn from_text(text: &str) -> anyhow::Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty bpe file"))?;
        let vocab: usize = header
            .strip_prefix("psf-bpe v1 vocab ")
            .ok_or_else(|| anyhow::anyhow!("bad bpe header: {header}"))?
            .parse()?;
        let mut merges = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let l: u32 = it.next().ok_or_else(|| anyhow::anyhow!("bad merge line"))?.parse()?;
            let r: u32 = it.next().ok_or_else(|| anyhow::anyhow!("bad merge line"))?.parse()?;
            merges.push((l, r));
        }
        let ranks = merges.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        Ok(Bpe { merges, ranks, vocab })
    }
}

fn merge_once(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact() {
        let text = b"the quick brown fox jumps over the lazy dog. the quick brown fox.";
        let bpe = Bpe::train(text, 300);
        let ids = bpe.encode(text);
        assert_eq!(bpe.decode(&ids), text);
        assert!(ids.len() < text.len(), "no compression achieved");
    }

    #[test]
    fn never_emits_pad_or_overflow() {
        let text: Vec<u8> = (0..2000u32).map(|i| (i % 251) as u8).collect();
        let bpe = Bpe::train(&text, 400);
        for &id in &bpe.encode(&text) {
            assert_ne!(id, PAD);
            assert!((id as usize) < bpe.vocab_size());
        }
    }

    #[test]
    fn merges_capped_by_vocab() {
        let text = b"aaaaabbbbbaaaaabbbbb";
        let bpe = Bpe::train(text, 300);
        assert!(bpe.num_merges() <= 300 - 257);
    }

    #[test]
    fn deterministic() {
        let text = b"abcabcabcabc the same text twice abcabcabcabc the same text twice";
        let a = Bpe::train(text, 280);
        let b = Bpe::train(text, 280);
        assert_eq!(a.encode(text), b.encode(text));
    }

    #[test]
    fn serialization_roundtrip() {
        let text = b"hello world hello world hello";
        let bpe = Bpe::train(text, 270);
        let back = Bpe::from_text(&bpe.to_text()).unwrap();
        assert_eq!(back.encode(text), bpe.encode(text));
        assert_eq!(back.vocab_size(), bpe.vocab_size());
    }

    #[test]
    fn empty_input() {
        let bpe = Bpe::train(b"some training text for the tokenizer", 260);
        assert!(bpe.encode(b"").is_empty());
        assert!(bpe.decode(&[]).is_empty());
    }

    #[test]
    fn unseen_bytes_still_encode() {
        let bpe = Bpe::train(b"only ascii here", 260);
        let exotic = [0u8, 255, 128, 7];
        let ids = bpe.encode(&exotic);
        assert_eq!(bpe.decode(&ids), exotic);
    }
}
