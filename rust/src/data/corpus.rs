//! Synthetic corpus generators — the data substrate (DESIGN.md §4).
//!
//! The paper trains on PG-19 (books), Wiki-40B and C4.  Those are not
//! available here, so this module builds deterministic generative corpora
//! with the statistical properties the experiments exercise:
//!
//! * `Books`  (PG-19-like): long documents with persistent "characters"
//!   and slowly-drifting topics — genuine long-range reuse, the regime
//!   where long-context attention pays off.
//! * `Wiki`   (Wiki-40B-like): shorter articles, strong per-document topic
//!   concentration, heavier vocabulary skew.
//! * `Web`    (C4-like): a noisy mixture of the two plus boilerplate.
//!
//! Word frequencies follow a Zipf law over a synthetic lexicon; sentences
//! come from a small grammar (subject/verb/object over topic-biased word
//! pools), so bigram structure exists for a language model to learn.

use crate::util::rng::Pcg;

/// Which synthetic corpus to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    Books,
    Wiki,
    Web,
}

impl Flavor {
    pub fn parse(s: &str) -> Option<Flavor> {
        match s {
            "books" | "pg19" => Some(Flavor::Books),
            "wiki" => Some(Flavor::Wiki),
            "web" | "c4" => Some(Flavor::Web),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Flavor::Books => "books",
            Flavor::Wiki => "wiki",
            Flavor::Web => "web",
        }
    }
}

/// Corpus generator with a fixed lexicon and topic structure.
pub struct CorpusGen {
    lexicon: Vec<String>,
    /// Per-topic word-pool indices into the lexicon.
    topics: Vec<Vec<usize>>,
    names: Vec<String>,
    flavor: Flavor,
}

const N_TOPICS: usize = 12;
const TOPIC_POOL: usize = 120;
const LEXICON: usize = 900;
const N_NAMES: usize = 40;

impl CorpusGen {
    pub fn new(flavor: Flavor, seed: u64) -> Self {
        let mut rng = Pcg::new(seed, 0xc0ffee);
        let lexicon: Vec<String> = (0..LEXICON).map(|_| synth_word(&mut rng)).collect();
        let topics = (0..N_TOPICS)
            .map(|_| (0..TOPIC_POOL).map(|_| rng.usize_below(LEXICON)).collect())
            .collect();
        let names = (0..N_NAMES)
            .map(|_| {
                let mut w = synth_word(&mut rng);
                if let Some(c) = w.get_mut(0..1) {
                    let upper = c.to_uppercase();
                    w.replace_range(0..1, &upper);
                }
                w
            })
            .collect();
        CorpusGen { lexicon, topics, names, flavor }
    }

    /// Generate ~`target_bytes` of text, deterministically from `seed`.
    pub fn generate(&self, target_bytes: usize, seed: u64) -> String {
        let mut rng = Pcg::new(seed, 0x7e57);
        let mut out = String::with_capacity(target_bytes + 1024);
        while out.len() < target_bytes {
            match self.flavor {
                Flavor::Books => self.book(&mut rng, &mut out),
                Flavor::Wiki => self.article(&mut rng, &mut out),
                Flavor::Web => {
                    if rng.f32() < 0.5 {
                        self.article(&mut rng, &mut out)
                    } else if rng.f32() < 0.6 {
                        self.book(&mut rng, &mut out)
                    } else {
                        self.boilerplate(&mut rng, &mut out)
                    }
                }
            }
            out.push('\n');
        }
        out.truncate(target_bytes);
        out
    }

    /// Long document: cast of characters persists for the whole document;
    /// topic drifts slowly (long-range dependence).
    fn book(&self, rng: &mut Pcg, out: &mut String) {
        let cast: Vec<&String> = (0..3 + rng.usize_below(3))
            .map(|_| &self.names[rng.usize_below(N_NAMES)])
            .collect();
        let mut topic = rng.usize_below(N_TOPICS);
        let paragraphs = 20 + rng.usize_below(30);
        for _ in 0..paragraphs {
            if rng.f32() < 0.15 {
                topic = (topic + 1 + rng.usize_below(N_TOPICS - 1)) % N_TOPICS;
            }
            let sentences = 3 + rng.usize_below(5);
            for _ in 0..sentences {
                self.sentence(rng, topic, Some(&cast), out);
            }
            out.push('\n');
        }
    }

    /// Short article: one dominant topic, titled.
    fn article(&self, rng: &mut Pcg, out: &mut String) {
        let topic = rng.usize_below(N_TOPICS);
        out.push_str("== ");
        out.push_str(self.topic_word(rng, topic));
        out.push_str(" ==\n");
        let sentences = 6 + rng.usize_below(10);
        for _ in 0..sentences {
            self.sentence(rng, topic, None, out);
        }
    }

    fn boilerplate(&self, rng: &mut Pcg, out: &mut String) {
        const SNIPPETS: &[&str] = &[
            "click here to subscribe.",
            "all rights reserved.",
            "terms of service apply.",
            "sign in to continue reading.",
        ];
        for _ in 0..1 + rng.usize_below(3) {
            out.push_str(SNIPPETS[rng.usize_below(SNIPPETS.len())]);
            out.push(' ');
        }
        out.push('\n');
    }

    fn sentence(&self, rng: &mut Pcg, topic: usize, cast: Option<&Vec<&String>>,
                out: &mut String) {
        // subject
        match cast {
            Some(cast) if rng.f32() < 0.6 => {
                out.push_str(cast[rng.usize_below(cast.len())]);
            }
            _ => {
                out.push_str("the ");
                out.push_str(self.topic_word(rng, topic));
            }
        }
        out.push(' ');
        // verb (global zipf draw keeps function-word statistics shared)
        out.push_str(self.zipf_word(rng));
        // object phrase: topic-biased
        let len = 2 + rng.usize_below(6);
        for _ in 0..len {
            out.push(' ');
            if rng.f32() < 0.7 {
                out.push_str(self.topic_word(rng, topic));
            } else {
                out.push_str(self.zipf_word(rng));
            }
        }
        out.push_str(". ");
    }

    fn topic_word(&self, rng: &mut Pcg, topic: usize) -> &str {
        let pool = &self.topics[topic];
        // Zipf within the pool.
        let idx = zipf_index(rng, pool.len());
        &self.lexicon[pool[idx]]
    }

    fn zipf_word(&self, rng: &mut Pcg) -> &str {
        &self.lexicon[zipf_index(rng, self.lexicon.len())]
    }
}

/// Zipf(s≈1) index in [0, n): p(i) ∝ 1/(i+1).
fn zipf_index(rng: &mut Pcg, n: usize) -> usize {
    // Inverse-CDF on the harmonic sum, done by rejection for simplicity:
    // draw u in (0,1], index = floor(exp(u * ln(n))) - 1 approximates the
    // heavy tail cheaply and deterministically.
    let u = rng.f64().max(1e-12);
    let idx = ((n as f64).powf(u) - 1.0) as usize;
    idx.min(n - 1)
}

/// Pronounceable synthetic word (CV syllables).
fn synth_word(rng: &mut Pcg) -> String {
    const C: &[u8] = b"bcdfghklmnprstvz";
    const V: &[u8] = b"aeiou";
    let syllables = 1 + rng.usize_below(3);
    let mut w = String::new();
    for _ in 0..syllables {
        w.push(C[rng.usize_below(C.len())] as char);
        w.push(V[rng.usize_below(V.len())] as char);
        if rng.f32() < 0.3 {
            w.push(C[rng.usize_below(C.len())] as char);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g1 = CorpusGen::new(Flavor::Books, 1).generate(10_000, 7);
        let g2 = CorpusGen::new(Flavor::Books, 1).generate(10_000, 7);
        assert_eq!(g1, g2);
    }

    #[test]
    fn seeds_differ() {
        let g = CorpusGen::new(Flavor::Books, 1);
        assert_ne!(g.generate(5_000, 1), g.generate(5_000, 2));
    }

    #[test]
    fn target_size_respected() {
        let g = CorpusGen::new(Flavor::Wiki, 2);
        assert_eq!(g.generate(12_345, 0).len(), 12_345);
    }

    #[test]
    fn flavors_have_distinct_texture() {
        let books = CorpusGen::new(Flavor::Books, 3).generate(20_000, 0);
        let wiki = CorpusGen::new(Flavor::Wiki, 3).generate(20_000, 0);
        let web = CorpusGen::new(Flavor::Web, 3).generate(20_000, 0);
        assert!(!books.contains("=="));
        assert!(wiki.contains("=="));
        assert!(web.contains("rights reserved") || web.contains("subscribe"));
    }

    #[test]
    fn zipf_skew() {
        // Most-frequent word should dominate the tail heavily.
        let mut rng = Pcg::seeded(0);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[zipf_index(&mut rng, 100)] += 1;
        }
        assert!(counts[0] > 10 * counts[50].max(1));
    }

    #[test]
    fn books_reuse_character_names() {
        // Long-range reuse: some capitalized name must appear many times.
        let text = CorpusGen::new(Flavor::Books, 4).generate(30_000, 0);
        let mut max_count = 0;
        for word in text.split_whitespace() {
            if word.chars().next().map_or(false, |c| c.is_uppercase()) {
                let count = text.matches(word).count();
                max_count = max_count.max(count);
            }
        }
        assert!(max_count >= 10, "no persistent names found ({max_count})");
    }
}
