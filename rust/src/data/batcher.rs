//! Sequence packing + deterministic batch iteration.
//!
//! Turns a token stream into fixed-length (batch, ctx+1) training batches:
//! the stream is cut into ctx+1-length segments (next-token targets need
//! one token of overhang), segments are shuffled deterministically per
//! epoch, and train/test splits are disjoint by construction.

use crate::util::rng::Pcg;

/// Token batches of shape (batch, seq) flattened row-major into i32 —
/// exactly the layout the PJRT tokens parameter expects.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn row(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq..(i + 1) * self.seq]
    }
}

/// Deterministic segment-shuffling batcher.  `Clone` snapshots the full
/// iteration state: a clone draws the same upcoming batches without
/// advancing the original (the trainer's fallback-eval primitive).
#[derive(Clone)]
pub struct Batcher {
    segments: Vec<Vec<u32>>,
    batch: usize,
    seq: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
    seed: u64,
}

impl Batcher {
    /// `seq` = ctx + 1 for training batches. Drops the final partial segment.
    pub fn new(stream: &[u32], batch: usize, seq: usize, seed: u64) -> Self {
        assert!(batch > 0 && seq > 1);
        let segments: Vec<Vec<u32>> = stream
            .chunks_exact(seq)
            .map(|c| c.to_vec())
            .collect();
        assert!(
            segments.len() >= batch,
            "stream too short: {} segments < batch {}",
            segments.len(),
            batch
        );
        let mut b = Batcher {
            segments,
            batch,
            seq,
            order: Vec::new(),
            cursor: 0,
            epoch: 0,
            seed,
        };
        b.reshuffle();
        b
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Rows per batch (the `batch` this source was built with).
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Tokens per row (ctx + 1: each row carries its shifted target).
    pub fn seq_len(&self) -> usize {
        self.seq
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.segments.len() / self.batch
    }

    fn reshuffle(&mut self) {
        self.order = (0..self.segments.len()).collect();
        let mut rng = Pcg::new(self.seed ^ self.epoch, 0xba7c4);
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next (batch, seq) batch; wraps epochs automatically.
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        for bi in 0..self.batch {
            let seg = &self.segments[self.order[self.cursor + bi]];
            tokens.extend(seg.iter().map(|&t| t as i32));
        }
        self.cursor += self.batch;
        Batch { tokens, batch: self.batch, seq: self.seq }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Advance past `n` batches without materializing them — the same
    /// cursor/epoch arithmetic as [`Batcher::next_batch`], so a resumed
    /// trainer lands on exactly the batch an uninterrupted run would see
    /// next.
    pub fn skip_batches(&mut self, n: u64) {
        for _ in 0..n {
            if self.cursor + self.batch > self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            self.cursor += self.batch;
        }
    }
}

/// Split a token stream into train/test by fraction (test gets the tail).
pub fn split_stream(stream: &[u32], test_frac: f64) -> (&[u32], &[u32]) {
    let cut = ((stream.len() as f64) * (1.0 - test_frac)) as usize;
    stream.split_at(cut)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| 1 + i % 100).collect()
    }

    #[test]
    fn batch_shapes() {
        let mut b = Batcher::new(&stream(1000), 4, 33, 0);
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), 4 * 33);
        assert_eq!(batch.row(3).len(), 33);
    }

    #[test]
    fn deterministic_given_seed() {
        let s = stream(1000);
        let mut a = Batcher::new(&s, 4, 33, 7);
        let mut b = Batcher::new(&s, 4, 33, 7);
        for _ in 0..5 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn epoch_covers_all_segments_once() {
        let s = stream(33 * 8);
        let mut b = Batcher::new(&s, 2, 33, 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..b.batches_per_epoch() {
            let batch = b.next_batch();
            for r in 0..batch.batch {
                seen.insert(batch.row(r).to_vec());
            }
        }
        assert_eq!(seen.len(), 8, "each segment exactly once per epoch");
        assert_eq!(b.epoch(), 0);
        b.next_batch();
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn epochs_reshuffle() {
        // The first batch of epoch 1 should differ from the first batch of
        // epoch 0 (different shuffle seed per epoch).
        let s = stream(33 * 16);
        let mut b = Batcher::new(&s, 2, 33, 1);
        let epoch0_first = b.next_batch().tokens;
        for _ in 0..b.batches_per_epoch() - 1 {
            b.next_batch();
        }
        let epoch1_first = b.next_batch().tokens;
        assert_eq!(b.epoch(), 1);
        assert_ne!(epoch0_first, epoch1_first);
    }

    #[test]
    fn skip_batches_matches_consuming() {
        let s = stream(33 * 10);
        let mut a = Batcher::new(&s, 3, 33, 5);
        let mut b = Batcher::new(&s, 3, 33, 5);
        for _ in 0..7 {
            a.next_batch();
        }
        b.skip_batches(7);
        assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        assert_eq!(a.epoch(), b.epoch());
    }

    #[test]
    fn split_disjoint() {
        let s = stream(100);
        let (train, test) = split_stream(&s, 0.2);
        assert_eq!(train.len(), 80);
        assert_eq!(test.len(), 20);
        assert_eq!(train.len() + test.len(), 100);
    }

    #[test]
    #[should_panic]
    fn too_short_stream_panics() {
        Batcher::new(&stream(10), 4, 33, 0);
    }
}
