//! Data pipeline substrate: synthetic corpora, BPE tokenizer, batching.
//!
//! End-to-end: `load_corpus_tokens` generates (or reads) text, trains /
//! loads a BPE tokenizer with the model's vocabulary, encodes, and returns
//! disjoint train/test token streams ready for the [`batcher::Batcher`].

pub mod batcher;
pub mod bpe;
pub mod corpus;
pub mod prefetch;

use std::path::Path;

use crate::util::rng::Pcg;

/// Tokenized dataset: train/test streams + the tokenizer that made them.
pub struct Dataset {
    pub train: Vec<u32>,
    pub test: Vec<u32>,
    pub bpe: bpe::Bpe,
    pub flavor: corpus::Flavor,
}

/// Generate a synthetic corpus of `bytes` bytes, train a BPE with `vocab`
/// ids on a prefix, and tokenize. Deterministic in `seed`. The tokenizer is
/// cached on disk next to `cache_dir` (training BPE is the slow part).
pub fn load_corpus_tokens(flavor: corpus::Flavor, bytes: usize, vocab: usize,
                          seed: u64, cache_dir: Option<&Path>) -> anyhow::Result<Dataset> {
    let gen = corpus::CorpusGen::new(flavor, seed);
    let text = gen.generate(bytes, seed ^ 0x9e37);

    let bpe = match cache_dir {
        Some(dir) => {
            let cache = dir.join(format!("bpe_{}_{}_{}.txt", flavor.label(), vocab, seed));
            if cache.exists() {
                bpe::Bpe::from_text(&std::fs::read_to_string(&cache)?)?
            } else {
                let trained = train_bpe(&text, vocab);
                std::fs::create_dir_all(dir)?;
                std::fs::write(&cache, trained.to_text())?;
                trained
            }
        }
        None => train_bpe(&text, vocab),
    };

    let tokens = bpe.encode(text.as_bytes());
    let (train, test) = batcher::split_stream(&tokens, 0.1);
    Ok(Dataset {
        train: train.to_vec(),
        test: test.to_vec(),
        bpe,
        flavor,
    })
}

fn train_bpe(text: &str, vocab: usize) -> bpe::Bpe {
    // Train on a bounded prefix: merge statistics converge quickly and
    // training is quadratic-ish in corpus size.
    let cap = text.len().min(200_000);
    bpe::Bpe::train(&text.as_bytes()[..cap], vocab)
}

/// Convenience for tests/benches: random token stream (ids in 1..vocab).
pub fn random_tokens(n: usize, vocab: usize, seed: u64) -> Vec<u32> {
    let mut rng = Pcg::seeded(seed);
    (0..n).map(|_| 1 + rng.below((vocab - 1) as u64) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_pipeline() {
        let ds = load_corpus_tokens(corpus::Flavor::Wiki, 60_000, 300, 0, None).unwrap();
        assert!(ds.train.len() > 1000);
        assert!(ds.test.len() > 100);
        // All ids valid and non-pad.
        for &t in ds.train.iter().chain(&ds.test) {
            assert!(t != 0 && (t as usize) < 300);
        }
    }

    #[test]
    fn deterministic_dataset() {
        let a = load_corpus_tokens(corpus::Flavor::Books, 30_000, 280, 5, None).unwrap();
        let b = load_corpus_tokens(corpus::Flavor::Books, 30_000, 280, 5, None).unwrap();
        assert_eq!(a.train, b.train);
        assert_eq!(a.test, b.test);
    }

    #[test]
    fn random_tokens_in_range() {
        for &t in &random_tokens(1000, 64, 0) {
            assert!((1..64).contains(&t));
        }
    }
}
