//! PolySketchFormer — Fast Transformers via Sketching Polynomial Kernels
//! (Kacham, Mirrokni & Zhong, ICML 2024): full-system reproduction.
//!
//! Three-layer architecture (see DESIGN.md):
//!   * L1: Pallas kernels + JAX model (`python/`, build-time only),
//!   * L2: AOT-lowered HLO artifacts (`artifacts/*.hlo.txt`),
//!   * L3: this crate — PJRT runtime, training coordinator, data pipeline,
//!     synthetic tasks, native attention kernels, the linear-time decoding
//!     subsystem (`infer`), the concurrent serving gateway with its
//!     constant-size prompt-state cache (`serve`), the deterministic
//!     multi-threaded compute backend every native hot path runs on
//!     (`exec::pool` — bitwise identical results at any thread count),
//!     the native training subsystem with hand-written backward passes
//!     through the kernel core (`train` — linear-time backward for the
//!     sketched mechanisms, `psf train-native`), and the bench harness
//!     that regenerates every table/figure of the paper's evaluation,
//!     multi-process sharded serving (`shard` — gateway + runner
//!     worker processes over a versioned Unix-socket IPC protocol,
//!     `psf serve --runners N`), and the std-only observability layer
//!     (`obs` — span tracing to Chrome trace-event JSON with
//!     cross-process trace-id propagation, fixed-bucket latency
//!     histograms with Prometheus exposition, per-phase kernel
//!     profiling, numeric-health sentinels with fault attribution, a
//!     flight-recorder gauge ring, and `incident.json` crash dumps;
//!     near-zero overhead when off), and the memory
//!     subsystem (`mem` — a paged slab arena with generation-tagged
//!     handles for decode states, plus `PSF_QUANT`-gated f16/int8
//!     quantized storage for cached states and weights).

pub mod attn;
pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod infer;
pub mod mem;
pub mod metrics;
pub mod obs;
pub mod prop;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod tasks;
pub mod tensor;
pub mod train;
pub mod util;

pub use util::rng::Pcg;
