//! Deterministic PRNG substrate (no `rand` crate in this environment).
//!
//! PCG64-DXSM-style generator plus Box–Muller Gaussians; every stochastic
//! component in the repo (data generation, native sketches, property tests)
//! derives its stream from one of these so runs are reproducible from a
//! single seed.

/// Permuted congruential generator (PCG-XSH-RR 64/32 extended to 64-bit
/// output by combining two draws). Small, fast, and statistically solid for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed with an arbitrary 64-bit value; `stream` picks an independent
    /// sequence (used to give threads/workers disjoint streams).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive a child generator; children with distinct tags are
    /// independent of each other and of the parent's future output.
    pub fn fork(&mut self, tag: u64) -> Pcg {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Pcg::new(seed, tag.wrapping_add(1))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard Gaussian via Box–Muller (one value per call; the pair's
    /// second half is discarded to keep the generator stateless-simple).
    pub fn gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fill a vector with standard Gaussians.
    pub fn gaussians(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.usize_below(weights.len());
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut rng = Pcg::seeded(3);
        for _ in 0..1000 {
            let v = rng.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg::seeded(11);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg::seeded(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg::seeded(13);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > 2 * counts[1]);
    }
}
