//! Shared utilities: deterministic RNG, streaming statistics, timing,
//! and the graceful-shutdown signal flag.

pub mod rng;
pub mod signal;
pub mod stats;

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Human-friendly duration formatting for logs.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(200.0).ends_with("min"));
    }

    #[test]
    fn timed_returns_value() {
        let (v, dt) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
