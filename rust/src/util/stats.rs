//! Streaming statistics used by the metrics and bench substrates.

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exponential moving average (for smoothed loss curves).
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Percentile over a sample (linear interpolation). `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
        assert_eq!(r.count(), 8);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..32 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_bounds() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 99.0) - 99.0).abs() < 1e-9);
    }
}
