//! Graceful-shutdown signal flag, std-only.
//!
//! `psf serve` (single-process and sharded) wants SIGTERM/SIGINT to
//! mean "stop accepting, drain, flush the closing metrics record" —
//! not instant death.  There is no libc crate in this tree, so the
//! handler is installed through the C `signal(2)` symbol directly; the
//! handler body only stores into a static atomic, which is the entire
//! async-signal-safe budget and all we need.  Serving loops poll
//! [`triggered`] and flip their own stop flags.
//!
//! Installation is idempotent and the flag is process-global: one
//! shutdown intent per process is the right granularity (the sharded
//! gateway forwards it to runners over IPC, not via signals).

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
const SIGINT: i32 = 2;
#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
extern "C" fn on_signal(_sig: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install the SIGINT/SIGTERM handlers.  Safe to call more than once.
#[cfg(unix)]
pub fn install() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
}

/// Non-unix builds: no handler; `triggered` just never fires.
#[cfg(not(unix))]
pub fn install() {}

/// Has a shutdown signal arrived since process start?
pub fn triggered() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Test hook: reset the flag (the handler can fire only once per test
/// process otherwise).
#[cfg(test)]
pub(crate) fn reset() {
    SHUTDOWN.store(false, Ordering::SeqCst);
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    #[test]
    fn sigterm_flips_the_flag() {
        extern "C" {
            fn raise(sig: i32) -> i32;
        }
        install();
        reset();
        assert!(!triggered());
        unsafe {
            raise(SIGTERM);
        }
        // Delivery is synchronous for raise() on the calling thread.
        assert!(triggered());
        reset();
        install(); // idempotent
        unsafe {
            raise(SIGINT);
        }
        assert!(triggered());
        reset();
    }
}
