//! Graceful-shutdown signal flag, std-only.
//!
//! `psf serve` (single-process and sharded) wants SIGTERM/SIGINT to
//! mean "stop accepting, drain, flush the closing metrics record" —
//! not instant death.  There is no libc crate in this tree, so the
//! handler is installed through the C `signal(2)` symbol directly; the
//! handler body only stores into a static atomic, which is the entire
//! async-signal-safe budget and all we need.  Serving loops poll
//! [`triggered`] and flip their own stop flags.
//!
//! Installation is idempotent and the flag is process-global: one
//! shutdown intent per process is the right granularity (the sharded
//! gateway forwards it to runners over IPC, not via signals).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Cleanup closures run on the *drain path* (not in the handler — the
/// handler's async-signal-safe budget is one atomic store).  Serving
/// loops call [`run_shutdown_hooks`] after they stop accepting; hooks
/// flush traces/metrics that would otherwise die with the process.
#[allow(clippy::type_complexity)]
static HOOKS: Mutex<Vec<Box<dyn FnOnce() + Send>>> = Mutex::new(Vec::new());

/// Register a cleanup closure for the drain path.  Hooks run once, in
/// registration order, when [`run_shutdown_hooks`] is called.
pub fn on_shutdown(hook: impl FnOnce() + Send + 'static) {
    HOOKS.lock().expect("shutdown hooks poisoned").push(Box::new(hook));
}

/// Run (and consume) every registered shutdown hook.  Idempotent:
/// a second call sees an empty registry and does nothing, so both the
/// signal drain path and normal exit can call it safely.
pub fn run_shutdown_hooks() {
    let hooks = std::mem::take(&mut *HOOKS.lock().expect("shutdown hooks poisoned"));
    for hook in hooks {
        hook();
    }
}

#[cfg(unix)]
const SIGINT: i32 = 2;
#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
extern "C" fn on_signal(_sig: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install the SIGINT/SIGTERM handlers.  Safe to call more than once.
#[cfg(unix)]
pub fn install() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
}

/// Non-unix builds: no handler; `triggered` just never fires.
#[cfg(not(unix))]
pub fn install() {}

/// Has a shutdown signal arrived since process start?
pub fn triggered() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Test hook: reset the flag (the handler can fire only once per test
/// process otherwise).
#[cfg(test)]
pub(crate) fn reset() {
    SHUTDOWN.store(false, Ordering::SeqCst);
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    #[test]
    fn sigterm_flips_the_flag() {
        extern "C" {
            fn raise(sig: i32) -> i32;
        }
        install();
        reset();
        assert!(!triggered());
        unsafe {
            raise(SIGTERM);
        }
        // Delivery is synchronous for raise() on the calling thread.
        assert!(triggered());
        reset();
        install(); // idempotent
        unsafe {
            raise(SIGINT);
        }
        assert!(triggered());
        reset();
    }

    #[test]
    fn shutdown_hooks_run_once_in_order() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let order = Arc::new(Mutex::new(Vec::new()));
        let runs = Arc::new(AtomicUsize::new(0));
        for i in 0..3 {
            let order = Arc::clone(&order);
            let runs = Arc::clone(&runs);
            on_shutdown(move || {
                order.lock().unwrap().push(i);
                runs.fetch_add(1, Ordering::SeqCst);
            });
        }
        run_shutdown_hooks();
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
        run_shutdown_hooks(); // second call is a no-op
        assert_eq!(runs.load(Ordering::SeqCst), 3);
    }
}
