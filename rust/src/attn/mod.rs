//! Native rust implementations of every attention mechanism in the paper.
//!
//! These mirror the L1 kernels bit-for-bit in math (tests cross-check
//! against the Python oracles through shared fixtures) and serve three
//! roles: property tests of the algorithms' invariants, large-n latency
//! benches (Figures 1/4, Table 4 — the interpreted Pallas kernels cannot
//! reach 32k), and host-side verification of PJRT artifacts.

pub mod block_lt;
pub mod performer;
pub mod poly;
pub mod sketch;
pub mod softmax;

use std::sync::Arc;

use crate::tensor::{layernorm_rows, Tensor};
use crate::util::rng::Pcg;

/// Which attention mechanism to run (native path).
#[derive(Clone, Debug, PartialEq)]
pub enum Mechanism {
    /// Naive causal softmax (quadratic, row-streaming).
    Softmax,
    /// FlashAttention-style blocked softmax (quadratic, tiled).
    Flash { block: usize },
    /// Exact degree-p polynomial attention (quadratic).
    Poly { p: u32 },
    /// Polysketch attention (linear): sketch size r, block b, degree p,
    /// optional local-exact diagonal blocks.
    Polysketch { r: usize, p: u32, block: usize, local: bool },
    /// Performer/FAVOR+ (linear) with m features.
    Performer { m: usize, block: usize },
}

impl Mechanism {
    pub fn label(&self) -> String {
        match self {
            Mechanism::Softmax => "softmax".into(),
            Mechanism::Flash { block } => format!("flash_b{block}"),
            Mechanism::Poly { p } => format!("poly{p}"),
            Mechanism::Polysketch { r, p, block, local } => {
                format!("psk{p}_r{r}_b{block}{}", if *local { "_local" } else { "" })
            }
            Mechanism::Performer { m, block } => format!("performer{m}_b{block}"),
        }
    }

    /// Parse a mechanism label — the exact inverse of [`Mechanism::label`]:
    /// `softmax`, `flash_b<block>`, `poly<p>`, `psk<p>_r<r>_b<block>[_local]`,
    /// `performer<m>_b<block>`.  Shared by the CLI `generate` subcommand and
    /// the benches so mechanism strings are spelled one way everywhere.
    pub fn parse(s: &str) -> Result<Mechanism, String> {
        let err = || format!("bad mechanism `{s}` (want softmax | flash_b<B> | poly<P> | psk<P>_r<R>_b<B>[_local] | performer<M>_b<B>)");
        if s == "softmax" {
            return Ok(Mechanism::Softmax);
        }
        if let Some(rest) = s.strip_prefix("flash_b") {
            let block: usize = rest.parse().map_err(|_| err())?;
            if block == 0 {
                return Err(format!("bad mechanism `{s}`: block must be >= 1"));
            }
            return Ok(Mechanism::Flash { block });
        }
        if let Some(rest) = s.strip_prefix("poly") {
            let p: u32 = rest.parse().map_err(|_| err())?;
            if p < 2 || p % 2 != 0 {
                return Err(format!("bad mechanism `{s}`: poly degree must be even and >= 2"));
            }
            return Ok(Mechanism::Poly { p });
        }
        if let Some(rest) = s.strip_prefix("psk") {
            let (body, local) = match rest.strip_suffix("_local") {
                Some(b) => (b, true),
                None => (rest, false),
            };
            let mut it = body.split('_');
            let p = it.next().and_then(|t| t.parse().ok()).ok_or_else(err)?;
            let r = it
                .next()
                .and_then(|t| t.strip_prefix('r'))
                .and_then(|t| t.parse().ok())
                .ok_or_else(err)?;
            let block = it
                .next()
                .and_then(|t| t.strip_prefix('b'))
                .and_then(|t| t.parse().ok())
                .ok_or_else(err)?;
            if it.next().is_some() {
                return Err(err());
            }
            if p < 2 || !u32::is_power_of_two(p) {
                return Err(format!("bad mechanism `{s}`: psk degree must be a power of two >= 2"));
            }
            if r == 0 || block == 0 {
                return Err(format!("bad mechanism `{s}`: sketch size and block must be >= 1"));
            }
            return Ok(Mechanism::Polysketch { r, p, block, local });
        }
        if let Some(rest) = s.strip_prefix("performer") {
            let (m, block) = rest.split_once("_b").ok_or_else(err)?;
            let m: usize = m.parse().map_err(|_| err())?;
            let block: usize = block.parse().map_err(|_| err())?;
            if m == 0 || block == 0 {
                return Err(format!("bad mechanism `{s}`: feature count and block must be >= 1"));
            }
            return Ok(Mechanism::Performer { m, block });
        }
        Err(err())
    }

    /// Linear-time in context length?
    pub fn is_linear(&self) -> bool {
        matches!(self, Mechanism::Polysketch { .. } | Mechanism::Performer { .. })
    }
}

/// A mechanism instantiated with its random state (sketches/features), so
/// repeated calls reuse the same projections — required for KV-style reuse
/// and for honest benchmarking (sampling is not part of the hot path).
///
/// The projections live behind `Arc`: decode states (and every cached
/// prompt-prefix snapshot cloned from them) share one copy per
/// (layer, head) instead of duplicating immutable model-derived tensors
/// on every clone.
pub enum Attention {
    Softmax,
    Flash { block: usize },
    Poly { p: u32 },
    Polysketch { sk: Arc<sketch::PolySketch>, block: usize, local: bool },
    Performer { feats: Arc<performer::PerformerFeatures>, block: usize },
}

impl Attention {
    pub fn new(mech: &Mechanism, head_dim: usize, rng: &mut Pcg) -> Self {
        match mech {
            Mechanism::Softmax => Attention::Softmax,
            Mechanism::Flash { block } => Attention::Flash { block: *block },
            Mechanism::Poly { p } => Attention::Poly { p: *p },
            Mechanism::Polysketch { r, p, block, local } => Attention::Polysketch {
                sk: Arc::new(sketch::PolySketch::sample(rng, head_dim, *r, *p as usize)),
                block: *block,
                local: *local,
            },
            Mechanism::Performer { m, block } => Attention::Performer {
                feats: Arc::new(performer::PerformerFeatures::sample(rng, head_dim, *m)),
                block: *block,
            },
        }
    }

    /// Run causal attention on one (batch, head) slice.
    pub fn run(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        match self {
            Attention::Softmax => softmax::softmax_attention(q, k, v),
            Attention::Flash { block } => {
                softmax::flash_attention(q, k, v, (*block).min(q.rows()))
            }
            Attention::Poly { p } => poly::poly_attention(q, k, v, *p),
            Attention::Polysketch { sk, block, local } => {
                let qn = layernorm_rows(q);
                let kn = layernorm_rows(k);
                let lh = sk.half(&qn);
                let rh = sk.half(&kn);
                let b = (*block).min(q.rows());
                let le = if *local {
                    Some(block_lt::LocalExact { q, k, p: sk.p as u32 })
                } else {
                    None
                };
                block_lt::polysketch_attention_block(&lh, &rh, v, b, le)
            }
            Attention::Performer { feats, block } => {
                let b = (*block).min(q.rows());
                performer::performer_attention(q, k, v, feats, b)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_inverts_label() {
        let ms = [
            Mechanism::Softmax,
            Mechanism::Flash { block: 256 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 16, p: 4, block: 64, local: true },
            Mechanism::Polysketch { r: 32, p: 2, block: 128, local: false },
            Mechanism::Performer { m: 64, block: 256 },
        ];
        for m in ms {
            assert_eq!(Mechanism::parse(&m.label()).unwrap(), m, "{}", m.label());
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "soft", "flash", "flash_b", "flash_bxx", "poly", "polyx", "psk4",
            "psk4_r16", "psk4_r16_b", "psk4_b64_r16", "psk4_r16_b64_extra",
            "performer64", "performer_b64", "psk4_r16_b64_localx",
        ] {
            assert!(Mechanism::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn parse_rejects_degenerate_parameters() {
        // Values that would only panic deep inside the kernels must be
        // rejected at the parse boundary (the CLI feeds this directly).
        for bad in [
            "flash_b0", "poly0", "poly1", "poly3", "psk3_r4_b8", "psk0_r4_b8",
            "psk4_r0_b8", "psk4_r4_b0", "performer0_b8", "performer16_b0",
        ] {
            assert!(Mechanism::parse(bad).is_err(), "`{bad}` should not parse");
        }
        // poly6 is legal for exact polynomial attention (even, not pow2)...
        assert!(Mechanism::parse("poly6").is_ok());
        // ...but sketches need a power of two.
        assert!(Mechanism::parse("psk6_r4_b8").is_err());
    }

    #[test]
    fn labels_distinct() {
        let ms = [
            Mechanism::Softmax,
            Mechanism::Flash { block: 64 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 16, p: 4, block: 64, local: true },
            Mechanism::Performer { m: 64, block: 64 },
        ];
        let labels: Vec<_> = ms.iter().map(|m| m.label()).collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }

    #[test]
    fn all_mechanisms_run_and_are_finite() {
        let mut rng = Pcg::seeded(0);
        let (n, h) = (32, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        for mech in [
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 8, p: 4, block: 8, local: true },
            Mechanism::Polysketch { r: 8, p: 4, block: 8, local: false },
            Mechanism::Performer { m: 16, block: 8 },
        ] {
            let attn = Attention::new(&mech, h, &mut rng);
            let out = attn.run(&q, &k, &v);
            assert_eq!(out.shape(), &[n, h]);
            assert!(out.data().iter().all(|x| x.is_finite()), "{}", mech.label());
        }
    }
}
