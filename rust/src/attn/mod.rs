//! Native rust implementations of every attention mechanism in the paper.
//!
//! These mirror the L1 kernels bit-for-bit in math (tests cross-check
//! against the Python oracles through shared fixtures) and serve three
//! roles: property tests of the algorithms' invariants, large-n latency
//! benches (Figures 1/4, Table 4 — the interpreted Pallas kernels cannot
//! reach 32k), and host-side verification of PJRT artifacts.
//!
//! Structure:
//!
//! * [`kernel`] — the trait core.  [`Mechanism`] (configuration +
//!   `parse`/`label`) dispatches **once**, in
//!   [`Mechanism::build_kernel`], onto one of two engines behind the
//!   object-safe [`CausalKernel`](kernel::CausalKernel) trait: a
//!   quadratic KV engine (softmax / flash / exact poly) and a linear
//!   engine routing every [`FeatureMap`](kernel::FeatureMap) through the
//!   one ragged block-lower-triangular path.  Prefill, decode, serving
//!   snapshots, and benches all flow through that object — no other
//!   module matches on mechanism variants (CI enforces it by grep).
//! * [`softmax`], [`poly`], [`block_lt`], [`sketch`], [`performer`] —
//!   the underlying math kernels and feature constructions, kept as
//!   small free functions so property tests and benches can probe them
//!   directly.

pub mod block_lt;
pub mod kernel;
pub mod performer;
pub mod poly;
pub mod sketch;
pub mod softmax;

pub use kernel::{CausalKernel, FeatureMap, KernelState, Mechanism};
