//! The linear engine: Section 3.1/3.2's block lower-triangular algorithm
//! as the *single* implementation behind every feature-map attention.
//!
//! Computes `lt(φ_q φ_kᵀ) [V | 1]` in time linear in n: per block
//! `H_l = φ_k_lᵀ [V_l|1]`, exclusive prefix `Z_l = Σ_{j<l} H_j`, diagonal
//! `P_l = lt(score(q, k)) [V_l|1]`, and row i of the result is
//! `P_l[i'] + φ(q_i) Z_l`.  The all-ones column riding with V produces
//! the normalizer, so numerator and the paper's `1 +` denominator come
//! out of one pass.  The tail block is processed *ragged* — callers
//! never zero-pad.
//!
//! One loop serves three historical kernels:
//! * explicit features (`DirectFeatures`) — the classic
//!   `linear_attention_block` interface, used by Performer;
//! * half sketches (`PolySketchMap` / `SelfTensorFeatures`) — diagonal
//!   scores are `(L Rᵀ)²` (Sec. 3.1's O(b² r) trick), prefix features
//!   the r²-dim self-tensor, expanded row by row;
//! * local-exact (Sec. 3.2) — a second, score-only map supplies exact
//!   `⟨q', k'⟩^p` weights inside diagonal blocks.
//!
//! The same decomposition *is* the decode recurrence: [`LinearState`]
//! holds Z plus the in-progress block's mapped rows, so `step` is the
//! b = 1-row specialization of the prefill loop and prefill leaves the
//! state bit-for-bit where stepping every token would have.

use std::sync::Arc;

use crate::attn::kernel::feature::FeatureMap;
use crate::attn::kernel::state::{KernelState, LinearState};
use crate::attn::kernel::CausalKernel;
use crate::obs::{self, Phase};
use crate::tensor::{axpy, dot, ln_row, micro, Tensor, TensorView, TensorViewMut};

/// Linear causal attention over an arbitrary [`FeatureMap`], with an
/// optional score-only local map for exact diagonal blocks.
pub struct LinearEngine {
    map: Arc<dyn FeatureMap>,
    local: Option<Arc<dyn FeatureMap>>,
    block: usize,
}

/// What the backward pass needs from the forward recompute: the prefix
/// state `Z_l` *entering* each block and the per-row denominators
/// `D_i = 1 + c_i`.  Filled by [`LinearEngine::forward_mapped`] when a
/// sink is passed — the forward loop itself is the recorder, so forward
/// and backward-recompute can never drift.
#[derive(Default)]
pub(crate) struct ForwardStats {
    pub(crate) denom: Vec<f32>,
    pub(crate) zsnaps: Vec<Vec<f32>>,
}

impl LinearEngine {
    pub fn new(
        map: Arc<dyn FeatureMap>,
        local: Option<Arc<dyn FeatureMap>>,
        block: usize,
    ) -> LinearEngine {
        LinearEngine { map, local, block: block.max(1) }
    }

    /// The unified blocked pass over *already-mapped* rows.  `mq`/`mk`
    /// are (n, c) mapped matrices; `lq`/`lk` (when a local map is
    /// configured) are the locally-mapped matrices scoring diagonal
    /// blocks.  Writes (n, h) into `out`; when `state` is given (must be
    /// fresh) it is left holding Z of every *full* block plus the ragged
    /// tail buffered — exactly what absorbing all n rows produces.  When
    /// `stats` is given, the pass additionally records what the backward
    /// needs (per-block Z snapshots, per-row denominators) — one loop
    /// serves forward and backward-recompute, so the two can never
    /// drift.
    pub(crate) fn forward_mapped(
        &self,
        mq: &Tensor,
        mk: &Tensor,
        lq: Option<&Tensor>,
        lk: Option<&Tensor>,
        v: &TensorView<'_>,
        state: Option<&mut LinearState>,
        mut stats: Option<&mut ForwardStats>,
        out: &mut TensorViewMut<'_>,
    ) {
        let n = mq.rows();
        let h = v.cols();
        assert_eq!(mk.rows(), n);
        assert_eq!(v.rows(), n);
        assert_eq!((out.rows(), out.cols()), (n, h));
        if n == 0 {
            return;
        }
        let f = self.map.feat_dim();
        let hc = h + 1;
        // The partition period is the *configured* block, never clamped:
        // it is the decode-state contract (a prompt shorter than one
        // block stays entirely buffered, exactly like pure stepping).
        let b = self.block;
        let bm = b.min(n); // widest block that actually occurs
        let nb = n.div_ceil(b);
        let local = self.local.as_ref().map(|m| {
            (m, lq.expect("local map needs mapped q"), lk.expect("local map needs mapped k"))
        });

        let mut z = vec![0.0f32; f * hc];
        let mut scores = vec![0.0f32; bm * bm];
        let mut pl = vec![0.0f32; bm * hc];
        let mut phi = vec![0.0f32; f];
        // Value row extended with the normalizer's 1: folding [v | 1]
        // into Z is one rank-1 update (kc·1.0 == kc bitwise).
        let mut vext = vec![0.0f32; hc];
        vext[h] = 1.0;

        for l in 0..nb {
            if let Some(s) = stats.as_deref_mut() {
                s.zsnaps.push(z.clone());
            }
            let base = l * b;
            let bl = b.min(n - base); // ragged tail: shorter final block
            // Diagonal block scores lt(score(q_i, k_j)).
            let t_phase = obs::phase::maybe_now();
            for bi in 0..bl {
                let srow = &mut scores[bi * bm..bi * bm + bl];
                match &local {
                    Some((lm, lq, lk)) => {
                        let qi = lq.row(base + bi);
                        for bj in 0..=bi {
                            srow[bj] = lm.score(qi, lk.row(base + bj));
                        }
                    }
                    None => {
                        let qi = mq.row(base + bi);
                        for bj in 0..=bi {
                            srow[bj] = self.map.score(qi, mk.row(base + bj));
                        }
                    }
                }
            }
            let t_phase = obs::phase::add_since(Phase::LinScores, t_phase);
            // Prefix contribution: pl[bi] = phi(q_i) . Z, the phi feature
            // expanded row-by-row into scratch.  Z is an (f, hc) packed
            // matrix, so the contraction is exactly the micro GEMM tile.
            for bi in 0..bl {
                self.map.expand(mq.row(base + bi), &mut phi);
                let prow = &mut pl[bi * hc..(bi + 1) * hc];
                prow.fill(0.0);
                micro::gemm_row(prow, &phi, &z);
            }
            let t_phase = obs::phase::add_since(Phase::LinPrefix, t_phase);
            // Diagonal contribution + emit normalized rows.
            for bi in 0..bl {
                let prow = &mut pl[bi * hc..(bi + 1) * hc];
                let srow = &scores[bi * bm..bi * bm + bl];
                for bj in 0..=bi {
                    let w = srow[bj];
                    axpy(&mut prow[..h], v.row(base + bj), w);
                    prow[h] += w;
                }
                let inv = 1.0 / (1.0 + prow[h]);
                if let Some(s) = stats.as_deref_mut() {
                    s.denom.push(1.0 + prow[h]);
                }
                micro::scale(out.row_mut(base + bi), &prow[..h], inv);
            }
            let t_phase = obs::phase::add_since(Phase::LinEmit, t_phase);
            // Z += phi(k_j)^T [V_l | 1] — full blocks only: a ragged tail
            // is never read by a later block, and the decode state keeps
            // tail rows buffered, not folded.
            if bl == b {
                for bj in 0..bl {
                    self.map.expand(mk.row(base + bj), &mut phi);
                    vext[..h].copy_from_slice(v.row(base + bj));
                    micro::outer_accum(&mut z, &phi, &vext);
                }
                // Z grows monotonically across blocks — the first place a
                // degree-p overflow becomes visible.  Write-only scan.
                obs::sentinel::scan(obs::sentinel::Site::ZFold, &z);
            }
            obs::phase::add_since(Phase::LinFold, t_phase);
        }

        if let Some(st) = state {
            assert_eq!(st.tokens, 0, "prefill requires a fresh state");
            st.ensure_init(h, f);
            st.z.copy_from_slice(&z);
            let full_end = (n / b) * b;
            for i in full_end..n {
                st.buf_mapped.push(mk.row(i).to_vec());
                if let Some((_, _, lk)) = &local {
                    st.buf_local.push(lk.row(i).to_vec());
                }
                st.buf_v.push(v.row(i).to_vec());
            }
            st.tokens = n;
        }
    }

    fn flush(&self, st: &mut LinearState) {
        let h = st.h;
        let hc = h + 1;
        let LinearState { z, buf_mapped, buf_local, buf_v, buf_raw, phi, .. } = st;
        let mut vext = vec![0.0f32; hc];
        vext[h] = 1.0;
        for (mrow, vrow) in buf_mapped.iter().zip(buf_v.iter()) {
            self.map.expand(mrow, phi);
            vext[..h].copy_from_slice(vrow);
            micro::outer_accum(z, phi, &vext);
        }
        buf_mapped.clear();
        buf_local.clear();
        buf_v.clear();
        buf_raw.clear();
    }

    fn maybe_flush(&self, st: &mut LinearState) {
        if st.buf_mapped.len() == self.block {
            self.flush(st);
        }
    }

    /// Map one raw row under both the global and (if any) local map,
    /// sharing a single row layernorm when both maps prenormalize — one
    /// LN per decode row, as the pre-trait-core code had.
    fn map_row_pair(&self, row: &[f32], st: &mut LinearState) -> (Vec<f32>, Option<Vec<f32>>) {
        match &self.local {
            Some(loc) if self.map.prenormalizes() && loc.prenormalizes() => {
                let normed = ln_row(row);
                let m = self.map.map_normed_row(&normed, &mut st.scratch);
                let l = loc.map_normed_row(&normed, &mut st.scratch);
                (m, Some(l))
            }
            Some(loc) => {
                let m = self.map.map_row(row, &mut st.scratch);
                let l = loc.map_row(row, &mut st.scratch);
                (m, Some(l))
            }
            None => (self.map.map_row(row, &mut st.scratch), None),
        }
    }

    /// Append a key to the in-progress block (no flush: the current
    /// position's output must still see this block as the diagonal).
    fn buffer_key(&self, k: &[f32], v: &[f32], st: &mut LinearState) {
        st.ensure_init(v.len(), self.map.feat_dim());
        let (mk, lk) = self.map_row_pair(k, st);
        st.buf_mapped.push(mk);
        if let Some(lk) = lk {
            st.buf_local.push(lk);
        }
        st.buf_v.push(v.to_vec());
        st.buf_raw.push(k.to_vec());
        st.tokens += 1;
    }

    fn linear_state<'a>(&self, state: &'a mut KernelState) -> &'a mut LinearState {
        match state {
            KernelState::Linear(st) => st,
            KernelState::Kv(_) => panic!("linear engine handed a KV state"),
        }
    }
}

impl CausalKernel for LinearEngine {
    fn new_state(&self) -> KernelState {
        KernelState::Linear(LinearState::new())
    }

    fn prefill_into(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        state: Option<&mut KernelState>,
        out: &mut TensorViewMut<'_>,
    ) {
        let _span = obs::span("lin_prefill", "kernel");
        let t_map = obs::phase::maybe_now();
        let mq = self.map.map(q);
        let mk = self.map.map(k);
        let (lq, lk) = match &self.local {
            Some(loc) => (Some(loc.map(q)), Some(loc.map(k))),
            None => (None, None),
        };
        obs::phase::add_since(Phase::LinMap, t_map);
        obs::sentinel::scan(obs::sentinel::Site::FeatureMap, mq.data());
        obs::sentinel::scan(obs::sentinel::Site::FeatureMap, mk.data());
        let mut st = state.map(|s| self.linear_state(s));
        self.forward_mapped(&mq, &mk, lq.as_ref(), lk.as_ref(), v, st.as_deref_mut(), None, out);
        if let Some(st) = st {
            // Raw tail keys ride along with the captured state (the
            // blocked pass only sees mapped rows); the compact cold
            // encoding re-absorbs them through the map on thaw.
            let n = k.rows();
            let full_end = (n / self.block) * self.block;
            for i in full_end..n {
                st.buf_raw.push(k.row(i).to_vec());
            }
        }
    }

    fn step(&self, q: &[f32], k: &[f32], v: &[f32], state: &mut KernelState) -> Vec<f32> {
        let _t = obs::phase::timer(Phase::LinStep);
        let st = self.linear_state(state);
        self.buffer_key(k, v, st);
        let (mq, lq) = self.map_row_pair(q, st);
        let hc = st.h + 1;
        // Prefix contribution phi(q) . Z — same feature-order
        // accumulation as the blocked prefill's prefix pass.
        self.map.expand(&mq, &mut st.phi);
        let mut acc = vec![0.0f32; hc];
        micro::gemm_row(&mut acc, &st.phi, &st.z);
        // Diagonal block: engine scores (or exact local scores) over the
        // buffered in-progress rows.
        for j in 0..st.buf_mapped.len() {
            let w = match (&self.local, &lq) {
                (Some(loc), Some(lq)) => loc.score(lq, &st.buf_local[j]),
                _ => self.map.score(&mq, &st.buf_mapped[j]),
            };
            axpy(&mut acc[..st.h], &st.buf_v[j], w);
            acc[st.h] += w;
        }
        let inv = 1.0 / (1.0 + acc[st.h]);
        acc.truncate(st.h);
        micro::scale_inplace(&mut acc, inv);
        self.maybe_flush(st);
        acc
    }

    fn absorb(&self, k: &[f32], v: &[f32], state: &mut KernelState) {
        let st = self.linear_state(state);
        self.buffer_key(k, v, st);
        self.maybe_flush(st);
    }

    /// The transpose of the block lower-triangular forward, still linear
    /// in n: iterate blocks in *reverse*, carrying `dZ_suffix = Σ_{l'>l}
    /// φ(Q_{l'})ᵀ dP_{l'}` — the suffix sum of feature outer-products.
    /// At block l the (full-block) keys consume the current suffix
    /// (`dφ(k) = [v|1]·dZ`, `dv += φ(k)·dZ`), then the block's queries
    /// add their own `φ(q) ⊗ dacc` for consumption by earlier blocks.
    /// Diagonal scores backprop through the score map (exact local map
    /// when configured), and everything funnels through the feature-map
    /// VJPs back to raw q/k rows.  O(n·(f·h + b·c)) per head.
    fn vjp(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        d_out: &TensorView<'_>,
        dq: &mut TensorViewMut<'_>,
        dk: &mut TensorViewMut<'_>,
        dv: &mut TensorViewMut<'_>,
    ) {
        let n = q.rows();
        if n == 0 {
            return;
        }
        let h = v.cols();
        let f = self.map.feat_dim();
        let hc = h + 1;
        let b = self.block;
        let nb = n.div_ceil(b);
        assert_eq!((d_out.rows(), d_out.cols()), (n, h));

        let mq = self.map.map(q);
        let mk = self.map.map(k);
        let (lq, lk) = match &self.local {
            Some(loc) => (Some(loc.map(q)), Some(loc.map(k))),
            None => (None, None),
        };
        let local = self
            .local
            .as_ref()
            .map(|m| (m, lq.as_ref().expect("local q"), lk.as_ref().expect("local k")));
        // Forward recompute through the one blocked loop, with the stats
        // sink capturing Z snapshots + denominators.
        let mut stats = ForwardStats::default();
        let mut out = Tensor::zeros(&[n, h]);
        self.forward_mapped(
            &mq,
            &mk,
            lq.as_ref(),
            lk.as_ref(),
            v,
            None,
            Some(&mut stats),
            &mut out.view_mut(),
        );
        let ForwardStats { denom, zsnaps } = stats;

        let mut dmq = Tensor::zeros(&[n, mq.cols()]);
        let mut dmk = Tensor::zeros(&[n, mk.cols()]);
        let (mut dlq, mut dlk) = match (&lq, &lk) {
            (Some(a), Some(c)) => (
                Some(Tensor::zeros(&[n, a.cols()])),
                Some(Tensor::zeros(&[n, c.cols()])),
            ),
            _ => (None, None),
        };

        let mut dz = vec![0.0f32; f * hc];
        let mut phi = vec![0.0f32; f];
        let mut dphi = vec![0.0f32; f];
        let mut dacc = vec![0.0f32; hc];
        let mut vext = vec![0.0f32; hc];
        vext[h] = 1.0;
        for l in (0..nb).rev() {
            let base = l * b;
            let bl = b.min(n - base);
            // Keys of a *full* block l feed the prefix of every later
            // block; the ragged tail's keys only ever score diagonally,
            // exactly as in the forward.
            if bl == b {
                for bj in 0..bl {
                    let j = base + bj;
                    self.map.expand(mk.row(j), &mut phi);
                    // dφ(k) = dZ·[v|1]: one fused dot-rows over the packed
                    // (f, hc) dZ with the extended value row.
                    vext[..h].copy_from_slice(v.row(j));
                    micro::dot_rows(&vext, &dz, &mut dphi);
                    {
                        let dvj = dv.row_mut(j);
                        for (c, &pc) in phi.iter().enumerate() {
                            if pc == 0.0 {
                                continue;
                            }
                            axpy(dvj, &dz[c * hc..c * hc + h], pc);
                        }
                    }
                    self.map.expand_vjp(mk.row(j), &dphi, dmk.row_mut(j));
                }
            }
            let zl = &zsnaps[l];
            for bi in 0..bl {
                let i = base + bi;
                let doi = d_out.row(i);
                let inv = 1.0 / denom[i];
                // out = acc[..h]/D, D = 1 + acc[h]:
                // dacc[..h] = dout/D, dacc[h] = −(dout·out)/D.
                for col in 0..h {
                    dacc[col] = doi[col] * inv;
                }
                dacc[h] = -dot(doi, out.row(i)) * inv;
                // Diagonal block.
                for bj in 0..=bi {
                    let j = base + bj;
                    let w = match &local {
                        Some((lm, lqm, lkm)) => lm.score(lqm.row(i), lkm.row(j)),
                        None => self.map.score(mq.row(i), mk.row(j)),
                    };
                    axpy(dv.row_mut(j), &dacc[..h], w);
                    let dw = dot(&dacc[..h], v.row(j)) + dacc[h];
                    match &local {
                        Some((lm, lqm, lkm)) => {
                            // dlq/dlk are distinct tensors, so the two
                            // row_mut borrows are disjoint even at i == j.
                            let (dlq, dlk) =
                                (dlq.as_mut().expect("dlq"), dlk.as_mut().expect("dlk"));
                            lm.score_vjp(
                                lqm.row(i),
                                lkm.row(j),
                                dw,
                                dlq.row_mut(i),
                                dlk.row_mut(j),
                            );
                        }
                        None => {
                            self.map.score_vjp(
                                mq.row(i),
                                mk.row(j),
                                dw,
                                dmq.row_mut(i),
                                dmk.row_mut(j),
                            );
                        }
                    }
                }
                // Prefix through Z_l (full hc width, like the forward):
                // dφ(q) = Z_l·dacc as one fused dot-rows, then the rank-1
                // suffix update dZ += φ(q) ⊗ dacc.
                self.map.expand(mq.row(i), &mut phi);
                micro::dot_rows(&dacc, zl, &mut dphi);
                self.map.expand_vjp(mq.row(i), &dphi, dmq.row_mut(i));
                micro::outer_accum(&mut dz, &phi, &dacc);
            }
        }

        // Pull mapped-row gradients back to the raw rows (both maps read
        // the same raw row, so contributions add).
        for i in 0..n {
            let mut draw_q = self.map.map_vjp(q.row(i), dmq.row(i));
            let mut draw_k = self.map.map_vjp(k.row(i), dmk.row(i));
            if let Some((lm, _, _)) = &local {
                let (dlq, dlk) = (dlq.as_ref().expect("dlq"), dlk.as_ref().expect("dlk"));
                for (a, g) in draw_q.iter_mut().zip(lm.map_vjp(q.row(i), dlq.row(i))) {
                    *a += g;
                }
                for (a, g) in draw_k.iter_mut().zip(lm.map_vjp(k.row(i), dlk.row(i))) {
                    *a += g;
                }
            }
            axpy(dq.row_mut(i), &draw_q, 1.0);
            axpy(dk.row_mut(i), &draw_k, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::kernel::feature::{IdentityPowerMap, SelfTensorFeatures};
    use crate::util::rng::Pcg;

    /// The stats sink must be a pure observer: attaching it cannot change
    /// output bytes, and what it records (per-row denominators, per-block
    /// Z snapshots) must be shaped for the ragged partition (n = 13 vs
    /// block 8), with and without a local map.
    #[test]
    fn forward_mapped_stats_sink_is_a_pure_observer() {
        let mut rng = Pcg::seeded(41);
        let (n, r, h, hl) = (13usize, 4usize, 5usize, 8usize);
        let mq = Tensor::gaussian(&mut rng, &[n, r]);
        let mk = Tensor::gaussian(&mut rng, &[n, r]);
        let lq = Tensor::gaussian(&mut rng, &[n, hl]);
        let lk = Tensor::gaussian(&mut rng, &[n, hl]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        for with_local in [false, true] {
            let local: Option<Arc<dyn FeatureMap>> =
                with_local.then(|| Arc::new(IdentityPowerMap::new(4)) as Arc<dyn FeatureMap>);
            let engine = LinearEngine::new(Arc::new(SelfTensorFeatures::new(r)), local, 8);
            let (lq_opt, lk_opt) = if with_local { (Some(&lq), Some(&lk)) } else { (None, None) };
            let mut plain = Tensor::zeros(&[n, h]);
            engine.forward_mapped(
                &mq, &mk, lq_opt, lk_opt, &v.view(), None, None, &mut plain.view_mut(),
            );
            let mut stats = ForwardStats::default();
            let mut observed = Tensor::zeros(&[n, h]);
            engine.forward_mapped(
                &mq,
                &mk,
                lq_opt,
                lk_opt,
                &v.view(),
                None,
                Some(&mut stats),
                &mut observed.view_mut(),
            );
            assert_eq!(plain, observed, "with_local={with_local}: stats sink changed bytes");
            assert_eq!(stats.denom.len(), n);
            assert_eq!(stats.zsnaps.len(), n.div_ceil(8));
            assert!(stats.zsnaps[0].iter().all(|&z| z == 0.0), "block 0 enters with Z = 0");
            // Non-negative kernel weights keep D = 1 + c near or above 1
            // (allow float slack in the accumulated normalizer).
            assert!(stats.denom.iter().all(|d| d.is_finite() && *d > 0.5));
        }
    }
}
