//! The linear engine: Section 3.1/3.2's block lower-triangular algorithm
//! as the *single* implementation behind every feature-map attention.
//!
//! Computes `lt(φ_q φ_kᵀ) [V | 1]` in time linear in n: per block
//! `H_l = φ_k_lᵀ [V_l|1]`, exclusive prefix `Z_l = Σ_{j<l} H_j`, diagonal
//! `P_l = lt(score(q, k)) [V_l|1]`, and row i of the result is
//! `P_l[i'] + φ(q_i) Z_l`.  The all-ones column riding with V produces
//! the normalizer, so numerator and the paper's `1 +` denominator come
//! out of one pass.  The tail block is processed *ragged* — callers
//! never zero-pad.
//!
//! One loop serves three historical kernels:
//! * explicit features (`DirectFeatures`) — the classic
//!   `linear_attention_block` interface, used by Performer;
//! * half sketches (`PolySketchMap` / `SelfTensorFeatures`) — diagonal
//!   scores are `(L Rᵀ)²` (Sec. 3.1's O(b² r) trick), prefix features
//!   the r²-dim self-tensor, expanded row by row;
//! * local-exact (Sec. 3.2) — a second, score-only map supplies exact
//!   `⟨q', k'⟩^p` weights inside diagonal blocks.
//!
//! The same decomposition *is* the decode recurrence: [`LinearState`]
//! holds Z plus the in-progress block's mapped rows, so `step` is the
//! b = 1-row specialization of the prefill loop and prefill leaves the
//! state bit-for-bit where stepping every token would have.

use std::sync::Arc;

use crate::attn::kernel::feature::FeatureMap;
use crate::attn::kernel::state::{KernelState, LinearState};
use crate::attn::kernel::CausalKernel;
use crate::tensor::{axpy, ln_row, Tensor, TensorView, TensorViewMut};

/// Linear causal attention over an arbitrary [`FeatureMap`], with an
/// optional score-only local map for exact diagonal blocks.
pub struct LinearEngine {
    map: Arc<dyn FeatureMap>,
    local: Option<Arc<dyn FeatureMap>>,
    block: usize,
}

impl LinearEngine {
    pub fn new(
        map: Arc<dyn FeatureMap>,
        local: Option<Arc<dyn FeatureMap>>,
        block: usize,
    ) -> LinearEngine {
        LinearEngine { map, local, block: block.max(1) }
    }

    /// The unified blocked pass over *already-mapped* rows.  `mq`/`mk`
    /// are (n, c) mapped matrices; `lq`/`lk` (when a local map is
    /// configured) are the locally-mapped matrices scoring diagonal
    /// blocks.  Writes (n, h) into `out`; when `state` is given (must be
    /// fresh) it is left holding Z of every *full* block plus the ragged
    /// tail buffered — exactly what absorbing all n rows produces.
    pub(crate) fn forward_mapped(
        &self,
        mq: &Tensor,
        mk: &Tensor,
        lq: Option<&Tensor>,
        lk: Option<&Tensor>,
        v: &TensorView<'_>,
        state: Option<&mut LinearState>,
        out: &mut TensorViewMut<'_>,
    ) {
        let n = mq.rows();
        let h = v.cols();
        assert_eq!(mk.rows(), n);
        assert_eq!(v.rows(), n);
        assert_eq!((out.rows(), out.cols()), (n, h));
        if n == 0 {
            return;
        }
        let f = self.map.feat_dim();
        let hc = h + 1;
        // The partition period is the *configured* block, never clamped:
        // it is the decode-state contract (a prompt shorter than one
        // block stays entirely buffered, exactly like pure stepping).
        let b = self.block;
        let bm = b.min(n); // widest block that actually occurs
        let nb = n.div_ceil(b);
        let local = self.local.as_ref().map(|m| {
            (m, lq.expect("local map needs mapped q"), lk.expect("local map needs mapped k"))
        });

        let mut z = vec![0.0f32; f * hc];
        let mut scores = vec![0.0f32; bm * bm];
        let mut pl = vec![0.0f32; bm * hc];
        let mut phi = vec![0.0f32; f];

        for l in 0..nb {
            let base = l * b;
            let bl = b.min(n - base); // ragged tail: shorter final block
            // Diagonal block scores lt(score(q_i, k_j)).
            for bi in 0..bl {
                let srow = &mut scores[bi * bm..bi * bm + bl];
                match &local {
                    Some((lm, lq, lk)) => {
                        let qi = lq.row(base + bi);
                        for bj in 0..=bi {
                            srow[bj] = lm.score(qi, lk.row(base + bj));
                        }
                    }
                    None => {
                        let qi = mq.row(base + bi);
                        for bj in 0..=bi {
                            srow[bj] = self.map.score(qi, mk.row(base + bj));
                        }
                    }
                }
            }
            // Prefix contribution: pl[bi] = phi(q_i) . Z, the phi feature
            // expanded row-by-row into scratch.
            for bi in 0..bl {
                self.map.expand(mq.row(base + bi), &mut phi);
                let prow = &mut pl[bi * hc..(bi + 1) * hc];
                prow.fill(0.0);
                for (c, &qv) in phi.iter().enumerate() {
                    if qv == 0.0 {
                        continue;
                    }
                    axpy(prow, &z[c * hc..(c + 1) * hc], qv);
                }
            }
            // Diagonal contribution + emit normalized rows.
            for bi in 0..bl {
                let prow = &mut pl[bi * hc..(bi + 1) * hc];
                let srow = &scores[bi * bm..bi * bm + bl];
                for bj in 0..=bi {
                    let w = srow[bj];
                    axpy(&mut prow[..h], v.row(base + bj), w);
                    prow[h] += w;
                }
                let inv = 1.0 / (1.0 + prow[h]);
                let orow = out.row_mut(base + bi);
                for c in 0..h {
                    orow[c] = prow[c] * inv;
                }
            }
            // Z += phi(k_j)^T [V_l | 1] — full blocks only: a ragged tail
            // is never read by a later block, and the decode state keeps
            // tail rows buffered, not folded.
            if bl == b {
                for bj in 0..bl {
                    self.map.expand(mk.row(base + bj), &mut phi);
                    let vrow = v.row(base + bj);
                    for (c, &kc) in phi.iter().enumerate() {
                        if kc == 0.0 {
                            continue;
                        }
                        let zrow = &mut z[c * hc..(c + 1) * hc];
                        axpy(&mut zrow[..h], vrow, kc);
                        zrow[h] += kc;
                    }
                }
            }
        }

        if let Some(st) = state {
            assert_eq!(st.tokens, 0, "prefill requires a fresh state");
            st.ensure_init(h, f);
            st.z.copy_from_slice(&z);
            let full_end = (n / b) * b;
            for i in full_end..n {
                st.buf_mapped.push(mk.row(i).to_vec());
                if let Some((_, _, lk)) = &local {
                    st.buf_local.push(lk.row(i).to_vec());
                }
                st.buf_v.push(v.row(i).to_vec());
            }
            st.tokens = n;
        }
    }

    fn flush(&self, st: &mut LinearState) {
        let h = st.h;
        let hc = h + 1;
        let LinearState { z, buf_mapped, buf_local, buf_v, phi, .. } = st;
        for (mrow, vrow) in buf_mapped.iter().zip(buf_v.iter()) {
            self.map.expand(mrow, phi);
            for (c, &kc) in phi.iter().enumerate() {
                if kc == 0.0 {
                    continue;
                }
                let zrow = &mut z[c * hc..(c + 1) * hc];
                axpy(&mut zrow[..h], vrow, kc);
                zrow[h] += kc;
            }
        }
        buf_mapped.clear();
        buf_local.clear();
        buf_v.clear();
    }

    fn maybe_flush(&self, st: &mut LinearState) {
        if st.buf_mapped.len() == self.block {
            self.flush(st);
        }
    }

    /// Map one raw row under both the global and (if any) local map,
    /// sharing a single row layernorm when both maps prenormalize — one
    /// LN per decode row, as the pre-trait-core code had.
    fn map_row_pair(&self, row: &[f32], st: &mut LinearState) -> (Vec<f32>, Option<Vec<f32>>) {
        match &self.local {
            Some(loc) if self.map.prenormalizes() && loc.prenormalizes() => {
                let normed = ln_row(row);
                let m = self.map.map_normed_row(&normed, &mut st.scratch);
                let l = loc.map_normed_row(&normed, &mut st.scratch);
                (m, Some(l))
            }
            Some(loc) => {
                let m = self.map.map_row(row, &mut st.scratch);
                let l = loc.map_row(row, &mut st.scratch);
                (m, Some(l))
            }
            None => (self.map.map_row(row, &mut st.scratch), None),
        }
    }

    /// Append a key to the in-progress block (no flush: the current
    /// position's output must still see this block as the diagonal).
    fn buffer_key(&self, k: &[f32], v: &[f32], st: &mut LinearState) {
        st.ensure_init(v.len(), self.map.feat_dim());
        let (mk, lk) = self.map_row_pair(k, st);
        st.buf_mapped.push(mk);
        if let Some(lk) = lk {
            st.buf_local.push(lk);
        }
        st.buf_v.push(v.to_vec());
        st.tokens += 1;
    }

    fn linear_state<'a>(&self, state: &'a mut KernelState) -> &'a mut LinearState {
        match state {
            KernelState::Linear(st) => st,
            KernelState::Kv(_) => panic!("linear engine handed a KV state"),
        }
    }
}

impl CausalKernel for LinearEngine {
    fn new_state(&self) -> KernelState {
        KernelState::Linear(LinearState::new())
    }

    fn prefill_into(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        state: Option<&mut KernelState>,
        out: &mut TensorViewMut<'_>,
    ) {
        let mq = self.map.map(q);
        let mk = self.map.map(k);
        let (lq, lk) = match &self.local {
            Some(loc) => (Some(loc.map(q)), Some(loc.map(k))),
            None => (None, None),
        };
        let st = state.map(|s| self.linear_state(s));
        self.forward_mapped(&mq, &mk, lq.as_ref(), lk.as_ref(), v, st, out);
    }

    fn step(&self, q: &[f32], k: &[f32], v: &[f32], state: &mut KernelState) -> Vec<f32> {
        let st = self.linear_state(state);
        self.buffer_key(k, v, st);
        let (mq, lq) = self.map_row_pair(q, st);
        let hc = st.h + 1;
        // Prefix contribution phi(q) . Z — same feature-order
        // accumulation as the blocked prefill's prefix pass.
        self.map.expand(&mq, &mut st.phi);
        let mut acc = vec![0.0f32; hc];
        for (c, &qv) in st.phi.iter().enumerate() {
            if qv == 0.0 {
                continue;
            }
            axpy(&mut acc, &st.z[c * hc..(c + 1) * hc], qv);
        }
        // Diagonal block: engine scores (or exact local scores) over the
        // buffered in-progress rows.
        for j in 0..st.buf_mapped.len() {
            let w = match (&self.local, &lq) {
                (Some(loc), Some(lq)) => loc.score(lq, &st.buf_local[j]),
                _ => self.map.score(&mq, &st.buf_mapped[j]),
            };
            axpy(&mut acc[..st.h], &st.buf_v[j], w);
            acc[st.h] += w;
        }
        let inv = 1.0 / (1.0 + acc[st.h]);
        acc.truncate(st.h);
        for o in acc.iter_mut() {
            *o *= inv;
        }
        self.maybe_flush(st);
        acc
    }

    fn absorb(&self, k: &[f32], v: &[f32], state: &mut KernelState) {
        let st = self.linear_state(state);
        self.buffer_key(k, v, st);
        self.maybe_flush(st);
    }
}
