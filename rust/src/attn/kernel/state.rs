//! Decode state of one (layer, head): the recurrent view of causal
//! attention, one variant per *engine* (not per mechanism — that is the
//! whole point of the kernel core).
//!
//! * [`KvState`] — growing key/value cache for the quadratic engine
//!   (softmax family rescans it per token: O(n));
//! * [`LinearState`] — recurrent prefix moments `Z ∈ R^{f×(h+1)}` plus
//!   the in-progress diagonal block's mapped rows, reproducing the
//!   block-lower-triangular prefill partition exactly: O(1) per token,
//!   constant memory.
//!
//! `Clone` is load-bearing: the serving gateway's prompt-prefix cache
//! (`serve::cache`) stores cloned states, so a clone must be a deep,
//! independent copy — O(f·h) for the recurrent variant, O(n·h) for the
//! KV cache.

use crate::attn::kernel::feature::MapScratch;
use crate::mem::arena::{PagedBuf, StateArena};
use crate::tensor::{axpy, dot, micro};

/// Attention state of one (layer, head) during autoregressive decoding.
/// Engines construct and interpret it; everyone else treats it as an
/// opaque, cloneable blob with size/occupancy accessors.
#[derive(Clone)]
pub enum KernelState {
    /// Quadratic engine: exact attention over a growing KV cache.
    Kv(KvState),
    /// Linear engine: recurrent prefix + in-progress block buffer.
    Linear(LinearState),
}

impl KernelState {
    /// Number of tokens folded in so far.
    pub fn tokens_seen(&self) -> usize {
        match self {
            KernelState::Kv(st) => st.len,
            KernelState::Linear(st) => st.tokens,
        }
    }

    /// O(1)-per-token state (true for the linear engine)?
    pub fn is_recurrent(&self) -> bool {
        matches!(self, KernelState::Linear(_))
    }

    /// Current state footprint in f32 words — constant in context length
    /// for recurrent states, linear for KV caches.
    pub fn memory_floats(&self) -> usize {
        match self {
            KernelState::Kv(st) => st.k.len() + st.v.len(),
            KernelState::Linear(st) => {
                st.z.len()
                    + st.buf_mapped.iter().map(Vec::len).sum::<usize>()
                    + st.buf_local.iter().map(Vec::len).sum::<usize>()
                    + st.buf_v.iter().map(Vec::len).sum::<usize>()
                    + st.buf_raw.iter().map(Vec::len).sum::<usize>()
            }
        }
    }
}

// ------------------------------------------------------------- KV cache

/// Growing key/value cache (flat row-major storage).  Keys are stored in
/// whatever form the engine scores them in (raw for softmax, layernormed
/// for exact poly).
#[derive(Clone, Default)]
pub struct KvState {
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) kd: usize,
    pub(crate) vd: usize,
    pub(crate) len: usize,
}

impl KvState {
    pub(crate) fn new() -> KvState {
        KvState::default()
    }

    pub(crate) fn push(&mut self, k: &[f32], v: &[f32]) {
        if self.len == 0 {
            self.kd = k.len();
            self.vd = v.len();
        }
        debug_assert_eq!(k.len(), self.kd);
        debug_assert_eq!(v.len(), self.vd);
        self.k.extend_from_slice(k);
        self.v.extend_from_slice(v);
        self.len += 1;
    }

    pub(crate) fn krow(&self, j: usize) -> &[f32] {
        &self.k[j * self.kd..(j + 1) * self.kd]
    }

    pub(crate) fn vrow(&self, j: usize) -> &[f32] {
        &self.v[j * self.vd..(j + 1) * self.vd]
    }

    /// Stable softmax attention of one query over the cache — the same
    /// operation order as `softmax::softmax_attention`'s row loop.
    pub(crate) fn softmax_row(&self, q: &[f32]) -> Vec<f32> {
        let scale = 1.0 / (q.len() as f32).sqrt();
        let mut scores = vec![0.0f32; self.len];
        let mut mx = f32::NEG_INFINITY;
        for j in 0..self.len {
            scores[j] = dot(q, self.krow(j)) * scale;
            mx = mx.max(scores[j]);
        }
        for s in scores.iter_mut() {
            *s = (*s - mx).exp();
        }
        let sum = micro::sum(&scores);
        let mut out = vec![0.0f32; self.vd];
        for j in 0..self.len {
            axpy(&mut out, self.vrow(j), scores[j] / sum);
        }
        out
    }

    /// Degree-p polynomial attention of one (LN'd) query over the cache
    /// of LN'd keys, with the paper's `1 +` denominator — mirrors
    /// `poly::poly_attention_prenormed`'s row loop.
    pub(crate) fn poly_row(&self, qn: &[f32], p: u32) -> Vec<f32> {
        use crate::attn::poly::powi;
        let mut out = vec![0.0f32; self.vd];
        let mut denom = 1.0f32;
        for j in 0..self.len {
            let w = powi(dot(qn, self.krow(j)), p);
            denom += w;
            axpy(&mut out, self.vrow(j), w);
        }
        micro::scale_inplace(&mut out, 1.0 / denom);
        out
    }
}

// ------------------------------------------------------ linear (blocked)

/// Linear-engine decode state: prefix moments + current diagonal block.
///
/// Mirrors the blocked prefill decomposition exactly: keys in completed
/// blocks live only as `Z += φ(k_j)ᵀ [v_j | 1]` (constant memory); keys
/// of the in-progress block are buffered in *mapped* form so the
/// diagonal uses the engine's score function — or, with a local map, the
/// exact Section 3.2 scores over the locally-mapped buffer.  Work per
/// token is O(f·h + b·c): independent of context length.
#[derive(Clone, Default)]
pub struct LinearState {
    /// Value dim (+1 normalizer column); set on first token.
    pub(crate) h: usize,
    /// Prefix state Z: f x (h+1), row-major by feature index.  Leased
    /// from the global [`StateArena`] — the dominant per-session
    /// footprint must come from page-able, free-listed slots.
    pub(crate) z: PagedBuf,
    /// In-progress block: mapped key rows.
    pub(crate) buf_mapped: Vec<Vec<f32>>,
    /// In-progress block: locally-mapped key rows (only with a local map).
    pub(crate) buf_local: Vec<Vec<f32>>,
    /// In-progress block: value rows (h,).
    pub(crate) buf_v: Vec<Vec<f32>>,
    /// In-progress block: *raw* key rows.  Never read by decode math
    /// (mapped rows serve the diagonal) — kept so the compact f16 cold
    /// encoding can re-absorb the tail through the feature map on thaw.
    pub(crate) buf_raw: Vec<Vec<f32>>,
    /// Scratch for one φ feature row (f,) — reused every token so the
    /// per-token hot path does not hit the allocator for it.  Arena-
    /// leased alongside Z.
    pub(crate) phi: PagedBuf,
    /// Feature-map scratch (e.g. the half-sketch row recursion), same
    /// rationale: the token × layer × head hot path must not rebuild
    /// per-level temporaries on every call.
    pub(crate) scratch: MapScratch,
    pub(crate) tokens: usize,
}

impl LinearState {
    pub(crate) fn new() -> LinearState {
        LinearState::default()
    }

    /// Lease Z/φ on first contact with a value row of width `h`.
    pub(crate) fn ensure_init(&mut self, h: usize, feat_dim: usize) {
        if self.h == 0 {
            self.h = h;
            self.z = StateArena::global().alloc_zeroed(feat_dim * (h + 1));
            self.phi = StateArena::global().alloc_zeroed(feat_dim);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::kernel::Mechanism;
    use crate::attn::poly::powi;
    use crate::attn::sketch::PolySketch;
    use crate::attn::performer::PerformerFeatures;
    use crate::tensor::{axpy, layernorm_rows, Tensor};
    use crate::util::rng::Pcg;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    fn mechs() -> Vec<Mechanism> {
        vec![
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 16, block: 8 },
        ]
    }

    /// Per-row causal oracle with NO blocking or padding anywhere:
    /// softmax math for the softmax family, exact poly weights for poly,
    /// hybrid local/sketched weights (respecting the block partition) for
    /// polysketch, feature dots (respecting the block partition's
    /// diagonal) for performer.  Reconstructs the mechanism's random
    /// state from the same seeded RNG `build_kernel` consumed.
    fn naive_oracle(mech: &Mechanism, seed: u64, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        use crate::attn::poly::poly_attention;
        use crate::attn::softmax::softmax_attention;
        use crate::tensor::dot;
        let h = q.cols();
        let mut rng = Pcg::seeded(seed);
        let linear = |wf: &dyn Fn(usize, usize) -> f32| -> Tensor {
            let (n, hv) = (q.rows(), v.cols());
            let mut out = Tensor::zeros(&[n, hv]);
            for i in 0..n {
                let mut denom = 1.0f32;
                let mut acc = vec![0.0f32; hv];
                for j in 0..=i {
                    let w = wf(i, j);
                    denom += w;
                    axpy(&mut acc, v.row(j), w);
                }
                for (o, a) in out.row_mut(i).iter_mut().zip(&acc) {
                    *o = a / denom;
                }
            }
            out
        };
        match mech {
            Mechanism::Softmax | Mechanism::Flash { .. } => softmax_attention(q, k, v),
            Mechanism::Poly { p } => poly_attention(q, k, v, *p),
            Mechanism::Polysketch { r, p, block, local } => {
                let sk = PolySketch::sample(&mut rng, h, *r, *p as usize);
                let qn = layernorm_rows(q);
                let kn = layernorm_rows(k);
                let lq = sk.half(&qn);
                let lk = sk.half(&kn);
                linear(&|i, j| {
                    if *local && i / block == j / block {
                        powi(dot(qn.row(i), kn.row(j)), *p)
                    } else {
                        let s = dot(lq.row(i), lk.row(j));
                        s * s
                    }
                })
            }
            Mechanism::Performer { m, block } => {
                let feats = PerformerFeatures::sample(&mut rng, h, *m);
                let pq = feats.apply(q);
                let pk = feats.apply(k);
                // The blocked kernel scores the in-progress diagonal block
                // directly and the prefix through Z — mathematically the
                // same plain feature dot everywhere.
                let _ = block;
                linear(&|i, j| dot(pq.row(i), pk.row(j)))
            }
        }
    }

    #[test]
    fn ragged_prefill_matches_unpadded_oracle() {
        // n = 13 against block 8: the kernels process the ragged tail
        // natively — every row must match an oracle computed with no
        // blocking at all, for every mechanism.
        let mut rng = Pcg::seeded(11);
        let (n, h) = (13usize, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        for mech in mechs() {
            let kernel = mech.build_kernel(h, &mut Pcg::seeded(17));
            let got = kernel.forward(&q, &k, &v);
            let want = naive_oracle(&mech, 17, &q, &k, &v);
            for i in 0..n {
                for (g, w) in got.row(i).iter().zip(want.row(i)) {
                    assert!(close(*g, *w, 2e-3), "{} row {i}: {g} vs {w}", mech.label());
                }
            }
        }
    }

    #[test]
    fn step_matches_full_context_attention() {
        // The parity anchor at the attention level: token-by-token decode
        // must reproduce the full-context kernel row by row, including at
        // lengths that straddle block boundaries.
        let mut rng = Pcg::seeded(0);
        let h = 8;
        for n in [5usize, 8, 13, 24] {
            let q = Tensor::gaussian(&mut rng, &[n, h]);
            let k = Tensor::gaussian(&mut rng, &[n, h]);
            let v = Tensor::gaussian(&mut rng, &[n, h]);
            for mech in mechs() {
                let kernel = mech.build_kernel(h, &mut Pcg::seeded(11));
                let want = kernel.forward(&q, &k, &v);
                let mut st = kernel.new_state();
                for i in 0..n {
                    let got = kernel.step(q.row(i), k.row(i), v.row(i), &mut st);
                    for (g, w) in got.iter().zip(want.row(i)) {
                        assert!(
                            close(*g, *w, 2e-3),
                            "{} n={n} row {i}: {g} vs {w}",
                            mech.label()
                        );
                    }
                }
                assert_eq!(st.tokens_seen(), n);
            }
        }
    }

    #[test]
    fn absorb_then_step_matches_pure_stepping() {
        // Absorbing a prefix must leave the state exactly where stepping
        // token-by-token would have — byte-for-byte.
        let mut rng = Pcg::seeded(1);
        let (n, h, split) = (19usize, 8, 11usize);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        for mech in mechs() {
            let kernel = mech.build_kernel(h, &mut Pcg::seeded(3));
            let mut stepped = kernel.new_state();
            let mut absorbed = kernel.new_state();
            for i in 0..split {
                kernel.step(q.row(i), k.row(i), v.row(i), &mut stepped);
                kernel.absorb(k.row(i), v.row(i), &mut absorbed);
            }
            for i in split..n {
                let a = kernel.step(q.row(i), k.row(i), v.row(i), &mut stepped);
                let b = kernel.step(q.row(i), k.row(i), v.row(i), &mut absorbed);
                assert_eq!(a, b, "{} row {i}", mech.label());
            }
        }
    }

    #[test]
    fn prefill_state_bitwise_matches_absorb_loop() {
        // The engines capture the decode state *inside* the blocked
        // prefill pass (no per-row absorb sweep); the captured state must
        // continue byte-identically to one built by absorbing row by row.
        let mut rng = Pcg::seeded(8);
        let h = 8;
        for n in [5usize, 8, 13, 16, 24] {
            let q = Tensor::gaussian(&mut rng, &[n, h]);
            let k = Tensor::gaussian(&mut rng, &[n, h]);
            let v = Tensor::gaussian(&mut rng, &[n, h]);
            for mech in mechs() {
                let kernel = mech.build_kernel(h, &mut Pcg::seeded(29));
                let mut captured = kernel.new_state();
                kernel.prefill(&q.view(), &k.view(), &v.view(), Some(&mut captured));
                let mut absorbed = kernel.new_state();
                for i in 0..n {
                    kernel.absorb(k.row(i), v.row(i), &mut absorbed);
                }
                assert_eq!(captured.tokens_seen(), absorbed.tokens_seen());
                assert_eq!(captured.memory_floats(), absorbed.memory_floats());
                let (nq, nk, nv) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
                let a = kernel.step(&nq, &nk, &nv, &mut captured);
                let b = kernel.step(&nq, &nk, &nv, &mut absorbed);
                assert_eq!(a, b, "{} n={n}", mech.label());
            }
        }
    }

    #[test]
    fn recurrent_states_have_constant_memory() {
        let mut rng = Pcg::seeded(2);
        let h = 8;
        for mech in mechs() {
            let kernel = mech.build_kernel(h, &mut rng);
            let mut st = kernel.new_state();
            let probe = |st: &mut KernelState, rng: &mut Pcg, n: usize| {
                for _ in 0..n {
                    let q: Vec<f32> = rng.gaussians(h);
                    let k: Vec<f32> = rng.gaussians(h);
                    let v: Vec<f32> = rng.gaussians(h);
                    kernel.step(&q, &k, &v, st);
                }
                st.memory_floats()
            };
            let m64 = probe(&mut st, &mut rng, 64);
            let m256 = probe(&mut st, &mut rng, 192);
            if st.is_recurrent() {
                // Buffer occupancy wobbles within a block; totals must not
                // grow with tokens. 64 and 256 are both block multiples.
                assert_eq!(m64, m256, "{}", mech.label());
            } else {
                assert!(m256 > m64, "{}", mech.label());
            }
        }
    }

    #[test]
    fn cloned_state_is_deep_and_continues_identically() {
        // The cache primitive: a cloned state must be an independent deep
        // copy — identical continuation under identical inputs, and no
        // aliasing (stepping one must not perturb the other).
        let mut rng = Pcg::seeded(7);
        let h = 8;
        for mech in mechs() {
            let kernel = mech.build_kernel(h, &mut Pcg::seeded(5));
            let mut orig = kernel.new_state();
            for _ in 0..13 {
                let (q, k, v) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
                kernel.step(&q, &k, &v, &mut orig);
            }
            let mut copy = orig.clone();
            assert_eq!(copy.tokens_seen(), orig.tokens_seen());
            // Divergent input on the copy leaves the original untouched...
            let (dq, dk, dv) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
            kernel.step(&dq, &dk, &dv, &mut copy);
            // ...so a fresh clone of the original still replays the copy's
            // step bit-for-bit.
            let mut copy2 = orig.clone();
            let a = kernel.step(&dq, &dk, &dv, &mut copy2);
            let mut copy3 = orig.clone();
            let b = kernel.step(&dq, &dk, &dv, &mut copy3);
            assert_eq!(a, b, "{}", mech.label());
            assert_eq!(orig.tokens_seen(), 13, "{}", mech.label());
        }
    }
}
