//! The quadratic engine: exact causal attention over a KV cache —
//! softmax (naive or flash-blocked prefill; identical math, different
//! schedule) and exact degree-p polynomial attention.
//!
//! Prefill delegates to the row/block-streaming kernels in
//! `attn::{softmax, poly}` (which parallelize their own query rows on
//! the deterministic pool) and captures the cache; `step` reproduces the
//! same row arithmetic over the cache, so prefill-then-step equals pure
//! stepping exactly.

use crate::attn::kernel::state::{KernelState, KvState};
use crate::attn::kernel::CausalKernel;
use crate::attn::poly;
use crate::attn::softmax;
use crate::tensor::{layernorm_rows, ln_row, TensorView, TensorViewMut};

enum QuadKind {
    Softmax,
    Flash { block: usize },
    Poly { p: u32 },
}

/// Exact attention over a growing KV cache (the softmax family and the
/// exact polynomial baseline).
pub struct QuadraticEngine {
    kind: QuadKind,
}

impl QuadraticEngine {
    pub fn softmax() -> QuadraticEngine {
        QuadraticEngine { kind: QuadKind::Softmax }
    }

    pub fn flash(block: usize) -> QuadraticEngine {
        QuadraticEngine { kind: QuadKind::Flash { block: block.max(1) } }
    }

    pub fn poly(p: u32) -> QuadraticEngine {
        QuadraticEngine { kind: QuadKind::Poly { p } }
    }

    fn kv_state<'a>(&self, state: &'a mut KernelState) -> &'a mut KvState {
        match state {
            KernelState::Kv(st) => st,
            KernelState::Linear(_) => panic!("quadratic engine handed a linear state"),
        }
    }
}

impl CausalKernel for QuadraticEngine {
    fn new_state(&self) -> KernelState {
        KernelState::Kv(KvState::new())
    }

    fn prefill_into(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        state: Option<&mut KernelState>,
        out: &mut TensorViewMut<'_>,
    ) {
        let n = q.rows();
        // Keys are cached in score form: layernormed for exact poly, raw
        // for the softmax family.
        let mut normed_k: Option<crate::tensor::Tensor> = None;
        match &self.kind {
            QuadKind::Softmax => out.copy_from(&softmax::softmax_attention(q, k, v)),
            QuadKind::Flash { block } => {
                out.copy_from(&softmax::flash_attention(q, k, v, (*block).min(n.max(1))));
            }
            QuadKind::Poly { p } => {
                let qn = layernorm_rows(q);
                let kn = layernorm_rows(k);
                out.copy_from(&poly::poly_attention_prenormed(&qn, &kn, v, *p));
                normed_k = Some(kn);
            }
        }
        if let Some(st) = state {
            let st = self.kv_state(st);
            assert_eq!(st.len, 0, "prefill requires a fresh state");
            for i in 0..n {
                match &normed_k {
                    Some(kn) => st.push(kn.row(i), v.row(i)),
                    None => st.push(k.row(i), v.row(i)),
                }
            }
        }
    }

    fn step(&self, q: &[f32], k: &[f32], v: &[f32], state: &mut KernelState) -> Vec<f32> {
        let st = self.kv_state(state);
        match &self.kind {
            // Blocked streaming is a prefill-side layout; the decode math
            // of softmax and flash is identical.
            QuadKind::Softmax | QuadKind::Flash { .. } => {
                st.push(k, v);
                st.softmax_row(q)
            }
            QuadKind::Poly { p } => {
                st.push(&ln_row(k), v);
                st.poly_row(&ln_row(q), *p)
            }
        }
    }

    fn absorb(&self, k: &[f32], v: &[f32], state: &mut KernelState) {
        let st = self.kv_state(state);
        match &self.kind {
            QuadKind::Softmax | QuadKind::Flash { .. } => st.push(k, v),
            QuadKind::Poly { .. } => st.push(&ln_row(k), v),
        }
    }
}
