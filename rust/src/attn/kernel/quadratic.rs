//! The quadratic engine: exact causal attention over a KV cache —
//! softmax (naive or flash-blocked prefill; identical math, different
//! schedule) and exact degree-p polynomial attention.
//!
//! Prefill delegates to the row/block-streaming kernels in
//! `attn::{softmax, poly}` (which parallelize their own query rows on
//! the deterministic pool) and captures the cache; `step` reproduces the
//! same row arithmetic over the cache, so prefill-then-step equals pure
//! stepping exactly.

use crate::attn::kernel::state::{KernelState, KvState};
use crate::attn::kernel::CausalKernel;
use crate::attn::poly::{self, powi};
use crate::attn::softmax;
use crate::obs::{self, Phase};
use crate::tensor::{
    axpy, dot, layernorm_rows, ln_row, ln_row_vjp, micro, Tensor, TensorView, TensorViewMut,
};

enum QuadKind {
    Softmax,
    Flash { block: usize },
    Poly { p: u32 },
}

/// Exact attention over a growing KV cache (the softmax family and the
/// exact polynomial baseline).
pub struct QuadraticEngine {
    kind: QuadKind,
}

impl QuadraticEngine {
    pub fn softmax() -> QuadraticEngine {
        QuadraticEngine { kind: QuadKind::Softmax }
    }

    pub fn flash(block: usize) -> QuadraticEngine {
        QuadraticEngine { kind: QuadKind::Flash { block: block.max(1) } }
    }

    pub fn poly(p: u32) -> QuadraticEngine {
        QuadraticEngine { kind: QuadKind::Poly { p } }
    }

    fn kv_state<'a>(&self, state: &'a mut KernelState) -> &'a mut KvState {
        match state {
            KernelState::Kv(st) => st,
            KernelState::Linear(_) => panic!("quadratic engine handed a linear state"),
        }
    }
}

impl CausalKernel for QuadraticEngine {
    fn new_state(&self) -> KernelState {
        KernelState::Kv(KvState::new())
    }

    fn prefill_into(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        state: Option<&mut KernelState>,
        out: &mut TensorViewMut<'_>,
    ) {
        let _span = obs::span("quad_prefill", "kernel");
        let n = q.rows();
        // Keys are cached in score form: layernormed for exact poly, raw
        // for the softmax family.
        let mut normed_k: Option<crate::tensor::Tensor> = None;
        let t_attn = obs::phase::maybe_now();
        match &self.kind {
            QuadKind::Softmax => out.copy_from(&softmax::softmax_attention(q, k, v)),
            QuadKind::Flash { block } => {
                out.copy_from(&softmax::flash_attention(q, k, v, (*block).min(n.max(1))));
            }
            QuadKind::Poly { p } => {
                let qn = layernorm_rows(q);
                let kn = layernorm_rows(k);
                out.copy_from(&poly::poly_attention_prenormed(&qn, &kn, v, *p));
                normed_k = Some(kn);
            }
        }
        let t_capture = obs::phase::add_since(Phase::QuadAttn, t_attn);
        // Write-only numeric-health scan of the attention output block.
        obs::sentinel::scan_rows(
            obs::sentinel::Site::AttnOut,
            (0..n).map(|i| out.row(i)),
        );
        if let Some(st) = state {
            let st = self.kv_state(st);
            assert_eq!(st.len, 0, "prefill requires a fresh state");
            for i in 0..n {
                match &normed_k {
                    Some(kn) => st.push(kn.row(i), v.row(i)),
                    None => st.push(k.row(i), v.row(i)),
                }
            }
        }
        obs::phase::add_since(Phase::QuadCapture, t_capture);
    }

    fn step(&self, q: &[f32], k: &[f32], v: &[f32], state: &mut KernelState) -> Vec<f32> {
        let _t = obs::phase::timer(Phase::QuadStep);
        let st = self.kv_state(state);
        match &self.kind {
            // Blocked streaming is a prefill-side layout; the decode math
            // of softmax and flash is identical.
            QuadKind::Softmax | QuadKind::Flash { .. } => {
                st.push(k, v);
                st.softmax_row(q)
            }
            QuadKind::Poly { p } => {
                st.push(&ln_row(k), v);
                st.poly_row(&ln_row(q), *p)
            }
        }
    }

    fn absorb(&self, k: &[f32], v: &[f32], state: &mut KernelState) {
        let st = self.kv_state(state);
        match &self.kind {
            QuadKind::Softmax | QuadKind::Flash { .. } => st.push(k, v),
            QuadKind::Poly { .. } => st.push(&ln_row(k), v),
        }
    }

    /// Recompute-attention backward.  Blocking (flash) is a prefill-side
    /// schedule, not different math, so softmax and flash share the same
    /// row-streaming backward; exact poly chains through the row
    /// layernorms.  O(n²·h) per head — the quadratic engines pay the
    /// quadratic price in training too, which is exactly what the
    /// train_throughput bench measures against the linear engine.
    fn vjp(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        d_out: &TensorView<'_>,
        dq: &mut TensorViewMut<'_>,
        dk: &mut TensorViewMut<'_>,
        dv: &mut TensorViewMut<'_>,
    ) {
        let n = q.rows();
        let hd = q.cols();
        let hv = v.cols();
        assert_eq!((d_out.rows(), d_out.cols()), (n, hv));
        match &self.kind {
            QuadKind::Softmax | QuadKind::Flash { .. } => {
                let scale = 1.0 / (hd as f32).sqrt();
                let mut scores = vec![0.0f32; n];
                let mut dp = vec![0.0f32; n];
                let mut dq_acc = vec![0.0f32; hd];
                for i in 0..n {
                    let qi = q.row(i);
                    let doi = d_out.row(i);
                    let m = i + 1;
                    let mut mx = f32::NEG_INFINITY;
                    for j in 0..m {
                        scores[j] = dot(qi, k.row(j)) * scale;
                        mx = mx.max(scores[j]);
                    }
                    for s in scores[..m].iter_mut() {
                        *s = (*s - mx).exp();
                    }
                    let sum = micro::sum(&scores[..m]);
                    // Normalize in place: scores becomes the probability row
                    // s_j; softmax VJP: da_j = s_j(dp_j - Σ s dp).
                    for s in scores[..m].iter_mut() {
                        *s /= sum;
                    }
                    for j in 0..m {
                        dp[j] = dot(doi, v.row(j));
                    }
                    let sdot = micro::dot(&scores[..m], &dp[..m]);
                    dq_acc.fill(0.0);
                    for j in 0..m {
                        let s = scores[j];
                        axpy(dv.row_mut(j), doi, s);
                        let da = s * (dp[j] - sdot) * scale;
                        axpy(&mut dq_acc, k.row(j), da);
                        axpy(dk.row_mut(j), qi, da);
                    }
                    axpy(dq.row_mut(i), &dq_acc, 1.0);
                }
            }
            QuadKind::Poly { p } => {
                let qn = layernorm_rows(q);
                let kn = layernorm_rows(k);
                let mut dqn = Tensor::zeros(&[n, hd]);
                let mut dkn = Tensor::zeros(&[n, hd]);
                let mut acc = vec![0.0f32; hv];
                let mut w = vec![0.0f32; n];
                for i in 0..n {
                    let qni = qn.row(i);
                    let doi = d_out.row(i);
                    let mut denom = 1.0f32;
                    acc.fill(0.0);
                    for j in 0..=i {
                        w[j] = powi(dot(qni, kn.row(j)), *p);
                        denom += w[j];
                        axpy(&mut acc, v.row(j), w[j]);
                    }
                    let inv = 1.0 / denom;
                    // out_i = acc·inv; ∂out/∂w_j = (v_j − out_i)/denom.
                    let dout_dot_out = micro::dot(doi, &acc) * inv;
                    for j in 0..=i {
                        axpy(dv.row_mut(j), doi, w[j] * inv);
                        let dw = (dot(doi, v.row(j)) - dout_dot_out) * inv;
                        let t = dot(qni, kn.row(j));
                        let dt = dw * *p as f32 * powi(t, *p - 1);
                        axpy(dqn.row_mut(i), kn.row(j), dt);
                        axpy(dkn.row_mut(j), qni, dt);
                    }
                }
                for i in 0..n {
                    axpy(dq.row_mut(i), &ln_row_vjp(q.row(i), dqn.row(i)), 1.0);
                    axpy(dk.row_mut(i), &ln_row_vjp(k.row(i), dkn.row(i)), 1.0);
                }
            }
        }
    }
}
