//! [`FeatureMap`] — the kernel-trick interface every linear (and exact
//! polynomial) attention factors through.
//!
//! A feature map turns raw q/k rows into *mapped* rows such that the
//! attention weight between positions i ≥ j is `score(map(q_i), map(k_j))`
//! = ⟨φ(q_i), φ(k_j)⟩ for an implicit non-negative feature φ, and
//! `expand` materializes φ itself (the prefix-state feature the linear
//! engine folds into `Z += φ(k)ᵀ [v | 1]`).  Keeping the mapped form
//! separate from φ is Section 3.1's trick: polysketch buffers r-dim half
//! sketches and scores diagonal blocks with `(L Rᵀ)²` — the r²-dim φ is
//! only ever expanded row-by-row into the prefix state.

use std::sync::Arc;

use crate::attn::block_lt::self_tensor_row;
use crate::attn::performer::PerformerFeatures;
use crate::attn::poly::powi;
use crate::attn::sketch::{HalfRowScratch, PolySketch};
use crate::tensor::{axpy, dot, layernorm_rows, ln_row, ln_row_vjp, Tensor, TensorView};

/// Reusable per-state scratch for [`FeatureMap::map_row`] — the decode
/// hot path (token × layer × head) must not rebuild recursion
/// intermediates on every call.  Contents are overwritten before every
/// read, so cloning (decode states are `Clone` for the prompt cache)
/// just carries capacity, never data.
#[derive(Clone, Debug, Default)]
pub struct MapScratch {
    /// Half-sketch recursion buffers (polysketch maps).
    pub sketch: HalfRowScratch,
}

/// Maps raw attention rows to kernel features.  Object safe: engines
/// hold `Arc<dyn FeatureMap>` and the serving stack never learns which
/// map is behind a head.
pub trait FeatureMap: Send + Sync {
    /// Width f of the expanded prefix feature φ (the linear engine's Z is
    /// f × (h+1)).  Panics for maps with no tractable expansion
    /// ([`IdentityPowerMap`]) — those serve only as diagonal/quadratic
    /// score maps.
    fn feat_dim(&self) -> usize;

    /// Map a whole (n, h) matrix of raw rows to (n, map_dim).
    fn map(&self, x: &TensorView<'_>) -> Tensor;

    /// Map one raw row — bitwise identical to the corresponding row of
    /// [`FeatureMap::map`].
    fn map_row(&self, row: &[f32], scratch: &mut MapScratch) -> Vec<f32>;

    /// Is this map "row layernorm, then a pure function of the
    /// normalized row"?  When a global and a local map both
    /// prenormalize, the linear engine computes the layernorm **once**
    /// per raw row and feeds [`FeatureMap::map_normed_row`] to both —
    /// keeping the per-token decode cost flat (one LN per row, as the
    /// pre-trait-core code had).
    fn prenormalizes(&self) -> bool {
        false
    }

    /// Map an already-layernormed row; bitwise identical to
    /// `map_row(raw)` when `normed == ln_row(raw)`.  Called only when
    /// [`FeatureMap::prenormalizes`] returns true.
    fn map_normed_row(&self, _normed: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        unreachable!("map_normed_row on a map that does not prenormalize")
    }

    /// Kernel value ⟨φ(a), φ(b)⟩ from two *mapped* rows, without
    /// expanding φ.
    fn score(&self, a: &[f32], b: &[f32]) -> f32;

    /// Expand a mapped row into φ (length [`FeatureMap::feat_dim`]).
    /// Panics for score-only maps ([`IdentityPowerMap`]).
    fn expand(&self, mapped: &[f32], out: &mut [f32]);

    // ----- training surface (VJPs; the forward path never calls these)

    /// VJP of [`FeatureMap::map_row`]: gradient w.r.t. the *raw* row
    /// given the gradient w.r.t. the mapped row.  Recomputes whatever
    /// forward intermediates it needs (training recomputes, never tapes
    /// inside the maps).
    fn map_vjp(&self, raw: &[f32], d_mapped: &[f32]) -> Vec<f32>;

    /// VJP of [`FeatureMap::score`]: accumulate into `da`/`db` the
    /// gradient of `ds · score(a, b)` w.r.t. the two *mapped* rows.
    fn score_vjp(&self, a: &[f32], b: &[f32], ds: f32, da: &mut [f32], db: &mut [f32]);

    /// VJP of [`FeatureMap::expand`]: accumulate into `d_mapped` the
    /// gradient pulled back from `d_phi` (length feat_dim).  Panics for
    /// score-only maps, exactly like [`FeatureMap::expand`].
    fn expand_vjp(&self, mapped: &[f32], d_phi: &[f32], d_mapped: &mut [f32]);
}

/// Shared pullback of the row self-tensor φ = l ⊗ l: with φ[i·r+j] =
/// l[i]·l[j], `dl[i] += Σ_j (dφ[i·r+j] + dφ[j·r+i]) l[j]`.  The row and
/// column slices of dφ are gathered into one temp so the reduction runs
/// through the micro lane tree like every other dot in the codebase.
fn self_tensor_row_vjp(mapped: &[f32], d_phi: &[f32], d_mapped: &mut [f32]) {
    let r = mapped.len();
    debug_assert_eq!(d_phi.len(), r * r);
    let mut t = vec![0.0f32; r];
    for i in 0..r {
        for (j, tj) in t.iter_mut().enumerate() {
            *tj = d_phi[i * r + j] + d_phi[j * r + i];
        }
        d_mapped[i] += dot(&t, mapped);
    }
}

// ---------------------------------------------------------- polysketch

/// Algorithm 1: layernorm, then the degree-p/2 half sketch L; the
/// implicit non-negative feature is the row self-tensor φ = l ⊗ l
/// (Theorem 2.4), so scores square the half-sketch dot.
pub struct PolySketchMap {
    sk: Arc<PolySketch>,
}

impl PolySketchMap {
    pub fn new(sk: Arc<PolySketch>) -> PolySketchMap {
        PolySketchMap { sk }
    }
}

impl FeatureMap for PolySketchMap {
    fn feat_dim(&self) -> usize {
        self.sk.r * self.sk.r
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        self.sk.half(&layernorm_rows(x))
    }

    fn map_row(&self, row: &[f32], scratch: &mut MapScratch) -> Vec<f32> {
        self.sk.half_row_scratch(&ln_row(row), &mut scratch.sketch)
    }

    fn prenormalizes(&self) -> bool {
        true
    }

    fn map_normed_row(&self, normed: &[f32], scratch: &mut MapScratch) -> Vec<f32> {
        self.sk.half_row_scratch(normed, &mut scratch.sketch)
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        let s = dot(a, b);
        s * s // (L R^T)^2: phi' never materialized
    }

    fn expand(&self, mapped: &[f32], out: &mut [f32]) {
        self_tensor_row(mapped, out);
    }

    fn map_vjp(&self, raw: &[f32], d_mapped: &[f32]) -> Vec<f32> {
        let normed = ln_row(raw);
        let d_normed = self.sk.half_row_vjp(&normed, d_mapped);
        ln_row_vjp(raw, &d_normed)
    }

    fn score_vjp(&self, a: &[f32], b: &[f32], ds: f32, da: &mut [f32], db: &mut [f32]) {
        // s = (a·b)² ⇒ ds/da = 2(a·b)·b.
        let coef = ds * 2.0 * dot(a, b);
        axpy(da, b, coef);
        axpy(db, a, coef);
    }

    fn expand_vjp(&self, mapped: &[f32], d_phi: &[f32], d_mapped: &mut [f32]) {
        self_tensor_row_vjp(mapped, d_phi, d_mapped);
    }
}

// ----------------------------------------------------------- performer

/// FAVOR+ positive random features: φ is the mapped row itself.
pub struct PerformerMap {
    feats: Arc<PerformerFeatures>,
}

impl PerformerMap {
    pub fn new(feats: Arc<PerformerFeatures>) -> PerformerMap {
        PerformerMap { feats }
    }
}

impl FeatureMap for PerformerMap {
    fn feat_dim(&self) -> usize {
        self.feats.w.cols()
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        self.feats.apply(x)
    }

    fn map_row(&self, row: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        self.feats.apply_row(row)
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        dot(a, b)
    }

    fn expand(&self, mapped: &[f32], out: &mut [f32]) {
        out.copy_from_slice(mapped);
    }

    fn map_vjp(&self, raw: &[f32], d_mapped: &[f32]) -> Vec<f32> {
        let mapped = self.feats.apply_row(raw);
        self.feats.apply_row_vjp(raw, &mapped, d_mapped)
    }

    fn score_vjp(&self, a: &[f32], b: &[f32], ds: f32, da: &mut [f32], db: &mut [f32]) {
        axpy(da, b, ds);
        axpy(db, a, ds);
    }

    fn expand_vjp(&self, _mapped: &[f32], d_phi: &[f32], d_mapped: &mut [f32]) {
        axpy(d_mapped, d_phi, 1.0);
    }
}

// ----------------------------------------------- identity-power (exact)

/// The exact degree-p polynomial kernel: mapped rows are layernormed raw
/// rows, scores are ⟨q', k'⟩^p.  φ would be the degree-p tensor power
/// (h^p dims) — intractable as a prefix feature, so this map is
/// score-only: it drives the quadratic engine's exact-poly path and the
/// linear engine's Section 3.2 local-exact diagonal blocks.
pub struct IdentityPowerMap {
    p: u32,
}

impl IdentityPowerMap {
    pub fn new(p: u32) -> IdentityPowerMap {
        IdentityPowerMap { p }
    }
}

impl FeatureMap for IdentityPowerMap {
    fn feat_dim(&self) -> usize {
        panic!("identity-power features have no tractable prefix expansion (score-only map)");
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        layernorm_rows(x)
    }

    fn map_row(&self, row: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        ln_row(row)
    }

    fn prenormalizes(&self) -> bool {
        true
    }

    fn map_normed_row(&self, normed: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        normed.to_vec()
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        powi(dot(a, b), self.p)
    }

    fn expand(&self, _mapped: &[f32], _out: &mut [f32]) {
        panic!("identity-power features have no tractable prefix expansion (score-only map)");
    }

    fn map_vjp(&self, raw: &[f32], d_mapped: &[f32]) -> Vec<f32> {
        ln_row_vjp(raw, d_mapped)
    }

    fn score_vjp(&self, a: &[f32], b: &[f32], ds: f32, da: &mut [f32], db: &mut [f32]) {
        // s = (a·b)^p ⇒ ds/da = p·(a·b)^{p-1}·b.
        let coef = ds * self.p as f32 * powi(dot(a, b), self.p - 1);
        axpy(da, b, coef);
        axpy(db, a, coef);
    }

    fn expand_vjp(&self, _mapped: &[f32], _d_phi: &[f32], _d_mapped: &mut [f32]) {
        panic!("identity-power features have no tractable prefix expansion (score-only map)");
    }
}

// ------------------------------------------------- pre-mapped adapters

/// Adapter for callers that already hold explicit feature rows (the
/// classic `lt(φ_q φ_kᵀ) [V|1]` interface): map is the identity, φ is
/// the row itself.
pub struct DirectFeatures {
    f: usize,
}

impl DirectFeatures {
    pub fn new(f: usize) -> DirectFeatures {
        DirectFeatures { f }
    }
}

impl FeatureMap for DirectFeatures {
    fn feat_dim(&self) -> usize {
        self.f
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        x.to_tensor()
    }

    fn map_row(&self, row: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        row.to_vec()
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        dot(a, b)
    }

    fn expand(&self, mapped: &[f32], out: &mut [f32]) {
        out.copy_from_slice(mapped);
    }

    fn map_vjp(&self, _raw: &[f32], d_mapped: &[f32]) -> Vec<f32> {
        d_mapped.to_vec()
    }

    fn score_vjp(&self, a: &[f32], b: &[f32], ds: f32, da: &mut [f32], db: &mut [f32]) {
        axpy(da, b, ds);
        axpy(db, a, ds);
    }

    fn expand_vjp(&self, _mapped: &[f32], d_phi: &[f32], d_mapped: &mut [f32]) {
        axpy(d_mapped, d_phi, 1.0);
    }
}

/// Adapter for callers that already hold *half-sketch* rows: map is the
/// identity on r-dim rows, φ is the self-tensor (r² dims), scores square
/// the dot — `polysketch_attention_block`'s historical contract.
pub struct SelfTensorFeatures {
    r: usize,
}

impl SelfTensorFeatures {
    pub fn new(r: usize) -> SelfTensorFeatures {
        SelfTensorFeatures { r }
    }
}

impl FeatureMap for SelfTensorFeatures {
    fn feat_dim(&self) -> usize {
        self.r * self.r
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        x.to_tensor()
    }

    fn map_row(&self, row: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        row.to_vec()
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        let s = dot(a, b);
        s * s
    }

    fn expand(&self, mapped: &[f32], out: &mut [f32]) {
        self_tensor_row(mapped, out);
    }

    fn map_vjp(&self, _raw: &[f32], d_mapped: &[f32]) -> Vec<f32> {
        d_mapped.to_vec()
    }

    fn score_vjp(&self, a: &[f32], b: &[f32], ds: f32, da: &mut [f32], db: &mut [f32]) {
        let coef = ds * 2.0 * dot(a, b);
        axpy(da, b, coef);
        axpy(db, a, coef);
    }

    fn expand_vjp(&self, mapped: &[f32], d_phi: &[f32], d_mapped: &mut [f32]) {
        self_tensor_row_vjp(mapped, d_phi, d_mapped);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn map_row_bitwise_matches_map() {
        let mut rng = Pcg::seeded(3);
        let x = Tensor::gaussian(&mut rng, &[6, 8]);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(PolySketchMap::new(Arc::new(PolySketch::sample(&mut rng, 8, 4, 4)))),
            Box::new(PerformerMap::new(Arc::new(PerformerFeatures::sample(&mut rng, 8, 16)))),
            Box::new(IdentityPowerMap::new(4)),
            Box::new(DirectFeatures::new(8)),
        ];
        for (mi, map) in maps.iter().enumerate() {
            let full = map.map(&x.view());
            let mut scratch = MapScratch::default();
            for i in 0..x.rows() {
                assert_eq!(
                    map.map_row(x.row(i), &mut scratch).as_slice(),
                    full.row(i),
                    "map {mi} row {i}"
                );
            }
        }
    }

    #[test]
    fn map_normed_row_bitwise_matches_map_row() {
        // The shared-layernorm fast path of the decode loop must be a
        // pure refactor of map_row: same bytes when fed ln_row(raw).
        let mut rng = Pcg::seeded(9);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(PolySketchMap::new(Arc::new(PolySketch::sample(&mut rng, 8, 4, 4)))),
            Box::new(IdentityPowerMap::new(4)),
        ];
        for (mi, map) in maps.iter().enumerate() {
            assert!(map.prenormalizes(), "map {mi}");
            let mut scratch = MapScratch::default();
            for t in 0..5 {
                let raw: Vec<f32> = rng.gaussians(8);
                let a = map.map_row(&raw, &mut scratch);
                let b = map.map_normed_row(&ln_row(&raw), &mut scratch);
                assert_eq!(a, b, "map {mi} trial {t}");
            }
        }
    }

    fn fd_close(fd: f64, an: f64, ctx: &str) {
        assert!(
            (fd - an).abs() <= 1e-2 * (1.0 + fd.abs().max(an.abs())),
            "{ctx}: fd {fd} vs analytic {an}"
        );
    }

    #[test]
    fn map_vjp_matches_finite_difference() {
        let mut rng = Pcg::seeded(31);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(PolySketchMap::new(Arc::new(PolySketch::sample(&mut rng, 8, 4, 4)))),
            Box::new(PerformerMap::new(Arc::new(PerformerFeatures::sample(&mut rng, 8, 12)))),
            Box::new(IdentityPowerMap::new(4)),
            Box::new(DirectFeatures::new(8)),
        ];
        for (mi, map) in maps.iter().enumerate() {
            let raw: Vec<f32> = rng.gaussians(8);
            let mut scratch = MapScratch::default();
            let width = map.map_row(&raw, &mut scratch).len();
            let c: Vec<f32> = rng.gaussians(width);
            let loss = |x: &[f32]| -> f64 {
                let mut s = MapScratch::default();
                map.map_row(x, &mut s)
                    .iter()
                    .zip(&c)
                    .map(|(&m, &w)| (m as f64) * (w as f64))
                    .sum()
            };
            let an = map.map_vjp(&raw, &c);
            let eps = 1e-3f32;
            for i in 0..raw.len() {
                let mut xp = raw.clone();
                xp[i] += eps;
                let mut xm = raw.clone();
                xm[i] -= eps;
                let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
                fd_close(fd, an[i] as f64, &format!("map {mi} coord {i}"));
            }
        }
    }

    #[test]
    fn score_vjp_matches_finite_difference() {
        let mut rng = Pcg::seeded(32);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(PolySketchMap::new(Arc::new(PolySketch::sample(&mut rng, 8, 5, 4)))),
            Box::new(PerformerMap::new(Arc::new(PerformerFeatures::sample(&mut rng, 5, 5)))),
            Box::new(IdentityPowerMap::new(4)),
            Box::new(SelfTensorFeatures::new(5)),
            Box::new(DirectFeatures::new(5)),
        ];
        for (mi, map) in maps.iter().enumerate() {
            // Mapped rows are free inputs here: score is a function of
            // two already-mapped rows of any common width.
            let a: Vec<f32> = rng.gaussians(5);
            let b: Vec<f32> = rng.gaussians(5);
            let ds = 0.7f32;
            let (mut da, mut db) = (vec![0.0f32; 5], vec![0.0f32; 5]);
            map.score_vjp(&a, &b, ds, &mut da, &mut db);
            let eps = 1e-3f32;
            for i in 0..5 {
                let mut ap = a.clone();
                ap[i] += eps;
                let mut am = a.clone();
                am[i] -= eps;
                let fd = (ds as f64)
                    * ((map.score(&ap, &b) as f64) - (map.score(&am, &b) as f64))
                    / (2.0 * eps as f64);
                fd_close(fd, da[i] as f64, &format!("map {mi} da[{i}]"));
                let mut bp = b.clone();
                bp[i] += eps;
                let mut bm = b.clone();
                bm[i] -= eps;
                let fd = (ds as f64)
                    * ((map.score(&a, &bp) as f64) - (map.score(&a, &bm) as f64))
                    / (2.0 * eps as f64);
                fd_close(fd, db[i] as f64, &format!("map {mi} db[{i}]"));
            }
        }
    }

    #[test]
    fn expand_vjp_matches_finite_difference() {
        let mut rng = Pcg::seeded(33);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(SelfTensorFeatures::new(4)),
            Box::new(DirectFeatures::new(4)),
        ];
        for (mi, map) in maps.iter().enumerate() {
            let mapped: Vec<f32> = rng.gaussians(4);
            let f = map.feat_dim();
            let c: Vec<f32> = rng.gaussians(f);
            let loss = |m: &[f32]| -> f64 {
                let mut phi = vec![0.0f32; f];
                map.expand(m, &mut phi);
                phi.iter().zip(&c).map(|(&p, &w)| (p as f64) * (w as f64)).sum()
            };
            let mut an = vec![0.0f32; 4];
            map.expand_vjp(&mapped, &c, &mut an);
            let eps = 1e-3f32;
            for i in 0..4 {
                let mut mp = mapped.clone();
                mp[i] += eps;
                let mut mm = mapped.clone();
                mm[i] -= eps;
                let fd = (loss(&mp) - loss(&mm)) / (2.0 * eps as f64);
                fd_close(fd, an[i] as f64, &format!("map {mi} coord {i}"));
            }
        }
    }

    #[test]
    fn score_matches_expanded_dot() {
        // For expandable maps, score(a, b) must equal <phi(a), phi(b)>.
        let mut rng = Pcg::seeded(4);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(SelfTensorFeatures::new(5)),
            Box::new(DirectFeatures::new(5)),
        ];
        for map in &maps {
            let a: Vec<f32> = rng.gaussians(5);
            let b: Vec<f32> = rng.gaussians(5);
            let f = map.feat_dim();
            let (mut pa, mut pb) = (vec![0.0; f], vec![0.0; f]);
            map.expand(&a, &mut pa);
            map.expand(&b, &mut pb);
            assert!((map.score(&a, &b) - dot(&pa, &pb)).abs() < 1e-4);
        }
    }
}
