//! [`FeatureMap`] — the kernel-trick interface every linear (and exact
//! polynomial) attention factors through.
//!
//! A feature map turns raw q/k rows into *mapped* rows such that the
//! attention weight between positions i ≥ j is `score(map(q_i), map(k_j))`
//! = ⟨φ(q_i), φ(k_j)⟩ for an implicit non-negative feature φ, and
//! `expand` materializes φ itself (the prefix-state feature the linear
//! engine folds into `Z += φ(k)ᵀ [v | 1]`).  Keeping the mapped form
//! separate from φ is Section 3.1's trick: polysketch buffers r-dim half
//! sketches and scores diagonal blocks with `(L Rᵀ)²` — the r²-dim φ is
//! only ever expanded row-by-row into the prefix state.

use std::sync::Arc;

use crate::attn::block_lt::self_tensor_row;
use crate::attn::performer::PerformerFeatures;
use crate::attn::poly::powi;
use crate::attn::sketch::{HalfRowScratch, PolySketch};
use crate::tensor::{dot, layernorm_rows, ln_row, Tensor, TensorView};

/// Reusable per-state scratch for [`FeatureMap::map_row`] — the decode
/// hot path (token × layer × head) must not rebuild recursion
/// intermediates on every call.  Contents are overwritten before every
/// read, so cloning (decode states are `Clone` for the prompt cache)
/// just carries capacity, never data.
#[derive(Clone, Debug, Default)]
pub struct MapScratch {
    /// Half-sketch recursion buffers (polysketch maps).
    pub sketch: HalfRowScratch,
}

/// Maps raw attention rows to kernel features.  Object safe: engines
/// hold `Arc<dyn FeatureMap>` and the serving stack never learns which
/// map is behind a head.
pub trait FeatureMap: Send + Sync {
    /// Width f of the expanded prefix feature φ (the linear engine's Z is
    /// f × (h+1)).  Panics for maps with no tractable expansion
    /// ([`IdentityPowerMap`]) — those serve only as diagonal/quadratic
    /// score maps.
    fn feat_dim(&self) -> usize;

    /// Map a whole (n, h) matrix of raw rows to (n, map_dim).
    fn map(&self, x: &TensorView<'_>) -> Tensor;

    /// Map one raw row — bitwise identical to the corresponding row of
    /// [`FeatureMap::map`].
    fn map_row(&self, row: &[f32], scratch: &mut MapScratch) -> Vec<f32>;

    /// Is this map "row layernorm, then a pure function of the
    /// normalized row"?  When a global and a local map both
    /// prenormalize, the linear engine computes the layernorm **once**
    /// per raw row and feeds [`FeatureMap::map_normed_row`] to both —
    /// keeping the per-token decode cost flat (one LN per row, as the
    /// pre-trait-core code had).
    fn prenormalizes(&self) -> bool {
        false
    }

    /// Map an already-layernormed row; bitwise identical to
    /// `map_row(raw)` when `normed == ln_row(raw)`.  Called only when
    /// [`FeatureMap::prenormalizes`] returns true.
    fn map_normed_row(&self, _normed: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        unreachable!("map_normed_row on a map that does not prenormalize")
    }

    /// Kernel value ⟨φ(a), φ(b)⟩ from two *mapped* rows, without
    /// expanding φ.
    fn score(&self, a: &[f32], b: &[f32]) -> f32;

    /// Expand a mapped row into φ (length [`FeatureMap::feat_dim`]).
    /// Panics for score-only maps ([`IdentityPowerMap`]).
    fn expand(&self, mapped: &[f32], out: &mut [f32]);
}

// ---------------------------------------------------------- polysketch

/// Algorithm 1: layernorm, then the degree-p/2 half sketch L; the
/// implicit non-negative feature is the row self-tensor φ = l ⊗ l
/// (Theorem 2.4), so scores square the half-sketch dot.
pub struct PolySketchMap {
    sk: Arc<PolySketch>,
}

impl PolySketchMap {
    pub fn new(sk: Arc<PolySketch>) -> PolySketchMap {
        PolySketchMap { sk }
    }
}

impl FeatureMap for PolySketchMap {
    fn feat_dim(&self) -> usize {
        self.sk.r * self.sk.r
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        self.sk.half(&layernorm_rows(x))
    }

    fn map_row(&self, row: &[f32], scratch: &mut MapScratch) -> Vec<f32> {
        self.sk.half_row_scratch(&ln_row(row), &mut scratch.sketch)
    }

    fn prenormalizes(&self) -> bool {
        true
    }

    fn map_normed_row(&self, normed: &[f32], scratch: &mut MapScratch) -> Vec<f32> {
        self.sk.half_row_scratch(normed, &mut scratch.sketch)
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        let s = dot(a, b);
        s * s // (L R^T)^2: phi' never materialized
    }

    fn expand(&self, mapped: &[f32], out: &mut [f32]) {
        self_tensor_row(mapped, out);
    }
}

// ----------------------------------------------------------- performer

/// FAVOR+ positive random features: φ is the mapped row itself.
pub struct PerformerMap {
    feats: Arc<PerformerFeatures>,
}

impl PerformerMap {
    pub fn new(feats: Arc<PerformerFeatures>) -> PerformerMap {
        PerformerMap { feats }
    }
}

impl FeatureMap for PerformerMap {
    fn feat_dim(&self) -> usize {
        self.feats.w.cols()
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        self.feats.apply(x)
    }

    fn map_row(&self, row: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        self.feats.apply_row(row)
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        dot(a, b)
    }

    fn expand(&self, mapped: &[f32], out: &mut [f32]) {
        out.copy_from_slice(mapped);
    }
}

// ----------------------------------------------- identity-power (exact)

/// The exact degree-p polynomial kernel: mapped rows are layernormed raw
/// rows, scores are ⟨q', k'⟩^p.  φ would be the degree-p tensor power
/// (h^p dims) — intractable as a prefix feature, so this map is
/// score-only: it drives the quadratic engine's exact-poly path and the
/// linear engine's Section 3.2 local-exact diagonal blocks.
pub struct IdentityPowerMap {
    p: u32,
}

impl IdentityPowerMap {
    pub fn new(p: u32) -> IdentityPowerMap {
        IdentityPowerMap { p }
    }
}

impl FeatureMap for IdentityPowerMap {
    fn feat_dim(&self) -> usize {
        panic!("identity-power features have no tractable prefix expansion (score-only map)");
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        layernorm_rows(x)
    }

    fn map_row(&self, row: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        ln_row(row)
    }

    fn prenormalizes(&self) -> bool {
        true
    }

    fn map_normed_row(&self, normed: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        normed.to_vec()
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        powi(dot(a, b), self.p)
    }

    fn expand(&self, _mapped: &[f32], _out: &mut [f32]) {
        panic!("identity-power features have no tractable prefix expansion (score-only map)");
    }
}

// ------------------------------------------------- pre-mapped adapters

/// Adapter for callers that already hold explicit feature rows (the
/// classic `lt(φ_q φ_kᵀ) [V|1]` interface): map is the identity, φ is
/// the row itself.
pub struct DirectFeatures {
    f: usize,
}

impl DirectFeatures {
    pub fn new(f: usize) -> DirectFeatures {
        DirectFeatures { f }
    }
}

impl FeatureMap for DirectFeatures {
    fn feat_dim(&self) -> usize {
        self.f
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        x.to_tensor()
    }

    fn map_row(&self, row: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        row.to_vec()
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        dot(a, b)
    }

    fn expand(&self, mapped: &[f32], out: &mut [f32]) {
        out.copy_from_slice(mapped);
    }
}

/// Adapter for callers that already hold *half-sketch* rows: map is the
/// identity on r-dim rows, φ is the self-tensor (r² dims), scores square
/// the dot — `polysketch_attention_block`'s historical contract.
pub struct SelfTensorFeatures {
    r: usize,
}

impl SelfTensorFeatures {
    pub fn new(r: usize) -> SelfTensorFeatures {
        SelfTensorFeatures { r }
    }
}

impl FeatureMap for SelfTensorFeatures {
    fn feat_dim(&self) -> usize {
        self.r * self.r
    }

    fn map(&self, x: &TensorView<'_>) -> Tensor {
        x.to_tensor()
    }

    fn map_row(&self, row: &[f32], _scratch: &mut MapScratch) -> Vec<f32> {
        row.to_vec()
    }

    fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        let s = dot(a, b);
        s * s
    }

    fn expand(&self, mapped: &[f32], out: &mut [f32]) {
        self_tensor_row(mapped, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn map_row_bitwise_matches_map() {
        let mut rng = Pcg::seeded(3);
        let x = Tensor::gaussian(&mut rng, &[6, 8]);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(PolySketchMap::new(Arc::new(PolySketch::sample(&mut rng, 8, 4, 4)))),
            Box::new(PerformerMap::new(Arc::new(PerformerFeatures::sample(&mut rng, 8, 16)))),
            Box::new(IdentityPowerMap::new(4)),
            Box::new(DirectFeatures::new(8)),
        ];
        for (mi, map) in maps.iter().enumerate() {
            let full = map.map(&x.view());
            let mut scratch = MapScratch::default();
            for i in 0..x.rows() {
                assert_eq!(
                    map.map_row(x.row(i), &mut scratch).as_slice(),
                    full.row(i),
                    "map {mi} row {i}"
                );
            }
        }
    }

    #[test]
    fn map_normed_row_bitwise_matches_map_row() {
        // The shared-layernorm fast path of the decode loop must be a
        // pure refactor of map_row: same bytes when fed ln_row(raw).
        let mut rng = Pcg::seeded(9);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(PolySketchMap::new(Arc::new(PolySketch::sample(&mut rng, 8, 4, 4)))),
            Box::new(IdentityPowerMap::new(4)),
        ];
        for (mi, map) in maps.iter().enumerate() {
            assert!(map.prenormalizes(), "map {mi}");
            let mut scratch = MapScratch::default();
            for t in 0..5 {
                let raw: Vec<f32> = rng.gaussians(8);
                let a = map.map_row(&raw, &mut scratch);
                let b = map.map_normed_row(&ln_row(&raw), &mut scratch);
                assert_eq!(a, b, "map {mi} trial {t}");
            }
        }
    }

    #[test]
    fn score_matches_expanded_dot() {
        // For expandable maps, score(a, b) must equal <phi(a), phi(b)>.
        let mut rng = Pcg::seeded(4);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(SelfTensorFeatures::new(5)),
            Box::new(DirectFeatures::new(5)),
        ];
        for map in &maps {
            let a: Vec<f32> = rng.gaussians(5);
            let b: Vec<f32> = rng.gaussians(5);
            let f = map.feat_dim();
            let (mut pa, mut pb) = (vec![0.0; f], vec![0.0; f]);
            map.expand(&a, &mut pa);
            map.expand(&b, &mut pb);
            assert!((map.score(&a, &b) - dot(&pa, &pb)).abs() < 1e-4);
        }
    }
}
