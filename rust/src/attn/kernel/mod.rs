//! The kernel core: every attention mechanism behind two traits.
//!
//! PolySketchFormer's central observation (Sec. 3.1/3.2) is that one
//! block-based lower-triangular algorithm serves *every* feature-map
//! attention; this module is that observation as architecture.  Two
//! traits:
//!
//! * [`FeatureMap`] — maps raw q/k rows to kernel features.  Impls:
//!   [`feature::PolySketchMap`] (LN → half sketch, Algorithm 1),
//!   [`feature::PerformerMap`] (FAVOR+), [`feature::IdentityPowerMap`]
//!   (LN + degree-p dot — the exact polynomial kernel, also the
//!   local-exact diagonal of Sec. 3.2), plus the pre-mapped adapters
//!   [`feature::DirectFeatures`] / [`feature::SelfTensorFeatures`].
//! * [`CausalKernel`] — object-safe prefill/step/state interface with
//!   exactly **two** concrete engines: [`quadratic::QuadraticEngine`]
//!   (softmax / flash / exact poly over a KV cache) and
//!   [`linear::LinearEngine`] (every feature map through the one ragged
//!   block-lower-triangular path with a recurrent prefix state).
//!
//! [`Mechanism`] — the user-facing configuration enum — lives here too,
//! and `build_kernel` is its **only** dispatch point: outside this
//! module no code matches on mechanism variants (CI greps for it).
//! Adding a mechanism (e.g. the paper's learned or mixed sketches) means
//! implementing a `FeatureMap` and extending `build_kernel` — the
//! decode states, serving cache, scheduler, and benches come for free.

pub mod feature;
pub mod linear;
pub mod quadratic;
pub mod state;

use std::sync::Arc;

use crate::attn::performer::PerformerFeatures;
use crate::attn::sketch::PolySketch;
use crate::exec::pool;
use crate::obs;
use crate::tensor::{Tensor, TensorView, TensorViewMut};
use crate::util::rng::Pcg;

pub use feature::{FeatureMap, MapScratch};
pub use linear::LinearEngine;
pub use quadratic::QuadraticEngine;
pub use state::{KernelState, KvState, LinearState};

/// Which attention mechanism to run (native path).
#[derive(Clone, Debug, PartialEq)]
pub enum Mechanism {
    /// Naive causal softmax (quadratic, row-streaming).
    Softmax,
    /// FlashAttention-style blocked softmax (quadratic, tiled).
    Flash { block: usize },
    /// Exact degree-p polynomial attention (quadratic).
    Poly { p: u32 },
    /// Polysketch attention (linear): sketch size r, block b, degree p,
    /// optional local-exact diagonal blocks.
    Polysketch { r: usize, p: u32, block: usize, local: bool },
    /// Performer/FAVOR+ (linear) with m features.
    Performer { m: usize, block: usize },
}

impl Mechanism {
    pub fn label(&self) -> String {
        match self {
            Mechanism::Softmax => "softmax".into(),
            Mechanism::Flash { block } => format!("flash_b{block}"),
            Mechanism::Poly { p } => format!("poly{p}"),
            Mechanism::Polysketch { r, p, block, local } => {
                format!("psk{p}_r{r}_b{block}{}", if *local { "_local" } else { "" })
            }
            Mechanism::Performer { m, block } => format!("performer{m}_b{block}"),
        }
    }

    /// Parse a mechanism label — the exact inverse of [`Mechanism::label`]:
    /// `softmax`, `flash_b<block>`, `poly<p>`, `psk<p>_r<r>_b<block>[_local]`,
    /// `performer<m>_b<block>`.  Shared by the CLI `generate`/`serve`
    /// subcommands and the benches so mechanism strings are spelled one
    /// way everywhere.
    pub fn parse(s: &str) -> Result<Mechanism, String> {
        let err = || format!("bad mechanism `{s}` (want softmax | flash_b<B> | poly<P> | psk<P>_r<R>_b<B>[_local] | performer<M>_b<B>)");
        if s == "softmax" {
            return Ok(Mechanism::Softmax);
        }
        if let Some(rest) = s.strip_prefix("flash_b") {
            let block: usize = rest.parse().map_err(|_| err())?;
            if block == 0 {
                return Err(format!("bad mechanism `{s}`: block must be >= 1"));
            }
            return Ok(Mechanism::Flash { block });
        }
        if let Some(rest) = s.strip_prefix("poly") {
            let p: u32 = rest.parse().map_err(|_| err())?;
            if p < 2 || p % 2 != 0 {
                return Err(format!("bad mechanism `{s}`: poly degree must be even and >= 2"));
            }
            return Ok(Mechanism::Poly { p });
        }
        if let Some(rest) = s.strip_prefix("psk") {
            let (body, local) = match rest.strip_suffix("_local") {
                Some(b) => (b, true),
                None => (rest, false),
            };
            let mut it = body.split('_');
            let p = it.next().and_then(|t| t.parse().ok()).ok_or_else(err)?;
            let r = it
                .next()
                .and_then(|t| t.strip_prefix('r'))
                .and_then(|t| t.parse().ok())
                .ok_or_else(err)?;
            let block = it
                .next()
                .and_then(|t| t.strip_prefix('b'))
                .and_then(|t| t.parse().ok())
                .ok_or_else(err)?;
            if it.next().is_some() {
                return Err(err());
            }
            if p < 2 || !u32::is_power_of_two(p) {
                return Err(format!("bad mechanism `{s}`: psk degree must be a power of two >= 2"));
            }
            if r == 0 || block == 0 {
                return Err(format!("bad mechanism `{s}`: sketch size and block must be >= 1"));
            }
            return Ok(Mechanism::Polysketch { r, p, block, local });
        }
        if let Some(rest) = s.strip_prefix("performer") {
            let (m, block) = rest.split_once("_b").ok_or_else(err)?;
            let m: usize = m.parse().map_err(|_| err())?;
            let block: usize = block.parse().map_err(|_| err())?;
            if m == 0 || block == 0 {
                return Err(format!("bad mechanism `{s}`: feature count and block must be >= 1"));
            }
            return Ok(Mechanism::Performer { m, block });
        }
        Err(err())
    }

    /// Linear-time in context length?
    pub fn is_linear(&self) -> bool {
        matches!(self, Mechanism::Polysketch { .. } | Mechanism::Performer { .. })
    }

    /// Instantiate the kernel engine for one head: samples the mechanism's
    /// random state (sketches/features) from `rng` and wires it into the
    /// matching engine.  **The single dispatch point** — every prefill,
    /// decode step, cache snapshot, and bench flows through the object
    /// this returns.
    ///
    /// The RNG consumption order per variant is part of the golden-fixture
    /// contract: Polysketch draws `PolySketch::sample(rng, head_dim, r, p)`,
    /// Performer draws `PerformerFeatures::sample(rng, head_dim, m)`, the
    /// quadratic mechanisms draw nothing.
    pub fn build_kernel(&self, head_dim: usize, rng: &mut Pcg) -> Arc<dyn CausalKernel> {
        match self {
            Mechanism::Softmax => Arc::new(QuadraticEngine::softmax()),
            Mechanism::Flash { block } => Arc::new(QuadraticEngine::flash(*block)),
            Mechanism::Poly { p } => Arc::new(QuadraticEngine::poly(*p)),
            Mechanism::Polysketch { r, p, block, local } => {
                let sk = Arc::new(PolySketch::sample(rng, head_dim, *r, *p as usize));
                let map = Arc::new(feature::PolySketchMap::new(sk));
                let local_map: Option<Arc<dyn FeatureMap>> = if *local {
                    Some(Arc::new(feature::IdentityPowerMap::new(*p)))
                } else {
                    None
                };
                Arc::new(LinearEngine::new(map, local_map, *block))
            }
            Mechanism::Performer { m, block } => {
                let feats = Arc::new(PerformerFeatures::sample(rng, head_dim, *m));
                Arc::new(LinearEngine::new(
                    Arc::new(feature::PerformerMap::new(feats)),
                    None,
                    *block,
                ))
            }
        }
    }
}

/// One causal-attention complexity class, instantiated for one head.
///
/// Object safe on purpose: models hold `Vec<Arc<dyn CausalKernel>>` and
/// never know which engine (or feature map) is behind a head.  All three
/// entry points operate on the *same* state type, so prefill → step →
/// snapshot/restore compose freely:
///
/// * [`prefill_into`](CausalKernel::prefill_into) — full-context forward
///   over strided views of the fused q/k/v projections, writing this
///   head's output stripe in place and (optionally) leaving `state`
///   exactly as if every position had been absorbed token by token;
/// * [`step`](CausalKernel::step) — one decode token;
/// * [`absorb`](CausalKernel::absorb) — fold a (k, v) pair without
///   producing output (incremental prefill).
pub trait CausalKernel: Send + Sync {
    /// Fresh, empty decode state for this engine.
    fn new_state(&self) -> KernelState;

    /// Full-context causal attention for one head; `q`/`k`/`v` are
    /// (n, hd) views (typically column stripes of fused projections) and
    /// `out` is this head's (n, hd) output stripe.  When `state` is
    /// given it must be fresh; on return it holds the full-prefix decode
    /// state (identical to having `absorb`ed all n positions in order).
    fn prefill_into(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        state: Option<&mut KernelState>,
        out: &mut TensorViewMut<'_>,
    );

    /// One decode step: fold `(k, v)` into the state and return this
    /// position's attention output for query `q`.
    fn step(&self, q: &[f32], k: &[f32], v: &[f32], state: &mut KernelState) -> Vec<f32>;

    /// Fold a key/value pair into the state without producing an output.
    fn absorb(&self, k: &[f32], v: &[f32], state: &mut KernelState);

    /// Training backward: accumulate into `dq`/`dk`/`dv` the gradients of
    /// a scalar loss w.r.t. this head's raw `q`/`k`/`v`, given `d_out` =
    /// ∂loss/∂(prefill output).  Forward internals are *recomputed*, not
    /// taped (the recompute-softmax backward for the quadratic engine;
    /// the reverse-direction blocked recurrence over suffix sums of
    /// feature outer-products — still O(n·f·h) — for the linear engine).
    /// Gradients accumulate (`+=`), so callers zero the buffers once and
    /// may fold several heads into shared stripes.
    fn vjp(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        d_out: &TensorView<'_>,
        dq: &mut TensorViewMut<'_>,
        dk: &mut TensorViewMut<'_>,
        dv: &mut TensorViewMut<'_>,
    );

    /// Allocating convenience over [`prefill_into`](CausalKernel::prefill_into).
    fn prefill(
        &self,
        q: &TensorView<'_>,
        k: &TensorView<'_>,
        v: &TensorView<'_>,
        state: Option<&mut KernelState>,
    ) -> Tensor {
        let mut out = Tensor::zeros(&[q.rows(), v.cols()]);
        self.prefill_into(q, k, v, state, &mut out.view_mut());
        out
    }

    /// Stateless full-context forward — the bench/test entry point.
    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        self.prefill(&q.view(), &k.view(), &v.view(), None)
    }
}

/// Prefill every head of one layer in parallel over *fused* (n, H·hd)
/// projections: head `h` reads the column stripes `h·hd..(h+1)·hd` of
/// `q`/`k`/`v` through strided views and writes the same stripe of `out`
/// in place.  This is the single pool fan-out for the prefill path —
/// heads are independent units, and each engine parallelizes its own row
/// blocks beneath (the pool supports nesting), so callers never touch
/// the pool themselves.
pub fn prefill_heads(
    kernels: &[Arc<dyn CausalKernel>],
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    states: Option<&mut [KernelState]>,
    out: &mut Tensor,
) {
    let heads = kernels.len();
    assert!(heads > 0, "prefill_heads: no heads");
    let qv = q.head_views(heads);
    let kv = k.head_views(heads);
    let vv = v.head_views(heads);
    let ov = out.head_views_mut(heads);
    let mut units: Vec<(TensorViewMut<'_>, Option<&mut KernelState>)> = match states {
        Some(s) => {
            assert_eq!(s.len(), heads, "prefill_heads: state/head count mismatch");
            ov.into_iter().zip(s.iter_mut().map(Some)).collect()
        }
        None => ov.into_iter().map(|o| (o, None)).collect(),
    };
    pool::par_map_mut(&mut units, 1, |hi, (o, st)| {
        obs::sentinel::set_head(hi); // fault attribution only; no compute effect
        kernels[hi].prefill_into(&qv[hi], &kv[hi], &vv[hi], st.as_deref_mut(), o);
    });
}

/// Head-range variant of [`prefill_heads`] for tensor-parallel shards:
/// runs only heads `range.start..range.end`, leaving the other output
/// stripes untouched (callers pass a zeroed `out`, so the product
/// `out · wo` is this shard's *partial* attention output).  The views
/// are still built over the full head count — a head's stripe offset is
/// its index in the whole layer, not its index within the shard.
pub fn prefill_head_range(
    kernels: &[Arc<dyn CausalKernel>],
    range: std::ops::Range<usize>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    states: Option<&mut [KernelState]>,
    out: &mut Tensor,
) {
    let heads = kernels.len();
    assert!(heads > 0, "prefill_head_range: no heads");
    assert!(
        range.start < range.end && range.end <= heads,
        "prefill_head_range: bad head range {}..{} of {heads}",
        range.start,
        range.end,
    );
    let qv = q.head_views(heads);
    let kv = k.head_views(heads);
    let vv = v.head_views(heads);
    let ov = out.head_views_mut(heads);
    // Tag each unit with its head index: after filtering, position in
    // the vec no longer equals the head index.
    let mut units: Vec<(usize, TensorViewMut<'_>, Option<&mut KernelState>)> = match states {
        Some(s) => {
            assert_eq!(s.len(), heads, "prefill_head_range: state/head count mismatch");
            ov.into_iter()
                .zip(s.iter_mut().map(Some))
                .enumerate()
                .filter(|(hi, _)| range.contains(hi))
                .map(|(hi, (o, st))| (hi, o, st))
                .collect()
        }
        None => ov
            .into_iter()
            .enumerate()
            .filter(|(hi, _)| range.contains(hi))
            .map(|(hi, o)| (hi, o, None))
            .collect(),
    };
    pool::par_map_mut(&mut units, 1, |_, (hi, o, st)| {
        obs::sentinel::set_head(*hi); // fault attribution only; no compute effect
        kernels[*hi].prefill_into(&qv[*hi], &kv[*hi], &vv[*hi], st.as_deref_mut(), o);
    });
}

/// Backward twin of [`prefill_heads`]: head `h` reads the column stripes
/// of `q`/`k`/`v`/`d_out` and accumulates its raw-input gradients into
/// the same stripes of `dq`/`dk`/`dv` (which must be zeroed by the
/// caller).  Heads are independent and write disjoint stripes, so the
/// pool fan-out cannot change bytes.
pub fn vjp_heads(
    kernels: &[Arc<dyn CausalKernel>],
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    d_out: &Tensor,
    dq: &mut Tensor,
    dk: &mut Tensor,
    dv: &mut Tensor,
) {
    let heads = kernels.len();
    assert!(heads > 0, "vjp_heads: no heads");
    let qv = q.head_views(heads);
    let kv = k.head_views(heads);
    let vv = v.head_views(heads);
    let dov = d_out.head_views(heads);
    let dqv = dq.head_views_mut(heads);
    let dkv = dk.head_views_mut(heads);
    let dvv = dv.head_views_mut(heads);
    let mut units: Vec<(TensorViewMut<'_>, TensorViewMut<'_>, TensorViewMut<'_>)> = dqv
        .into_iter()
        .zip(dkv)
        .zip(dvv)
        .map(|((a, b), c)| (a, b, c))
        .collect();
    pool::par_map_mut(&mut units, 1, |hi, (dqh, dkh, dvh)| {
        kernels[hi].vjp(&qv[hi], &kv[hi], &vv[hi], &dov[hi], dqh, dkh, dvh);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn parse_inverts_label() {
        let ms = [
            Mechanism::Softmax,
            Mechanism::Flash { block: 256 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 16, p: 4, block: 64, local: true },
            Mechanism::Polysketch { r: 32, p: 2, block: 128, local: false },
            Mechanism::Performer { m: 64, block: 256 },
        ];
        for m in ms {
            assert_eq!(Mechanism::parse(&m.label()).unwrap(), m, "{}", m.label());
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "soft", "flash", "flash_b", "flash_bxx", "poly", "polyx", "psk4",
            "psk4_r16", "psk4_r16_b", "psk4_b64_r16", "psk4_r16_b64_extra",
            "performer64", "performer_b64", "psk4_r16_b64_localx",
        ] {
            assert!(Mechanism::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn parse_rejects_degenerate_parameters() {
        // Values that would only panic deep inside the kernels must be
        // rejected at the parse boundary (the CLI feeds this directly).
        for bad in [
            "flash_b0", "poly0", "poly1", "poly3", "psk3_r4_b8", "psk0_r4_b8",
            "psk4_r0_b8", "psk4_r4_b0", "performer0_b8", "performer16_b0",
        ] {
            assert!(Mechanism::parse(bad).is_err(), "`{bad}` should not parse");
        }
        // poly6 is legal for exact polynomial attention (even, not pow2)...
        assert!(Mechanism::parse("poly6").is_ok());
        // ...but sketches need a power of two.
        assert!(Mechanism::parse("psk6_r4_b8").is_err());
    }

    #[test]
    fn labels_distinct() {
        let ms = [
            Mechanism::Softmax,
            Mechanism::Flash { block: 64 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 16, p: 4, block: 64, local: true },
            Mechanism::Performer { m: 64, block: 64 },
        ];
        let labels: Vec<_> = ms.iter().map(|m| m.label()).collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }

    #[test]
    fn all_mechanisms_run_and_are_finite() {
        let mut rng = Pcg::seeded(0);
        let (n, h) = (32, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        for mech in [
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 8, p: 4, block: 8, local: true },
            Mechanism::Polysketch { r: 8, p: 4, block: 8, local: false },
            Mechanism::Performer { m: 16, block: 8 },
        ] {
            let kernel = mech.build_kernel(h, &mut rng);
            let out = kernel.forward(&q, &k, &v);
            assert_eq!(out.shape(), &[n, h]);
            assert!(out.data().iter().all(|x| x.is_finite()), "{}", mech.label());
        }
    }

    #[test]
    fn prefill_heads_matches_per_head_copies() {
        // The strided-view fan-out must produce exactly what slicing each
        // head into its own contiguous tensors produces.
        let mut rng = Pcg::seeded(9);
        let (n, heads, hd) = (24usize, 3usize, 8usize);
        let d = heads * hd;
        let q = Tensor::gaussian(&mut rng, &[n, d]);
        let k = Tensor::gaussian(&mut rng, &[n, d]);
        let v = Tensor::gaussian(&mut rng, &[n, d]);
        for mech in [
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 2 },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 8, block: 8 },
        ] {
            let mut krng = Pcg::seeded(5);
            let kernels: Vec<_> = (0..heads).map(|_| mech.build_kernel(hd, &mut krng)).collect();
            let mut fused = Tensor::zeros(&[n, d]);
            prefill_heads(&kernels, &q, &k, &v, None, &mut fused);
            for (hi, kernel) in kernels.iter().enumerate() {
                let slice = |t: &Tensor| t.head_views(heads)[hi].to_tensor();
                let want = kernel.forward(&slice(&q), &slice(&k), &slice(&v));
                let got = fused.head_views(heads)[hi].to_tensor();
                assert_eq!(got, want, "{} head {hi}", mech.label());
            }
        }
    }

    #[test]
    fn head_range_shards_reassemble_to_full_prefill() {
        // Two disjoint ranges, each into its own zeroed output, must sum
        // (= disjoint-stripe assemble) to exactly the full fan-out —
        // bitwise, since every head computes identical bytes either way.
        let mut rng = Pcg::seeded(11);
        let (n, heads, hd) = (16usize, 4usize, 8usize);
        let d = heads * hd;
        let q = Tensor::gaussian(&mut rng, &[n, d]);
        let k = Tensor::gaussian(&mut rng, &[n, d]);
        let v = Tensor::gaussian(&mut rng, &[n, d]);
        let mech = Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true };
        let mut krng = Pcg::seeded(5);
        let kernels: Vec<_> = (0..heads).map(|_| mech.build_kernel(hd, &mut krng)).collect();
        let mut full = Tensor::zeros(&[n, d]);
        prefill_heads(&kernels, &q, &k, &v, None, &mut full);
        let mut lo = Tensor::zeros(&[n, d]);
        let mut hi = Tensor::zeros(&[n, d]);
        prefill_head_range(&kernels, 0..1, &q, &k, &v, None, &mut lo);
        prefill_head_range(&kernels, 1..heads, &q, &k, &v, None, &mut hi);
        let sum = lo.add(&hi);
        assert_eq!(sum, full);
    }
}
