//! Section 3.1/3.2: block-based lower-triangular multiplication —
//! compatibility wrappers over the unified linear engine.
//!
//! The algorithm (per block H_l = phi_k_l^T [V_l|1], exclusive prefix
//! Z_l = sum_{j<l} H_j, diagonal P_l = lt(scores) [V_l|1], row i =
//! normalize(P_l[i'] + phi_q_i Z_l)) lives **once**, in
//! [`kernel::linear::LinearEngine`](crate::attn::kernel::LinearEngine);
//! these free functions adapt the historical explicit-feature and
//! half-sketch interfaces onto it via the pre-mapped feature adapters.
//! Sequence lengths need not be block multiples: the tail block is
//! processed ragged, bit-identically to the zero-padded computation on
//! real rows — callers never pad.
//!
//! This is the native (pure rust) twin of the Pallas kernel in
//! python/compile/kernels/pallas/ — same math, used for property tests and
//! for latency benches at context lengths (up to 32k) that the interpreted
//! kernel cannot reach.

use std::sync::Arc;

use crate::attn::kernel::feature::{DirectFeatures, IdentityPowerMap, SelfTensorFeatures};
use crate::attn::kernel::{FeatureMap, LinearEngine};
use crate::tensor::{layernorm_rows, micro, Tensor};

/// Generic causal linear attention over explicit feature maps.
///
/// phi_q, phi_k: (n, f); v: (n, h). Returns (n, h).  `n % block` may be
/// nonzero: the final block is simply shorter.
pub fn linear_attention_block(phi_q: &Tensor, phi_k: &Tensor, v: &Tensor,
                              block: usize) -> Tensor {
    let (n, f) = (phi_q.rows(), phi_q.cols());
    let h = v.cols();
    assert_eq!(phi_k.rows(), n);
    assert_eq!(v.rows(), n);
    let engine = LinearEngine::new(Arc::new(DirectFeatures::new(f)), None, block);
    let mut out = Tensor::zeros(&[n, h]);
    engine.forward_mapped(phi_q, phi_k, None, None, &v.view(), None, None, &mut out.view_mut());
    out
}

/// Local-exact configuration for [`polysketch_attention_block`].
pub struct LocalExact<'a> {
    /// Raw queries/keys (n, h) — layer norm applied inside.
    pub q: &'a Tensor,
    pub k: &'a Tensor,
    /// Polynomial degree p.
    pub p: u32,
}

/// Polysketch attention over half sketches L, R (n, rs).
///
/// Implicit features are the row self-tensors (rs^2-dim); the diagonal
/// block uses (L_l R_l^T)^2 — Section 3.1's O(b^2 rs) trick — or, with
/// `local`, the exact polynomial weights (Q_l K_l^T)^p of Section 3.2.
pub fn polysketch_attention_block(lh: &Tensor, rh: &Tensor, v: &Tensor,
                                  block: usize,
                                  local: Option<LocalExact>) -> Tensor {
    let (n, rs) = (lh.rows(), lh.cols());
    let h = v.cols();
    assert_eq!(rh.rows(), n);
    let map = Arc::new(SelfTensorFeatures::new(rs));
    let mut out = Tensor::zeros(&[n, h]);
    match local {
        Some(le) => {
            let local_map: Arc<dyn FeatureMap> = Arc::new(IdentityPowerMap::new(le.p));
            let lq = layernorm_rows(le.q);
            let lk = layernorm_rows(le.k);
            let engine = LinearEngine::new(map, Some(local_map), block);
            engine.forward_mapped(lh, rh, Some(&lq), Some(&lk), &v.view(), None, None,
                                  &mut out.view_mut());
        }
        None => {
            let engine = LinearEngine::new(map, None, block);
            engine.forward_mapped(lh, rh, None, None, &v.view(), None, None, &mut out.view_mut());
        }
    }
    out
}

/// Row self Kronecker product into scratch: the implicit phi' feature of a
/// half-sketch row. Shared with the per-token decode path (the linear
/// engine's state expansion).
#[inline]
pub(crate) fn self_tensor_row(l: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), l.len() * l.len());
    micro::outer(out, l, l);
}

/// Naive lt(A B^T) C — oracle for the block algorithm's tests/benches.
pub fn lt_mult_naive(a: &Tensor, b: &Tensor, c: &Tensor) -> Tensor {
    let n = a.rows();
    let mut out = Tensor::zeros(&[n, c.cols()]);
    for i in 0..n {
        let ar = a.row(i);
        let orow = out.row_mut(i);
        for j in 0..=i {
            micro::axpy(orow, c.row(j), micro::dot(ar, b.row(j)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::sketch::self_tensor_rows;
    use crate::attn::poly::poly_attention;
    use crate::attn::sketch::PolySketch;
    use crate::tensor::{axpy, dot};
    use crate::util::rng::Pcg;

    fn naive_linear(pq: &Tensor, pk: &Tensor, v: &Tensor) -> Tensor {
        let n = pq.rows();
        let h = v.cols();
        let mut out = Tensor::zeros(&[n, h]);
        for i in 0..n {
            let mut denom = 1.0;
            let orow = out.row_mut(i);
            for j in 0..=i {
                let w = dot(pq.row(i), pk.row(j));
                denom += w;
                axpy(orow, v.row(j), w);
            }
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
        out
    }

    #[test]
    fn generic_block_matches_naive() {
        let mut rng = Pcg::seeded(0);
        let (n, f, h) = (48, 6, 5);
        let pq = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let pk = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let want = naive_linear(&pq, &pk, &v);
        for block in [4, 8, 16, 48] {
            let got = linear_attention_block(&pq, &pk, &v, block);
            assert!(got.max_abs_diff(&want) < 1e-4, "block {block}");
        }
    }

    #[test]
    fn ragged_tail_matches_naive_and_padded() {
        // n = 29 against blocks that do not divide it: the native ragged
        // tail must agree with the naive oracle AND be bit-identical (on
        // real rows) to the historical zero-pad-then-truncate recipe.
        let mut rng = Pcg::seeded(5);
        let (n, f, h) = (29, 6, 5);
        let pq = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let pk = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let want = naive_linear(&pq, &pk, &v);
        for block in [4usize, 8, 16, 64] {
            let got = linear_attention_block(&pq, &pk, &v, block);
            assert!(got.max_abs_diff(&want) < 1e-4, "block {block}");

            let np = n.div_ceil(block) * block;
            let pad = |t: &Tensor| {
                let mut out = Tensor::zeros(&[np, t.cols()]);
                out.data_mut()[..t.len()].copy_from_slice(t.data());
                out
            };
            let padded = linear_attention_block(&pad(&pq), &pad(&pk), &pad(&v), block);
            for i in 0..n {
                assert_eq!(got.row(i), padded.row(i), "block {block} row {i}");
            }
        }
    }

    #[test]
    fn polysketch_block_matches_self_tensored_generic() {
        let mut rng = Pcg::seeded(1);
        let (n, h, rs) = (32, 8, 4);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let sk = PolySketch::sample(&mut rng, h, rs, 4);
        let lh = sk.half(&layernorm_rows(&q));
        let rh = sk.half(&layernorm_rows(&k));
        let got = polysketch_attention_block(&lh, &rh, &v, 8, None);
        let want = linear_attention_block(&self_tensor_rows(&lh),
                                          &self_tensor_rows(&rh), &v, 8);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn local_exact_single_block_equals_exact_poly() {
        // With one block covering the whole sequence, local-exact polysketch
        // degenerates to exact polynomial attention.
        let mut rng = Pcg::seeded(2);
        let (n, h, rs) = (16, 8, 4);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let sk = PolySketch::sample(&mut rng, h, rs, 4);
        let lh = sk.half(&layernorm_rows(&q));
        let rh = sk.half(&layernorm_rows(&k));
        let got = polysketch_attention_block(
            &lh, &rh, &v, n, Some(LocalExact { q: &q, k: &k, p: 4 }));
        let want = poly_attention(&q, &k, &v, 4);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn lt_mult_block_decomposition() {
        let mut rng = Pcg::seeded(3);
        let (n, f, h) = (24, 5, 3);
        let a = Tensor::gaussian(&mut rng, &[n, f]);
        let b = Tensor::gaussian(&mut rng, &[n, f]);
        let c = Tensor::gaussian(&mut rng, &[n, h]);
        // Check the un-normalized identity via the generic path by removing
        // normalization: compare numerators through one-hot value probes.
        let want = lt_mult_naive(&a, &b, &c);
        // Reconstruct numerator from linear_attention_block by multiplying
        // back the denominator obtained with an all-ones value column.
        let got_norm = linear_attention_block(&a, &b, &c, 8);
        let ones = Tensor::ones(&[n, 1]);
        let den = lt_mult_naive(&a, &b, &ones);
        let mut got = Tensor::zeros(&[n, h]);
        for i in 0..n {
            let d = 1.0 + den.at2(i, 0);
            for j in 0..h {
                got.set2(i, j, got_norm.at2(i, j) * d);
            }
        }
        assert!(got.max_abs_diff(&want) < 2e-3);
    }

    #[test]
    fn causality_of_block_algorithm() {
        let mut rng = Pcg::seeded(4);
        let (n, f, h) = (32, 4, 4);
        let pq = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let pk = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let v1 = Tensor::gaussian(&mut rng, &[n, h]);
        let mut v2 = v1.clone();
        for j in 0..h {
            v2.set2(n - 1, j, 7.0);
        }
        let a = linear_attention_block(&pq, &pk, &v1, 8);
        let b = linear_attention_block(&pq, &pk, &v2, 8);
        for i in 0..n - 1 {
            for j in 0..h {
                assert!((a.at2(i, j) - b.at2(i, j)).abs() < 1e-6);
            }
        }
    }
}
