//! Section 3.1/3.2: block-based lower-triangular multiplication.
//!
//! Computes lt(phi_q phi_k^T) [V | 1] in time linear in n: per block
//! H_l = phi_k_l^T [V_l|1], exclusive prefix Z_l = sum_{j<l} H_j, diagonal
//! P_l = lt(phi_q_l phi_k_l^T) [V_l|1], and row i of the result is
//! P_l[i'] + phi_q_i Z_l.  The all-ones column riding with V produces the
//! normalizer, so numerator and the paper's `1 +` denominator come out of
//! one pass.
//!
//! This is the native (pure rust) twin of the Pallas kernel in
//! python/compile/kernels/pallas/ — same math, used for property tests and
//! for latency benches at context lengths (up to 32k) that the interpreted
//! kernel cannot reach.

use crate::attn::poly::powi;
use crate::tensor::{axpy, dot, layernorm_rows, Tensor};

/// Generic causal linear attention over explicit feature maps.
///
/// phi_q, phi_k: (n, f); v: (n, h). Returns (n, h).
pub fn linear_attention_block(phi_q: &Tensor, phi_k: &Tensor, v: &Tensor,
                              block: usize) -> Tensor {
    let (n, f) = (phi_q.rows(), phi_q.cols());
    let h = v.cols();
    assert_eq!(phi_k.rows(), n);
    assert_eq!(v.rows(), n);
    assert!(n % block == 0, "n={n} % block={block} != 0");
    let hc = h + 1;
    let nb = n / block;

    let mut out = Tensor::zeros(&[n, h]);
    let mut z = vec![0.0f32; f * hc];           // prefix state Z
    let mut scores = vec![0.0f32; block * block];
    let mut pl = vec![0.0f32; block * hc];      // P_l + A_l Z_l

    for l in 0..nb {
        let base = l * block;
        // diagonal scores lt(phi_q_l phi_k_l^T)
        for bi in 0..block {
            let qi = phi_q.row(base + bi);
            let srow = &mut scores[bi * block..(bi + 1) * block];
            for bj in 0..=bi {
                srow[bj] = dot(qi, phi_k.row(base + bj));
            }
        }
        // pl = phi_q_l Z  (prefix contribution)
        matmul_into_rows(phi_q, base, block, &z, f, hc, &mut pl);
        // pl += lt(scores) [V_l | 1]
        for bi in 0..block {
            let prow = &mut pl[bi * hc..(bi + 1) * hc];
            let srow = &scores[bi * block..(bi + 1) * block];
            for bj in 0..=bi {
                let w = srow[bj];
                axpy(&mut prow[..h], v.row(base + bj), w);
                prow[h] += w;
            }
        }
        // emit normalized rows
        for bi in 0..block {
            let prow = &pl[bi * hc..(bi + 1) * hc];
            let inv = 1.0 / (1.0 + prow[h]);
            let orow = out.row_mut(base + bi);
            for c in 0..h {
                orow[c] = prow[c] * inv;
            }
        }
        // Z += phi_k_l^T [V_l | 1]
        for bj in 0..block {
            let krow = phi_k.row(base + bj);
            let vrow = v.row(base + bj);
            for (c, &kc) in krow.iter().enumerate() {
                if kc == 0.0 {
                    continue;
                }
                let zrow = &mut z[c * hc..(c + 1) * hc];
                axpy(&mut zrow[..h], vrow, kc);
                zrow[h] += kc;
            }
        }
    }
    out
}

/// pl = phi[base..base+block] @ z  where z is (f, hc) row-major.
fn matmul_into_rows(phi: &Tensor, base: usize, block: usize, z: &[f32],
                    f: usize, hc: usize, pl: &mut [f32]) {
    pl.fill(0.0);
    for bi in 0..block {
        let prow = &mut pl[bi * hc..(bi + 1) * hc];
        let qrow = phi.row(base + bi);
        for c in 0..f {
            let qv = qrow[c];
            if qv == 0.0 {
                continue;
            }
            axpy(prow, &z[c * hc..(c + 1) * hc], qv);
        }
    }
}

/// Local-exact configuration for [`polysketch_attention_block`].
pub struct LocalExact<'a> {
    /// Raw queries/keys (n, h) — layer norm applied inside.
    pub q: &'a Tensor,
    pub k: &'a Tensor,
    /// Polynomial degree p.
    pub p: u32,
}

/// Polysketch attention over half sketches L, R (n, rs).
///
/// Implicit features are the row self-tensors (rs^2-dim); the diagonal
/// block uses (L_l R_l^T)^2 — Section 3.1's O(b^2 rs) trick — or, with
/// `local`, the exact polynomial weights (Q_l K_l^T)^p of Section 3.2.
pub fn polysketch_attention_block(lh: &Tensor, rh: &Tensor, v: &Tensor,
                                  block: usize,
                                  local: Option<LocalExact>) -> Tensor {
    let (n, rs) = (lh.rows(), lh.cols());
    let h = v.cols();
    assert_eq!(rh.rows(), n);
    assert!(n % block == 0, "n={n} % block={block} != 0");
    let f = rs * rs;
    let hc = h + 1;
    let nb = n / block;

    let (qn, kn) = match &local {
        Some(le) => (Some(layernorm_rows(le.q)), Some(layernorm_rows(le.k))),
        None => (None, None),
    };

    let mut out = Tensor::zeros(&[n, h]);
    let mut z = vec![0.0f32; f * hc];
    let mut scores = vec![0.0f32; block * block];
    let mut pl = vec![0.0f32; block * hc];
    let mut phi_row = vec![0.0f32; f];

    for l in 0..nb {
        let base = l * block;
        // Diagonal block scores.
        match &local {
            Some(le) => {
                let (qn, kn) = (qn.as_ref().unwrap(), kn.as_ref().unwrap());
                for bi in 0..block {
                    let qi = qn.row(base + bi);
                    let srow = &mut scores[bi * block..(bi + 1) * block];
                    for bj in 0..=bi {
                        srow[bj] = powi(dot(qi, kn.row(base + bj)), le.p);
                    }
                }
            }
            None => {
                for bi in 0..block {
                    let li = lh.row(base + bi);
                    let srow = &mut scores[bi * block..(bi + 1) * block];
                    for bj in 0..=bi {
                        let s = dot(li, rh.row(base + bj));
                        srow[bj] = s * s; // (L R^T)^2: phi' never materialized
                    }
                }
            }
        }
        // Prefix contribution: phi_q_i Z with phi_q_i = l_i (x) l_i,
        // computed row-by-row into a scratch feature vector.
        for bi in 0..block {
            self_tensor_row(lh.row(base + bi), &mut phi_row);
            let prow = &mut pl[bi * hc..(bi + 1) * hc];
            prow.fill(0.0);
            for (c, &qv) in phi_row.iter().enumerate() {
                if qv == 0.0 {
                    continue;
                }
                axpy(prow, &z[c * hc..(c + 1) * hc], qv);
            }
        }
        // Diagonal contribution + emit.
        for bi in 0..block {
            let prow = &mut pl[bi * hc..(bi + 1) * hc];
            let srow = &scores[bi * block..(bi + 1) * block];
            for bj in 0..=bi {
                let w = srow[bj];
                axpy(&mut prow[..h], v.row(base + bj), w);
                prow[h] += w;
            }
            let inv = 1.0 / (1.0 + prow[h]);
            let orow = out.row_mut(base + bi);
            for c in 0..h {
                orow[c] = prow[c] * inv;
            }
        }
        // Z += phi_k_l^T [V_l | 1].
        for bj in 0..block {
            self_tensor_row(rh.row(base + bj), &mut phi_row);
            let vrow = v.row(base + bj);
            for (c, &kc) in phi_row.iter().enumerate() {
                if kc == 0.0 {
                    continue;
                }
                let zrow = &mut z[c * hc..(c + 1) * hc];
                axpy(&mut zrow[..h], vrow, kc);
                zrow[h] += kc;
            }
        }
    }
    out
}

/// Row self Kronecker product into scratch: the implicit phi' feature of a
/// half-sketch row. Shared with the per-token decode path (`infer::state`).
#[inline]
pub(crate) fn self_tensor_row(l: &[f32], out: &mut [f32]) {
    let r = l.len();
    debug_assert_eq!(out.len(), r * r);
    for a in 0..r {
        let la = l[a];
        let orow = &mut out[a * r..(a + 1) * r];
        for b in 0..r {
            orow[b] = la * l[b];
        }
    }
}

/// Naive lt(A B^T) C — oracle for the block algorithm's tests/benches.
pub fn lt_mult_naive(a: &Tensor, b: &Tensor, c: &Tensor) -> Tensor {
    let n = a.rows();
    let mut out = Tensor::zeros(&[n, c.cols()]);
    for i in 0..n {
        let ar = a.row(i);
        let orow = out.row_mut(i);
        for j in 0..=i {
            axpy(orow, c.row(j), dot(ar, b.row(j)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::sketch::self_tensor_rows;
    use crate::attn::poly::poly_attention;
    use crate::attn::sketch::PolySketch;
    use crate::util::rng::Pcg;

    fn naive_linear(pq: &Tensor, pk: &Tensor, v: &Tensor) -> Tensor {
        let n = pq.rows();
        let h = v.cols();
        let mut out = Tensor::zeros(&[n, h]);
        for i in 0..n {
            let mut denom = 1.0;
            let orow = out.row_mut(i);
            for j in 0..=i {
                let w = dot(pq.row(i), pk.row(j));
                denom += w;
                axpy(orow, v.row(j), w);
            }
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
        out
    }

    #[test]
    fn generic_block_matches_naive() {
        let mut rng = Pcg::seeded(0);
        let (n, f, h) = (48, 6, 5);
        let pq = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let pk = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let want = naive_linear(&pq, &pk, &v);
        for block in [4, 8, 16, 48] {
            let got = linear_attention_block(&pq, &pk, &v, block);
            assert!(got.max_abs_diff(&want) < 1e-4, "block {block}");
        }
    }

    #[test]
    fn polysketch_block_matches_self_tensored_generic() {
        let mut rng = Pcg::seeded(1);
        let (n, h, rs) = (32, 8, 4);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let sk = PolySketch::sample(&mut rng, h, rs, 4);
        let lh = sk.half(&layernorm_rows(&q));
        let rh = sk.half(&layernorm_rows(&k));
        let got = polysketch_attention_block(&lh, &rh, &v, 8, None);
        let want = linear_attention_block(&self_tensor_rows(&lh),
                                          &self_tensor_rows(&rh), &v, 8);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn local_exact_single_block_equals_exact_poly() {
        // With one block covering the whole sequence, local-exact polysketch
        // degenerates to exact polynomial attention.
        let mut rng = Pcg::seeded(2);
        let (n, h, rs) = (16, 8, 4);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let sk = PolySketch::sample(&mut rng, h, rs, 4);
        let lh = sk.half(&layernorm_rows(&q));
        let rh = sk.half(&layernorm_rows(&k));
        let got = polysketch_attention_block(
            &lh, &rh, &v, n, Some(LocalExact { q: &q, k: &k, p: 4 }));
        let want = poly_attention(&q, &k, &v, 4);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn lt_mult_block_decomposition() {
        let mut rng = Pcg::seeded(3);
        let (n, f, h) = (24, 5, 3);
        let a = Tensor::gaussian(&mut rng, &[n, f]);
        let b = Tensor::gaussian(&mut rng, &[n, f]);
        let c = Tensor::gaussian(&mut rng, &[n, h]);
        // Check the un-normalized identity via the generic path by removing
        // normalization: compare numerators through one-hot value probes.
        let want = lt_mult_naive(&a, &b, &c);
        // Reconstruct numerator from linear_attention_block by multiplying
        // back the denominator obtained with an all-ones value column.
        let got_norm = linear_attention_block(&a, &b, &c, 8);
        let ones = Tensor::ones(&[n, 1]);
        let den = lt_mult_naive(&a, &b, &ones);
        let mut got = Tensor::zeros(&[n, h]);
        for i in 0..n {
            let d = 1.0 + den.at2(i, 0);
            for j in 0..h {
                got.set2(i, j, got_norm.at2(i, j) * d);
            }
        }
        assert!(got.max_abs_diff(&want) < 2e-3);
    }

    #[test]
    fn causality_of_block_algorithm() {
        let mut rng = Pcg::seeded(4);
        let (n, f, h) = (32, 4, 4);
        let pq = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let pk = Tensor::gaussian(&mut rng, &[n, f]).map(f32::abs);
        let v1 = Tensor::gaussian(&mut rng, &[n, h]);
        let mut v2 = v1.clone();
        for j in 0..h {
            v2.set2(n - 1, j, 7.0);
        }
        let a = linear_attention_block(&pq, &pk, &v1, 8);
        let b = linear_attention_block(&pq, &pk, &v2, 8);
        for i in 0..n - 1 {
            for j in 0..h {
                assert!((a.at2(i, j) - b.at2(i, j)).abs() < 1e-6);
            }
        }
    }
}
