//! Performer (FAVOR+) baseline: positive orthogonal random features.

use crate::exec::pool;
use crate::tensor::{matmul_rowmat, micro, RowMat, Tensor};
use crate::util::rng::Pcg;
use crate::attn::block_lt::linear_attention_block;

/// Output elements (n · m) below which the feature map runs inline —
/// cheap per element, so the gate sits lower than the matmul family's.
const PAR_MIN_WORK: usize = 16 * 1024;

/// Positive random-feature map for the exponential kernel.
#[derive(Clone, Debug)]
pub struct PerformerFeatures {
    /// (h, m) projection matrix.
    pub w: Tensor,
}

impl PerformerFeatures {
    /// Sample `m` Gaussian features for dimension `h`; blocks of `h`
    /// features are orthogonalized (Gram–Schmidt) then rescaled to the
    /// expected Gaussian row norm — the "orthogonal random features" of
    /// Choromanski et al. (2020).
    pub fn sample(rng: &mut Pcg, h: usize, m: usize) -> Self {
        let mut w = Tensor::zeros(&[h, m]);
        let mut done = 0;
        while done < m {
            let take = (m - done).min(h);
            // Draw h x h Gaussian, orthogonalize its first `take` columns.
            let mut cols: Vec<Vec<f32>> = (0..take).map(|_| rng.gaussians(h)).collect();
            for c in 0..take {
                for prev in 0..c {
                    let proj = micro::dot(&cols[c], &cols[prev]);
                    let prev_col = cols[prev].clone();
                    micro::axpy(&mut cols[c], &prev_col, -proj);
                }
                let norm = micro::dot(&cols[c], &cols[c]).sqrt().max(1e-12);
                // Rescale to chi(h)-distributed norm like an iid Gaussian row.
                let target = chi_sample(rng, h);
                for x in cols[c].iter_mut() {
                    *x = *x / norm * target;
                }
            }
            for (ci, col) in cols.iter().enumerate() {
                for (row, &val) in col.iter().enumerate() {
                    w.set2(row, done + ci, val);
                }
            }
            done += take;
        }
        PerformerFeatures { w }
    }

    /// phi(x) = exp(w^T x - ||x||^2 / 2) / sqrt(m): (n, h) -> (n, m).
    /// Row-parallel (rows are independent; bitwise thread-count
    /// invariant), generic over [`RowMat`] so strided per-head views of
    /// fused projections map without a copy.
    pub fn apply(&self, x: &impl RowMat) -> Tensor {
        let (n, h) = (x.rows(), x.cols());
        assert_eq!(h, self.w.rows());
        let m = self.w.cols();
        let proj = matmul_rowmat(x, &self.w);
        let mut out = Tensor::zeros(&[n, m]);
        if out.is_empty() {
            return out;
        }
        let scale = 1.0 / (m as f32).sqrt();
        let kernel = |row0: usize, chunk: &mut [f32]| {
            for (r, orow) in chunk.chunks_mut(m).enumerate() {
                let i = row0 + r;
                let sq = 0.5 * micro::dot(x.row(i), x.row(i));
                for (o, &p) in orow.iter_mut().zip(proj.row(i)) {
                    *o = (p - sq).exp() * scale;
                }
            }
        };
        if n * m < PAR_MIN_WORK {
            kernel(0, out.data_mut());
        } else {
            pool::par_row_chunks(out.data_mut(), m, 8, kernel);
        }
        out
    }
}

impl PerformerFeatures {
    /// Feature map of a single row: (h,) -> (m,).  The per-token hot path
    /// of the decoding subsystem; row-wise identical to
    /// [`PerformerFeatures::apply`] on a one-row tensor.
    pub fn apply_row(&self, row: &[f32]) -> Vec<f32> {
        let t = Tensor::from_vec(&[1, row.len()], row.to_vec());
        self.apply(&t).into_vec()
    }

    /// VJP of [`PerformerFeatures::apply_row`]: with φ_j(x) =
    /// exp(w_j·x − ||x||²/2)/√m, dφ_j/dx = φ_j(x)·(w_j − x), so
    /// `dx = Σ_j d_j φ_j (w_j − x)`.  `mapped` is the forward output
    /// (recomputed by the caller); the training path through every
    /// performer head runs through here.
    pub fn apply_row_vjp(&self, row: &[f32], mapped: &[f32], d_mapped: &[f32]) -> Vec<f32> {
        let h = row.len();
        let m = self.w.cols();
        debug_assert_eq!(mapped.len(), m);
        debug_assert_eq!(d_mapped.len(), m);
        // dx = W·c − (Σ c)·x with c = d ⊙ φ: one elementwise product,
        // one fused dot-rows over W's packed rows, one axpy.
        let mut cvec = d_mapped.to_vec();
        micro::mul_inplace(&mut cvec, mapped);
        let csum = micro::sum(&cvec);
        let mut dx = vec![0.0f32; h];
        micro::dot_rows(&cvec, self.w.data(), &mut dx);
        micro::axpy(&mut dx, row, -csum);
        dx
    }
}

fn chi_sample(rng: &mut Pcg, h: usize) -> f32 {
    let s: f32 = (0..h).map(|_| {
        let g = rng.gaussian();
        g * g
    }).sum();
    s.sqrt()
}

/// Full Performer attention: features + block lt-multiplication (the
/// unified linear engine underneath; ragged n handled natively).
pub fn performer_attention(q: &Tensor, k: &Tensor, v: &Tensor,
                           feats: &PerformerFeatures, block: usize) -> Tensor {
    let pq = feats.apply(q);
    let pk = feats.apply(k);
    linear_attention_block(&pq, &pk, v, block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::softmax::softmax_attention;
    use crate::tensor::dot;

    #[test]
    fn features_positive() {
        let mut rng = Pcg::seeded(0);
        let f = PerformerFeatures::sample(&mut rng, 8, 32);
        let x = Tensor::gaussian(&mut rng, &[16, 8]);
        for &v in f.apply(&x).data() {
            assert!(v > 0.0);
        }
    }

    #[test]
    fn apply_row_bitwise_matches_apply() {
        let mut rng = Pcg::seeded(4);
        let f = PerformerFeatures::sample(&mut rng, 8, 16);
        let x = Tensor::gaussian(&mut rng, &[5, 8]);
        let full = f.apply(&x);
        for i in 0..5 {
            assert_eq!(f.apply_row(x.row(i)).as_slice(), full.row(i));
        }
    }

    #[test]
    fn apply_row_vjp_matches_finite_difference() {
        let mut rng = Pcg::seeded(8);
        let f = PerformerFeatures::sample(&mut rng, 8, 16);
        let x: Vec<f32> = rng.gaussians(8).iter().map(|v| v * 0.5).collect();
        let c: Vec<f32> = rng.gaussians(16);
        let loss = |x: &[f32]| -> f64 {
            f.apply_row(x).iter().zip(&c).map(|(&p, &w)| (p as f64) * (w as f64)).sum()
        };
        let mapped = f.apply_row(&x);
        let an = f.apply_row_vjp(&x, &mapped, &c);
        let eps = 1e-3f32;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
            let a = an[i] as f64;
            assert!(
                (fd - a).abs() <= 1e-2 * (1.0 + fd.abs().max(a.abs())),
                "coord {i}: fd {fd} vs analytic {a}"
            );
        }
    }

    #[test]
    fn kernel_estimate_tracks_exponential() {
        // <phi(x), phi(y)> estimates exp(<x,y>) for small-norm inputs.
        let mut rng = Pcg::seeded(1);
        let f = PerformerFeatures::sample(&mut rng, 8, 2048);
        let x = Tensor::gaussian(&mut rng, &[6, 8]).scale(0.3);
        let phi = f.apply(&x);
        let approx = phi.matmul_t(&phi);
        for i in 0..6 {
            for j in 0..6 {
                let want = dot(x.row(i), x.row(j)).exp();
                let got = approx.at2(i, j);
                assert!((got - want).abs() / want < 0.35,
                        "({i},{j}): got {got} want {want}");
            }
        }
    }

    #[test]
    fn approximates_unscaled_softmax_loosely() {
        // With small inputs the Performer output should correlate with
        // softmax attention output (scale=1 variant).
        let mut rng = Pcg::seeded(2);
        let (n, h) = (16, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]).scale(0.3);
        let k = Tensor::gaussian(&mut rng, &[n, h]).scale(0.3);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let f = PerformerFeatures::sample(&mut rng, h, 1024);
        let got = performer_attention(&q, &k, &v, &f, 8);
        let want = softmax_attention(&q.clone().scale((h as f32).sqrt()), &k, &v);
        // Correlation, not equality: performer has the 1+ denominator.
        let mut num = 0.0f64;
        let (mut da, mut db) = (0.0f64, 0.0f64);
        for (a, b) in got.data().iter().zip(want.data()) {
            num += (*a as f64) * (*b as f64);
            da += (*a as f64).powi(2);
            db += (*b as f64).powi(2);
        }
        let corr = num / (da.sqrt() * db.sqrt());
        assert!(corr > 0.7, "corr {corr}");
    }
}
