//! Softmax attention baselines: naive O(n^2) and FlashAttention-style
//! blocked streaming (the paper's speed baseline in Figures 1/4, Table 4).
//!
//! Both kernels are query-row (resp. query-block) parallel on the
//! deterministic backend (`exec::pool`): each output row depends only on
//! its own scores/accumulators, so the partition changes wall time, never
//! bytes.  Both are generic over [`RowMat`], so they run unchanged on
//! owned tensors and on strided per-head views of fused projections, and
//! both handle sequence lengths that are not block multiples natively
//! (the final query/key blocks are simply shorter) — callers never pad.

use crate::exec::pool;
use crate::tensor::{micro, RowMat, Tensor};

/// Quadratic work (n² · h MACs) below which the kernels run inline.
const PAR_MIN_WORK: usize = 32 * 1024;

/// Naive causal softmax attention; materializes each score row.
/// Row-parallel: rows are independent (private score buffer per chunk).
pub fn softmax_attention(q: &impl RowMat, k: &impl RowMat, v: &impl RowMat) -> Tensor {
    let (n, h) = (q.rows(), q.cols());
    assert_eq!(k.rows(), n);
    assert_eq!(v.rows(), n);
    let hv = v.cols();
    let scale = 1.0 / (h as f32).sqrt();
    let mut out = Tensor::zeros(&[n, hv]);
    if out.is_empty() {
        return out;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        let mut scores = vec![0.0f32; n];
        for (r, orow) in chunk.chunks_mut(hv).enumerate() {
            let i = row0 + r;
            let qi = q.row(i);
            let mut mx = f32::NEG_INFINITY;
            for j in 0..=i {
                scores[j] = micro::dot(qi, k.row(j)) * scale;
                mx = mx.max(scores[j]);
            }
            for s in scores[..=i].iter_mut() {
                *s = (*s - mx).exp();
            }
            let sum = micro::sum(&scores[..=i]);
            for j in 0..=i {
                micro::axpy(orow, v.row(j), scores[j] / sum);
            }
        }
    };
    if n * n * h < PAR_MIN_WORK {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_chunks(out.data_mut(), hv, 4, kernel);
    }
    out
}

/// Blocked causal softmax with the online max/sum recurrence — the same
/// algorithm FlashAttention executes on an accelerator, expressed on the
/// CPU so the quadratic cost curve of the baseline is measured with a
/// cache-friendly, honest implementation rather than a strawman.  The
/// final query/key blocks may be ragged; results are identical to the
/// zero-padded computation on real rows.
pub fn flash_attention(
    q: &impl RowMat,
    k: &impl RowMat,
    v: &impl RowMat,
    block: usize,
) -> Tensor {
    let (n, h) = (q.rows(), q.cols());
    let hv = v.cols();
    assert_eq!(k.rows(), n);
    assert_eq!(v.rows(), n);
    let block = block.max(1).min(n.max(1));
    let mut out = Tensor::zeros(&[n, hv]);
    if out.is_empty() {
        return out;
    }
    // Query blocks are independent (online max/sum state is per q-block),
    // so chunks of whole q-blocks parallelize with identical per-block
    // math; `par_row_groups` keeps chunk boundaries block-aligned even
    // when the tail block is ragged.  Scratch is allocated once per
    // chunk, not per block, to keep the hot path's allocation count flat.
    let kernel = |qb0: usize, chunk: &mut [f32]| {
        let mut scratch = FlashScratch::new(block, hv);
        let mut off = 0;
        let mut qb = qb0;
        while off < chunk.len() {
            let qlen = block.min(n - qb * block);
            flash_query_block(q, k, v, block, qb, qlen, &mut chunk[off..off + qlen * hv], &mut scratch);
            off += qlen * hv;
            qb += 1;
        }
    };
    if n * n * h < PAR_MIN_WORK {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_groups(out.data_mut(), hv, block, 1, kernel);
    }
    out
}

/// Per-chunk scratch of the flash recurrence (reset per query block).
struct FlashScratch {
    m: Vec<f32>,
    s: Vec<f32>,
    acc: Vec<f32>,
    tile: Vec<f32>,
}

impl FlashScratch {
    fn new(block: usize, hv: usize) -> FlashScratch {
        FlashScratch {
            m: vec![f32::NEG_INFINITY; block],
            s: vec![0.0f32; block],
            acc: vec![0.0f32; block * hv],
            tile: vec![0.0f32; block * block],
        }
    }
}

/// One query block (of `qlen <= block` real rows) of the online-softmax
/// recurrence; writes the block's `qlen x hv` output rows.
#[allow(clippy::too_many_arguments)]
fn flash_query_block(
    q: &impl RowMat,
    k: &impl RowMat,
    v: &impl RowMat,
    block: usize,
    qb: usize,
    qlen: usize,
    orows: &mut [f32],
    scratch: &mut FlashScratch,
) {
    let n = k.rows();
    let h = q.cols();
    let hv = v.cols();
    let scale = 1.0 / (h as f32).sqrt();

    let FlashScratch { m, s, acc, tile } = scratch;
    m.fill(f32::NEG_INFINITY);
    s.fill(0.0);
    acc.fill(0.0);

    let q0 = qb * block;
    for kb in 0..=qb {
        let k0 = kb * block;
        let klen = block.min(n - k0);
        // score tile
        for bi in 0..qlen {
            let qi = q.row(q0 + bi);
            let trow = &mut tile[bi * block..bi * block + klen];
            for (bj, t) in trow.iter_mut().enumerate() {
                let j = k0 + bj;
                *t = if j <= q0 + bi { micro::dot(qi, k.row(j)) * scale } else { f32::NEG_INFINITY };
            }
        }
        // online rescale + accumulate
        for bi in 0..qlen {
            let trow = &tile[bi * block..bi * block + klen];
            let tile_max = micro::row_max(trow);
            let m_new = m[bi].max(tile_max);
            if m_new == f32::NEG_INFINITY {
                continue;
            }
            let corr = if m[bi] == f32::NEG_INFINITY { 0.0 } else { (m[bi] - m_new).exp() };
            let arow = &mut acc[bi * hv..(bi + 1) * hv];
            micro::scale_inplace(arow, corr);
            let mut local_sum = 0.0;
            for (bj, &t) in trow.iter().enumerate() {
                if t == f32::NEG_INFINITY {
                    continue;
                }
                let p = (t - m_new).exp();
                local_sum += p;
                micro::axpy(arow, v.row(k0 + bj), p);
            }
            s[bi] = s[bi] * corr + local_sum;
            m[bi] = m_new;
        }
    }
    for bi in 0..qlen {
        let orow = &mut orows[bi * hv..(bi + 1) * hv];
        let arow = &acc[bi * hv..(bi + 1) * hv];
        micro::scale(orow, arow, 1.0 / s[bi]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn flash_matches_naive() {
        let mut rng = Pcg::seeded(0);
        let (n, h) = (32, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let a = softmax_attention(&q, &k, &v);
        for block in [4, 8, 16, 32] {
            let b = flash_attention(&q, &k, &v, block);
            assert!(a.max_abs_diff(&b) < 1e-4, "block {block}");
        }
    }

    #[test]
    fn ragged_flash_matches_naive() {
        // n not a multiple of block: the ragged tail blocks must change
        // nothing — every row agrees with the row-streaming oracle.
        let mut rng = Pcg::seeded(7);
        let (n, h) = (29, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let a = softmax_attention(&q, &k, &v);
        for block in [4, 8, 16, 64] {
            let b = flash_attention(&q, &k, &v, block);
            assert!(a.max_abs_diff(&b) < 1e-4, "block {block}");
        }
    }

    #[test]
    fn strided_views_match_owned_tensors() {
        // Head views of a fused projection must produce the exact bytes
        // the copied per-head tensors produce.
        let mut rng = Pcg::seeded(9);
        let (n, heads, hd) = (24, 2, 8);
        let q = Tensor::gaussian(&mut rng, &[n, heads * hd]);
        let k = Tensor::gaussian(&mut rng, &[n, heads * hd]);
        let v = Tensor::gaussian(&mut rng, &[n, heads * hd]);
        for hi in 0..heads {
            let (qv, kv, vv) =
                (q.head_views(heads)[hi], k.head_views(heads)[hi], v.head_views(heads)[hi]);
            let (qc, kc, vc) = (qv.to_tensor(), kv.to_tensor(), vv.to_tensor());
            assert_eq!(softmax_attention(&qv, &kv, &vv), softmax_attention(&qc, &kc, &vc));
            assert_eq!(flash_attention(&qv, &kv, &vv, 8), flash_attention(&qc, &kc, &vc, 8));
        }
    }

    #[test]
    fn parallel_kernels_bitwise_match_serial() {
        // n²h clears PAR_MIN_WORK, so the pooled paths actually engage.
        let mut rng = Pcg::seeded(5);
        let (n, h) = (128, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let pooled = (softmax_attention(&q, &k, &v), flash_attention(&q, &k, &v, 16));
        let inline = crate::exec::pool::serial(|| {
            (softmax_attention(&q, &k, &v), flash_attention(&q, &k, &v, 16))
        });
        assert_eq!(pooled.0, inline.0);
        assert_eq!(pooled.1, inline.1);
    }

    #[test]
    fn rows_are_convex_combinations() {
        let mut rng = Pcg::seeded(1);
        let (n, h) = (16, 4);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        // v = identity-ish: attention output row sums must be 1.
        let mut v = Tensor::zeros(&[n, 1]);
        for i in 0..n {
            v.set2(i, 0, 1.0);
        }
        let out = softmax_attention(&q, &k, &v);
        for i in 0..n {
            assert!((out.at2(i, 0) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn first_row_copies_first_value() {
        let mut rng = Pcg::seeded(2);
        let (n, h) = (8, 4);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        let out = softmax_attention(&q, &k, &v);
        for j in 0..h {
            assert!((out.at2(0, j) - v.at2(0, j)).abs() < 1e-6);
        }
    }
}
