//! Native implementation of Algorithm 1 (polynomial sketches).
//!
//! Mirrors python/compile/kernels/sketch.py exactly: the same recursion,
//! the same Gaussian-consumption order, the same sqrt(1/r) scaling —
//! property tests in this module assert the paper's guarantees (Theorem 1.1
//! non-negativity, AMM error decay with r).

use crate::exec::pool;
use crate::tensor::{micro, Tensor};
use crate::util::rng::Pcg;

/// Output elements (n · r²) below which `self_tensor_rows` runs inline —
/// cheap per element, so the gate sits lower than the matmul family's.
const PAR_MIN_WORK: usize = 16 * 1024;

/// Number of Gaussian matrices PolySketchWithNegativity(., r, p) consumes:
/// count(p) = 2 (p - 1); the non-negative map of degree p consumes p - 2.
pub fn num_projections(p: usize) -> usize {
    assert!(p.is_power_of_two(), "degree must be power of two, got {p}");
    if p == 1 {
        0
    } else {
        2 * num_projections(p / 2) + 2
    }
}

/// Shapes of the Gaussian matrices in consumption order ((h,r) leaves,
/// (r,r) above).
pub fn projection_shapes(h: usize, r: usize, p: usize) -> Vec<(usize, usize)> {
    assert!(p.is_power_of_two());
    if p == 1 {
        return vec![];
    }
    let sub = projection_shapes(h, r, p / 2);
    let inner = if p == 2 { h } else { r };
    let mut out = sub.clone();
    out.extend(sub);
    out.push((inner, r));
    out.push((inner, r));
    out
}

/// The sketch: Gaussian stack + sizes. Construct once, apply to Q and K —
/// sharing the same instance between Q and K is required for correctness.
#[derive(Clone, Debug)]
pub struct PolySketch {
    pub r: usize,
    pub p: usize,
    gs: Vec<Tensor>,
}

impl PolySketch {
    /// Sample the projection stack for vectors of dim `h`, sketch size `r`,
    /// kernel degree `p` (the recursion itself runs at degree p/2 — the
    /// self-tensoring squares it back, Theorem 2.4).
    pub fn sample(rng: &mut Pcg, h: usize, r: usize, p: usize) -> Self {
        assert!(p >= 2 && p.is_power_of_two());
        let gs = projection_shapes(h, r, p / 2)
            .into_iter()
            .map(|(a, b)| Tensor::gaussian(rng, &[a, b]))
            .collect();
        PolySketch { r, p, gs }
    }

    /// Half sketch L = PolySketchWithNegativity(A, r, p/2): (n, r).
    /// The implicit non-negative feature map is the row-wise self-tensor.
    pub fn half(&self, a: &Tensor) -> Tensor {
        self.pswn(a, &self.gs, self.p / 2)
    }

    /// Full non-negative feature map phi'(A) = half(A)^{(x)2}: (n, r^2).
    pub fn nonnegative(&self, a: &Tensor) -> Tensor {
        self_tensor_rows(&self.half(a))
    }

    /// Half sketch of a single (already-normalized) row: (h,) -> (r,).
    /// Bitwise row-wise identical to [`PolySketch::half`] on a one-row
    /// tensor.  Convenience wrapper over [`PolySketch::half_row_scratch`]
    /// with throwaway scratch — the decode hot path holds a
    /// [`HalfRowScratch`] instead and skips the per-call allocations.
    pub fn half_row(&self, row: &[f32]) -> Vec<f32> {
        self.half_row_scratch(row, &mut HalfRowScratch::default())
    }

    /// [`PolySketch::half_row`] with caller-owned scratch: the recursion's
    /// intermediates live in `scratch` and are reused across calls, so the
    /// per-token × layer × head decode path allocates only the returned
    /// sketch row.  Same Gaussian-consumption order, same operation order
    /// (including the matmul zero-skip) as the tensor path — the parity
    /// test pins bitwise equality with [`PolySketch::half`].
    pub fn half_row_scratch(&self, row: &[f32], scratch: &mut HalfRowScratch) -> Vec<f32> {
        let d = self.p / 2;
        if d == 1 {
            return row.to_vec();
        }
        // 3 buffers (two child results + one projection temp) per level.
        let levels = d.trailing_zeros() as usize;
        if scratch.bufs.len() < 3 * levels {
            scratch.bufs.resize_with(3 * levels, Vec::new);
        }
        let mut out = vec![0.0f32; self.r];
        self.pswn_row(row, &self.gs, d, &mut scratch.bufs, &mut out);
        out
    }

    /// Row twin of [`PolySketch::pswn`]: out = PolySketchWithNegativity of
    /// one row at degree `d`, using `scratch` (>= 3·log2(d) buffers) for
    /// intermediates.
    fn pswn_row(&self, a: &[f32], gs: &[Tensor], d: usize, scratch: &mut [Vec<f32>], out: &mut [f32]) {
        debug_assert!(d >= 2 && d.is_power_of_two());
        let n_sub = num_projections(d / 2);
        let g1 = &gs[2 * n_sub];
        let g2 = &gs[2 * n_sub + 1];
        let (head, tail) = scratch.split_at_mut(3);
        let (c1, rest) = head.split_first_mut().expect("scratch level");
        let (c2, rest) = rest.split_first_mut().expect("scratch level");
        let tmp = &mut rest[0];
        let (m1, m2): (&[f32], &[f32]) = if d == 2 {
            // Children are the degree-1 base case: the row itself.
            (a, a)
        } else {
            c1.clear();
            c1.resize(self.r, 0.0);
            self.pswn_row(a, &gs[..n_sub], d / 2, tail, c1);
            c2.clear();
            c2.resize(self.r, 0.0);
            self.pswn_row(a, &gs[n_sub..2 * n_sub], d / 2, tail, c2);
            (c1.as_slice(), c2.as_slice())
        };
        // out = (m1 @ g1) ⊙ (m2 @ g2) · r^{-1/2}, in exactly the tensor
        // path's operation order: matmul rows accumulate in column order
        // with the zero-skip, hadamard multiplies, scale multiplies last.
        tmp.clear();
        tmp.resize(self.r, 0.0);
        matvec(m1, g1, out);
        matvec(m2, g2, tmp);
        micro::mul_inplace(out, tmp);
        micro::scale_inplace(out, 1.0 / (self.r as f32).sqrt());
    }

    /// VJP of [`PolySketch::half_row`]: gradient of the half sketch with
    /// respect to the (already-normalized) input row.  The recursion is a
    /// composition of fixed linear projections and elementwise products,
    /// so the backward is the mirrored recursion: `out = (m1 G1) ⊙ (m2 G2)
    /// · r^{-1/2}` gives `dm1 = G1 (d_out ⊙ m2G2) · r^{-1/2}` (and
    /// symmetrically), with child gradients summed at the shared input.
    /// The training path through every polysketch head runs through here.
    pub fn half_row_vjp(&self, row: &[f32], d_out: &[f32]) -> Vec<f32> {
        let d = self.p / 2;
        let mut da = vec![0.0f32; row.len()];
        if d == 1 {
            // Degree-1 base case: the half sketch is the row itself.
            da.copy_from_slice(d_out);
            return da;
        }
        self.pswn_row_vjp(row, &self.gs, d, d_out, &mut da);
        da
    }

    /// Allocating forward of `pswn_row` for the backward pass (the
    /// training path recomputes intermediates instead of taping them).
    /// Delegates to the *same* recursion the forward runs — bitwise
    /// identical by construction, never by hand-kept parallel code.
    fn pswn_row_alloc(&self, a: &[f32], gs: &[Tensor], d: usize) -> Vec<f32> {
        if d == 1 {
            return a.to_vec();
        }
        let levels = d.trailing_zeros() as usize;
        let mut scratch = vec![Vec::new(); 3 * levels];
        let mut out = vec![0.0f32; self.r];
        self.pswn_row(a, gs, d, &mut scratch, &mut out);
        out
    }

    fn pswn_row_vjp(&self, a: &[f32], gs: &[Tensor], d: usize, d_out: &[f32], da: &mut [f32]) {
        debug_assert!(d >= 2 && d.is_power_of_two());
        let n_sub = num_projections(d / 2);
        let g1 = &gs[2 * n_sub];
        let g2 = &gs[2 * n_sub + 1];
        let (m1, m2): (Vec<f32>, Vec<f32>) = if d == 2 {
            (a.to_vec(), a.to_vec())
        } else {
            (
                self.pswn_row_alloc(a, &gs[..n_sub], d / 2),
                self.pswn_row_alloc(a, &gs[n_sub..2 * n_sub], d / 2),
            )
        };
        let mut u = vec![0.0f32; self.r];
        let mut w = vec![0.0f32; self.r];
        matvec(&m1, g1, &mut u);
        matvec(&m2, g2, &mut w);
        let s = 1.0 / (self.r as f32).sqrt();
        let du: Vec<f32> = d_out.iter().zip(&w).map(|(&d0, &wv)| d0 * wv * s).collect();
        let dw: Vec<f32> = d_out.iter().zip(&u).map(|(&d0, &uv)| d0 * uv * s).collect();
        // dm = G · du — fused dot-rows over the packed Gaussian rows.
        let mut dm1 = vec![0.0f32; m1.len()];
        micro::dot_rows(&du, g1.data(), &mut dm1);
        let mut dm2 = vec![0.0f32; m2.len()];
        micro::dot_rows(&dw, g2.data(), &mut dm2);
        if d == 2 {
            for (o, (x, y)) in da.iter_mut().zip(dm1.iter().zip(&dm2)) {
                *o += x + y;
            }
        } else {
            self.pswn_row_vjp(a, &gs[..n_sub], d / 2, &dm1, da);
            self.pswn_row_vjp(a, &gs[n_sub..2 * n_sub], d / 2, &dm2, da);
        }
    }

    fn pswn(&self, a: &Tensor, gs: &[Tensor], d: usize) -> Tensor {
        if d == 1 {
            return a.clone();
        }
        let n_sub = num_projections(d / 2);
        let m1 = self.pswn(a, &gs[..n_sub], d / 2);
        let m2 = self.pswn(a, &gs[n_sub..2 * n_sub], d / 2);
        let g1 = &gs[2 * n_sub];
        let g2 = &gs[2 * n_sub + 1];
        let prod = m1.matmul(g1).hadamard(&m2.matmul(g2));
        prod.scale(1.0 / (self.r as f32).sqrt())
    }
}

/// Reusable intermediates for [`PolySketch::half_row_scratch`].  Contents
/// are overwritten before every read, so cloning (decode states are
/// `Clone` for the prompt cache) just carries capacity, never data.
#[derive(Clone, Debug, Default)]
pub struct HalfRowScratch {
    bufs: Vec<Vec<f32>>,
}

/// out = a @ g for one row — the m=1 case of `tensor::matmul_into`, with
/// the identical accumulation order and zero-skip (bitwise parity).
fn matvec(a: &[f32], g: &Tensor, out: &mut [f32]) {
    out.fill(0.0);
    micro::gemm_row(out, a, g.data());
}

/// Row-wise self Kronecker product: (n, r) -> (n, r^2).  Row-parallel;
/// rows are independent so bytes never depend on the thread count.
pub fn self_tensor_rows(m: &Tensor) -> Tensor {
    let (n, r) = (m.rows(), m.cols());
    let mut out = Tensor::zeros(&[n, r * r]);
    if out.is_empty() {
        return out;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (i, orow) in chunk.chunks_mut(r * r).enumerate() {
            let row = m.row(row0 + i);
            micro::outer(orow, row, row);
        }
    };
    if n * r * r < PAR_MIN_WORK {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_chunks(out.data_mut(), r * r, 8, kernel);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dot, layernorm_rows};
    use crate::attn::poly::powi;

    fn unit_rows(rng: &mut Pcg, n: usize, h: usize) -> Tensor {
        let mut t = Tensor::gaussian(rng, &[n, h]);
        for i in 0..n {
            let norm = dot(t.row(i), t.row(i)).sqrt();
            for v in t.row_mut(i) {
                *v /= norm;
            }
        }
        t
    }

    #[test]
    fn projection_counts_match_python() {
        assert_eq!(num_projections(1), 0);
        assert_eq!(num_projections(2), 2);
        assert_eq!(num_projections(4), 6);
        assert_eq!(projection_shapes(8, 4, 2), vec![(8, 4), (8, 4)]);
        assert_eq!(
            projection_shapes(8, 4, 4),
            vec![(8, 4), (8, 4), (8, 4), (8, 4), (4, 4), (4, 4)]
        );
    }

    #[test]
    fn nonnegativity_theorem_1_1() {
        let mut rng = Pcg::seeded(0);
        for p in [2usize, 4, 8] {
            let sk = PolySketch::sample(&mut rng, 8, 8, p);
            let q = Tensor::gaussian(&mut rng, &[24, 8]);
            let k = Tensor::gaussian(&mut rng, &[24, 8]);
            let pq = sk.nonnegative(&q);
            let pk = sk.nonnegative(&k);
            let w = pq.matmul_t(&pk);
            for &x in w.data() {
                assert!(x >= -1e-5, "negative sketched weight {x} at p={p}");
            }
        }
    }

    #[test]
    fn approximates_polynomial_kernel() {
        let mut rng = Pcg::seeded(1);
        let x = unit_rows(&mut rng, 48, 8);
        let sk = PolySketch::sample(&mut rng, 8, 32, 4);
        let half = sk.half(&x);
        let approx = {
            let s = half.matmul_t(&half);
            s.map(|v| v * v)
        };
        let exact = x.matmul_t(&x).map(|v| powi(v, 4));
        // The guarantee is Frobenius/average (Definition 2.1), not
        // entrywise — assert the RMSE, not the max deviation.
        let rmse = {
            let d: f32 = approx
                .data()
                .iter()
                .zip(exact.data())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            (d / approx.len() as f32).sqrt()
        };
        assert!(rmse < 0.4, "rmse {rmse}");
    }

    #[test]
    fn error_decays_with_sketch_size() {
        let mut rng = Pcg::seeded(2);
        let x = unit_rows(&mut rng, 48, 8);
        let exact = x.matmul_t(&x).map(|v| powi(v, 4));
        let rmse = |r: usize, rng: &mut Pcg| -> f32 {
            let sk = PolySketch::sample(rng, 8, r, 4);
            let half = sk.half(&x);
            let approx = half.matmul_t(&half).map(|v| v * v);
            let d: f32 = approx
                .data()
                .iter()
                .zip(exact.data())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            (d / approx.len() as f32).sqrt()
        };
        let e_small = rmse(4, &mut rng);
        let e_big = rmse(64, &mut rng);
        assert!(e_big < e_small, "r=4 {e_small} vs r=64 {e_big}");
    }

    #[test]
    fn half_consistent_with_nonnegative() {
        let mut rng = Pcg::seeded(3);
        let sk = PolySketch::sample(&mut rng, 8, 4, 4);
        let x = Tensor::gaussian(&mut rng, &[10, 8]);
        let half = sk.half(&x);
        let full = sk.nonnegative(&x);
        assert!(self_tensor_rows(&half).max_abs_diff(&full) < 1e-6);
    }

    #[test]
    fn half_row_bitwise_matches_half() {
        let mut rng = Pcg::seeded(5);
        let sk = PolySketch::sample(&mut rng, 8, 8, 4);
        let x = Tensor::gaussian(&mut rng, &[6, 8]);
        let full = sk.half(&x);
        for i in 0..6 {
            assert_eq!(sk.half_row(x.row(i)).as_slice(), full.row(i));
        }
    }

    #[test]
    fn half_row_scratch_reuse_bitwise_matches_half() {
        // The decode hot path reuses one scratch across every token: the
        // reused-buffer results must stay bitwise equal to the tensor
        // path, at every degree the recursion exercises (p = 2 is the
        // d == 1 base case, p = 8 recurses two levels).
        let mut rng = Pcg::seeded(6);
        for p in [2usize, 4, 8] {
            let sk = PolySketch::sample(&mut rng, 8, 8, p);
            let x = Tensor::gaussian(&mut rng, &[7, 8]);
            let full = sk.half(&x);
            let mut scratch = HalfRowScratch::default();
            for i in 0..7 {
                let got = sk.half_row_scratch(x.row(i), &mut scratch);
                assert_eq!(got.as_slice(), full.row(i), "p={p} row {i}");
            }
        }
    }

    #[test]
    fn half_row_vjp_matches_finite_difference() {
        // Central difference against the analytic VJP at every degree the
        // recursion exercises (p = 2 is the base case, p = 8 is two
        // recursion levels).
        let mut rng = Pcg::seeded(9);
        for p in [2usize, 4, 8] {
            let sk = PolySketch::sample(&mut rng, 8, 4, p);
            let x: Vec<f32> = rng.gaussians(8);
            // p = 2 is the degree-1 base case: the half sketch is the row
            // itself (length h), not an r-dim sketch — size the cotangent
            // to the actual output.
            let c: Vec<f32> = rng.gaussians(sk.half_row(&x).len());
            let loss = |x: &[f32]| -> f64 {
                sk.half_row(x).iter().zip(&c).map(|(&h, &w)| (h as f64) * (w as f64)).sum()
            };
            let an = sk.half_row_vjp(&x, &c);
            let eps = 1e-3f32;
            for i in 0..x.len() {
                let mut xp = x.clone();
                xp[i] += eps;
                let mut xm = x.clone();
                xm[i] -= eps;
                let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
                let a = an[i] as f64;
                assert!(
                    (fd - a).abs() <= 1e-2 * (1.0 + fd.abs().max(a.abs())),
                    "p={p} coord {i}: fd {fd} vs analytic {a}"
                );
            }
        }
    }

    #[test]
    fn layernormed_inputs_keep_norms_bounded() {
        // After LN, row norms are ~sqrt(h); sketched kernel values stay
        // finite — the regime the model actually runs in.
        let mut rng = Pcg::seeded(4);
        let sk = PolySketch::sample(&mut rng, 8, 16, 4);
        let x = layernorm_rows(&Tensor::gaussian(&mut rng, &[16, 8]).scale(100.0));
        let half = sk.half(&x);
        for &v in half.data() {
            assert!(v.is_finite());
        }
    }
}
