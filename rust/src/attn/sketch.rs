//! Native implementation of Algorithm 1 (polynomial sketches).
//!
//! Mirrors python/compile/kernels/sketch.py exactly: the same recursion,
//! the same Gaussian-consumption order, the same sqrt(1/r) scaling —
//! property tests in this module assert the paper's guarantees (Theorem 1.1
//! non-negativity, AMM error decay with r).

use crate::tensor::Tensor;
use crate::util::rng::Pcg;

/// Number of Gaussian matrices PolySketchWithNegativity(., r, p) consumes:
/// count(p) = 2 (p - 1); the non-negative map of degree p consumes p - 2.
pub fn num_projections(p: usize) -> usize {
    assert!(p.is_power_of_two(), "degree must be power of two, got {p}");
    if p == 1 {
        0
    } else {
        2 * num_projections(p / 2) + 2
    }
}

/// Shapes of the Gaussian matrices in consumption order ((h,r) leaves,
/// (r,r) above).
pub fn projection_shapes(h: usize, r: usize, p: usize) -> Vec<(usize, usize)> {
    assert!(p.is_power_of_two());
    if p == 1 {
        return vec![];
    }
    let sub = projection_shapes(h, r, p / 2);
    let inner = if p == 2 { h } else { r };
    let mut out = sub.clone();
    out.extend(sub);
    out.push((inner, r));
    out.push((inner, r));
    out
}

/// The sketch: Gaussian stack + sizes. Construct once, apply to Q and K —
/// sharing the same instance between Q and K is required for correctness.
#[derive(Clone, Debug)]
pub struct PolySketch {
    pub r: usize,
    pub p: usize,
    gs: Vec<Tensor>,
}

impl PolySketch {
    /// Sample the projection stack for vectors of dim `h`, sketch size `r`,
    /// kernel degree `p` (the recursion itself runs at degree p/2 — the
    /// self-tensoring squares it back, Theorem 2.4).
    pub fn sample(rng: &mut Pcg, h: usize, r: usize, p: usize) -> Self {
        assert!(p >= 2 && p.is_power_of_two());
        let gs = projection_shapes(h, r, p / 2)
            .into_iter()
            .map(|(a, b)| Tensor::gaussian(rng, &[a, b]))
            .collect();
        PolySketch { r, p, gs }
    }

    /// Half sketch L = PolySketchWithNegativity(A, r, p/2): (n, r).
    /// The implicit non-negative feature map is the row-wise self-tensor.
    pub fn half(&self, a: &Tensor) -> Tensor {
        self.pswn(a, &self.gs, self.p / 2)
    }

    /// Full non-negative feature map phi'(A) = half(A)^{(x)2}: (n, r^2).
    pub fn nonnegative(&self, a: &Tensor) -> Tensor {
        self_tensor_rows(&self.half(a))
    }

    /// Half sketch of a single (already-normalized) row: (h,) -> (r,).
    /// The per-token hot path of the decoding subsystem (`infer::state`);
    /// row-wise identical to [`PolySketch::half`] on a one-row tensor.
    pub fn half_row(&self, row: &[f32]) -> Vec<f32> {
        let t = Tensor::from_vec(&[1, row.len()], row.to_vec());
        self.half(&t).into_vec()
    }

    fn pswn(&self, a: &Tensor, gs: &[Tensor], d: usize) -> Tensor {
        if d == 1 {
            return a.clone();
        }
        let n_sub = num_projections(d / 2);
        let m1 = self.pswn(a, &gs[..n_sub], d / 2);
        let m2 = self.pswn(a, &gs[n_sub..2 * n_sub], d / 2);
        let g1 = &gs[2 * n_sub];
        let g2 = &gs[2 * n_sub + 1];
        let prod = m1.matmul(g1).hadamard(&m2.matmul(g2));
        prod.scale(1.0 / (self.r as f32).sqrt())
    }
}

/// Row-wise self Kronecker product: (n, r) -> (n, r^2).
pub fn self_tensor_rows(m: &Tensor) -> Tensor {
    let (n, r) = (m.rows(), m.cols());
    let mut out = Tensor::zeros(&[n, r * r]);
    for i in 0..n {
        let row = m.row(i);
        let orow = out.row_mut(i);
        for a in 0..r {
            let ra = row[a];
            for b in 0..r {
                orow[a * r + b] = ra * row[b];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dot, layernorm_rows};
    use crate::attn::poly::powi;

    fn unit_rows(rng: &mut Pcg, n: usize, h: usize) -> Tensor {
        let mut t = Tensor::gaussian(rng, &[n, h]);
        for i in 0..n {
            let norm = dot(t.row(i), t.row(i)).sqrt();
            for v in t.row_mut(i) {
                *v /= norm;
            }
        }
        t
    }

    #[test]
    fn projection_counts_match_python() {
        assert_eq!(num_projections(1), 0);
        assert_eq!(num_projections(2), 2);
        assert_eq!(num_projections(4), 6);
        assert_eq!(projection_shapes(8, 4, 2), vec![(8, 4), (8, 4)]);
        assert_eq!(
            projection_shapes(8, 4, 4),
            vec![(8, 4), (8, 4), (8, 4), (8, 4), (4, 4), (4, 4)]
        );
    }

    #[test]
    fn nonnegativity_theorem_1_1() {
        let mut rng = Pcg::seeded(0);
        for p in [2usize, 4, 8] {
            let sk = PolySketch::sample(&mut rng, 8, 8, p);
            let q = Tensor::gaussian(&mut rng, &[24, 8]);
            let k = Tensor::gaussian(&mut rng, &[24, 8]);
            let pq = sk.nonnegative(&q);
            let pk = sk.nonnegative(&k);
            let w = pq.matmul_t(&pk);
            for &x in w.data() {
                assert!(x >= -1e-5, "negative sketched weight {x} at p={p}");
            }
        }
    }

    #[test]
    fn approximates_polynomial_kernel() {
        let mut rng = Pcg::seeded(1);
        let x = unit_rows(&mut rng, 48, 8);
        let sk = PolySketch::sample(&mut rng, 8, 32, 4);
        let half = sk.half(&x);
        let approx = {
            let s = half.matmul_t(&half);
            s.map(|v| v * v)
        };
        let exact = x.matmul_t(&x).map(|v| powi(v, 4));
        // The guarantee is Frobenius/average (Definition 2.1), not
        // entrywise — assert the RMSE, not the max deviation.
        let rmse = {
            let d: f32 = approx
                .data()
                .iter()
                .zip(exact.data())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            (d / approx.len() as f32).sqrt()
        };
        assert!(rmse < 0.4, "rmse {rmse}");
    }

    #[test]
    fn error_decays_with_sketch_size() {
        let mut rng = Pcg::seeded(2);
        let x = unit_rows(&mut rng, 48, 8);
        let exact = x.matmul_t(&x).map(|v| powi(v, 4));
        let rmse = |r: usize, rng: &mut Pcg| -> f32 {
            let sk = PolySketch::sample(rng, 8, r, 4);
            let half = sk.half(&x);
            let approx = half.matmul_t(&half).map(|v| v * v);
            let d: f32 = approx
                .data()
                .iter()
                .zip(exact.data())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            (d / approx.len() as f32).sqrt()
        };
        let e_small = rmse(4, &mut rng);
        let e_big = rmse(64, &mut rng);
        assert!(e_big < e_small, "r=4 {e_small} vs r=64 {e_big}");
    }

    #[test]
    fn half_consistent_with_nonnegative() {
        let mut rng = Pcg::seeded(3);
        let sk = PolySketch::sample(&mut rng, 8, 4, 4);
        let x = Tensor::gaussian(&mut rng, &[10, 8]);
        let half = sk.half(&x);
        let full = sk.nonnegative(&x);
        assert!(self_tensor_rows(&half).max_abs_diff(&full) < 1e-6);
    }

    #[test]
    fn half_row_bitwise_matches_half() {
        let mut rng = Pcg::seeded(5);
        let sk = PolySketch::sample(&mut rng, 8, 8, 4);
        let x = Tensor::gaussian(&mut rng, &[6, 8]);
        let full = sk.half(&x);
        for i in 0..6 {
            assert_eq!(sk.half_row(x.row(i)).as_slice(), full.row(i));
        }
    }

    #[test]
    fn layernormed_inputs_keep_norms_bounded() {
        // After LN, row norms are ~sqrt(h); sketched kernel values stay
        // finite — the regime the model actually runs in.
        let mut rng = Pcg::seeded(4);
        let sk = PolySketch::sample(&mut rng, 8, 16, 4);
        let x = layernorm_rows(&Tensor::gaussian(&mut rng, &[16, 8]).scale(100.0));
        let half = sk.half(&x);
        for &v in half.data() {
            assert!(v.is_finite());
        }
    }
}
