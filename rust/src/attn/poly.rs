//! Exact degree-p polynomial attention (Section 2.1) — quadratic baseline.

use crate::exec::pool;
use crate::tensor::{layernorm_rows, micro, RowMat, Tensor};

/// Quadratic work (n² · h MACs) below which the kernel runs inline —
/// the same tuning knob family as `attn::softmax::PAR_MIN_WORK`.
const PAR_MIN_WORK: usize = 32 * 1024;

/// Raise to integer power by repeated squaring over f32.
#[inline]
pub fn powi(x: f32, p: u32) -> f32 {
    let mut acc = 1.0f32;
    let mut base = x;
    let mut e = p;
    while e > 0 {
        if e & 1 == 1 {
            acc *= base;
        }
        base *= base;
        e >>= 1;
    }
    acc
}

/// Causal degree-p polynomial attention with layer-normalized q/k and the
/// paper's `1 +` denominator:
///   out_i = sum_{j<=i} <q'_i,k'_j>^p v_j / (1 + sum_{j<=i} <q'_i,k'_j>^p).
pub fn poly_attention(q: &impl RowMat, k: &impl RowMat, v: &impl RowMat, p: u32) -> Tensor {
    assert!(p >= 2 && p % 2 == 0, "even p >= 2 required, got {p}");
    let qn = layernorm_rows(q);
    let kn = layernorm_rows(k);
    poly_attention_prenormed(&qn, &kn, v, p)
}

/// Same but assumes q/k already normalized (hot path for block composition).
/// Query-row parallel on the deterministic backend: rows are independent,
/// so bytes never depend on the thread count.
pub fn poly_attention_prenormed(qn: &Tensor, kn: &Tensor, v: &impl RowMat, p: u32) -> Tensor {
    let n = qn.rows();
    let hv = v.cols();
    let mut out = Tensor::zeros(&[n, hv]);
    if out.is_empty() {
        return out;
    }
    let kernel = |row0: usize, chunk: &mut [f32]| {
        for (r, orow) in chunk.chunks_mut(hv).enumerate() {
            let i = row0 + r;
            let qi = qn.row(i);
            let mut denom = 1.0f32;
            for j in 0..=i {
                let w = powi(micro::dot(qi, kn.row(j)), p);
                denom += w;
                micro::axpy(orow, v.row(j), w);
            }
            micro::scale_inplace(orow, 1.0 / denom);
        }
    };
    if n * n * qn.cols() < PAR_MIN_WORK {
        kernel(0, out.data_mut());
    } else {
        pool::par_row_chunks(out.data_mut(), hv, 4, kernel);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn powi_matches_std() {
        for p in [2u32, 4, 8] {
            for x in [-2.0f32, -0.5, 0.0, 0.3, 1.7] {
                assert!((powi(x, p) - x.powi(p as i32)).abs() < 1e-5 * x.powi(p as i32).abs().max(1.0));
            }
        }
    }

    #[test]
    fn weights_nonnegative_rows_below_one() {
        let mut rng = Pcg::seeded(0);
        let (n, h) = (16, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let mut v = Tensor::zeros(&[n, 1]);
        for i in 0..n {
            v.set2(i, 0, 1.0);
        }
        let out = poly_attention(&q, &k, &v, 4);
        for i in 0..n {
            let w = out.at2(i, 0);
            assert!(w >= 0.0 && w < 1.0, "row {i}: {w}");
        }
    }

    #[test]
    fn causality() {
        let mut rng = Pcg::seeded(1);
        let (n, h) = (16, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v1 = Tensor::gaussian(&mut rng, &[n, h]);
        let mut v2 = v1.clone();
        for j in 0..h {
            v2.set2(n - 1, j, 99.0);
        }
        let a = poly_attention(&q, &k, &v1, 4);
        let b = poly_attention(&q, &k, &v2, 4);
        for i in 0..n - 1 {
            for j in 0..h {
                assert!((a.at2(i, j) - b.at2(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn higher_degree_concentrates() {
        // p -> infinity approaches argmax attention (Section 2.1): the
        // entropy of the weight distribution should not increase with p.
        let mut rng = Pcg::seeded(2);
        let (n, h) = (24, 8);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let mut v = Tensor::zeros(&[n, n]); // one-hot values expose weights
        for i in 0..n {
            v.set2(i, i, 1.0);
        }
        let ent = |t: &Tensor| -> f32 {
            let row = t.row(n - 1);
            let sum: f32 = row.iter().sum();
            row.iter()
                .filter(|&&w| w > 1e-12)
                .map(|&w| {
                    let p = w / sum;
                    -p * p.ln()
                })
                .sum()
        };
        let e2 = ent(&poly_attention(&q, &k, &v, 2));
        let e8 = ent(&poly_attention(&q, &k, &v, 8));
        assert!(e8 <= e2 + 1e-4, "entropy grew: p2={e2} p8={e8}");
    }
}
