//! Slab/paged arena for linear-mechanism decode state.
//!
//! Every psk/performer/local decode state has a fixed O(r²·h) footprint
//! per (mechanism, config), so state buffers come in a handful of exact
//! sizes.  The arena exploits that: each distinct buffer length is a
//! *size class*, and a class commits backing memory one page-sized batch
//! of uniform slots at a time instead of hitting the global allocator
//! once per session.  Freed slots go on the class free list and are
//! handed back zeroed, so steady-state admission/eviction churn at 10k+
//! sessions allocates nothing.
//!
//! Three properties the serve layer builds on:
//!
//! * **Generation-tagged handles** — every slot carries a generation
//!   counter bumped on free.  A [`Handle`] captured before eviction can
//!   never alias the session that later reuses the slot:
//!   [`StateArena::is_live`] goes false the instant the slot is freed.
//! * **Page-pressure counters** — live/committed byte counters are
//!   maintained outside the lock and drive cache admission/eviction
//!   (`serve::cache`), replacing the old approximate byte ledger.
//! * **Deterministic contents** — a slot is returned `0.0`-filled
//!   whether fresh or reused, so allocation history can never leak into
//!   output bytes (invariant #11 stays intact).

use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Target page size: a size class commits backing memory in batches of
/// roughly this many bytes (at least one slot, at most
/// [`MAX_SLOTS_PER_PAGE`] slots per batch).
pub const PAGE_BYTES: usize = 64 * 1024;

/// Cap on slots carved from one page batch, so tiny classes (short
/// ragged-tail payloads) do not over-commit thousands of slots up front.
const MAX_SLOTS_PER_PAGE: usize = 64;

/// Slot id meaning "no slot": the buffer is empty and arena-less.
const NO_SLOT: u32 = u32::MAX;

/// Generation-tagged reference to an arena slot.  Stale handles (the
/// slot was freed, possibly reused) are detected by generation mismatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Handle {
    pub slot: u32,
    pub gen: u32,
}

/// Point-in-time arena gauges, exported on `/healthz` and as Prometheus
/// gauges.  `bytes_live` counts leased slots; `bytes_committed` counts
/// leased + free-listed slots (what the process actually holds).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    pub slots_total: usize,
    pub slots_live: usize,
    pub bytes_live: usize,
    pub bytes_committed: usize,
    pub high_water_bytes: usize,
    pub gen_bumps: u64,
    pub pages: usize,
}

struct SlotMeta {
    gen: u32,
    live: bool,
    words: usize,
}

struct FreeSlot {
    id: u32,
    data: Box<[f32]>,
}

#[derive(Default)]
struct Class {
    free: Vec<FreeSlot>,
}

#[derive(Default)]
struct ArenaInner {
    /// Size class per distinct slot length (in f32 words).
    classes: HashMap<usize, Class>,
    /// Slot registry indexed by slot id; ids are never reused, only the
    /// backing boxes are.
    slots: Vec<SlotMeta>,
}

/// The arena.  One process-global instance backs active decode states
/// ([`StateArena::global`]); each `PromptCache` owns a private instance
/// for its cold (frozen) entries so cache pressure is its own ledger.
pub struct StateArena {
    inner: Mutex<ArenaInner>,
    bytes_live: AtomicUsize,
    bytes_committed: AtomicUsize,
    high_water: AtomicUsize,
    slots_live: AtomicUsize,
    slots_total: AtomicUsize,
    gen_bumps: AtomicU64,
}

impl StateArena {
    pub fn new() -> Arc<StateArena> {
        Arc::new(StateArena {
            inner: Mutex::new(ArenaInner::default()),
            bytes_live: AtomicUsize::new(0),
            bytes_committed: AtomicUsize::new(0),
            high_water: AtomicUsize::new(0),
            slots_live: AtomicUsize::new(0),
            slots_total: AtomicUsize::new(0),
            gen_bumps: AtomicU64::new(0),
        })
    }

    /// The process-global arena backing *active* decode states (Z/φ of
    /// every live `LinearState`).
    pub fn global() -> &'static Arc<StateArena> {
        static GLOBAL: OnceLock<Arc<StateArena>> = OnceLock::new();
        GLOBAL.get_or_init(StateArena::new)
    }

    /// Lease a zero-filled slot of exactly `words` f32s.  `words == 0`
    /// returns an empty, arena-less buffer.
    pub fn alloc_zeroed(self: &Arc<Self>, words: usize) -> PagedBuf {
        if words == 0 {
            return PagedBuf::default();
        }
        let (id, gen, mut data) = {
            let mut inner = self.inner.lock().expect("arena lock");
            let popped = inner.classes.entry(words).or_default().free.pop();
            let (id, data) = match popped {
                Some(fs) => (fs.id, fs.data),
                None => {
                    // Commit a fresh page batch for this class: uniform
                    // slots, all but one parked on the free list.
                    let batch = (PAGE_BYTES / (words * 4)).clamp(1, MAX_SLOTS_PER_PAGE);
                    let first = inner.slots.len() as u32;
                    for i in 0..batch {
                        inner.slots.push(SlotMeta { gen: 0, live: false, words });
                        if i > 0 {
                            let boxed = vec![0.0f32; words].into_boxed_slice();
                            inner
                                .classes
                                .get_mut(&words)
                                .expect("class just created")
                                .free
                                .push(FreeSlot { id: first + i as u32, data: boxed });
                        }
                    }
                    self.slots_total.fetch_add(batch, Ordering::Relaxed);
                    self.bytes_committed.fetch_add(batch * words * 4, Ordering::Relaxed);
                    (first, vec![0.0f32; words].into_boxed_slice())
                }
            };
            let meta = &mut inner.slots[id as usize];
            debug_assert!(!meta.live, "free-listed slot marked live");
            meta.live = true;
            (id, meta.gen, data)
        };
        // Reused slots hold the previous lease's bytes; the zero-fill is
        // the determinism contract (fresh boxes are already zero).
        data.fill(0.0);
        self.slots_live.fetch_add(1, Ordering::Relaxed);
        let live = self.bytes_live.fetch_add(words * 4, Ordering::Relaxed) + words * 4;
        self.high_water.fetch_max(live, Ordering::Relaxed);
        PagedBuf { data, slot: id, gen, arena: Some(Arc::clone(self)) }
    }

    /// Lease a slot holding a copy of `src`.
    pub fn alloc_copy(self: &Arc<Self>, src: &[f32]) -> PagedBuf {
        let mut buf = self.alloc_zeroed(src.len());
        buf.copy_from_slice(src);
        buf
    }

    /// Is the slot behind `h` still the same lease the handle was taken
    /// from?  False the moment the buffer is dropped (generation bump),
    /// and forever after — reuse can never resurrect a stale handle.
    pub fn is_live(&self, h: Handle) -> bool {
        let inner = self.inner.lock().expect("arena lock");
        inner
            .slots
            .get(h.slot as usize)
            .map(|m| m.live && m.gen == h.gen)
            .unwrap_or(false)
    }

    pub fn stats(&self) -> ArenaStats {
        let committed = self.bytes_committed.load(Ordering::Relaxed);
        ArenaStats {
            slots_total: self.slots_total.load(Ordering::Relaxed),
            slots_live: self.slots_live.load(Ordering::Relaxed),
            bytes_live: self.bytes_live.load(Ordering::Relaxed),
            bytes_committed: committed,
            high_water_bytes: self.high_water.load(Ordering::Relaxed),
            gen_bumps: self.gen_bumps.load(Ordering::Relaxed),
            pages: committed.div_ceil(PAGE_BYTES),
        }
    }

    /// Release free-listed slots until committed bytes fall to `target`
    /// (or every free slot is gone — leased slots are never touched).
    /// Retired slot ids stay in the registry so stale handles keep
    /// resolving to "not live".
    pub fn trim(&self, target_bytes: usize) {
        let mut inner = self.inner.lock().expect("arena lock");
        if self.bytes_committed.load(Ordering::Relaxed) <= target_bytes {
            return;
        }
        let sizes: Vec<usize> = inner.classes.keys().copied().collect();
        'outer: for words in sizes {
            loop {
                if self.bytes_committed.load(Ordering::Relaxed) <= target_bytes {
                    break 'outer;
                }
                let Some(fs) = inner.classes.get_mut(&words).and_then(|c| c.free.pop()) else {
                    break;
                };
                let meta = &mut inner.slots[fs.id as usize];
                debug_assert!(!meta.live);
                meta.gen = meta.gen.wrapping_add(1);
                self.bytes_committed.fetch_sub(words * 4, Ordering::Relaxed);
                self.slots_total.fetch_sub(1, Ordering::Relaxed);
                drop(fs.data);
            }
        }
    }

    fn release(&self, slot: u32, data: Box<[f32]>) {
        let words = data.len();
        let mut inner = self.inner.lock().expect("arena lock");
        let meta = &mut inner.slots[slot as usize];
        debug_assert!(meta.live, "double free of arena slot");
        debug_assert_eq!(meta.words, words);
        meta.live = false;
        meta.gen = meta.gen.wrapping_add(1);
        inner.classes.entry(words).or_default().free.push(FreeSlot { id: slot, data });
        drop(inner);
        self.gen_bumps.fetch_add(1, Ordering::Relaxed);
        self.slots_live.fetch_sub(1, Ordering::Relaxed);
        self.bytes_live.fetch_sub(words * 4, Ordering::Relaxed);
    }
}

/// An arena-leased f32 buffer.  Derefs to `[f32]`, so callers use it
/// exactly like the `Vec<f32>` it replaces; the backing slot returns to
/// the arena free list on drop (with a generation bump).  `Clone` takes
/// a fresh lease and copies — deep-copy semantics, as the prompt cache
/// requires.
pub struct PagedBuf {
    data: Box<[f32]>,
    slot: u32,
    gen: u32,
    arena: Option<Arc<StateArena>>,
}

impl PagedBuf {
    /// Generation-tagged handle to the backing slot (sentinel slot id
    /// for empty buffers).
    pub fn handle(&self) -> Handle {
        Handle { slot: self.slot, gen: self.gen }
    }

    pub fn arena(&self) -> Option<&Arc<StateArena>> {
        self.arena.as_ref()
    }
}

impl Default for PagedBuf {
    fn default() -> PagedBuf {
        PagedBuf { data: Box::default(), slot: NO_SLOT, gen: 0, arena: None }
    }
}

impl Deref for PagedBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl DerefMut for PagedBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl Clone for PagedBuf {
    fn clone(&self) -> PagedBuf {
        if self.data.is_empty() {
            return PagedBuf::default();
        }
        self.arena.as_ref().unwrap_or_else(|| StateArena::global()).alloc_copy(&self.data)
    }
}

impl Drop for PagedBuf {
    fn drop(&mut self) {
        if let Some(arena) = self.arena.take() {
            if self.slot != NO_SLOT {
                arena.release(self.slot, std::mem::take(&mut self.data));
            }
        }
    }
}

impl std::fmt::Debug for PagedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedBuf")
            .field("len", &self.data.len())
            .field("slot", &self.slot)
            .field("gen", &self.gen)
            .finish()
    }
}

impl PartialEq for PagedBuf {
    fn eq(&self, other: &PagedBuf) -> bool {
        self.data == other.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_zeroed_and_deref_works() {
        let arena = StateArena::new();
        let mut a = arena.alloc_zeroed(17);
        assert_eq!(a.len(), 17);
        assert!(a.iter().all(|&x| x.to_bits() == 0));
        a[3] = 2.5;
        assert_eq!(a[3], 2.5);
        let stats = arena.stats();
        assert_eq!(stats.slots_live, 1);
        assert_eq!(stats.bytes_live, 17 * 4);
        assert!(stats.bytes_committed >= stats.bytes_live);
    }

    #[test]
    fn page_batches_commit_uniform_slots() {
        let arena = StateArena::new();
        // 1024-word slots: 64KiB page / 4KiB slot = 16 slots per batch.
        let a = arena.alloc_zeroed(1024);
        let stats = arena.stats();
        assert_eq!(stats.slots_total, 16);
        assert_eq!(stats.bytes_committed, 16 * 1024 * 4);
        assert_eq!(stats.pages, 1);
        // A second lease comes off the free list: no new commitment.
        let b = arena.alloc_zeroed(1024);
        assert_eq!(arena.stats().bytes_committed, 16 * 1024 * 4);
        assert_eq!(arena.stats().slots_live, 2);
        drop((a, b));
        assert_eq!(arena.stats().slots_live, 0);
        assert_eq!(arena.stats().bytes_live, 0);
    }

    #[test]
    fn reused_slot_is_rezeroed_and_generation_bumps() {
        let arena = StateArena::new();
        let mut a = arena.alloc_zeroed(8);
        a.fill(7.0);
        let h = a.handle();
        assert!(arena.is_live(h));
        drop(a);
        assert!(!arena.is_live(h), "freed slot must kill the handle");
        let b = arena.alloc_zeroed(8);
        assert!(b.iter().all(|&x| x.to_bits() == 0), "reused slot not rezeroed");
        if b.handle().slot == h.slot {
            assert_ne!(b.handle().gen, h.gen, "reuse must change the generation");
        }
        assert!(!arena.is_live(h), "stale handle must stay dead after reuse");
        assert!(arena.is_live(b.handle()));
        assert_eq!(arena.stats().gen_bumps, 1);
    }

    #[test]
    fn clone_is_a_deep_copy_on_a_fresh_slot() {
        let arena = StateArena::new();
        let mut a = arena.alloc_copy(&[1.0, 2.0, 3.0]);
        let b = a.clone();
        a[0] = 9.0;
        assert_eq!(&b[..], &[1.0, 2.0, 3.0]);
        assert_ne!(a.handle(), b.handle());
    }

    #[test]
    fn trim_releases_only_free_slots() {
        let arena = StateArena::new();
        let a = arena.alloc_zeroed(1024); // commits a 16-slot batch
        let committed = arena.stats().bytes_committed;
        arena.trim(0);
        // Only the 15 free slots can go; the leased one stays.
        assert_eq!(arena.stats().bytes_committed, 1024 * 4);
        assert!(arena.stats().bytes_committed < committed);
        drop(a);
        arena.trim(0);
        assert_eq!(arena.stats().bytes_committed, 0);
        assert_eq!(arena.stats().slots_total, 0);
    }

    #[test]
    fn empty_alloc_is_arena_less() {
        let arena = StateArena::new();
        let a = arena.alloc_zeroed(0);
        assert!(a.is_empty());
        assert_eq!(arena.stats().slots_total, 0);
        let b = PagedBuf::default();
        assert_eq!(a.handle(), b.handle());
    }
}
