//! Freeze/thaw: the cold form a kernel decode state takes while it sits
//! in the prompt-prefix cache, stored in arena slots.
//!
//! Freezing happens on the evict-to-cache boundary, thawing on
//! promote-to-active; active decode states are always full f32, so the
//! hot-path math never sees narrowed values.  Two tiers:
//!
//! * **Exact** (`PSF_QUANT=off`) — a bit-for-bit f32 image of the
//!   state.  Thawing reconstructs the state byte-identically, so serve
//!   output with caching on equals serve output with caching off.
//! * **f16** (`PSF_QUANT=f16|q8`) — the *compact* cold encoding: the
//!   prefix moments Z in f16, plus the in-progress block's **raw** key
//!   and value rows in f16.  Mapped/local rows and φ scratch are not
//!   stored — thawing replays the tail rows through
//!   [`CausalKernel::absorb`], which regenerates them through the same
//!   deterministic feature-map code the live path uses.  For sub-block
//!   prompts (Z still all-zero, elided) this stores 2 rows of `h` halves
//!   per token versus 4 rows of f32 — a >3x cut; Z-dominated states
//!   approach the plain f16 2x.
//!
//! Both tiers elide an all-`+0.0` Z (`has_z = false`): bit-exact either
//! way, and it is what makes short-prefix entries cheap.

use std::sync::Arc;

use crate::attn::kernel::{CausalKernel, KernelState, KvState, LinearState};
use crate::mem::arena::{Handle, PagedBuf, StateArena};
use crate::mem::quant::{self, QuantMode};
use crate::obs::{self, Phase};

/// One (layer, head) state in cold form.  `bytes` come from the arena
/// slot backing `data`, so the cache ledger is exact by construction.
/// Cloning deep-copies through the backing arena.
#[derive(Clone)]
pub struct FrozenState {
    repr: Repr,
}

#[derive(Clone)]
enum Repr {
    /// Exact f32 image of a KV cache: k rows then v rows.
    KvExact { kd: usize, vd: usize, len: usize, data: PagedBuf },
    /// f16 image of a KV cache (packed halves): k rows then v rows.
    KvF16 { kd: usize, vd: usize, len: usize, data: PagedBuf },
    /// Exact f32 image of a linear state: Z (when `has_z`), then per
    /// buffered tail row: mapped, local (when `ld > 0`), v, raw.
    LinExact {
        h: usize,
        feat: usize,
        md: usize,
        ld: usize,
        kd: usize,
        tokens: usize,
        tail: usize,
        has_z: bool,
        data: PagedBuf,
    },
    /// Compact f16 image of a linear state (packed halves): Z (when
    /// `has_z`), then per buffered tail row: raw key, then v.  Mapped
    /// rows are regenerated via `absorb` on thaw.
    LinF16 {
        h: usize,
        feat: usize,
        kd: usize,
        tokens: usize,
        tail: usize,
        has_z: bool,
        data: PagedBuf,
    },
}

/// Is every word an exact `+0.0`?  (`-0.0` has a different bit pattern
/// and must be preserved, so the test is on bits, not value.)
fn all_zero_bits(xs: &[f32]) -> bool {
    xs.iter().all(|x| x.to_bits() == 0)
}

fn push_f16(halves: &mut Vec<u16>, xs: &[f32]) {
    for &x in xs {
        halves.push(quant::f16_encode(x));
    }
}

/// Cursor over packed f16 halves.
struct HalfReader<'a> {
    words: &'a [f32],
    idx: usize,
}

impl<'a> HalfReader<'a> {
    fn new(words: &'a [f32]) -> HalfReader<'a> {
        HalfReader { words, idx: 0 }
    }

    fn read_into(&mut self, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = quant::f16_decode(quant::unpack_half(self.words, self.idx));
            self.idx += 1;
        }
    }

    fn read_vec(&mut self, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        self.read_into(&mut out);
        out
    }
}

impl FrozenState {
    /// Freeze one state into `arena` under `mode` (q8 uses the f16 cold
    /// tier; weight quantization is a separate, model-level concern).
    pub fn freeze(state: &KernelState, mode: QuantMode, arena: &Arc<StateArena>) -> FrozenState {
        let _t = obs::phase::timer(Phase::Quantize);
        let repr = match state {
            KernelState::Kv(st) => {
                if mode.f16_cold_tier() {
                    let mut halves = Vec::with_capacity(st.k.len() + st.v.len());
                    push_f16(&mut halves, &st.k);
                    push_f16(&mut halves, &st.v);
                    let mut data = arena.alloc_zeroed(quant::packed_words(halves.len()));
                    quant::pack_halves(&halves, &mut data);
                    Repr::KvF16 { kd: st.kd, vd: st.vd, len: st.len, data }
                } else {
                    let mut data = arena.alloc_zeroed(st.k.len() + st.v.len());
                    data[..st.k.len()].copy_from_slice(&st.k);
                    data[st.k.len()..].copy_from_slice(&st.v);
                    Repr::KvExact { kd: st.kd, vd: st.vd, len: st.len, data }
                }
            }
            KernelState::Linear(st) => {
                let tail = st.buf_v.len();
                let md = st.buf_mapped.first().map_or(0, Vec::len);
                let ld = st.buf_local.first().map_or(0, Vec::len);
                let kd = st.buf_raw.first().map_or(0, Vec::len);
                debug_assert_eq!(st.buf_mapped.len(), tail);
                debug_assert_eq!(st.buf_raw.len(), tail, "raw tail rows out of sync");
                let feat = if st.h == 0 { 0 } else { st.z.len() / (st.h + 1) };
                let has_z = !all_zero_bits(&st.z);
                if mode.f16_cold_tier() {
                    let mut halves = Vec::new();
                    if has_z {
                        push_f16(&mut halves, &st.z);
                    }
                    for t in 0..tail {
                        push_f16(&mut halves, &st.buf_raw[t]);
                        push_f16(&mut halves, &st.buf_v[t]);
                    }
                    let mut data = arena.alloc_zeroed(quant::packed_words(halves.len()));
                    quant::pack_halves(&halves, &mut data);
                    Repr::LinF16 { h: st.h, feat, kd, tokens: st.tokens, tail, has_z, data }
                } else {
                    let z_words = if has_z { st.z.len() } else { 0 };
                    let words = z_words + tail * (md + ld + st.h + kd);
                    let mut data = arena.alloc_zeroed(words);
                    let mut at = 0usize;
                    let mut put = |src: &[f32], data: &mut PagedBuf| {
                        data[at..at + src.len()].copy_from_slice(src);
                        at += src.len();
                    };
                    if has_z {
                        put(&st.z, &mut data);
                    }
                    for t in 0..tail {
                        put(&st.buf_mapped[t], &mut data);
                        if ld > 0 {
                            put(&st.buf_local[t], &mut data);
                        }
                        put(&st.buf_v[t], &mut data);
                        put(&st.buf_raw[t], &mut data);
                    }
                    debug_assert_eq!(at, words);
                    Repr::LinExact {
                        h: st.h,
                        feat,
                        md,
                        ld,
                        kd,
                        tokens: st.tokens,
                        tail,
                        has_z,
                        data,
                    }
                }
            }
        };
        FrozenState { repr }
    }

    /// Rebuild an active (f32) state.  Exact images reconstruct
    /// byte-identically; f16 images decode Z and replay the tail rows
    /// through `kernel.absorb`, regenerating mapped rows with the same
    /// deterministic feature-map code the live path uses.
    pub fn thaw(&self, kernel: &Arc<dyn CausalKernel>) -> KernelState {
        let _t = obs::phase::timer(Phase::Dequantize);
        match &self.repr {
            Repr::KvExact { kd, vd, len, data } => {
                let ksz = len * kd;
                KernelState::Kv(KvState {
                    k: data[..ksz].to_vec(),
                    v: data[ksz..].to_vec(),
                    kd: *kd,
                    vd: *vd,
                    len: *len,
                })
            }
            Repr::KvF16 { kd, vd, len, data } => {
                let mut r = HalfReader::new(data);
                let k = r.read_vec(len * kd);
                let v = r.read_vec(len * vd);
                KernelState::Kv(KvState { k, v, kd: *kd, vd: *vd, len: *len })
            }
            Repr::LinExact { h, feat, md, ld, kd, tokens, tail, has_z, data } => {
                let mut st = LinearState::new();
                if *h > 0 {
                    st.ensure_init(*h, *feat);
                }
                let mut at = 0usize;
                let mut take = |n: usize, at: &mut usize| {
                    let s = data[*at..*at + n].to_vec();
                    *at += n;
                    s
                };
                if *has_z {
                    st.z.copy_from_slice(&data[..st.z.len()]);
                    at = st.z.len();
                }
                for _ in 0..*tail {
                    st.buf_mapped.push(take(*md, &mut at));
                    if *ld > 0 {
                        st.buf_local.push(take(*ld, &mut at));
                    }
                    st.buf_v.push(take(*h, &mut at));
                    st.buf_raw.push(take(*kd, &mut at));
                }
                st.tokens = *tokens;
                KernelState::Linear(st)
            }
            Repr::LinF16 { h, feat, kd, tokens, tail, has_z, data } => {
                let mut state = kernel.new_state();
                {
                    let KernelState::Linear(st) = &mut state else {
                        unreachable!("f16 linear image thawed by a non-linear kernel")
                    };
                    if *h > 0 {
                        st.ensure_init(*h, *feat);
                    }
                    st.tokens = tokens - tail;
                }
                let mut r = HalfReader::new(data);
                if *has_z {
                    let KernelState::Linear(st) = &mut state else { unreachable!() };
                    r.read_into(&mut st.z);
                }
                for _ in 0..*tail {
                    let raw = r.read_vec(*kd);
                    let vrow = r.read_vec(*h);
                    kernel.absorb(&raw, &vrow, &mut state);
                }
                state
            }
        }
    }

    /// Bytes this image holds in its arena slot.
    pub fn arena_bytes(&self) -> usize {
        self.data().len() * 4
    }

    /// Generation-tagged handle to the backing slot.
    pub fn handle(&self) -> Handle {
        self.data().handle()
    }

    pub fn is_f16(&self) -> bool {
        matches!(self.repr, Repr::KvF16 { .. } | Repr::LinF16 { .. })
    }

    fn data(&self) -> &PagedBuf {
        match &self.repr {
            Repr::KvExact { data, .. }
            | Repr::KvF16 { data, .. }
            | Repr::LinExact { data, .. }
            | Repr::LinF16 { data, .. } => data,
        }
    }
}

/// A frozen f32 row (the cached last-logits vector): exact under `off`,
/// packed f16 otherwise.
#[derive(Clone)]
pub struct FrozenRow {
    n: usize,
    f16: bool,
    data: PagedBuf,
}

impl FrozenRow {
    pub fn freeze(row: &[f32], mode: QuantMode, arena: &Arc<StateArena>) -> FrozenRow {
        let _t = obs::phase::timer(Phase::Quantize);
        if mode.f16_cold_tier() {
            let mut halves = Vec::with_capacity(row.len());
            push_f16(&mut halves, row);
            let mut data = arena.alloc_zeroed(quant::packed_words(halves.len()));
            quant::pack_halves(&halves, &mut data);
            FrozenRow { n: row.len(), f16: true, data }
        } else {
            FrozenRow { n: row.len(), f16: false, data: arena.alloc_copy(row) }
        }
    }

    pub fn thaw(&self) -> Vec<f32> {
        let _t = obs::phase::timer(Phase::Dequantize);
        if self.f16 {
            HalfReader::new(&self.data).read_vec(self.n)
        } else {
            self.data.to_vec()
        }
    }

    pub fn arena_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::util::rng::Pcg;

    fn mechs() -> Vec<Mechanism> {
        vec![
            Mechanism::Softmax,
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 16, block: 8 },
        ]
    }

    /// Exact freeze → thaw must continue bit-identically to the
    /// original state, for both engines, at a ragged tail length.
    #[test]
    fn exact_roundtrip_continues_bitwise() {
        let arena = StateArena::new();
        let h = 8;
        for mech in mechs() {
            let kernel = mech.build_kernel(h, &mut Pcg::seeded(3));
            let mut rng = Pcg::seeded(9);
            let mut st = kernel.new_state();
            for _ in 0..13 {
                let (q, k, v) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
                kernel.step(&q, &k, &v, &mut st);
            }
            let frozen = FrozenState::freeze(&st, QuantMode::Off, &arena);
            assert!(!frozen.is_f16());
            let mut thawed = frozen.thaw(&kernel);
            assert_eq!(thawed.tokens_seen(), st.tokens_seen(), "{}", mech.label());
            assert_eq!(thawed.memory_floats(), st.memory_floats(), "{}", mech.label());
            let (q, k, v) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
            let a = kernel.step(&q, &k, &v, &mut st);
            let b = kernel.step(&q, &k, &v, &mut thawed);
            assert_eq!(a, b, "{}: exact thaw diverged", mech.label());
        }
    }

    /// f16 freeze → thaw is deterministic (same image thaws to the same
    /// continuation) and stays close to the f32 state's continuation.
    #[test]
    fn f16_roundtrip_is_deterministic_and_close() {
        let arena = StateArena::new();
        let h = 8;
        for mech in mechs() {
            let kernel = mech.build_kernel(h, &mut Pcg::seeded(3));
            let mut rng = Pcg::seeded(10);
            let mut st = kernel.new_state();
            for _ in 0..13 {
                let (q, k, v) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
                kernel.step(&q, &k, &v, &mut st);
            }
            let frozen = FrozenState::freeze(&st, QuantMode::F16, &arena);
            assert!(frozen.is_f16());
            let mut t1 = frozen.thaw(&kernel);
            let mut t2 = frozen.thaw(&kernel);
            assert_eq!(t1.tokens_seen(), 13, "{}", mech.label());
            let (q, k, v) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
            let a = kernel.step(&q, &k, &v, &mut t1);
            let b = kernel.step(&q, &k, &v, &mut t2);
            assert_eq!(a, b, "{}: f16 thaw not deterministic", mech.label());
            let exact = kernel.step(&q, &k, &v, &mut st);
            for (x, y) in a.iter().zip(&exact) {
                assert!(
                    (x - y).abs() <= 2e-2 * (1.0 + y.abs()),
                    "{}: f16 drift {x} vs {y}",
                    mech.label()
                );
            }
        }
    }

    /// The compact f16 linear image beats exact f32 by >3x for
    /// sub-block prefixes (Z elided, tail stored as raw+v halves).
    #[test]
    fn f16_linear_image_is_compact_for_subblock_prefixes() {
        let arena = StateArena::new();
        let h = 8;
        let mech = Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true };
        let kernel = mech.build_kernel(h, &mut Pcg::seeded(3));
        let mut rng = Pcg::seeded(11);
        let mut st = kernel.new_state();
        for _ in 0..7 {
            let (q, k, v) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
            kernel.step(&q, &k, &v, &mut st);
        }
        let exact = FrozenState::freeze(&st, QuantMode::Off, &arena);
        let f16 = FrozenState::freeze(&st, QuantMode::F16, &arena);
        let ratio = exact.arena_bytes() as f64 / f16.arena_bytes() as f64;
        assert!(ratio > 3.0, "compact tier ratio {ratio:.2} <= 3x");
    }

    #[test]
    fn frozen_row_roundtrips() {
        let arena = StateArena::new();
        let row = vec![0.5f32, -1.25, 3.0, 0.0];
        let exact = FrozenRow::freeze(&row, QuantMode::Off, &arena);
        assert_eq!(exact.thaw(), row);
        let f16 = FrozenRow::freeze(&row, QuantMode::F16, &arena);
        // These values are all exactly representable in f16.
        assert_eq!(f16.thaw(), row);
        assert!(f16.arena_bytes() < exact.arena_bytes());
    }
}
