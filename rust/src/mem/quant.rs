//! Quantized storage: the `PSF_QUANT` mode gate, the IEEE 754 binary16
//! (f16) round-to-nearest-even conversion spec, and per-row-scaled int8
//! weight matrices.
//!
//! The scalar routines here are the *spec*: any vectorized path (the
//! micro q8 primitives, a future f16 SIMD encoder) must match them
//! bit-for-bit.  Three modes, process-global like the micro backend:
//!
//! * `off` — everything stays f32; byte-identical to the pre-quant tree
//!   (the default, and the mode all golden fixtures are blessed under);
//! * `f16` — *cold* prompt-prefix states narrow to f16 on the
//!   evict-to-cache boundary and widen back on promote-to-active;
//!   active decode math is untouched f32;
//! * `q8`  — additionally stores weight matrices as per-row int8 with an
//!   f32 scale per row; decode matvecs accumulate in f32.  Implies the
//!   f16 cold tier.
//!
//! Quantization error contract (tested in `tests/properties.rs`): f16
//! round-trip is exact nearest-even per IEEE 754; int8 per-row error is
//! at most `scale / 2` per element with `scale = max|row| / 127`.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::tensor::Tensor;

/// Storage-narrowing mode, selected once per process via `PSF_QUANT`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// All storage f32 — bitwise identical to the pre-quant code.
    Off,
    /// Cold cached states in f16; active states and weights f32.
    F16,
    /// f16 cold tier + per-row int8 weights with f32 accumulation.
    Q8,
}

impl QuantMode {
    pub fn label(self) -> &'static str {
        match self {
            QuantMode::Off => "off",
            QuantMode::F16 => "f16",
            QuantMode::Q8 => "q8",
        }
    }

    /// Does this mode narrow cached (cold) states to f16?
    pub fn f16_cold_tier(self) -> bool {
        self != QuantMode::Off
    }

    /// Does this mode run decode matvecs over int8 weights?
    pub fn q8_weights(self) -> bool {
        self == QuantMode::Q8
    }

    fn code(self) -> u8 {
        match self {
            QuantMode::Off => 1,
            QuantMode::F16 => 2,
            QuantMode::Q8 => 3,
        }
    }

    fn from_code(code: u8) -> Option<QuantMode> {
        match code {
            1 => Some(QuantMode::Off),
            2 => Some(QuantMode::F16),
            3 => Some(QuantMode::Q8),
            _ => None,
        }
    }
}

const UNINIT: u8 = 0;

/// Process-wide active mode; resolved from `PSF_QUANT` on first use,
/// overridable for tests/benches via [`force_mode`] (mirrors
/// `micro::force_backend`).
static ACTIVE: AtomicU8 = AtomicU8::new(UNINIT);

fn detect_from_env() -> QuantMode {
    match std::env::var("PSF_QUANT").ok().as_deref() {
        Some("f16") => QuantMode::F16,
        Some("q8") => QuantMode::Q8,
        // "off", unset, or unrecognized: the bitwise-identical default.
        _ => QuantMode::Off,
    }
}

/// The active quantization mode (reads `PSF_QUANT` once).
pub fn mode() -> QuantMode {
    match QuantMode::from_code(ACTIVE.load(Ordering::Relaxed)) {
        Some(m) => m,
        None => {
            let m = detect_from_env();
            ACTIVE.store(m.code(), Ordering::Relaxed);
            m
        }
    }
}

/// Pin the mode, bypassing `PSF_QUANT` (tests and benches).
pub fn force_mode(m: QuantMode) {
    ACTIVE.store(m.code(), Ordering::Relaxed);
}

/// Drop back to env-driven selection on next use.
pub fn reset_mode() {
    ACTIVE.store(UNINIT, Ordering::Relaxed);
}

// ----------------------------------------------------------------- f16

/// f32 → IEEE 754 binary16, round-to-nearest-even.  This scalar routine
/// is the conversion spec: subnormals round correctly, overflow past
/// 65520 goes to ±inf, NaN stays NaN (quiet, top payload bits kept),
/// ±0 and ±inf pass through exactly.
pub fn f16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        if man == 0 {
            return sign | 0x7c00; // infinity
        }
        // NaN: force quiet, keep the top 9 payload bits.
        return sign | 0x7e00 | ((man >> 13) as u16 & 0x01ff);
    }
    let e = exp - 127;
    if e >= 16 {
        // Magnitude ≥ 2^16: beyond the largest representable half even
        // before rounding.
        return sign | 0x7c00;
    }
    if e >= -14 {
        // Normal half range.  Mantissa rounding may carry into the
        // exponent; at e = 15 that carry lands exactly on the infinity
        // encoding, which is the correct nearest-even result for
        // values in [65520, 65536).
        let half_exp = (e + 15) as u32;
        let mant = man >> 13;
        let rest = man & 0x1fff;
        let mut h = (half_exp << 10) | mant;
        if rest > 0x1000 || (rest == 0x1000 && (mant & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    if e >= -25 {
        // Subnormal half: the 24-bit significand (implicit bit included)
        // shifts right so the result lsb is 2^-24, then rounds RTNE.
        let shift = (13 - 14 - e) as u32;
        let full = 0x0080_0000 | man;
        let mant = full >> shift;
        let rest = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = mant;
        if rest > halfway || (rest == halfway && (mant & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    // Magnitude below half the smallest subnormal: rounds to ±0.
    sign
}

/// binary16 → f32 — exact (every half value is representable in f32).
pub fn f16_decode(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN (payload shifted up)
    } else if exp != 0 {
        sign | ((exp + 112) << 23) | (man << 13)
    } else if man != 0 {
        // Subnormal half → normal f32: value = man · 2^-24.
        let n = 32 - man.leading_zeros(); // bit length, 1..=10
        sign | ((102 + n) << 23) | ((man << (24 - n)) & 0x007f_ffff)
    } else {
        sign // ±0
    };
    f32::from_bits(bits)
}

/// Pack a stream of u16 halves into f32 bit-words, two per word, low
/// half first.  The words are *bit patterns* riding in arena slots —
/// they are never used arithmetically.
pub fn pack_halves(halves: &[u16], words: &mut [f32]) {
    assert_eq!(words.len(), halves.len().div_ceil(2));
    for (w, pair) in words.iter_mut().zip(halves.chunks(2)) {
        let lo = pair[0] as u32;
        let hi = if pair.len() > 1 { pair[1] as u32 } else { 0 };
        *w = f32::from_bits(lo | (hi << 16));
    }
}

/// Read half `idx` back out of a packed word stream.
pub fn unpack_half(words: &[f32], idx: usize) -> u16 {
    let bits = words[idx / 2].to_bits();
    if idx % 2 == 0 {
        (bits & 0xffff) as u16
    } else {
        (bits >> 16) as u16
    }
}

/// Words needed to pack `halves` u16s.
pub fn packed_words(halves: usize) -> usize {
    halves.div_ceil(2)
}

// ------------------------------------------------------------- int8 rows

/// A weight matrix stored as per-row int8 codes plus one f32 scale per
/// row: `w[r][c] ≈ q[r·cols + c] · scales[r]` with
/// `scales[r] = max|row r| / 127`.  Rows are the *contraction* axis of
/// the decode matvec (`out[c] = Σ_r x[r]·w[r][c]`), so per-row scales
/// fold into the activation exactly once per row and accumulation stays
/// f32 throughout.
#[derive(Clone, Debug, Default)]
pub struct QuantMatrix {
    pub rows: usize,
    pub cols: usize,
    pub q: Vec<i8>,
    pub scales: Vec<f32>,
}

impl QuantMatrix {
    /// Quantize a row-major `rows × cols` f32 matrix.  All-zero rows get
    /// scale 0 (and all-zero codes), so dequantization is exact there.
    pub fn from_rows(data: &[f32], rows: usize, cols: usize) -> QuantMatrix {
        assert_eq!(data.len(), rows * cols);
        let mut q = vec![0i8; rows * cols];
        let mut scales = vec![0.0f32; rows];
        for r in 0..rows {
            let row = &data[r * cols..(r + 1) * cols];
            let mut amax = 0.0f32;
            for &x in row {
                let a = x.abs();
                if a > amax {
                    amax = a;
                }
            }
            if amax == 0.0 {
                continue;
            }
            let inv = 127.0 / amax;
            scales[r] = amax / 127.0;
            for (qc, &x) in q[r * cols..(r + 1) * cols].iter_mut().zip(row) {
                *qc = (x * inv).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantMatrix { rows, cols, q, scales }
    }

    pub fn from_tensor(t: &Tensor) -> QuantMatrix {
        QuantMatrix::from_rows(t.data(), t.rows(), t.cols())
    }

    /// Storage footprint: one byte per element + one f32 scale per row.
    pub fn bytes(&self) -> usize {
        self.q.len() + self.scales.len() * 4
    }

    pub fn qrow(&self, r: usize) -> &[i8] {
        &self.q[r * self.cols..(r + 1) * self.cols]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: force_mode/reset_mode flip process-global state, and lib
    // unit tests share one process — mode-switching behavior is covered
    // in `tests/integration_quant.rs`, which owns its process.

    #[test]
    fn mode_labels_and_tier_implications() {
        assert_eq!(QuantMode::Off.label(), "off");
        assert_eq!(QuantMode::F16.label(), "f16");
        assert_eq!(QuantMode::Q8.label(), "q8");
        assert!(!QuantMode::Off.f16_cold_tier());
        assert!(QuantMode::F16.f16_cold_tier());
        assert!(QuantMode::Q8.f16_cold_tier(), "q8 implies the f16 cold tier");
        assert!(QuantMode::Q8.q8_weights());
        assert!(!QuantMode::F16.q8_weights());
    }

    #[test]
    fn f16_well_known_values() {
        // (f32, expected half bits) — transcribed from the IEEE 754
        // tables, independent of the encoder implementation.
        let cases: &[(f32, u16)] = &[
            (0.0, 0x0000),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),         // largest normal half
            (65520.0, 0x7c00),         // halfway to 2^16, ties-to-even → inf
            (65519.9, 0x7bff),         // just under halfway stays finite
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
            (6.103_515_6e-5, 0x0400),  // smallest normal half
            (5.960_464_5e-8, 0x0001),  // smallest subnormal half
            (2.980_232_2e-8, 0x0000),  // exactly half the smallest subnormal: ties to even 0
            (3.0e-8, 0x0001),          // just above: rounds up
        ];
        for &(x, want) in cases {
            assert_eq!(f16_encode(x), want, "encode {x}");
        }
        assert_eq!(f16_decode(0x3c00), 1.0);
        assert_eq!(f16_decode(0x0001), 5.960_464_5e-8);
        assert!(f16_decode(f16_encode(f32::NAN)).is_nan());
    }

    #[test]
    fn pack_unpack_roundtrips_odd_and_even_counts() {
        for n in [0usize, 1, 2, 3, 7, 8] {
            let halves: Vec<u16> = (0..n).map(|i| (i as u16) * 1031 + 7).collect();
            let mut words = vec![0.0f32; packed_words(n)];
            pack_halves(&halves, &mut words);
            for (i, &h) in halves.iter().enumerate() {
                assert_eq!(unpack_half(&words, i), h, "n={n} idx={i}");
            }
        }
    }

    #[test]
    fn quant_matrix_error_bound_and_zero_rows() {
        let data: Vec<f32> = (0..24).map(|i| ((i * 37 % 17) as f32 - 8.0) * 0.31).collect();
        let qm = QuantMatrix::from_rows(&data, 4, 6);
        for r in 0..4 {
            let scale = qm.scales[r];
            for c in 0..6 {
                let want = data[r * 6 + c];
                let got = qm.qrow(r)[c] as f32 * scale;
                assert!(
                    (want - got).abs() <= scale * 0.5 + 1e-7,
                    "row {r} col {c}: {want} vs {got} (scale {scale})"
                );
            }
        }
        let zeros = QuantMatrix::from_rows(&[0.0; 6], 1, 6);
        assert_eq!(zeros.scales[0], 0.0);
        assert!(zeros.q.iter().all(|&q| q == 0));
    }
}
