//! Memory subsystem: the paged state arena and quantized cold storage.
//!
//! Serving "millions of users" rests on the linear mechanisms' O(r²·h)
//! constant-size decode state; this module is about how many of those
//! states a box can actually hold.  Three layers:
//!
//! * [`arena`] — slab/paged allocation for state buffers: uniform-size
//!   slots with free lists, generation-tagged handles, and
//!   page-pressure counters that drive cache admission/eviction.
//! * [`quant`] — the `PSF_QUANT=off|f16|q8` gate, the f16
//!   round-to-nearest-even conversion spec, and per-row int8 weight
//!   matrices (f32 accumulation; see `tensor::micro`'s q8 primitives).
//! * [`freeze`] — the cold form cached prompt-prefix states take:
//!   exact f32 under `off` (byte-identical serve output), compact f16
//!   under `f16`/`q8`.
//!
//! `PSF_QUANT=off` (the default) is bitwise-identical to the
//! pre-quantization tree; that contract is what CI's fixture rerun
//! pins.

pub mod arena;
pub mod freeze;
pub mod quant;

pub use arena::{ArenaStats, Handle, PagedBuf, StateArena};
pub use freeze::{FrozenRow, FrozenState};
pub use quant::{QuantMatrix, QuantMode};
