//! Bench substrate: a criterion-style harness (no criterion crate in this
//! environment) used by every `rust/benches/*.rs` target (harness = false).
//!
//! Provides warmup + timed iterations with mean/p50/p95 statistics, paper-
//! style table printing, CSV persistence under `bench_out/`, and a bench
//! *mode* knob so `cargo bench` stays tractable:
//!
//!   PSF_BENCH_MODE = smoke | quick (default) | full
//!
//! smoke: seconds per bench (CI / sanity); quick: minutes (defaults used in
//! EXPERIMENTS.md unless noted); full: the closest to the paper's protocol
//! this testbed supports.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Global bench effort level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Mode {
    Smoke,
    Quick,
    Full,
}

impl Mode {
    pub fn from_env() -> Mode {
        match std::env::var("PSF_BENCH_MODE").as_deref() {
            Ok("smoke") => Mode::Smoke,
            Ok("full") => Mode::Full,
            _ => Mode::Quick,
        }
    }

    /// Pick a value by mode.
    pub fn pick<T: Copy>(&self, smoke: T, quick: T, full: T) -> T {
        match self {
            Mode::Smoke => smoke,
            Mode::Quick => quick,
            Mode::Full => full,
        }
    }
}

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct Timing {
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    Timing {
        iters: n,
        mean_s: samples.iter().sum::<f64>() / n as f64,
        p50_s: samples[n / 2],
        p95_s: samples[((n - 1) as f64 * 0.95) as usize],
        min_s: samples[0],
    }
}

/// A paper-style results table: row labels x column labels of cells.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub col_header: String,
    pub cols: Vec<String>,
    pub rows: Vec<(String, Vec<String>)>,
}

impl Table {
    pub fn new(title: &str, col_header: &str, cols: Vec<String>) -> Self {
        Table { title: title.into(), col_header: col_header.into(), cols, rows: Vec::new() }
    }

    pub fn row(&mut self, label: &str, cells: Vec<String>) {
        assert_eq!(cells.len(), self.cols.len(), "row width mismatch");
        self.rows.push((label.into(), cells));
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([self.col_header.len()])
            .max()
            .unwrap_or(8)
            + 2;
        let col_w = self
            .cols
            .iter()
            .map(String::len)
            .chain(self.rows.iter().flat_map(|(_, cs)| cs.iter().map(String::len)))
            .max()
            .unwrap_or(8)
            + 2;
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.title);
        let _ = write!(s, "{:<label_w$}", self.col_header);
        for c in &self.cols {
            let _ = write!(s, "{c:>col_w$}");
        }
        let _ = writeln!(s);
        for (label, cells) in &self.rows {
            let _ = write!(s, "{label:<label_w$}");
            for c in cells {
                let _ = write!(s, "{c:>col_w$}");
            }
            let _ = writeln!(s);
        }
        s
    }

    /// Persist as CSV under `bench_out/<name>.csv`.
    pub fn save_csv(&self, name: &str) -> anyhow::Result<PathBuf> {
        let dir = out_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut s = String::new();
        let _ = write!(s, "{}", csv_cell(&self.col_header));
        for c in &self.cols {
            let _ = write!(s, ",{}", csv_cell(c));
        }
        let _ = writeln!(s);
        for (label, cells) in &self.rows {
            let _ = write!(s, "{}", csv_cell(label));
            for c in cells {
                let _ = write!(s, ",{}", csv_cell(c));
            }
            let _ = writeln!(s);
        }
        std::fs::write(&path, s)?;
        Ok(path)
    }
}

fn csv_cell(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Bench output directory: `$PSF_BENCH_OUT` or `./bench_out`.
pub fn out_dir() -> PathBuf {
    std::env::var_os("PSF_BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new("bench_out").to_path_buf())
}

/// Write a bench JSON artifact under `bench_out/<name>.json`:
/// `{"bench": name, <header pairs>, "results": [records...]}`.  Header
/// values are pre-encoded JSON fragments (`"\"Quick\""`, `"{...}"`,
/// `"128"`), records use the same hand-rolled encoder `metrics` uses —
/// one writer for every bench that emits a cross-PR tracking artifact.
pub fn write_json(
    name: &str,
    header: &[(&str, String)],
    records: &[crate::metrics::Record],
) -> anyhow::Result<PathBuf> {
    let mut json = format!("{{\n  \"bench\": \"{name}\",\n");
    for (k, v) in header {
        let _ = writeln!(json, "  \"{k}\": {v},");
    }
    json.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let dir = out_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json)?;
    Ok(path)
}

/// Standard bench banner.
pub fn banner(name: &str, paper_ref: &str, mode: Mode) {
    println!("\n########################################################");
    println!("# bench: {name}");
    println!("# regenerates: {paper_ref}");
    println!("# mode: {mode:?} (set PSF_BENCH_MODE=smoke|quick|full)");
    println!("########################################################");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_iters() {
        let mut calls = 0;
        let t = time_fn(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(t.iters, 5);
        assert!(t.mean_s >= 0.0);
        assert!(t.p50_s >= t.min_s);
    }

    #[test]
    fn table_renders_and_saves() {
        let mut t = Table::new("demo", "mech", vec!["512".into(), "1k".into()]);
        t.row("softmax", vec!["1.0".into(), "2.0".into()]);
        let r = t.render();
        assert!(r.contains("softmax"));
        assert!(r.contains("512"));
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(csv_cell("plain"), "plain");
        assert_eq!(csv_cell("a,b"), "\"a,b\"");
        assert_eq!(csv_cell("q\"q"), "\"q\"\"q\"");
    }

    #[test]
    fn mode_pick() {
        assert_eq!(Mode::Smoke.pick(1, 2, 3), 1);
        assert_eq!(Mode::Quick.pick(1, 2, 3), 2);
        assert_eq!(Mode::Full.pick(1, 2, 3), 3);
    }
}
