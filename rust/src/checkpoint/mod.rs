//! Checkpoint substrate: versioned binary format with CRC32 integrity.
//!
//! Layout (little-endian):
//!   magic "PSFCKPT1" (8 bytes)
//!   u64 step
//!   u32 n_sections
//!   per section: u32 name_len, name bytes, u64 f32_count, payload
//!   u32 crc32 of everything above
//!
//! Sections are free-form ("theta", "m", "v", ...) so the trainer can
//! store the flat parameter vector plus optimizer state in one file.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 8] = b"PSFCKPT1";

/// Std-only CRC-32 (IEEE 802.3, the zlib polynomial) with the same
/// `Hasher::new/update/finalize` surface as the `crc32fast` crate — this
/// environment is fully offline, so the checksum lives in-crate.
mod crc32 {
    const POLY: u32 = 0xedb8_8320;

    const fn table() -> [u32; 256] {
        let mut t = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    }

    // Const-evaluated once at compile time.
    static TABLE: [u32; 256] = table();

    pub struct Hasher {
        state: u32,
    }

    impl Hasher {
        pub fn new() -> Hasher {
            Hasher { state: 0xffff_ffff }
        }

        pub fn update(&mut self, data: &[u8]) {
            for &b in data {
                let idx = ((self.state ^ b as u32) & 0xff) as usize;
                self.state = TABLE[idx] ^ (self.state >> 8);
            }
        }

        pub fn finalize(self) -> u32 {
            self.state ^ 0xffff_ffff
        }
    }
}

// The historical call sites spell `crc32fast::Hasher`; keep that name
// aliased to the in-crate implementation.
use crc32 as crc32fast;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub sections: BTreeMap<String, Vec<f32>>,
}

#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    BadMagic,
    Truncated(usize),
    Crc { stored: u32, computed: u32 },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "io: {e}"),
            CkptError::BadMagic => write!(f, "bad magic (not a PSF checkpoint)"),
            CkptError::Truncated(off) => write!(f, "truncated checkpoint at offset {off}"),
            CkptError::Crc { stored, computed } => {
                write!(f, "crc mismatch: stored {stored:#010x} computed {computed:#010x}")
            }
        }
    }
}

impl std::error::Error for CkptError {}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> CkptError {
        CkptError::Io(e)
    }
}

impl Checkpoint {
    pub fn new(step: u64) -> Self {
        Checkpoint { step, sections: BTreeMap::new() }
    }

    pub fn with(mut self, name: &str, data: Vec<f32>) -> Self {
        self.sections.insert(name.to_string(), data);
        self
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.sections.get(name).map(Vec::as_slice)
    }

    pub fn save(&self, path: &Path) -> Result<(), CkptError> {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, data) in &self.sections {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut hasher = crc32fast::Hasher::new();
        hasher.update(&buf);
        let crc = hasher.finalize();
        buf.extend_from_slice(&crc.to_le_bytes());

        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        // Write-to-temp + rename for crash atomicity.
        let tmp = path.with_extension("tmp");
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(&buf)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self, CkptError> {
        let buf = fs::read(path)?;
        if buf.len() < MAGIC.len() + 8 + 4 + 4 {
            return Err(CkptError::Truncated(buf.len()));
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let mut hasher = crc32fast::Hasher::new();
        hasher.update(body);
        let computed = hasher.finalize();
        if stored != computed {
            return Err(CkptError::Crc { stored, computed });
        }
        if &body[..8] != MAGIC {
            return Err(CkptError::BadMagic);
        }
        let mut off = 8;
        let step = read_u64(body, &mut off)?;
        let n_sections = read_u32(body, &mut off)? as usize;
        let mut sections = BTreeMap::new();
        for _ in 0..n_sections {
            let name_len = read_u32(body, &mut off)? as usize;
            let name = String::from_utf8_lossy(
                body.get(off..off + name_len).ok_or(CkptError::Truncated(off))?,
            )
            .into_owned();
            off += name_len;
            let count = read_u64(body, &mut off)? as usize;
            let bytes = body
                .get(off..off + count * 4)
                .ok_or(CkptError::Truncated(off))?;
            off += count * 4;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            sections.insert(name, data);
        }
        Ok(Checkpoint { step, sections })
    }
}

fn read_u32(buf: &[u8], off: &mut usize) -> Result<u32, CkptError> {
    let b = buf.get(*off..*off + 4).ok_or(CkptError::Truncated(*off))?;
    *off += 4;
    Ok(u32::from_le_bytes(b.try_into().unwrap()))
}

fn read_u64(buf: &[u8], off: &mut usize) -> Result<u64, CkptError> {
    let b = buf.get(*off..*off + 8).ok_or(CkptError::Truncated(*off))?;
    *off += 8;
    Ok(u64::from_le_bytes(b.try_into().unwrap()))
}

/// Load a raw little-endian f32 file (the aot.py `.init.bin` format).
pub fn load_f32_file(path: &Path) -> anyhow::Result<Vec<f32>> {
    let bytes = fs::read(path)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "f32 file size not multiple of 4");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("psf_ckpt_test").join(name)
    }

    #[test]
    fn crc32_known_answer() {
        // The canonical IEEE CRC-32 check value.
        let mut h = crc32fast::Hasher::new();
        h.update(b"123456789");
        assert_eq!(h.finalize(), 0xcbf4_3926);
        // Incremental updates agree with one-shot hashing.
        let mut a = crc32fast::Hasher::new();
        a.update(b"1234");
        a.update(b"56789");
        let mut b = crc32fast::Hasher::new();
        b.update(b"123456789");
        assert_eq!(a.finalize(), b.finalize());
    }

    #[test]
    fn roundtrip() {
        let c = Checkpoint::new(42)
            .with("theta", vec![1.0, -2.5, 3.25])
            .with("m", vec![0.0; 7]);
        let p = tmpfile("a.ckpt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn corruption_detected() {
        let c = Checkpoint::new(1).with("theta", vec![1.0; 16]);
        let p = tmpfile("b.ckpt");
        c.save(&p).unwrap();
        let mut bytes = fs::read(&p).unwrap();
        bytes[24] ^= 0xff;
        fs::write(&p, &bytes).unwrap();
        assert!(matches!(Checkpoint::load(&p), Err(CkptError::Crc { .. })));
    }

    #[test]
    fn truncation_detected() {
        let c = Checkpoint::new(1).with("theta", vec![1.0; 16]);
        let p = tmpfile("c.ckpt");
        c.save(&p).unwrap();
        let bytes = fs::read(&p).unwrap();
        fs::write(&p, &bytes[..10]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn not_a_checkpoint() {
        let p = tmpfile("d.ckpt");
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        // valid CRC over garbage body shorter than magic check
        let mut buf = b"NOTMAGIC".to_vec();
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        let mut h = crc32fast::Hasher::new();
        h.update(&buf);
        let crc = h.finalize();
        buf.extend_from_slice(&crc.to_le_bytes());
        fs::write(&p, &buf).unwrap();
        assert!(matches!(Checkpoint::load(&p), Err(CkptError::BadMagic)));
    }

    #[test]
    fn empty_sections_ok() {
        let p = tmpfile("e.ckpt");
        Checkpoint::new(7).save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.step, 7);
        assert!(back.sections.is_empty());
    }
}
