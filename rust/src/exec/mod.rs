//! Execution substrate: std-only thread pools (no tokio/rayon here).
//!
//! Two layers with different contracts:
//!
//! * [`pool`] — the deterministic data-parallel compute backend behind
//!   the tensor/attention/prefill hot paths (fixed partitioning, bitwise
//!   identical results at any thread count, sized by `PSF_THREADS` /
//!   `--threads`);
//! * [`ThreadPool`] below — a plain FIFO job pool used by the batch
//!   prefetcher (data/prefetch.rs) to overlap host batch assembly with
//!   blocking PJRT execution, where ordering is a latency concern, not a
//!   numerics one.

pub mod pool;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            pending.fetch_sub(1, Ordering::SeqCst);
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool sized to available parallelism.
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(job)).expect("pool closed");
    }

    /// Block until every queued job has completed.
    pub fn wait_idle(&self) {
        while self.pending.load(Ordering::SeqCst) > 0 {
            std::thread::yield_now();
        }
    }

    /// Run `f(i)` for i in 0..n across the pool and collect results in
    /// index order (fork/join).
    pub fn map<T: Send + 'static>(
        &self,
        n: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, T)>, Receiver<(usize, T)>) = channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.spawn(move || {
                let out = f(i);
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.expect("worker dropped result")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel: workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map(32, |i| i * i);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map(8, |i| i + 1);
        assert_eq!(out, (1..9).collect::<Vec<_>>());
    }
}
