//! Deterministic data-parallel compute backend (std-only, no rayon).
//!
//! One long-lived global worker pool backs every hot path in the crate —
//! the tiled matmuls in `tensor`, row-parallel softmax/flash kernels in
//! `attn`, head-parallel prefill in `infer::model`, and per-session
//! stepping in `infer::scheduler`.  Sizing: `--threads` CLI flag (via
//! [`set_threads`]) > `PSF_THREADS` env var > `available_parallelism`.
//!
//! # Determinism contract
//!
//! Results are **bitwise identical** for every thread count, including 1.
//! Two rules make that true and every primitive here enforces them:
//!
//! 1. **Fixed partitioning** — work is split into chunks whose boundaries
//!    depend only on the problem shape ([`chunk_len`]), never on the
//!    thread count or on which worker claims what.
//! 2. **Disjoint writes, sequential reductions** — each chunk owns a
//!    disjoint output region and runs the exact same sequential inner
//!    loop the single-threaded code runs.  No cross-chunk floating-point
//!    reduction ever happens in claim order.
//!
//! Under those rules, scheduling is free: chunks are *claimed* by an
//! atomic counter (first come, first served), which affects wall time
//! only, never bytes.  `tests/determinism.rs` pins the contract for
//! forward logits, decode sessions, and served requests.
//!
//! # Execution model
//!
//! A parallel call packages its chunks as one [`Batch`], pushes it to the
//! pool's FIFO injector, then *participates*: the calling thread claims
//! and runs chunks of its own batch until none remain, and only then
//! blocks for stragglers.  Because every waiter first drains its own
//! batch, nested parallel sections cannot deadlock — a worker that opens
//! an inner batch finishes that inner batch itself even if every other
//! worker is busy.  With `threads = 1` (or inside [`serial`]) nothing is
//! ever enqueued and the call runs inline — the sequential path *is* the
//! 1-thread path.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Upper bound on chunks per parallel call.  Oversplitting relative to
/// the largest sane thread count keeps claim-order load balancing
/// effective while the partition itself stays thread-count independent.
const MAX_CHUNKS: usize = 64;

// ------------------------------------------------------------------ batch

/// Type-erased `&dyn Fn(usize) + Sync` whose pointee is only guaranteed
/// alive while `done < chunks` (the submitting call blocks until then).
#[derive(Clone, Copy)]
struct RunPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is Sync, and `Batch` never dereferences it after
// all `chunks` executions completed (see `Batch::work`).
unsafe impl Send for RunPtr {}
unsafe impl Sync for RunPtr {}

/// One parallel call: `chunks` tasks claimed by atomic counter.
struct Batch {
    run: RunPtr,
    chunks: usize,
    /// Next unclaimed chunk index; claims at or beyond `chunks` are no-ops,
    /// so an exhausted batch lingering in the injector is inert.
    next: AtomicUsize,
    /// Completed chunk count; the submitter returns only once this reaches
    /// `chunks`, which is what keeps the borrowed closure alive long enough.
    done: AtomicUsize,
    /// First panic payload out of any chunk, rethrown by the submitter.
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
    lock: Mutex<()>,
    cvar: Condvar,
}

impl Batch {
    /// Claim and run chunks until none remain.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.chunks {
                return;
            }
            // SAFETY: i < chunks, so done < chunks and the submitter is
            // still blocked in `wait` — the closure is alive.
            let f = unsafe { &*self.run.0 };
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = self.panic.lock().expect("pool batch panic slot");
                slot.get_or_insert(payload);
            }
            if self.done.fetch_add(1, Ordering::Release) + 1 == self.chunks {
                let _g = self.lock.lock().expect("pool batch lock");
                self.cvar.notify_all();
            }
        }
    }

    /// Block until every chunk has completed.
    fn wait(&self) {
        let mut g = self.lock.lock().expect("pool batch lock");
        while self.done.load(Ordering::Acquire) < self.chunks {
            g = self.cvar.wait(g).expect("pool batch wait");
        }
    }
}

// ------------------------------------------------------------------- pool

struct Shared {
    injector: Mutex<VecDeque<Arc<Batch>>>,
    cvar: Condvar,
    shutdown: AtomicBool,
}

struct Pool {
    shared: Arc<Shared>,
    /// Total compute threads this pool represents, including the caller;
    /// `threads - 1` worker threads are spawned.
    threads: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            injector: Mutex::new(VecDeque::new()),
            cvar: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (1..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Pool { shared, threads, handles: Mutex::new(handles) }
    }

    /// Stop accepting work and join the workers.  In-flight batches still
    /// complete: their submitters participate, so a batch never depends on
    /// pool workers for progress.
    fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cvar.notify_all();
        let handles = std::mem::take(&mut *self.handles.lock().expect("pool handles"));
        for h in handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        // Busy/idle accounting is wall-clock-only profiling: the obs
        // timers never influence which chunk a worker claims.
        let t_idle = crate::obs::phase::maybe_now();
        let batch = {
            let mut q = shared.injector.lock().expect("pool injector");
            loop {
                // Exhausted batches at the front are done being claimed
                // (their submitters drain them); drop our reference.
                while q.front().is_some_and(|b| b.next.load(Ordering::Relaxed) >= b.chunks) {
                    q.pop_front();
                }
                if let Some(b) = q.front() {
                    break Arc::clone(b);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.cvar.wait(q).expect("pool injector wait");
            }
        };
        crate::obs::phase::add_since(crate::obs::Phase::PoolIdle, t_idle);
        let t_busy = crate::obs::phase::maybe_now();
        batch.work();
        crate::obs::phase::add_since(crate::obs::Phase::PoolBusy, t_busy);
    }
}

// ------------------------------------------------- global pool + sizing

static POOL: Mutex<Option<Arc<Pool>>> = Mutex::new(None);

fn current() -> Arc<Pool> {
    let mut g = POOL.lock().expect("global pool");
    Arc::clone(g.get_or_insert_with(|| Arc::new(Pool::new(default_threads()))))
}

/// The thread count the pool adopts with no explicit override:
/// `PSF_THREADS` (>= 1) if set, else `available_parallelism`.
pub fn default_threads() -> usize {
    match std::env::var("PSF_THREADS").ok().and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Current total compute thread count (caller included).
pub fn threads() -> usize {
    current().threads
}

/// Thread budget for each of `processes` cooperating processes on this
/// machine (sharded serving spawns one model runner per shard; giving
/// every runner the full `default_threads` would oversubscribe the
/// cores `processes`-fold and serialize in the OS scheduler instead).
pub fn per_process_threads(processes: usize) -> usize {
    (default_threads() / processes.max(1)).max(1)
}

/// Replace the global pool with one of `n` threads (clamped to >= 1).
/// By the determinism contract this can never change results — only wall
/// time.  Safe to call at any point from a *non-worker* thread (the CLI
/// at startup, benches between sweeps, tests); in-flight parallel calls
/// on the old pool complete because their submitters self-drain.  Must
/// not be called from inside a parallel section.
pub fn set_threads(n: usize) {
    let fresh = Arc::new(Pool::new(n.max(1)));
    let old = POOL.lock().expect("global pool").replace(fresh);
    if let Some(old) = old {
        old.shutdown();
    }
}

thread_local! {
    static SERIAL: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with every pool primitive forced inline on this thread — the
/// `threads = 1` execution, regardless of the global pool size.  The
/// determinism tests compare this arm against the pooled arm byte for
/// byte.
pub fn serial<R>(f: impl FnOnce() -> R) -> R {
    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            SERIAL.with(|s| s.set(self.0));
        }
    }
    let _restore = SERIAL.with(|s| {
        let prev = s.get();
        s.set(true);
        Restore(prev)
    });
    f()
}

// ------------------------------------------------------------ primitives

/// Run `f(i)` for every `i < n`, distributed over the pool; blocks until
/// all complete.  `f` must confine each `i` to its own disjoint output
/// (the determinism contract).  Panics in any task are rethrown here.
pub fn par_iter(n: usize, f: impl Fn(usize) + Sync) {
    if n == 0 {
        return;
    }
    let inline = n == 1 || SERIAL.with(|s| s.get());
    let pool = if inline { None } else { Some(current()) };
    let pool = match pool {
        Some(p) if p.threads > 1 => p,
        _ => {
            for i in 0..n {
                f(i);
            }
            return;
        }
    };
    // SAFETY: the lifetime is erased only until `wait` returns below, and
    // `wait` returns only after all `chunks` executions completed; claims
    // past `chunks` never dereference (Batch::work).
    let run = RunPtr(unsafe { erase(&f) });
    let batch = Arc::new(Batch {
        run,
        chunks: n,
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        panic: Mutex::new(None),
        lock: Mutex::new(()),
        cvar: Condvar::new(),
    });
    {
        let mut q = pool.shared.injector.lock().expect("pool injector");
        q.push_back(Arc::clone(&batch));
    }
    pool.shared.cvar.notify_all();
    batch.work(); // participate: drain our own batch first…
    batch.wait(); // …then block for chunks claimed by workers
    let payload = batch.panic.lock().expect("pool batch panic slot").take();
    if let Some(payload) = payload {
        resume_unwind(payload);
    }
}

/// Erase the borrow lifetime of a task closure.  Both types are fat
/// pointers of identical layout; callers must not let the result outlive
/// the borrow (enforced by `par_iter` blocking until all chunks ran).
unsafe fn erase<'a>(
    f: &'a (dyn Fn(usize) + Sync + 'a),
) -> *const (dyn Fn(usize) + Sync + 'static) {
    std::mem::transmute(f)
}

/// Chunk length for `n` items: depends only on `(n, min_chunk)` — never
/// on thread count — so the partition is reproducible everywhere.
fn chunk_len(n: usize, min_chunk: usize) -> usize {
    n.div_ceil(MAX_CHUNKS).max(min_chunk.max(1))
}

/// Run `f(lo, hi)` over a fixed partition of `0..n` into ranges of at
/// least `min_chunk` items.  Each range must write only its own state.
pub fn par_ranges(n: usize, min_chunk: usize, f: impl Fn(usize, usize) + Sync) {
    if n == 0 {
        return;
    }
    let cl = chunk_len(n, min_chunk);
    let chunks = n.div_ceil(cl);
    if chunks <= 1 {
        f(0, n);
        return;
    }
    par_iter(chunks, |i| {
        let lo = i * cl;
        f(lo, (lo + cl).min(n));
    });
}

/// Raw pointer that may cross threads; every use must touch a region
/// disjoint from every concurrent use (the callers below guarantee it by
/// indexing with non-overlapping ranges).
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Split `data` (a row-major `rows x width` buffer) into fixed row chunks
/// and run `f(first_row, chunk)` on each in parallel.  The workhorse for
/// matmul/attention outputs: each chunk is a disjoint `&mut` region.
pub fn par_row_chunks<T: Send>(
    data: &mut [T],
    width: usize,
    min_rows: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(width > 0 && data.len() % width == 0, "par_row_chunks: ragged buffer");
    let rows = data.len() / width;
    let base = SendPtr(data.as_mut_ptr());
    par_ranges(rows, min_rows, |lo, hi| {
        // SAFETY: par_ranges hands out non-overlapping [lo, hi) ranges, so
        // each chunk slice is disjoint; T: Send moves the access across
        // threads; the underlying borrow outlives the blocking call.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(lo * width), (hi - lo) * width) };
        f(lo, chunk);
    });
}

/// Like [`par_row_chunks`], but the partition respects caller-defined row
/// *groups* of `rows_per_group` rows (the last group may be ragged): each
/// task receives `f(first_group, chunk)` where `chunk` covers whole
/// groups.  Used by kernels whose unit of work spans several rows (e.g. a
/// flash query block) so chunk boundaries never split a unit.
pub fn par_row_groups<T: Send>(
    data: &mut [T],
    width: usize,
    rows_per_group: usize,
    min_groups: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(width > 0 && data.len() % width == 0, "par_row_groups: ragged buffer");
    assert!(rows_per_group > 0, "par_row_groups: empty group");
    let rows = data.len() / width;
    let groups = rows.div_ceil(rows_per_group);
    if groups == 0 {
        return;
    }
    let base = SendPtr(data.as_mut_ptr());
    par_ranges(groups, min_groups, |lo, hi| {
        let row_lo = lo * rows_per_group;
        let row_hi = (hi * rows_per_group).min(rows);
        // SAFETY: group ranges are disjoint (par_ranges contract), so the
        // row ranges derived from them are disjoint too.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(row_lo * width), (row_hi - row_lo) * width)
        };
        f(lo, chunk);
    });
}

/// Parallel map over `&mut` items, results collected in index order.
/// Used where each unit owns real mutable state (per-head decode states,
/// per-session stepping) rather than a flat output buffer.
pub fn par_map_mut<T: Send, R: Send>(
    items: &mut [T],
    min_chunk: usize,
    f: impl Fn(usize, &mut T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    {
        let ip = SendPtr(items.as_mut_ptr());
        let op = SendPtr(out.as_mut_ptr());
        par_ranges(n, min_chunk, |lo, hi| {
            for i in lo..hi {
                // SAFETY: index i belongs to exactly one range, so both the
                // item and its result slot are accessed by one task only.
                let item = unsafe { &mut *ip.0.add(i) };
                let r = f(i, item);
                unsafe { *op.0.add(i) = Some(r) };
            }
        });
    }
    out.into_iter().map(|r| r.expect("parallel map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_process_threads_divides_and_floors_at_one() {
        let total = default_threads();
        assert_eq!(per_process_threads(1), total);
        assert_eq!(per_process_threads(2), (total / 2).max(1));
        assert_eq!(per_process_threads(0), total); // treated as 1 process
        assert_eq!(per_process_threads(total * 8), 1);
    }

    #[test]
    fn par_iter_covers_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        par_iter(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn partition_is_thread_count_independent() {
        // chunk_len depends only on (n, min_chunk).
        assert_eq!(chunk_len(10, 1), 1);
        assert_eq!(chunk_len(6400, 1), 100);
        assert_eq!(chunk_len(6400, 256), 256);
        assert_eq!(chunk_len(1, 8), 8);
    }

    #[test]
    fn par_ranges_tiles_exactly() {
        let n = 1003;
        let seen: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_ranges(n, 4, |lo, hi| {
            assert!(lo < hi && hi <= n);
            for s in &seen[lo..hi] {
                s.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(seen.iter().all(|s| s.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_row_chunks_writes_disjoint_rows() {
        let mut data = vec![0u32; 129 * 7];
        par_row_chunks(&mut data, 7, 2, |row0, chunk| {
            for (r, row) in chunk.chunks_mut(7).enumerate() {
                row.fill((row0 + r) as u32);
            }
        });
        for (r, row) in data.chunks(7).enumerate() {
            assert!(row.iter().all(|&v| v == r as u32), "row {r}");
        }
    }

    #[test]
    fn par_row_groups_never_splits_a_group() {
        // 29 rows in groups of 8: tasks must see group-aligned chunks and
        // the final ragged group (5 rows) must arrive whole.
        let mut data = vec![0u32; 29 * 3];
        par_row_groups(&mut data, 3, 8, 1, |g0, chunk| {
            let rows = chunk.len() / 3;
            assert_eq!(g0 * 8 % 8, 0);
            // Chunk covers whole groups except possibly the ragged tail.
            assert!(rows % 8 == 0 || g0 * 8 + rows == 29, "g0={g0} rows={rows}");
            for (r, row) in chunk.chunks_mut(3).enumerate() {
                row.fill((g0 * 8 + r) as u32);
            }
        });
        for (r, row) in data.chunks(3).enumerate() {
            assert!(row.iter().all(|&v| v == r as u32), "row {r}");
        }
    }

    #[test]
    fn par_map_mut_orders_results_and_mutates() {
        let mut items: Vec<usize> = (0..100).collect();
        let out = par_map_mut(&mut items, 1, |i, it| {
            *it += 1;
            i * 2
        });
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(items, (1..101).collect::<Vec<_>>());
    }

    #[test]
    fn serial_matches_parallel_bytes() {
        let work = || {
            let mut out = vec![0.0f32; 64 * 9];
            par_row_chunks(&mut out, 9, 1, |row0, chunk| {
                for (r, row) in chunk.chunks_mut(9).enumerate() {
                    let mut acc = 0.0f32;
                    for (j, v) in row.iter_mut().enumerate() {
                        acc += ((row0 + r) * 31 + j) as f32 * 0.001;
                        *v = acc.sin();
                    }
                }
            });
            out
        };
        let pooled = work();
        let inline = serial(work);
        assert_eq!(pooled, inline);
    }

    #[test]
    fn nested_parallel_sections_complete() {
        let total = AtomicUsize::new(0);
        par_iter(8, |_| {
            par_iter(8, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn task_panic_propagates_to_submitter() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            par_iter(16, |i| {
                if i == 7 {
                    panic!("boom {i}");
                }
            });
        }));
        assert!(caught.is_err());
        // The pool must still be usable afterwards.
        let n = AtomicUsize::new(0);
        par_iter(4, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 4);
    }
}
