//! CLI substrate: declarative flag parsing (no clap in this environment).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative argument parser for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
    positional: Vec<(String, String)>,
}

/// Parse result.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    BadValue { flag: String, value: String, msg: String },
    Help,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(name) => write!(f, "unknown flag: --{name}"),
            CliError::MissingValue(name) => write!(f, "flag --{name} requires a value"),
            CliError::BadValue { flag, value, msg } => {
                write!(f, "invalid value for --{flag}: {value} ({msg})")
            }
            CliError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args { program: program.into(), about: about.into(), ..Default::default() }
    }

    /// Flag taking a value, with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: Some(default.into()),
        });
        self
    }

    /// Required flag taking a value.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: None,
        });
        self
    }

    /// Boolean switch (default false).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positional.push((name.into(), help.into()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nusage: {} [flags] {}", self.program,
                         self.positional.iter().map(|(n, _)| format!("<{n}>"))
                             .collect::<Vec<_>>().join(" "));
        if !self.flags.is_empty() {
            let _ = writeln!(s, "\nflags:");
            for f in &self.flags {
                let v = if f.takes_value {
                    match &f.default {
                        Some(d) => format!(" <value> (default: {d})"),
                        None => " <value> (required)".to_string(),
                    }
                } else {
                    String::new()
                };
                let _ = writeln!(s, "  --{}{}\n      {}", f.name, v, f.help);
            }
        }
        for (n, h) in &self.positional {
            let _ = writeln!(s, "  <{n}>: {h}");
        }
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Parsed, CliError> {
        let mut out = Parsed::default();
        for f in &self.flags {
            if let Some(d) = &f.default {
                out.values.insert(f.name.clone(), d.clone());
            }
            if !f.takes_value {
                out.bools.insert(f.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError::Help);
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or(CliError::MissingValue(name.clone()))?
                        }
                    };
                    out.values.insert(name, value);
                } else {
                    out.bools.insert(name, true);
                }
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if f.takes_value && f.default.is_none() && !out.values.contains_key(&f.name) {
                return Err(CliError::MissingValue(f.name.clone()));
            }
        }
        Ok(out)
    }
}

impl Parsed {
    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(String::as_str).unwrap_or("")
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.typed(name)
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.typed(name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.typed(name)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list of usizes ("64,128,256").
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim().parse::<usize>().map_err(|e| CliError::BadValue {
                    flag: name.into(),
                    value: s.into(),
                    msg: e.to_string(),
                })
            })
            .collect()
    }

    fn typed<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.str(name);
        raw.parse::<T>().map_err(|e| CliError::BadValue {
            flag: name.into(),
            value: raw.into(),
            msg: e.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new("t", "test")
            .opt("steps", "100", "steps")
            .req("name", "run name")
            .switch("verbose", "chatty")
            .positional("input", "file")
    }

    #[test]
    fn defaults_and_overrides() {
        let p = spec().parse(&argv(&["--name", "x"])).unwrap();
        assert_eq!(p.usize("steps").unwrap(), 100);
        assert_eq!(p.str("name"), "x");
        assert!(!p.flag("verbose"));

        let p = spec().parse(&argv(&["--steps=7", "--name", "y", "--verbose", "in.txt"])).unwrap();
        assert_eq!(p.usize("steps").unwrap(), 7);
        assert!(p.flag("verbose"));
        assert_eq!(p.positional(), &["in.txt".to_string()]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(matches!(spec().parse(&argv(&[])), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(matches!(
            spec().parse(&argv(&["--name", "x", "--nope"])),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn help_short_circuits() {
        assert!(matches!(spec().parse(&argv(&["-h"])), Err(CliError::Help)));
    }

    #[test]
    fn usize_list_parses() {
        let p = Args::new("t", "t").opt("ctxs", "64,128", "ctx list")
            .parse(&argv(&[])).unwrap();
        assert_eq!(p.usize_list("ctxs").unwrap(), vec![64, 128]);
    }

    #[test]
    fn usage_mentions_flags() {
        let u = spec().usage();
        assert!(u.contains("--steps"));
        assert!(u.contains("required"));
    }
}
