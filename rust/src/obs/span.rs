//! Span tracing: thread-local span stacks feeding per-thread ring
//! buffers of completed events.
//!
//! A [`Span`] is an RAII guard: construction records the start
//! timestamp, drop records the duration and pushes one event into the
//! calling thread's buffer.  Buffers are bounded rings ([`RING_CAP`]
//! events; oldest dropped first, with a drop counter) registered in a
//! global list so [`drain_all`] can collect everything at flush time.
//!
//! Timestamps are wall-clock microseconds: a per-process base pair
//! (`SystemTime` + `Instant`) is captured once, and every event stamp is
//! `wall_base + monotonic_elapsed` — monotonic within a process, and
//! roughly aligned *across* processes so gateway and runner spans land
//! on one Perfetto timeline.  Exact cross-process ordering is not
//! promised; the shared trace id is what stitches a request together.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Per-thread event capacity.  At ~100 bytes/event this bounds tracing
/// memory to a few MiB per thread no matter how long the server runs.
pub const RING_CAP: usize = 1 << 16;

/// One completed span, ready for trace-event export.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: String,
    /// Category shown in the trace UI (`gateway` / `serve` / `kernel` /
    /// `train` / `shard`).
    pub cat: &'static str,
    /// Start, microseconds since the UNIX epoch.
    pub ts_us: u64,
    pub dur_us: u64,
    /// Small per-process thread ordinal (not the OS tid).
    pub tid: u64,
    /// Request trace id active on the thread when the span closed
    /// (0 = none).
    pub trace_id: u64,
    /// Nesting depth at open (0 = top-level span on its thread).
    pub depth: u32,
}

struct ThreadBuf {
    tid: u64,
    events: Mutex<VecDeque<Event>>,
    /// Drops since the last [`drain_all`] (folded into the trace file).
    dropped: AtomicU64,
    /// Drops since process start — never reset, so the Prometheus
    /// exposition stays a monotone counter across trace flushes.
    dropped_total: AtomicU64,
}

static REGISTRY: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TRACE_ID: Cell<u64> = const { Cell::new(0) };
    static DEPTH: Cell<u32> = const { Cell::new(0) };
    static BUF: RefCell<Option<Arc<ThreadBuf>>> = const { RefCell::new(None) };
}

/// (wall µs at base, monotonic base) — captured once per process.
fn time_base() -> &'static (u64, Instant) {
    static BASE: OnceLock<(u64, Instant)> = OnceLock::new();
    BASE.get_or_init(|| {
        let wall =
            SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_micros() as u64).unwrap_or(0);
        (wall, Instant::now())
    })
}

/// Current timestamp in epoch microseconds (monotonic within the
/// process).
pub(crate) fn now_us() -> u64 {
    let (wall, mono) = *time_base();
    wall + mono.elapsed().as_micros() as u64
}

/// Set the request trace id for spans opened on this thread from now on.
/// Worker threads call this when they pick up a job; handler threads
/// when they admit a request.
pub fn set_trace_id(id: u64) {
    TRACE_ID.with(|t| t.set(id));
}

/// The trace id active on this thread (0 = none).
pub fn current_trace_id() -> u64 {
    TRACE_ID.with(|t| t.get())
}

/// Open a span.  When tracing is off this is one relaxed load and a
/// no-op guard — no clock read, no allocation.
pub fn span(name: &str, cat: &'static str) -> Span {
    if !super::tracing_on() {
        return Span { name: String::new(), cat, start_us: 0, depth: 0, active: false };
    }
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    Span { name: name.to_string(), cat, start_us: now_us(), depth, active: true }
}

/// RAII span guard — see [`span`].
pub struct Span {
    name: String,
    cat: &'static str,
    start_us: u64,
    depth: u32,
    active: bool,
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let dur_us = now_us().saturating_sub(self.start_us);
        let ev = Event {
            name: std::mem::take(&mut self.name),
            cat: self.cat,
            ts_us: self.start_us,
            dur_us,
            tid: 0, // stamped in push_event
            trace_id: current_trace_id(),
            depth: self.depth,
        };
        push_event(ev);
    }
}

fn push_event(mut ev: Event) {
    BUF.with(|slot| {
        let mut slot = slot.borrow_mut();
        let buf = slot.get_or_insert_with(|| {
            let buf = Arc::new(ThreadBuf {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                events: Mutex::new(VecDeque::new()),
                dropped: AtomicU64::new(0),
                dropped_total: AtomicU64::new(0),
            });
            REGISTRY.lock().expect("obs span registry").push(Arc::clone(&buf));
            buf
        });
        ev.tid = buf.tid;
        let mut q = buf.events.lock().expect("obs span ring");
        if q.len() >= RING_CAP {
            q.pop_front();
            buf.dropped.fetch_add(1, Ordering::Relaxed);
            buf.dropped_total.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(ev);
    });
}

/// Take every buffered event from every thread (clearing the buffers)
/// plus the total count of events dropped to ring overflow.
pub fn drain_all() -> (Vec<Event>, u64) {
    let registry = REGISTRY.lock().expect("obs span registry");
    let mut out = Vec::new();
    let mut dropped = 0u64;
    for buf in registry.iter() {
        let drained = std::mem::take(&mut *buf.events.lock().expect("obs span ring"));
        out.extend(drained);
        dropped += buf.dropped.swap(0, Ordering::Relaxed);
    }
    out.sort_by_key(|e| e.ts_us);
    (out, dropped)
}

/// Non-destructive per-thread ring stats for scrapes and incident
/// dumps: `(tid, ring occupancy, drops since process start)`.
pub fn ring_stats() -> Vec<(u64, usize, u64)> {
    let registry = REGISTRY.lock().expect("obs span registry");
    registry
        .iter()
        .map(|buf| {
            (
                buf.tid,
                buf.events.lock().expect("obs span ring").len(),
                buf.dropped_total.load(Ordering::Relaxed),
            )
        })
        .collect()
}

/// Non-destructive copy of the most recent `limit` events across all
/// threads (by timestamp).  Incident dumps use this so a crash report
/// carries the spans without consuming the pending trace flush.
pub fn recent(limit: usize) -> Vec<Event> {
    let registry = REGISTRY.lock().expect("obs span registry");
    let mut out = Vec::new();
    for buf in registry.iter() {
        out.extend(buf.events.lock().expect("obs span ring").iter().cloned());
    }
    drop(registry);
    out.sort_by_key(|e| e.ts_us);
    if out.len() > limit {
        out.drain(..out.len() - limit);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // The span substrate is global per-process; integration-level
    // lifecycle tests live in `tests/obs_trace.rs` (their own process).
    // Here: only the parts testable without toggling the global flag.

    #[test]
    fn trace_id_is_thread_local() {
        set_trace_id(0xabc);
        assert_eq!(current_trace_id(), 0xabc);
        let other = std::thread::spawn(|| current_trace_id()).join().unwrap();
        assert_eq!(other, 0, "fresh thread starts with no trace id");
        set_trace_id(0);
    }

    #[test]
    fn disabled_span_is_inert() {
        if super::super::tracing_on() {
            return; // another test enabled tracing; skip rather than race
        }
        let before = REGISTRY.lock().unwrap().len();
        {
            let _s = span("noop", "test");
        }
        assert_eq!(REGISTRY.lock().unwrap().len(), before, "no buffer registered when off");
        DEPTH.with(|d| assert_eq!(d.get(), 0));
    }

    #[test]
    fn now_us_is_monotonic() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
        // Sanity: the base is a plausible epoch stamp (after 2020).
        assert!(a > 1_577_836_800_000_000, "epoch base looks wrong: {a}");
    }
}
