//! Incident dumps: when something dies, write what the process knew.
//!
//! An incident is a single JSON file (`incident.json`) assembled from
//! state the other `obs` tiers already keep in memory: the flight
//! recorder's time-series window, the first sentinel fault with full
//! attribution, recent spans and ring drop counters, the kernel phase
//! table, build configuration (SIMD backend, quant mode, mechanism),
//! and the in-flight request registry.  Three paths trigger one:
//! a panic (via [`install_panic_hook`]), the first sentinel fault
//! ([`sentinel_trip`]), and the SIGTERM drain (the serve shutdown path
//! calls [`dump`] when an incident path is configured).  The shard
//! supervisor also dumps when it declares a runner dead, splicing any
//! per-runner incident files (passed to children as `--incident
//! <base>.runner<id>`) into the gateway's dump.
//!
//! First write wins: a runner-death incident is not overwritten by the
//! SIGTERM that follows it.  Unconfigured (no `--incident` flag, no
//! `PSF_INCIDENT`), every entry point is a no-op.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::metrics::json_escape;

static PATH: Mutex<Option<PathBuf>> = Mutex::new(None);
static WRITTEN: AtomicBool = AtomicBool::new(false);
static MECH: Mutex<Option<String>> = Mutex::new(None);
static RUNNER_FILES: Mutex<Vec<PathBuf>> = Mutex::new(Vec::new());
static INFLIGHT: Mutex<Vec<Inflight>> = Mutex::new(Vec::new());

/// Summary of one admitted-but-unfinished request, carried into dumps.
#[derive(Clone, Debug)]
struct Inflight {
    id: u64,
    prompt_tokens: usize,
    max_new: usize,
    ts_us: u64,
}

/// Survive lock poisoning: dumps run inside panic hooks, where refusing
/// to report because some unrelated thread died defeats the point.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Set the incident file path.  Nothing is written until a trigger
/// fires.
pub fn configure(path: &Path) {
    *lock(&PATH) = Some(path.to_path_buf());
}

pub fn configured() -> bool {
    lock(&PATH).is_some()
}

pub fn path() -> Option<PathBuf> {
    lock(&PATH).clone()
}

/// Record the mechanism label dumps will carry (ungated — one call at
/// model build).
pub fn set_mechanism(label: &str) {
    *lock(&MECH) = Some(label.to_string());
}

/// Tell the gateway-side dump where runner children write their own
/// incident files, so a gateway incident embeds them.
pub fn set_runner_files(files: Vec<PathBuf>) {
    *lock(&RUNNER_FILES) = files;
}

/// Register an admitted request.  `id` is the request trace id.
pub fn track(id: u64, prompt_tokens: usize, max_new: usize) {
    lock(&INFLIGHT).push(Inflight { id, prompt_tokens, max_new, ts_us: super::span::now_us() });
}

/// Remove a finished (or failed) request from the registry.
pub fn untrack(id: u64) {
    lock(&INFLIGHT).retain(|r| r.id != id);
}

/// Requests currently admitted and unfinished — doubles as the queue
/// depth gauge for the flight recorder.
pub fn inflight_count() -> usize {
    lock(&INFLIGHT).len()
}

/// Install a panic hook that writes an incident before the default hook
/// prints the backtrace.  Safe to call more than once per process;
/// no-ops at panic time unless a path is configured.
pub fn install_panic_hook() {
    static INSTALLED: AtomicBool = AtomicBool::new(false);
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let prior = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let msg = match info.payload().downcast_ref::<&str>() {
            Some(s) => (*s).to_string(),
            None => info
                .payload()
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_else(|| "non-string panic payload".into()),
        };
        let at = info.location().map(|l| format!(" at {}:{}", l.file(), l.line()));
        let _ = dump(&format!("panic: {msg}{}", at.unwrap_or_default()));
        prior(info);
    }));
}

/// Called by the sentinel layer on the *first* recorded fault.
pub(crate) fn sentinel_trip() {
    let reason = match super::sentinel::fault() {
        Some(f) => format!("sentinel trip: {} at {}", f.kind.name(), f.site.name()),
        None => "sentinel trip".to_string(),
    };
    let _ = dump(&reason);
}

/// Write the incident file.  Returns the path on the first successful
/// write; `None` when unconfigured or an incident was already written.
pub fn dump(reason: &str) -> Option<PathBuf> {
    let path = path()?;
    if WRITTEN.swap(true, Ordering::SeqCst) {
        return None;
    }
    // Capture one final flight-recorder frame so the dump's window ends
    // at the incident, not at the last timer tick.
    super::recorder::sample_once();
    let body = render_json(reason);
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, body) {
        Ok(()) => {
            eprintln!("psf incident: {} -> {}", reason, path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!("psf incident: failed to write {}: {e}", path.display());
            None
        }
    }
}

fn render_json(reason: &str) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(16 * 1024);
    let _ = write!(
        out,
        "{{\"kind\":\"incident\",\"reason\":{},\"ts_us\":{},\"pid\":{},\
         \"uptime_seconds\":{:.3}",
        json_escape(reason),
        super::span::now_us(),
        std::process::id(),
        super::uptime_secs(),
    );
    // Build configuration: what was *resolved*, plus the raw env knobs.
    let _ = write!(
        out,
        ",\"build\":{{\"version\":{},\"mech\":{},\"simd\":{},\"quant\":{},\
         \"env_simd\":{},\"env_quant\":{},\"env_threads\":{}}}",
        json_escape(env!("CARGO_PKG_VERSION")),
        match lock(&MECH).as_deref() {
            Some(m) => json_escape(m),
            None => "null".into(),
        },
        json_escape(crate::tensor::micro::backend_label()),
        json_escape(crate::mem::quant::mode().label()),
        env_or_null("PSF_SIMD"),
        env_or_null("PSF_QUANT"),
        env_or_null("PSF_THREADS"),
    );
    let _ = write!(
        out,
        ",\"sentinel\":{{\"enabled\":{},\"trips\":{},\"fault\":{}}}",
        super::sentinels_on(),
        super::sentinel::trip_count(),
        super::sentinel::fault_json(),
    );
    out.push_str(",\"phases\":[");
    for (i, (name, nanos, calls)) in super::phase::totals().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"name\":{},\"nanos\":{nanos},\"calls\":{calls}}}",
            json_escape(name)
        );
    }
    out.push(']');
    let _ = write!(out, ",\"flight\":{}", super::recorder::snapshot_json());
    out.push_str(",\"span_rings\":[");
    for (i, (tid, occ, dropped)) in super::span::ring_stats().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{{\"tid\":{tid},\"events\":{occ},\"dropped_total\":{dropped}}}");
    }
    out.push_str("],\"spans\":[");
    for (i, ev) in super::span::recent(RECENT_SPANS).iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"name\":{},\"cat\":{},\"ts_us\":{},\"dur_us\":{},\"tid\":{},\
             \"trace_id\":{},\"depth\":{}}}",
            json_escape(&ev.name),
            json_escape(ev.cat),
            ev.ts_us,
            ev.dur_us,
            ev.tid,
            ev.trace_id,
            ev.depth,
        );
    }
    out.push_str("],\"inflight\":[");
    let now = super::span::now_us();
    for (i, r) in lock(&INFLIGHT).iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"id\":{},\"prompt_tokens\":{},\"max_new\":{},\"age_us\":{}}}",
            r.id,
            r.prompt_tokens,
            r.max_new,
            now.saturating_sub(r.ts_us),
        );
    }
    out.push_str("],\"runners\":[");
    let mut wrote = 0usize;
    for file in lock(&RUNNER_FILES).iter() {
        let Ok(text) = std::fs::read_to_string(file) else {
            continue; // runner never wrote one (e.g. SIGKILL) — expected
        };
        // Embed only if it parses: a half-written runner file must not
        // corrupt the gateway's dump.
        if super::trace::parse_value(&text).is_err() {
            continue;
        }
        if wrote > 0 {
            out.push(',');
        }
        out.push_str(text.trim());
        wrote += 1;
    }
    out.push_str("]}");
    out
}

/// Spans embedded in a dump — enough to see the last moments without
/// ballooning the file.
const RECENT_SPANS: usize = 256;

fn env_or_null(key: &str) -> String {
    match std::env::var(key) {
        Ok(v) => json_escape(&v),
        Err(_) => "null".into(),
    }
}

// ---------------------------------------------------------------- report

/// Render an incident file as a human-readable report
/// (`psf incident-report`).
pub fn report(text: &str) -> Result<String, String> {
    use std::fmt::Write as _;
    let root = super::trace::parse_value(text)?;
    if root.get("kind").and_then(|v| v.as_str()) != Some("incident") {
        return Err("not an incident file (missing kind=incident)".into());
    }
    let mut out = String::new();
    let reason = root.get("reason").and_then(|v| v.as_str()).unwrap_or("?");
    let _ = writeln!(out, "incident: {reason}");
    let _ = writeln!(
        out,
        "  pid {}  uptime {:.1}s",
        root.get("pid").and_then(|v| v.as_u64()).unwrap_or(0),
        root.get("uptime_seconds").and_then(|v| v.as_f64()).unwrap_or(0.0),
    );
    if let Some(build) = root.get("build") {
        let _ = writeln!(
            out,
            "  build: v{}  mech={}  simd={}  quant={}",
            build.get("version").and_then(|v| v.as_str()).unwrap_or("?"),
            build.get("mech").and_then(|v| v.as_str()).unwrap_or("-"),
            build.get("simd").and_then(|v| v.as_str()).unwrap_or("?"),
            build.get("quant").and_then(|v| v.as_str()).unwrap_or("?"),
        );
    }
    if let Some(sentinel) = root.get("sentinel") {
        let trips = sentinel.get("trips").and_then(|v| v.as_u64()).unwrap_or(0);
        match sentinel.get("fault") {
            Some(f) if f.get("kind").is_some() => {
                let _ = writeln!(
                    out,
                    "  fault: {} at {} (mechanism {}, layer {}, head {}, step {}, token {})",
                    f.get("kind").and_then(|v| v.as_str()).unwrap_or("?"),
                    f.get("site").and_then(|v| v.as_str()).unwrap_or("?"),
                    f.get("mechanism").and_then(|v| v.as_str()).unwrap_or("-"),
                    f.get("layer").and_then(|v| v.as_f64()).unwrap_or(-1.0),
                    f.get("head").and_then(|v| v.as_f64()).unwrap_or(-1.0),
                    f.get("step").and_then(|v| v.as_f64()).unwrap_or(-1.0),
                    f.get("token").and_then(|v| v.as_f64()).unwrap_or(-1.0),
                );
                let _ = writeln!(
                    out,
                    "         value={}  absmax={}  detail={:?}  ({} trip(s) total)",
                    f.get("value").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                    f.get("absmax").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                    f.get("detail").and_then(|v| v.as_str()).unwrap_or(""),
                    trips,
                );
            }
            _ => {
                let _ = writeln!(out, "  fault: none recorded ({trips} trip(s))");
            }
        }
    }
    if let Some(phases) = root.get("phases").and_then(|v| v.as_arr()) {
        if !phases.is_empty() {
            let _ = writeln!(out, "  phases:");
            for p in phases {
                let _ = writeln!(
                    out,
                    "    {:<14} {:>10.3} ms  {:>8} calls",
                    p.get("name").and_then(|v| v.as_str()).unwrap_or("?"),
                    p.get("nanos").and_then(|v| v.as_f64()).unwrap_or(0.0) / 1e6,
                    p.get("calls").and_then(|v| v.as_u64()).unwrap_or(0),
                );
            }
        }
    }
    if let Some(flight) = root.get("flight") {
        let frames = flight.get("frames").and_then(|v| v.as_arr()).map(|a| a.len()).unwrap_or(0);
        let interval = flight.get("interval_ms").and_then(|v| v.as_u64()).unwrap_or(0);
        let _ = writeln!(
            out,
            "  flight recorder: {frames} frame(s) @ {interval}ms (~{:.1}s window)",
            frames as f64 * interval as f64 / 1e3,
        );
        if let Some(last) = flight.get("frames").and_then(|v| v.as_arr()).and_then(|a| a.last()) {
            if let Some(super::trace::JVal::Obj(kv)) = last.get("gauges") {
                let _ = writeln!(out, "  last frame:");
                for (k, v) in kv {
                    let _ = writeln!(
                        out,
                        "    {:<28} {}",
                        k,
                        v.as_f64().map(|x| format!("{x}")).unwrap_or_else(|| "null".into()),
                    );
                }
            }
        }
        if let Some(notes) = flight.get("notes").and_then(|v| v.as_arr()) {
            if !notes.is_empty() {
                let tail = &notes[notes.len().saturating_sub(5)..];
                let _ = writeln!(out, "  recent notes:");
                for n in tail {
                    let _ = writeln!(
                        out,
                        "    {} = {}",
                        n.get("name").and_then(|v| v.as_str()).unwrap_or("?"),
                        n.get("value").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                    );
                }
            }
        }
    }
    if let Some(inflight) = root.get("inflight").and_then(|v| v.as_arr()) {
        let _ = writeln!(out, "  in-flight requests: {}", inflight.len());
        for r in inflight.iter().take(10) {
            let _ = writeln!(
                out,
                "    id={} prompt_tokens={} max_new={} age={:.1}s",
                r.get("id").and_then(|v| v.as_u64()).unwrap_or(0),
                r.get("prompt_tokens").and_then(|v| v.as_u64()).unwrap_or(0),
                r.get("max_new").and_then(|v| v.as_u64()).unwrap_or(0),
                r.get("age_us").and_then(|v| v.as_f64()).unwrap_or(0.0) / 1e6,
            );
        }
    }
    if let Some(rings) = root.get("span_rings").and_then(|v| v.as_arr()) {
        let events: u64 = rings.iter().filter_map(|r| r.get("events")?.as_u64()).sum();
        let dropped: u64 = rings.iter().filter_map(|r| r.get("dropped_total")?.as_u64()).sum();
        let spans = root.get("spans").and_then(|v| v.as_arr()).map(|a| a.len()).unwrap_or(0);
        let _ = writeln!(
            out,
            "  spans: {spans} embedded, {events} buffered across {} ring(s), {dropped} dropped",
            rings.len(),
        );
    }
    if let Some(runners) = root.get("runners").and_then(|v| v.as_arr()) {
        if !runners.is_empty() {
            let _ = writeln!(out, "  runner incidents: {}", runners.len());
            for r in runners {
                let _ = writeln!(
                    out,
                    "    pid {}: {}",
                    r.get("pid").and_then(|v| v.as_u64()).unwrap_or(0),
                    r.get("reason").and_then(|v| v.as_str()).unwrap_or("?"),
                );
            }
        }
    }
    Ok(out)
}

/// Reset trigger state (tests).
#[cfg(test)]
pub(crate) fn reset_for_tests() {
    *lock(&PATH) = None;
    WRITTEN.store(false, Ordering::SeqCst);
    *lock(&MECH) = None;
    lock(&RUNNER_FILES).clear();
    lock(&INFLIGHT).clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn unconfigured_dump_is_noop() {
        let _g = TEST_LOCK.lock().unwrap();
        reset_for_tests();
        assert!(dump("test").is_none());
        reset_for_tests();
    }

    #[test]
    fn dump_writes_parseable_json_once() {
        let _g = TEST_LOCK.lock().unwrap();
        reset_for_tests();
        let dir = std::env::temp_dir().join("psf_incident_test");
        let path = dir.join("incident.json");
        let _ = std::fs::remove_file(&path);
        configure(&path);
        set_mechanism("psk4_r8_b16");
        track(7, 12, 32);
        let wrote = dump("unit test incident").expect("first dump writes");
        assert_eq!(wrote, path);
        assert!(dump("second").is_none(), "first write wins");
        let text = std::fs::read_to_string(&path).unwrap();
        let root = crate::obs::trace::parse_value(&text).expect("valid json");
        assert_eq!(root.get("kind").and_then(|v| v.as_str()), Some("incident"));
        assert_eq!(root.get("reason").and_then(|v| v.as_str()), Some("unit test incident"));
        assert_eq!(
            root.get("build").and_then(|b| b.get("mech")).and_then(|v| v.as_str()),
            Some("psk4_r8_b16")
        );
        let inflight = root.get("inflight").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(inflight.len(), 1);
        assert_eq!(inflight[0].get("prompt_tokens").and_then(|v| v.as_u64()), Some(12));
        let rendered = report(&text).expect("report renders");
        assert!(rendered.contains("incident: unit test incident"));
        assert!(rendered.contains("mech=psk4_r8_b16"));
        untrack(7);
        let _ = std::fs::remove_file(&path);
        reset_for_tests();
    }

    #[test]
    fn track_untrack_balance() {
        let _g = TEST_LOCK.lock().unwrap();
        reset_for_tests();
        track(1, 4, 8);
        track(2, 4, 8);
        assert_eq!(inflight_count(), 2);
        untrack(1);
        assert_eq!(inflight_count(), 1);
        untrack(2);
        assert_eq!(inflight_count(), 0);
        reset_for_tests();
    }

    #[test]
    fn report_rejects_non_incident_json() {
        let _g = TEST_LOCK.lock().unwrap();
        assert!(report("{\"kind\":\"other\"}").is_err());
        assert!(report("not json").is_err());
    }
}
