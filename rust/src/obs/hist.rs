//! Fixed-bucket atomic histograms for latency distributions.
//!
//! A [`Hist`] is a set of ascending upper bounds plus an implicit `+Inf`
//! bucket, each backed by an `AtomicU64` — `observe` is one binary
//! search and three relaxed atomic adds, memory is fixed at
//! construction forever (the bounded replacement for the old
//! grow-without-limit percentile vecs in `ServeCounters`).  Percentiles
//! come from linear interpolation inside the owning bucket, and the
//! whole thing renders as Prometheus text exposition (cumulative `le`
//! buckets, `_sum`, `_count`).

use std::sync::atomic::{AtomicU64, Ordering};

/// Fixed-bound histogram; values are seconds unless stated otherwise.
pub struct Hist {
    /// Ascending bucket upper bounds; an implicit `+Inf` bucket follows.
    bounds: Vec<f64>,
    /// Per-bucket counts, `bounds.len() + 1` entries.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Accumulated value in microseconds (u64 add is atomic; f64 isn't).
    sum_us: AtomicU64,
}

/// Default latency bounds: 50 µs to 60 s in a roughly 1-2.5-5 ladder —
/// wide enough for cache lookups (µs) and cold 32k prefills (seconds).
pub const LATENCY_BOUNDS: &[f64] = &[
    50e-6, 100e-6, 250e-6, 500e-6, 1e-3, 2.5e-3, 5e-3, 10e-3, 25e-3, 50e-3, 0.1, 0.25, 0.5, 1.0,
    2.5, 5.0, 10.0, 30.0, 60.0,
];

impl Hist {
    /// Build from ascending, finite, positive upper bounds.
    pub fn new(bounds: &[f64]) -> Hist {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && bounds.iter().all(|b| b.is_finite() && *b > 0.0),
            "histogram bounds must be ascending, finite, positive"
        );
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Hist { bounds: bounds.to_vec(), buckets, count: AtomicU64::new(0), sum_us: AtomicU64::new(0) }
    }

    /// The standard latency histogram every serving metric uses.
    pub fn latency() -> Hist {
        Hist::new(LATENCY_BOUNDS)
    }

    /// Record one sample.  Non-finite or negative samples count into the
    /// `+Inf` / first bucket rather than panicking (telemetry must never
    /// take the server down).
    pub fn observe(&self, secs: f64) {
        let idx = if secs.is_nan() {
            self.bounds.len() // NaN -> +Inf bucket
        } else {
            self.bounds.partition_point(|b| *b < secs)
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let us = if secs.is_finite() && secs > 0.0 { (secs * 1e6) as u64 } else { 0 };
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Per-bucket counts (last entry is the `+Inf` bucket).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// `q`-th percentile (`0.0..=100.0`) by linear interpolation inside
    /// the owning bucket; 0.0 on an empty histogram (any `q`, including
    /// out-of-range values, which clamp).
    ///
    /// The unbounded `+Inf` bucket has no upper edge to interpolate
    /// toward, so ranks landing there *clamp to the bucket's lower edge*
    /// (the last finite bound, 60s for [`LATENCY_BOUNDS`]) — never
    /// extrapolate past the histogram's resolution.  A reported p99 of
    /// exactly the top bound therefore reads as "at least this much".
    pub fn percentile(&self, q: f64) -> f64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 100.0);
        let rank = ((q / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if cum + c >= rank {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = match self.bounds.get(i) {
                    Some(b) => *b,
                    // +Inf bucket: clamp, don't extrapolate.
                    None => return *self.bounds.last().expect("bounds nonempty"),
                };
                let frac = (rank - cum) as f64 / c as f64;
                return lo + (hi - lo) * frac;
            }
            cum += c;
        }
        *self.bounds.last().expect("bounds nonempty")
    }

    /// Append Prometheus text exposition for this histogram: cumulative
    /// `le` buckets, `+Inf`, `_sum`, `_count`.
    pub fn prometheus_into(&self, name: &str, help: &str, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let counts = self.bucket_counts();
        let mut cum = 0u64;
        for (i, bound) in self.bounds.iter().enumerate() {
            cum += counts[i];
            let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
        }
        cum += counts[self.bounds.len()];
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum {}", self.sum_secs());
        let _ = writeln!(out, "{name}_count {}", self.count());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_values_land_in_their_bound_bucket() {
        // `le` semantics: a sample exactly on a bound counts into that
        // bound's bucket (bucket upper bounds are inclusive).
        let h = Hist::new(&[1.0, 2.0, 4.0]);
        h.observe(1.0);
        h.observe(2.0);
        h.observe(4.0);
        h.observe(4.0001);
        assert_eq!(h.bucket_counts(), vec![1, 1, 1, 1]);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn below_first_bound_and_overflow() {
        let h = Hist::new(&[0.5]);
        h.observe(0.0);
        h.observe(0.2);
        h.observe(9.0);
        assert_eq!(h.bucket_counts(), vec![2, 1]);
        // +Inf samples report the last bound (resolution limit).
        assert_eq!(h.percentile(100.0), 0.5);
    }

    #[test]
    fn nonfinite_samples_do_not_panic() {
        let h = Hist::new(&[1.0]);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        h.observe(-3.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.bucket_counts()[1], 2); // NaN + Inf overflow
        assert_eq!(h.bucket_counts()[0], 1); // negative clamps low
        assert_eq!(h.sum_secs(), 0.0);
    }

    #[test]
    fn percentiles_interpolate_and_stay_monotonic() {
        let h = Hist::latency();
        for i in 1..=100 {
            h.observe(0.001 * i as f64); // 1ms ..= 100ms
        }
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 >= 0.025 && p50 <= 0.05, "p50 {p50}");
        assert!(p99 > p50 && p99 <= 0.1, "p99 {p99}");
        let mut last = 0.0;
        for q in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let v = h.percentile(q);
            assert!(v >= last, "percentile not monotonic at q={q}: {v} < {last}");
            last = v;
        }
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Hist::latency();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_secs(), 0.0);
    }

    #[test]
    fn empty_histogram_is_zero_at_every_quantile() {
        // Regression: no quantile — in range or clamped — may divide by
        // the zero total or index past the bucket array on empty data.
        let h = Hist::latency();
        for q in [-10.0, 0.0, 0.1, 50.0, 99.999, 100.0, 250.0] {
            assert_eq!(h.percentile(q), 0.0, "q={q}");
        }
    }

    #[test]
    fn overflow_bucket_clamps_to_lower_edge() {
        // Regression: samples in the unbounded top bucket (>60s for the
        // latency ladder) must report the bucket's lower edge, never an
        // extrapolated value past the last bound.
        let h = Hist::latency();
        h.observe(120.0);
        h.observe(4000.0);
        let top = *LATENCY_BOUNDS.last().unwrap();
        for q in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.percentile(q), top, "q={q}");
        }
        // Mixed: ranks below the overflow bucket still interpolate,
        // ranks inside it still clamp.
        h.observe(0.001);
        assert!(h.percentile(1.0) <= 0.001 + 1e-9);
        assert_eq!(h.percentile(99.0), top);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let h = Hist::new(&[0.5, 1.0]);
        h.observe(0.1);
        h.observe(0.7);
        h.observe(2.0);
        let mut out = String::new();
        h.prometheus_into("psf_test_seconds", "test", &mut out);
        let want = [
            "# HELP psf_test_seconds test",
            "# TYPE psf_test_seconds histogram",
            "psf_test_seconds_bucket{le=\"0.5\"} 1",
            "psf_test_seconds_bucket{le=\"1\"} 2",
            "psf_test_seconds_bucket{le=\"+Inf\"} 3",
            "psf_test_seconds_count 3",
        ];
        for line in want {
            assert!(out.contains(line), "missing {line:?} in:\n{out}");
        }
        // Cumulative: each bucket count >= the previous one (checked
        // above by construction: 1 <= 2 <= 3).
        assert!(out.contains("psf_test_seconds_sum 2.8"), "{out}");
    }

    #[test]
    fn memory_is_fixed_under_sustained_load() {
        // The regression this module exists for: the old percentile vec
        // grew per request.  A histogram's footprint is its bucket count,
        // independent of samples.
        let h = Hist::latency();
        let buckets_before = h.bucket_counts().len();
        for i in 0..100_000u64 {
            h.observe((i % 977) as f64 * 1e-4);
        }
        assert_eq!(h.bucket_counts().len(), buckets_before);
        assert_eq!(h.count(), 100_000);
        assert!(h.percentile(50.0) > 0.0);
    }
}
