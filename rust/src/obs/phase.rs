//! Per-phase profiling accumulators: global `(nanos, count)` pairs, one
//! per [`Phase`], fed by hooks in the kernel engines, the exec pool, and
//! the trainer.
//!
//! This is the *only* sanctioned timing path inside `attn/kernel/` and
//! `tensor/` (CI greps for raw `Instant::now()` there): an engine asks
//! for a [`timer`], which is `None` — one relaxed load, no clock read —
//! unless phase accounting is on.  Accumulators are write-only
//! telemetry; nothing here feeds back into computation, so enabling
//! phases cannot change a single output byte.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Instrumented phases.  Kernel phases mirror the linear engine's
/// block-lower-triangular decomposition — exactly the breakdown the
/// SIMD work needs to target (feature expansion vs prefix multiply vs
/// diagonal scores vs output emit).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Linear engine: mapping raw q/k rows through the feature map.
    LinMap,
    /// Linear engine: diagonal-block score computation.
    LinScores,
    /// Linear engine: prefix contribution `phi(q) . Z`.
    LinPrefix,
    /// Linear engine: diagonal accumulate + normalized output emit.
    LinEmit,
    /// Linear engine: folding a full block into Z.
    LinFold,
    /// Linear engine: one decode step (recurrence update + output).
    LinStep,
    /// Quadratic engine: the attention computation itself.
    QuadAttn,
    /// Quadratic engine: capturing the KV decode state after prefill.
    QuadCapture,
    /// Quadratic engine: one decode step over the KV cache.
    QuadStep,
    /// Exec pool workers: time inside claimed batch chunks.
    PoolBusy,
    /// Exec pool workers: time blocked waiting for work.
    PoolIdle,
    /// Trainer: forward + backward (gradient computation).
    TrainGrad,
    /// Trainer: optimizer step (AdamW + clip).
    TrainOptim,
    /// Narrowing to cold storage: f16 state freeze, int8 weight quantize.
    Quantize,
    /// Widening from cold storage: f16 state thaw / row dequantize.
    Dequantize,
}

impl Phase {
    pub const ALL: [Phase; 15] = [
        Phase::LinMap,
        Phase::LinScores,
        Phase::LinPrefix,
        Phase::LinEmit,
        Phase::LinFold,
        Phase::LinStep,
        Phase::QuadAttn,
        Phase::QuadCapture,
        Phase::QuadStep,
        Phase::PoolBusy,
        Phase::PoolIdle,
        Phase::TrainGrad,
        Phase::TrainOptim,
        Phase::Quantize,
        Phase::Dequantize,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::LinMap => "lin_map",
            Phase::LinScores => "lin_scores",
            Phase::LinPrefix => "lin_prefix",
            Phase::LinEmit => "lin_emit",
            Phase::LinFold => "lin_fold",
            Phase::LinStep => "lin_step",
            Phase::QuadAttn => "quad_attn",
            Phase::QuadCapture => "quad_capture",
            Phase::QuadStep => "quad_step",
            Phase::PoolBusy => "pool_busy",
            Phase::PoolIdle => "pool_idle",
            Phase::TrainGrad => "train_grad",
            Phase::TrainOptim => "train_optim",
            Phase::Quantize => "quantize",
            Phase::Dequantize => "dequantize",
        }
    }

    fn index(self) -> usize {
        Phase::ALL.iter().position(|p| *p == self).expect("phase in ALL")
    }
}

struct Stat {
    nanos: AtomicU64,
    count: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)] // const used only as array initializer
const ZERO_STAT: Stat = Stat { nanos: AtomicU64::new(0), count: AtomicU64::new(0) };
static STATS: [Stat; Phase::ALL.len()] = [ZERO_STAT; Phase::ALL.len()];

/// Add `nanos` to a phase directly (for callers that already hold a
/// duration, like the pool's idle accounting).
pub fn add(phase: Phase, nanos: u64) {
    let s = &STATS[phase.index()];
    s.nanos.fetch_add(nanos, Ordering::Relaxed);
    s.count.fetch_add(1, Ordering::Relaxed);
}

/// A clock reading for later [`add_since`], `None` when phases are off.
#[inline]
pub fn maybe_now() -> Option<Instant> {
    if super::phases_on() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Accumulate the elapsed time since a [`maybe_now`] reading (no-op for
/// `None`).  Returns a fresh reading taken at the same clock sample, so
/// back-to-back phases can hand the timer off without gaps:
/// `let t = add_since(Phase::A, t); ... add_since(Phase::B, t);`
#[inline]
pub fn add_since(phase: Phase, t0: Option<Instant>) -> Option<Instant> {
    t0.map(|t0| {
        let now = Instant::now();
        add(phase, now.duration_since(t0).as_nanos() as u64);
        now
    })
}

/// RAII phase timer: accumulates on drop.  `None` when phases are off —
/// bind with `let _t = timer(...)` and the off-path is one relaxed load.
#[inline]
pub fn timer(phase: Phase) -> Option<PhaseTimer> {
    if super::phases_on() {
        Some(PhaseTimer { phase, t0: Instant::now() })
    } else {
        None
    }
}

pub struct PhaseTimer {
    phase: Phase,
    t0: Instant,
}

impl Drop for PhaseTimer {
    fn drop(&mut self) {
        add(self.phase, self.t0.elapsed().as_nanos() as u64);
    }
}

/// Snapshot of every phase with a nonzero count: `(name, nanos, count)`.
pub fn totals() -> Vec<(&'static str, u64, u64)> {
    Phase::ALL
        .iter()
        .map(|p| {
            let s = &STATS[p.index()];
            (p.name(), s.nanos.load(Ordering::Relaxed), s.count.load(Ordering::Relaxed))
        })
        .filter(|(_, n, c)| *n > 0 || *c > 0)
        .collect()
}

/// Zero every accumulator (benches call this between sweep points).
pub fn reset() {
    for s in &STATS {
        s.nanos.store(0, Ordering::Relaxed);
        s.count.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_stable() {
        let names: Vec<_> = Phase::ALL.iter().map(|p| p.name()).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate phase name");
        assert_eq!(Phase::LinScores.name(), "lin_scores");
    }

    #[test]
    fn add_accumulates_and_reset_clears() {
        // Global state: keep this test self-consistent under concurrent
        // unit tests by checking deltas on one rarely-used phase.
        let before: u64 = totals()
            .iter()
            .find(|(n, _, _)| *n == "train_optim")
            .map(|(_, ns, _)| *ns)
            .unwrap_or(0);
        add(Phase::TrainOptim, 1234);
        let after: u64 = totals()
            .iter()
            .find(|(n, _, _)| *n == "train_optim")
            .map(|(_, ns, _)| *ns)
            .unwrap_or(0);
        assert!(after >= before + 1234);
    }

    #[test]
    fn timer_is_none_when_off() {
        if !super::super::phases_on() {
            assert!(timer(Phase::LinMap).is_none());
            assert!(maybe_now().is_none());
        }
    }
}
