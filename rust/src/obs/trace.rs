//! Chrome trace-event export, parsing, merging, and reporting.
//!
//! The export is the JSON *object* flavor of the trace-event format —
//! `{"traceEvents": [...]}` with `"X"` (complete) events carrying
//! `ts`/`dur` in microseconds — loadable directly in Perfetto or
//! `chrome://tracing`.  Two PSF-specific extensions ride along as extra
//! top-level keys (legal in the format, ignored by viewers):
//! `psf_phases` (the kernel/pool phase accumulator totals) and `psf`
//! (drop counters).  The request trace id crosses as a hex string in
//! each event's `args` (u64 doesn't survive JS number precision).
//!
//! The parser is a minimal full-JSON reader (objects, arrays, strings,
//! numbers) — the flat parser in `serve::http` deliberately rejects
//! nesting, and `trace-report` / the shutdown merge need to re-read
//! files this module wrote (runner processes flush their own trace
//! files; the gateway merges them into one timeline at drain).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io;
use std::path::{Path, PathBuf};

use super::span::Event;

/// One parsed trace event (only the fields this crate emits/uses).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub ph: String,
    pub name: String,
    pub cat: String,
    pub ts_us: u64,
    pub dur_us: u64,
    pub pid: u64,
    pub tid: u64,
    /// Request trace id from `args.trace_id` (hex), 0 when absent.
    pub trace_id: u64,
    pub depth: u32,
}

/// A parsed trace file: events + the PSF phase totals extension.
#[derive(Clone, Debug, Default)]
pub struct TraceFile {
    pub events: Vec<TraceEvent>,
    /// `(phase name, nanos, count)` — summed on merge.
    pub phases: Vec<(String, u64, u64)>,
    pub dropped: u64,
}

// ----------------------------------------------------------------- write

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn process_label() -> String {
    // "psf serve" / "psf runner" — argv[0] basename + subcommand.
    let mut args = std::env::args();
    let exe = args
        .next()
        .map(|a| {
            Path::new(&a).file_name().map(|f| f.to_string_lossy().into_owned()).unwrap_or(a.clone())
        })
        .unwrap_or_else(|| "psf".into());
    match args.next() {
        Some(sub) => format!("{exe} {sub}"),
        None => exe,
    }
}

fn write_file(
    path: &Path,
    events: &[TraceEvent],
    phases: &[(String, u64, u64)],
    dropped: u64,
    labels: &[(u64, String)],
) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut out = String::with_capacity(events.len() * 128 + 1024);
    out.push_str("{\n\"displayTimeUnit\": \"ms\",\n");
    let _ = writeln!(out, "\"psf\": {{\"dropped_events\": {dropped}}},");
    out.push_str("\"psf_phases\": [");
    for (i, (name, nanos, count)) in phases.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\n  {{\"name\": {}, \"nanos\": {nanos}, \"count\": {count}}}", esc(name));
    }
    out.push_str("\n],\n\"traceEvents\": [");
    let mut first = true;
    let mut sep = |out: &mut String| {
        if first {
            first = false;
        } else {
            out.push(',');
        }
        out.push_str("\n  ");
    };
    for (pid, label) in labels {
        sep(&mut out);
        let _ = write!(
            out,
            "{{\"ph\": \"M\", \"name\": \"process_name\", \"pid\": {pid}, \"tid\": 0, \
             \"args\": {{\"name\": {}}}}}",
            esc(label)
        );
    }
    for ev in events {
        sep(&mut out);
        let _ = write!(
            out,
            "{{\"ph\": {}, \"name\": {}, \"cat\": {}, \"ts\": {}, \"dur\": {}, \"pid\": {}, \
             \"tid\": {}, \"args\": {{\"trace_id\": \"{:#x}\", \"depth\": {}}}}}",
            esc(&ev.ph),
            esc(&ev.name),
            esc(&ev.cat),
            ev.ts_us,
            ev.dur_us,
            ev.pid,
            ev.tid,
            ev.trace_id,
            ev.depth,
        );
    }
    out.push_str("\n]\n}\n");
    std::fs::write(path, out)
}

fn live_events(events: &[Event]) -> Vec<TraceEvent> {
    let pid = std::process::id() as u64;
    events
        .iter()
        .map(|e| TraceEvent {
            ph: "X".into(),
            name: e.name.clone(),
            cat: e.cat.into(),
            ts_us: e.ts_us,
            dur_us: e.dur_us,
            pid,
            tid: e.tid,
            trace_id: e.trace_id,
            depth: e.depth,
        })
        .collect()
}

/// Write this process's drained events + phase totals as a fresh trace.
pub fn write(
    path: &Path,
    events: &[Event],
    phases: &[(&'static str, u64, u64)],
    dropped: u64,
) -> io::Result<()> {
    let owned: Vec<(String, u64, u64)> =
        phases.iter().map(|(n, a, b)| (n.to_string(), *a, *b)).collect();
    let pid = std::process::id() as u64;
    write_file(path, &live_events(events), &owned, dropped, &[(pid, process_label())])
}

/// Merge this process's drained events into an existing trace file
/// (periodic flushes, or a signal-hook flush followed by the drain-path
/// flush, must not duplicate or clobber earlier spans).
pub fn append(
    path: &Path,
    events: &[Event],
    phases: &[(&'static str, u64, u64)],
    dropped: u64,
) -> io::Result<()> {
    let text = std::fs::read_to_string(path)?;
    let mut tf = parse(&text)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{}: {e}", path.display())))?;
    tf.events.extend(live_events(events));
    let mut sums: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    for (n, ns, c) in tf.phases.drain(..) {
        let e = sums.entry(n).or_insert((0, 0));
        e.0 += ns;
        e.1 += c;
    }
    for (n, ns, c) in phases {
        let e = sums.entry(n.to_string()).or_insert((0, 0));
        e.0 += ns;
        e.1 += c;
    }
    let merged: Vec<(String, u64, u64)> =
        sums.into_iter().map(|(n, (ns, c))| (n, ns, c)).collect();
    let pid = std::process::id() as u64;
    write_file(path, &tf.events, &merged, tf.dropped + dropped, &[(pid, process_label())])
}

/// Merge extra trace files (runner children flush their own) into
/// `main`, producing one Perfetto-loadable timeline whose events keep
/// their original pids.  Unreadable/unparsable extras are skipped with a
/// warning — a half-written runner trace must not break gateway
/// shutdown.  Returns the total merged event count.
pub fn merge_files(main: &Path, extras: &[PathBuf]) -> io::Result<usize> {
    let mut merged = match std::fs::read_to_string(main) {
        Ok(text) => parse(&text).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidData, format!("{}: {e}", main.display()))
        })?,
        Err(e) if e.kind() == io::ErrorKind::NotFound => TraceFile::default(),
        Err(e) => return Err(e),
    };
    let mut phase_sums: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    for (n, ns, c) in merged.phases.drain(..) {
        let e = phase_sums.entry(n).or_insert((0, 0));
        e.0 += ns;
        e.1 += c;
    }
    for extra in extras {
        let text = match std::fs::read_to_string(extra) {
            Ok(t) => t,
            Err(_) => continue, // runner died before flushing: skip
        };
        match parse(&text) {
            Ok(tf) => {
                merged.events.extend(tf.events);
                merged.dropped += tf.dropped;
                for (n, ns, c) in tf.phases {
                    let e = phase_sums.entry(n).or_insert((0, 0));
                    e.0 += ns;
                    e.1 += c;
                }
                let _ = std::fs::remove_file(extra); // subsumed by the merge
            }
            Err(e) => eprintln!("psf: skipping unparsable trace {}: {e}", extra.display()),
        }
    }
    merged.events.sort_by_key(|e| e.ts_us);
    merged.phases = phase_sums.into_iter().map(|(n, (ns, c))| (n, ns, c)).collect();
    write_file(main, &merged.events, &merged.phases, merged.dropped, &[])?;
    Ok(merged.events.len())
}

// ----------------------------------------------------------------- parse

/// Parsed JSON value — shared with `obs::incident` (the incident-report
/// renderer reuses this parser instead of growing a second one).
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum JVal {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JVal>),
    Obj(Vec<(String, JVal)>),
}

impl JVal {
    pub(crate) fn get(&self, key: &str) -> Option<&JVal> {
        match self {
            JVal::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub(crate) fn as_u64(&self) -> Option<u64> {
        match self {
            JVal::Num(n) if *n >= 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub(crate) fn as_f64(&self) -> Option<f64> {
        match self {
            JVal::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub(crate) fn as_str(&self) -> Option<&str> {
        match self {
            JVal::Str(s) => Some(s),
            _ => None,
        }
    }

    pub(crate) fn as_arr(&self) -> Option<&[JVal]> {
        match self {
            JVal::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse any standalone JSON document into a [`JVal`] (rejects trailing
/// bytes).  Crate-internal: the incident reporter's entry point.
pub(crate) fn parse_value(text: &str) -> Result<JVal, String> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let root = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing bytes after JSON value at {}", p.pos));
    }
    Ok(root)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, want: u8) -> Result<(), String> {
        match self.next() {
            Some(c) if c == want => Ok(()),
            other => Err(format!(
                "byte {}: expected `{}`, got {:?}",
                self.pos,
                want as char,
                other.map(char::from)
            )),
        }
    }

    fn value(&mut self) -> Result<JVal, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JVal::Str(self.string()?)),
            Some(b't') => self.literal("true", JVal::Bool(true)),
            Some(b'f') => self.literal("false", JVal::Bool(false)),
            Some(b'n') => self.literal("null", JVal::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, val: JVal) -> Result<JVal, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("byte {}: bad literal (expected {word})", self.pos))
        }
    }

    fn number(&mut self) -> Result<JVal, String> {
        let start = self.pos;
        while matches!(self.peek(), Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>().map(JVal::Num).map_err(|_| format!("bad number `{text}`"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.next() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.next() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                            .map_err(|e| e.to_string())?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape".to_string())?;
                        self.pos += 4;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {:?}", other.map(char::from))),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    if start + len > self.b.len() {
                        return Err("truncated utf-8 scalar".into());
                    }
                    let s = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|e| e.to_string())?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn object(&mut self) -> Result<JVal, String> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JVal::Obj(kv));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            kv.push((key, val));
            self.ws();
            match self.next() {
                Some(b',') => continue,
                Some(b'}') => return Ok(JVal::Obj(kv)),
                other => {
                    return Err(format!(
                        "byte {}: expected `,` or `}}`, got {:?}",
                        self.pos,
                        other.map(char::from)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<JVal, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JVal::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.next() {
                Some(b',') => continue,
                Some(b']') => return Ok(JVal::Arr(items)),
                other => {
                    return Err(format!(
                        "byte {}: expected `,` or `]`, got {:?}",
                        self.pos,
                        other.map(char::from)
                    ))
                }
            }
        }
    }
}

fn parse_trace_id(args: Option<&JVal>) -> u64 {
    let Some(s) = args.and_then(|a| a.get("trace_id")).and_then(|v| v.as_str()) else {
        return 0;
    };
    let hex = s.strip_prefix("0x").unwrap_or(s);
    u64::from_str_radix(hex, 16).unwrap_or(0)
}

/// Parse a trace file written by this module (tolerates any valid
/// trace-event object JSON; unknown keys are ignored).
pub fn parse(text: &str) -> Result<TraceFile, String> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let root = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing bytes after trace object at {}", p.pos));
    }
    let Some(JVal::Arr(raw_events)) = root.get("traceEvents").cloned() else {
        return Err("missing traceEvents array".into());
    };
    let mut events = Vec::with_capacity(raw_events.len());
    for ev in &raw_events {
        let ph = ev.get("ph").and_then(|v| v.as_str()).unwrap_or("X").to_string();
        if ph != "X" {
            continue; // metadata rows aren't spans
        }
        events.push(TraceEvent {
            ph,
            name: ev.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            cat: ev.get("cat").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            ts_us: ev.get("ts").and_then(|v| v.as_u64()).ok_or("event missing ts")?,
            dur_us: ev.get("dur").and_then(|v| v.as_u64()).unwrap_or(0),
            pid: ev.get("pid").and_then(|v| v.as_u64()).unwrap_or(0),
            tid: ev.get("tid").and_then(|v| v.as_u64()).unwrap_or(0),
            trace_id: parse_trace_id(ev.get("args")),
            depth: ev
                .get("args")
                .and_then(|a| a.get("depth"))
                .and_then(|v| v.as_u64())
                .unwrap_or(0) as u32,
        });
    }
    let mut phases = Vec::new();
    if let Some(JVal::Arr(raw)) = root.get("psf_phases") {
        for ph in raw {
            let name = ph.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string();
            let nanos = ph.get("nanos").and_then(|v| v.as_u64()).unwrap_or(0);
            let count = ph.get("count").and_then(|v| v.as_u64()).unwrap_or(0);
            phases.push((name, nanos, count));
        }
    }
    let dropped = root
        .get("psf")
        .and_then(|p| p.get("dropped_events"))
        .and_then(|v| v.as_u64())
        .unwrap_or(0);
    Ok(TraceFile { events, phases, dropped })
}

// ---------------------------------------------------------------- report

/// Per-name self-time aggregation: spans on one thread are properly
/// nested (RAII), so a ts-ordered stack replay attributes each span's
/// duration minus its direct children's durations as *self* time.
fn self_times(tf: &TraceFile) -> Vec<(String, String, u64, u64, u64)> {
    let mut by_thread: BTreeMap<(u64, u64), Vec<usize>> = BTreeMap::new();
    for (i, ev) in tf.events.iter().enumerate() {
        by_thread.entry((ev.pid, ev.tid)).or_default().push(i);
    }
    let mut self_us: Vec<u64> = tf.events.iter().map(|e| e.dur_us).collect();
    for idxs in by_thread.values_mut() {
        idxs.sort_by_key(|&i| (tf.events[i].ts_us, std::cmp::Reverse(tf.events[i].dur_us)));
        let mut stack: Vec<usize> = Vec::new();
        for &i in idxs.iter() {
            let ev = &tf.events[i];
            while let Some(&top) = stack.last() {
                let t = &tf.events[top];
                if t.ts_us + t.dur_us <= ev.ts_us {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&parent) = stack.last() {
                self_us[parent] = self_us[parent].saturating_sub(ev.dur_us);
            }
            stack.push(i);
        }
    }
    // (name, cat) -> (count, total, self)
    let mut agg: BTreeMap<(String, String), (u64, u64, u64)> = BTreeMap::new();
    for (i, ev) in tf.events.iter().enumerate() {
        let e = agg.entry((ev.name.clone(), ev.cat.clone())).or_insert((0, 0, 0));
        e.0 += 1;
        e.1 += ev.dur_us;
        e.2 += self_us[i];
    }
    let mut rows: Vec<(String, String, u64, u64, u64)> =
        agg.into_iter().map(|((n, c), (cnt, tot, slf))| (n, c, cnt, tot, slf)).collect();
    rows.sort_by_key(|r| std::cmp::Reverse(r.4));
    rows
}

/// Human-readable summary: overview, trace-id stitching, top spans by
/// self time, and the kernel/pool phase breakdown.
pub fn report(tf: &TraceFile, top: usize) -> String {
    let mut out = String::new();
    let pids: std::collections::BTreeSet<u64> = tf.events.iter().map(|e| e.pid).collect();
    let tids: std::collections::BTreeSet<(u64, u64)> =
        tf.events.iter().map(|e| (e.pid, e.tid)).collect();
    let (lo, hi) = tf.events.iter().fold((u64::MAX, 0u64), |(lo, hi), e| {
        (lo.min(e.ts_us), hi.max(e.ts_us + e.dur_us))
    });
    let wall_ms = if tf.events.is_empty() { 0.0 } else { (hi - lo) as f64 / 1e3 };
    let _ = writeln!(
        out,
        "trace report: {} events, {} processes, {} threads, wall {:.2} ms, dropped {}",
        tf.events.len(),
        pids.len(),
        tids.len(),
        wall_ms,
        tf.dropped
    );

    // Trace-id stitching: which requests span which processes.
    let mut ids: BTreeMap<u64, std::collections::BTreeSet<u64>> = BTreeMap::new();
    let mut id_events: BTreeMap<u64, u64> = BTreeMap::new();
    for ev in &tf.events {
        if ev.trace_id != 0 {
            ids.entry(ev.trace_id).or_default().insert(ev.pid);
            *id_events.entry(ev.trace_id).or_insert(0) += 1;
        }
    }
    let _ = writeln!(out, "trace ids: {} distinct", ids.len());
    for (id, pids) in ids.iter().take(8) {
        let _ = writeln!(
            out,
            "  trace {:#x}: {} events across {} process{}",
            id,
            id_events[id],
            pids.len(),
            if pids.len() == 1 { "" } else { "es" }
        );
    }

    let rows = self_times(tf);
    if !rows.is_empty() {
        let _ = writeln!(out, "top spans by self time:");
        let _ = writeln!(
            out,
            "  {:<24} {:<8} {:>8} {:>12} {:>12} {:>10}",
            "span", "cat", "count", "total ms", "self ms", "avg us"
        );
        for (name, cat, count, total, slf) in rows.iter().take(top) {
            let _ = writeln!(
                out,
                "  {:<24} {:<8} {:>8} {:>12.3} {:>12.3} {:>10.1}",
                name,
                cat,
                count,
                *total as f64 / 1e3,
                *slf as f64 / 1e3,
                *total as f64 / (*count).max(1) as f64
            );
        }
    }

    if !tf.phases.is_empty() {
        let kernel_total: u64 = tf
            .phases
            .iter()
            .filter(|(n, _, _)| n.starts_with("lin_") || n.starts_with("quad_"))
            .map(|(_, ns, _)| *ns)
            .sum();
        let _ = writeln!(out, "kernel/pool phase breakdown:");
        let _ = writeln!(
            out,
            "  {:<14} {:>10} {:>12} {:>10} {:>8}",
            "phase", "calls", "total ms", "avg us", "share"
        );
        for (name, nanos, count) in &tf.phases {
            let share = if kernel_total > 0 && (name.starts_with("lin_") || name.starts_with("quad_"))
            {
                format!("{:.1}%", *nanos as f64 / kernel_total as f64 * 100.0)
            } else {
                "-".into()
            };
            let _ = writeln!(
                out,
                "  {:<14} {:>10} {:>12.3} {:>10.2} {:>8}",
                name,
                count,
                *nanos as f64 / 1e6,
                *nanos as f64 / 1e3 / (*count).max(1) as f64,
                share
            );
        }
        let busy = tf.phases.iter().find(|(n, _, _)| n == "pool_busy").map(|(_, ns, _)| *ns);
        let idle = tf.phases.iter().find(|(n, _, _)| n == "pool_idle").map(|(_, ns, _)| *ns);
        if let (Some(b), Some(i)) = (busy, idle) {
            if b + i > 0 {
                let _ = writeln!(
                    out,
                    "pool utilization: {:.1}% busy ({:.1} ms busy / {:.1} ms idle)",
                    b as f64 / (b + i) as f64 * 100.0,
                    b as f64 / 1e6,
                    i as f64 / 1e6
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, ts: u64, dur: u64, tid: u64, depth: u32) -> TraceEvent {
        TraceEvent {
            ph: "X".into(),
            name: name.into(),
            cat: "test".into(),
            ts_us: ts,
            dur_us: dur,
            pid: 1,
            tid,
            trace_id: 0x42,
            depth,
        }
    }

    #[test]
    fn write_parse_roundtrip() {
        let dir = std::env::temp_dir().join("psf_obs_trace_test");
        let path = dir.join("roundtrip.json");
        let events =
            vec![ev("outer", 100, 50, 1, 0), ev("inner", 110, 20, 1, 1), ev("other", 200, 5, 2, 0)];
        let phases = vec![("lin_scores".to_string(), 1_000_000, 10)];
        write_file(&path, &events, &phases, 3, &[(1, "psf test".into())]).unwrap();
        let tf = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(tf.events.len(), 3, "metadata row must not parse as a span");
        assert_eq!(tf.events[0].name, "outer");
        assert_eq!(tf.events[0].trace_id, 0x42);
        assert_eq!(tf.events[1].depth, 1);
        assert_eq!(tf.phases, phases);
        assert_eq!(tf.dropped, 3);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("not json").is_err());
        assert!(parse("{\"traceEvents\": 3}").is_err());
        assert!(parse("{}").is_err());
        assert!(parse("{\"traceEvents\": []} x").is_err());
    }

    #[test]
    fn self_time_subtracts_children() {
        let tf = TraceFile {
            events: vec![
                ev("outer", 100, 100, 1, 0),
                ev("child_a", 110, 30, 1, 1),
                ev("child_b", 150, 20, 1, 1),
                ev("grandchild", 115, 10, 1, 2),
            ],
            phases: vec![],
            dropped: 0,
        };
        let rows = self_times(&tf);
        let find = |n: &str| rows.iter().find(|r| r.0 == n).unwrap();
        assert_eq!(find("outer").4, 50, "outer self = 100 - 30 - 20");
        assert_eq!(find("child_a").4, 20, "child_a self = 30 - 10");
        assert_eq!(find("child_b").4, 20);
        assert_eq!(find("grandchild").4, 10);
    }

    #[test]
    fn merge_files_combines_and_sums_phases() {
        let dir = std::env::temp_dir().join("psf_obs_trace_test");
        let main = dir.join("merge_main.json");
        let extra = dir.join("merge_extra.json");
        write_file(&main, &[ev("gw", 100, 10, 1, 0)], &[("lin_map".into(), 5, 1)], 0, &[]).unwrap();
        let mut rev = ev("run", 105, 5, 1, 0);
        rev.pid = 2;
        write_file(&extra, &[rev], &[("lin_map".into(), 7, 2)], 1, &[]).unwrap();
        let n = merge_files(&main, &[extra.clone()]).unwrap();
        assert_eq!(n, 2);
        assert!(!extra.exists(), "merged extras are removed");
        let tf = parse(&std::fs::read_to_string(&main).unwrap()).unwrap();
        assert_eq!(tf.events.len(), 2);
        let pids: Vec<u64> = tf.events.iter().map(|e| e.pid).collect();
        assert!(pids.contains(&1) && pids.contains(&2), "pids preserved: {pids:?}");
        assert_eq!(tf.phases, vec![("lin_map".to_string(), 12, 3)]);
        assert_eq!(tf.dropped, 1);
    }

    #[test]
    fn report_mentions_cross_process_ids() {
        let mut a = ev("gw", 100, 10, 1, 0);
        a.pid = 10;
        let mut b = ev("run", 105, 5, 1, 0);
        b.pid = 20;
        let tf = TraceFile {
            events: vec![a, b],
            phases: vec![("lin_scores".into(), 2_000_000, 4), ("pool_busy".into(), 100, 1)],
            dropped: 0,
        };
        let r = report(&tf, 10);
        assert!(r.contains("2 processes"), "{r}");
        assert!(r.contains("trace 0x42: 2 events across 2 processes"), "{r}");
        assert!(r.contains("lin_scores"), "{r}");
        assert!(r.contains("top spans by self time"), "{r}");
    }
}
