//! Flight recorder: a bounded time-series ring of system gauges.
//!
//! Subsystems register named gauge closures ([`register`]); a sampler
//! thread reads every gauge at a fixed interval into a frame, and a
//! bounded ring keeps the most recent frames — so the last N seconds of
//! system state (arena bytes, cache hit rate, queue depth, phase nanos,
//! loss/grad norms) are always in memory when an incident dump fires.
//! Event-shaped values that don't fit the sampled-gauge model (per-step
//! loss, grad norm) go through [`note`] into a parallel bounded ring.
//!
//! Like the rest of `obs`, the recorder is write-only telemetry: gauge
//! closures read shared counters, nothing reads the ring back into
//! computation, and when never started the whole module is inert.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use std::collections::VecDeque;

/// Default sampling cadence.
pub const DEFAULT_INTERVAL_MS: u64 = 250;
/// Frames kept: 240 x 250ms = the last minute of system state.
pub const DEFAULT_WINDOW_FRAMES: usize = 240;
/// Manual notes kept ([`note`] ring).
const NOTE_CAP: usize = 1024;

/// One sampled frame: timestamp + every registered gauge's value.
#[derive(Clone, Debug)]
pub struct Frame {
    pub ts_us: u64,
    pub values: Vec<(String, f64)>,
}

/// One manual observation pushed by [`note`].
#[derive(Clone, Debug)]
pub struct Note {
    pub ts_us: u64,
    pub name: String,
    pub value: f64,
}

type Gauge = Box<dyn Fn() -> f64 + Send + Sync>;

struct Inner {
    gauges: Mutex<Vec<(String, Gauge)>>,
    frames: Mutex<VecDeque<Frame>>,
    notes: Mutex<VecDeque<Note>>,
    running: AtomicBool,
    interval_ms: AtomicU64,
    window: AtomicU64,
    handle: Mutex<Option<JoinHandle<()>>>,
}

fn inner() -> &'static Inner {
    static INNER: OnceLock<Inner> = OnceLock::new();
    INNER.get_or_init(|| Inner {
        gauges: Mutex::new(Vec::new()),
        frames: Mutex::new(VecDeque::new()),
        notes: Mutex::new(VecDeque::new()),
        running: AtomicBool::new(false),
        interval_ms: AtomicU64::new(DEFAULT_INTERVAL_MS),
        window: AtomicU64::new(DEFAULT_WINDOW_FRAMES as u64),
        handle: Mutex::new(None),
    })
}

/// Register a named gauge. Idempotent by name: re-registering replaces
/// the closure (respawned components re-register safely).
pub fn register(name: &str, gauge: impl Fn() -> f64 + Send + Sync + 'static) {
    let mut gauges = inner().gauges.lock().expect("recorder gauges");
    if let Some(slot) = gauges.iter_mut().find(|(n, _)| n == name) {
        slot.1 = Box::new(gauge);
    } else {
        gauges.push((name.to_string(), Box::new(gauge)));
    }
}

/// Push one manual observation (per-step loss, grad norm, update ratio).
/// No-op unless the recorder has been started.
pub fn note(name: &str, value: f64) {
    let inn = inner();
    if !inn.running.load(Ordering::Relaxed) {
        return;
    }
    let mut notes = inn.notes.lock().expect("recorder notes");
    if notes.len() >= NOTE_CAP {
        notes.pop_front();
    }
    notes.push_back(Note { ts_us: super::span::now_us(), name: name.to_string(), value });
}

/// Take one sample now: registered gauges plus built-ins (uptime, phase
/// totals, span-ring drops, sentinel watermarks).  Called by the sampler
/// thread; public so tests and single-shot paths can tick manually.
pub fn sample_once() {
    let inn = inner();
    let mut values: Vec<(String, f64)> = Vec::new();
    values.push(("uptime_seconds".into(), super::uptime_secs()));
    {
        let gauges = inn.gauges.lock().expect("recorder gauges");
        for (name, g) in gauges.iter() {
            values.push((name.clone(), g()));
        }
    }
    for (name, nanos, calls) in super::phase::totals() {
        values.push((format!("phase_{name}_nanos"), nanos as f64));
        values.push((format!("phase_{name}_calls"), calls as f64));
    }
    let mut occupancy = 0u64;
    let mut dropped = 0u64;
    for (_tid, occ, drops) in super::span::ring_stats() {
        occupancy += occ as u64;
        dropped += drops;
    }
    values.push(("span_ring_events".into(), occupancy as f64));
    values.push(("span_ring_dropped_total".into(), dropped as f64));
    for (site, absmax) in super::sentinel::watermarks() {
        values.push((format!("sentinel_absmax_{site}"), absmax));
    }
    let frame = Frame { ts_us: super::span::now_us(), values };
    let window = inn.window.load(Ordering::Relaxed) as usize;
    let mut frames = inn.frames.lock().expect("recorder frames");
    while frames.len() >= window.max(1) {
        frames.pop_front();
    }
    frames.push_back(frame);
}

/// Start the sampler thread.  Idempotent; `interval_ms == 0` uses the
/// default cadence.
pub fn start(interval_ms: u64, window_frames: usize) {
    let inn = inner();
    let ms = if interval_ms == 0 { DEFAULT_INTERVAL_MS } else { interval_ms };
    inn.interval_ms.store(ms, Ordering::Relaxed);
    inn.window.store(window_frames.max(1) as u64, Ordering::Relaxed);
    if inn.running.swap(true, Ordering::SeqCst) {
        return;
    }
    let handle = std::thread::Builder::new()
        .name("psf-recorder".into())
        .spawn(move || {
            let inn = inner();
            while inn.running.load(Ordering::Relaxed) {
                sample_once();
                std::thread::sleep(Duration::from_millis(inn.interval_ms.load(Ordering::Relaxed)));
            }
        })
        .expect("spawn psf-recorder");
    *inn.handle.lock().expect("recorder handle") = Some(handle);
}

/// Stop the sampler thread and join it.  The ring is kept: incident
/// dumps after shutdown still see the final window.
pub fn stop() {
    let inn = inner();
    if !inn.running.swap(false, Ordering::SeqCst) {
        return;
    }
    if let Some(h) = inn.handle.lock().expect("recorder handle").take() {
        let _ = h.join();
    }
}

/// Is the sampler thread live?
pub fn running() -> bool {
    inner().running.load(Ordering::Relaxed)
}

/// Copy of the current frame window (oldest first).
pub fn frames() -> Vec<Frame> {
    inner().frames.lock().expect("recorder frames").iter().cloned().collect()
}

/// Copy of the current note ring (oldest first).
pub fn notes() -> Vec<Note> {
    inner().notes.lock().expect("recorder notes").iter().cloned().collect()
}

/// The whole window as one JSON object — embedded in incident dumps.
pub fn snapshot_json() -> String {
    use std::fmt::Write as _;
    let inn = inner();
    let mut out = String::with_capacity(4096);
    let _ = write!(
        out,
        "{{\"interval_ms\":{},\"frames\":[",
        inn.interval_ms.load(Ordering::Relaxed)
    );
    for (i, f) in frames().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{{\"ts_us\":{},\"gauges\":{{", f.ts_us);
        for (j, (name, v)) in f.values.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            let _ = write!(out, "{}:", crate::metrics::json_escape(name));
            if v.is_finite() {
                let _ = write!(out, "{v}");
            } else {
                out.push_str("null");
            }
        }
        out.push_str("}}");
    }
    out.push_str("],\"notes\":[");
    for (i, n) in notes().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"ts_us\":{},\"name\":{},\"value\":",
            n.ts_us,
            crate::metrics::json_escape(&n.name)
        );
        if n.value.is_finite() {
            let _ = write!(out, "{}", n.value);
        } else {
            out.push_str("null");
        }
        out.push('}');
    }
    out.push_str("]}");
    out
}

/// Clear frames and notes (tests).
pub fn reset() {
    let inn = inner();
    inn.frames.lock().expect("recorder frames").clear();
    inn.notes.lock().expect("recorder notes").clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn manual_samples_capture_registered_gauges() {
        let _g = TEST_LOCK.lock().unwrap();
        reset();
        register("test_gauge_a", || 42.0);
        sample_once();
        let frames = frames();
        let last = frames.last().expect("one frame");
        let v = last.values.iter().find(|(n, _)| n == "test_gauge_a").expect("gauge sampled");
        assert_eq!(v.1, 42.0);
        assert!(last.values.iter().any(|(n, _)| n == "uptime_seconds"));
        reset();
    }

    #[test]
    fn window_is_bounded() {
        let _g = TEST_LOCK.lock().unwrap();
        reset();
        let window = inner().window.load(Ordering::Relaxed) as usize;
        for _ in 0..window + 10 {
            sample_once();
        }
        assert_eq!(super::frames().len(), window);
        reset();
    }

    #[test]
    fn reregistering_replaces_not_duplicates() {
        let _g = TEST_LOCK.lock().unwrap();
        reset();
        register("test_gauge_b", || 1.0);
        register("test_gauge_b", || 2.0);
        sample_once();
        let frames = frames();
        let last = frames.last().expect("one frame");
        let hits: Vec<&(String, f64)> =
            last.values.iter().filter(|(n, _)| n == "test_gauge_b").collect();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].1, 2.0);
        reset();
    }

    #[test]
    fn notes_require_running_and_stay_bounded() {
        let _g = TEST_LOCK.lock().unwrap();
        reset();
        note("loss", 1.0);
        assert!(notes().is_empty(), "notes are inert before start");
        inner().running.store(true, Ordering::SeqCst);
        for i in 0..NOTE_CAP + 5 {
            note("loss", i as f64);
        }
        inner().running.store(false, Ordering::SeqCst);
        let ns = notes();
        assert_eq!(ns.len(), NOTE_CAP);
        assert_eq!(ns.last().unwrap().value, (NOTE_CAP + 4) as f64);
        reset();
    }

    #[test]
    fn snapshot_json_is_well_formed() {
        let _g = TEST_LOCK.lock().unwrap();
        reset();
        register("test_gauge_c", || 7.5);
        sample_once();
        let json = snapshot_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"interval_ms\""));
        assert!(json.contains("\"test_gauge_c\":7.5"));
        assert!(json.contains("\"frames\":["));
        assert!(json.contains("\"notes\":["));
        reset();
    }
}
