//! Observability: structured span tracing, fixed-bucket histograms, and
//! per-phase profiling accumulators — std-only, near-zero overhead when
//! off.
//!
//! Three instruments, one contract:
//!
//! * **Spans** ([`span`]) — RAII guards pushing complete events into
//!   per-thread ring buffers, exported as Chrome trace-event /
//!   Perfetto-compatible JSON ([`flush`]).  A thread-local *trace id*
//!   ([`set_trace_id`]) stitches one request's spans across threads and
//!   — carried in the shard protocol's Generate payload — across the
//!   gateway/runner process boundary.
//! * **Histograms** ([`Hist`]) — fixed-bound atomic bucket counters for
//!   latency distributions (TTFT, per-token, queue wait, IPC RTT, cache
//!   lookup); bounded memory forever, Prometheus text exposition.
//! * **Phases** ([`phase`]) — global per-phase time accumulators fed by
//!   hooks in the kernel engines, the exec pool, and the trainer.  The
//!   *only* sanctioned way to time `attn/kernel/` / `tensor/` code (a CI
//!   grep guard forbids raw `Instant::now()` there).
//!
//! **Overhead contract.**  Disabled, every hook is one relaxed atomic
//! load and a branch — no clock reads, no allocation, no locks.  Enabled
//! or not, timing is write-only telemetry: no computed value ever feeds
//! back into the math, so token streams, gradients, and golden fixtures
//! are byte-identical with tracing on or off.
//!
//! A second tier rides on the same contract: numeric-health
//! [`sentinel`]s (sampled absmax / non-finite scans at kernel and train
//! boundaries), the [`recorder`] flight ring (a bounded time-series of
//! registered gauges), and [`incident`] dumps (panic / sentinel-trip /
//! SIGTERM paths writing `incident.json` from state already in memory).

pub mod hist;
pub mod incident;
pub mod phase;
pub mod recorder;
pub mod sentinel;
pub mod span;
pub mod trace;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

pub use hist::Hist;
pub use phase::Phase;
pub use span::{current_trace_id, set_trace_id, span, Span};

const TRACE_BIT: u8 = 1;
const PHASE_BIT: u8 = 2;
const SENTINEL_BIT: u8 = 4;

/// Enable bits; the off-path cost of every hook is this one load.
static FLAGS: AtomicU8 = AtomicU8::new(0);

/// Where [`flush`] writes the trace; set by [`init_tracing`].
static TRACE_PATH: Mutex<Option<PathBuf>> = Mutex::new(None);

#[inline]
pub fn tracing_on() -> bool {
    FLAGS.load(Ordering::Relaxed) & TRACE_BIT != 0
}

#[inline]
pub fn phases_on() -> bool {
    FLAGS.load(Ordering::Relaxed) & PHASE_BIT != 0
}

#[inline]
pub fn sentinels_on() -> bool {
    FLAGS.load(Ordering::Relaxed) & SENTINEL_BIT != 0
}

/// Turn span tracing on, exporting to `path` on [`flush`].  Also enables
/// phase accounting so the exported trace carries the kernel breakdown.
pub fn init_tracing(path: &Path) {
    *TRACE_PATH.lock().expect("obs trace path") = Some(path.to_path_buf());
    FLAGS.fetch_or(TRACE_BIT | PHASE_BIT, Ordering::Relaxed);
}

/// Honor `PSF_TRACE=<path>` (the env-var twin of `--trace`).  Also
/// honors the second-tier knobs: `PSF_SENTINEL=1` enables the numeric
/// sentinels and `PSF_INCIDENT=<path>` arms incident dumps (env-var
/// twin of `--incident`).  Returns the trace path when tracing got
/// enabled.
pub fn init_from_env() -> Option<PathBuf> {
    uptime_anchor(); // pin uptime to first obs touch
    if std::env::var_os("PSF_SENTINEL").filter(|v| !v.is_empty() && v != "0").is_some() {
        set_sentinels(true);
    }
    if let Some(p) = std::env::var_os("PSF_INCIDENT").filter(|v| !v.is_empty()) {
        incident::configure(Path::new(&p));
        incident::install_panic_hook();
        // Arm the flight recorder too: an incident dump's time-series
        // window is whatever the ring holds when the dump fires.
        recorder::start(recorder::DEFAULT_INTERVAL_MS, recorder::DEFAULT_WINDOW_FRAMES);
    }
    let path = std::env::var_os("PSF_TRACE").filter(|v| !v.is_empty())?;
    let path = PathBuf::from(path);
    init_tracing(&path);
    Some(path)
}

/// Toggle span collection without touching the configured path — the
/// overhead A/B in `benches/serve_load.rs` flips this.
pub fn set_tracing(on: bool) {
    if on {
        FLAGS.fetch_or(TRACE_BIT, Ordering::Relaxed);
    } else {
        FLAGS.fetch_and(!TRACE_BIT, Ordering::Relaxed);
    }
}

/// Toggle phase accounting alone (no trace file needed) — the
/// `kernel_profile` bench runs with just this.
pub fn set_phases(on: bool) {
    if on {
        FLAGS.fetch_or(PHASE_BIT, Ordering::Relaxed);
    } else {
        FLAGS.fetch_and(!PHASE_BIT, Ordering::Relaxed);
    }
}

/// Toggle the numeric-health sentinels (env twin: `PSF_SENTINEL=1`).
/// Off, every scan hook is one relaxed load; on, scans stay write-only
/// — outputs are byte-identical either way.
pub fn set_sentinels(on: bool) {
    if on {
        FLAGS.fetch_or(SENTINEL_BIT, Ordering::Relaxed);
    } else {
        FLAGS.fetch_and(!SENTINEL_BIT, Ordering::Relaxed);
    }
}

/// Monotonic process-uptime anchor, pinned on first use.
fn uptime_anchor() -> std::time::Instant {
    use std::sync::OnceLock;
    static ANCHOR: OnceLock<std::time::Instant> = OnceLock::new();
    *ANCHOR.get_or_init(std::time::Instant::now)
}

/// Seconds since the process first touched the obs layer — `/healthz`
/// uptime and the flight recorder's built-in gauge.
pub fn uptime_secs() -> f64 {
    uptime_anchor().elapsed().as_secs_f64()
}

/// Mint a request trace id: process id in the high 32 bits, a request
/// sequence number in the low — unique across the gateway/runner fleet
/// without coordination, and never 0 for a real request (pid > 0).
pub fn mint_trace_id(seq: u64) -> u64 {
    ((std::process::id() as u64) << 32) | (seq & 0xffff_ffff)
}

/// The configured trace output path, if tracing was initialized.
pub fn trace_path() -> Option<PathBuf> {
    TRACE_PATH.lock().expect("obs trace path").clone()
}

/// Drain every thread's span buffer plus the phase totals and write the
/// Chrome trace JSON to the configured path.  Returns the path written,
/// or `None` when tracing was never initialized.  Draining consumes both
/// the buffered events *and* the phase accumulators, so repeated flushes
/// append-merge deltas into the same file rather than double-counting.
pub fn flush() -> std::io::Result<Option<PathBuf>> {
    let Some(path) = trace_path() else {
        return Ok(None);
    };
    let (events, dropped) = span::drain_all();
    let phases = phase::totals();
    phase::reset();
    if path.exists() {
        // A previous flush (periodic or a pre-drain signal hook) already
        // wrote events: merge rather than clobber.
        trace::append(&path, &events, &phases, dropped)?;
    } else {
        trace::write(&path, &events, &phases, dropped)?;
    }
    Ok(Some(path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_toggle_independently() {
        // Serialize against other flag-touching tests via the span-side
        // lock used by the integration suite; unit scope here is fine
        // because this test restores the off state.
        set_tracing(true);
        assert!(tracing_on());
        set_phases(true);
        assert!(phases_on());
        set_tracing(false);
        assert!(!tracing_on());
        assert!(phases_on());
        set_phases(false);
        assert!(!phases_on());
    }

    #[test]
    fn flush_without_init_is_none() {
        if trace_path().is_none() {
            assert!(flush().unwrap().is_none());
        }
    }
}
