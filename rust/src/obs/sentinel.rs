//! Numeric-health sentinels: cheap, sampled absmax / non-finite scans at
//! the numerically risky boundaries of the stack.
//!
//! High-degree polynomial attention is the riskiest arithmetic we run —
//! degree-p powers of q·k overflow f32 unless inputs stay normalized
//! (the paper's Section 3 layernorm is exactly a stability fix), and the
//! f16/q8 storage tiers add precision cliffs.  A sentinel is a scan over
//! one tensor at one boundary (feature-map output, Z-fold accumulator,
//! logits, per-section gradients) that records — never repairs — the
//! *first* non-finite or overflowing value it sees, attributed to
//! (mechanism, layer, head, site, step/token).
//!
//! **Contract (same as the rest of `obs`).**  Off, every hook is one
//! relaxed atomic load and a branch.  On, sentinels are *write-only*:
//! they read tensors, they never write them, and nothing they compute
//! feeds back into the math — token streams, gradients, and golden
//! fixtures are byte-identical with sentinels on or off.  The only
//! sanctioned consequence of a trip is telemetry: the fault record, an
//! incident dump, and (in the trainer) a graceful halt *between* steps.
//! Kernel-boundary scans are sampled ([`KERNEL_SAMPLE_STRIDE`]) so the
//! on-cost stays a small fraction of the math they watch.

use std::cell::Cell;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// |x| beyond this counts as overflow-in-progress: far above anything a
/// healthy layernormed degree-p kernel produces, far below f32::MAX so
/// the fault names the site *before* the first Inf appears downstream.
pub const OVERFLOW_ABS: f32 = 1e30;

/// Kernel-boundary scans run on every N-th call per site (the first
/// call always scans).  Grad/loss sites scan every observation.
pub const KERNEL_SAMPLE_STRIDE: u64 = 16;

/// Loss must exceed `LOSS_SPIKE_FACTOR` x its EMA (after a short warmup)
/// to count as a spike.
const LOSS_SPIKE_FACTOR: f64 = 8.0;
const LOSS_WARMUP: u64 = 8;

/// Where a scan ran.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Linear engine: feature-map output (mapped q/k rows).
    FeatureMap,
    /// Linear engine: the Z prefix accumulator after a block fold.
    ZFold,
    /// Quadratic engine: the attention output block.
    AttnOut,
    /// Model head: final logits.
    Logits,
    /// Trainer: one named gradient section.
    Grad,
    /// Trainer: batch loss stream (spike/non-finite detector).
    Loss,
    /// Trainer: per-section update ratio |Δw|/|w|.
    UpdateRatio,
}

impl Site {
    pub fn name(self) -> &'static str {
        match self {
            Site::FeatureMap => "feature_map",
            Site::ZFold => "z_fold",
            Site::AttnOut => "attn_out",
            Site::Logits => "logits",
            Site::Grad => "grad",
            Site::Loss => "loss",
            Site::UpdateRatio => "update_ratio",
        }
    }

    fn index(self) -> usize {
        match self {
            Site::FeatureMap => 0,
            Site::ZFold => 1,
            Site::AttnOut => 2,
            Site::Logits => 3,
            Site::Grad => 4,
            Site::Loss => 5,
            Site::UpdateRatio => 6,
        }
    }

    /// Kernel-phase sites are sampled; train-loop sites scan every call.
    fn sampled(self) -> bool {
        matches!(self, Site::FeatureMap | Site::ZFold | Site::AttnOut | Site::Logits)
    }
}

const SITE_COUNT: usize = 7;

/// What kind of bad number tripped the sentinel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// NaN or Inf — fatal: downstream math is already poisoned.
    NonFinite,
    /// |x| > [`OVERFLOW_ABS`] — advisory: overflow in progress.
    Overflow,
    /// Loss jumped far above its EMA — advisory: likely divergence.
    LossSpike,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::NonFinite => "non_finite",
            FaultKind::Overflow => "overflow",
            FaultKind::LossSpike => "loss_spike",
        }
    }

    /// Fatal faults justify halting a training run between steps;
    /// advisory ones only report.
    pub fn is_fatal(self) -> bool {
        matches!(self, FaultKind::NonFinite)
    }
}

/// The first fault the sentinels saw, with full attribution.
#[derive(Clone, Debug)]
pub struct Fault {
    pub kind: FaultKind,
    pub site: Site,
    pub mechanism: String,
    /// -1 when the dimension does not apply at the site.
    pub layer: i64,
    pub head: i64,
    pub step: i64,
    pub token: i64,
    /// Flat index of the offending element within the scanned slice.
    pub index: usize,
    pub value: f64,
    pub absmax: f64,
    /// Free-form attribution (gradient section name, spike context).
    pub detail: String,
    pub ts_us: u64,
}

// ------------------------------------------------------------- context
//
// Attribution rides on cheap globals rather than plumbed arguments so
// the kernel hooks stay one-liner scans.  Layer / step / token advance
// sequentially on the driving thread while head fan-out happens inside
// one layer, so: layer/step/token are process globals, head is a
// thread-local (each pool worker owns one head at a time).

static MECH: Mutex<Option<String>> = Mutex::new(None);
static LAYER: AtomicI64 = AtomicI64::new(-1);
static STEP: AtomicI64 = AtomicI64::new(-1);
static TOKEN: AtomicI64 = AtomicI64::new(-1);

thread_local! {
    static HEAD: Cell<i64> = const { Cell::new(-1) };
}

/// Record the mechanism label faults will carry.  Call once per model
/// build; cheap no-op when sentinels are off.
pub fn set_mechanism(label: &str) {
    if !super::sentinels_on() {
        return;
    }
    *MECH.lock().expect("sentinel mech") = Some(label.to_string());
}

/// Current layer index (forward passes walk layers sequentially).
#[inline]
pub fn set_layer(layer: usize) {
    if super::sentinels_on() {
        LAYER.store(layer as i64, Ordering::Relaxed);
    }
}

/// Current head index — thread-local: pool workers each own one head.
#[inline]
pub fn set_head(head: usize) {
    if super::sentinels_on() {
        HEAD.with(|h| h.set(head as i64));
    }
}

/// Current train step.
#[inline]
pub fn set_step(step: u64) {
    if super::sentinels_on() {
        STEP.store(step as i64, Ordering::Relaxed);
    }
}

/// Current decode token position.
#[inline]
pub fn set_token(pos: usize) {
    if super::sentinels_on() {
        TOKEN.store(pos as i64, Ordering::Relaxed);
    }
}

// --------------------------------------------------------------- state

static FAULT: Mutex<Option<Fault>> = Mutex::new(None);
static TRIPS: AtomicU64 = AtomicU64::new(0);
/// 1 once a fatal (non-finite) fault is recorded — the trainer's
/// between-steps halt check is one relaxed load.
static FATAL: AtomicU64 = AtomicU64::new(0);

#[allow(clippy::declare_interior_mutable_const)] // const used only as array initializer
const ZERO: AtomicU64 = AtomicU64::new(0);
/// Per-site call counters (sampling stride) and absmax watermarks
/// (f32 bits; absmax is non-negative so bit order == numeric order).
static CALLS: [AtomicU64; SITE_COUNT] = [ZERO; SITE_COUNT];
static WATERMARK: [AtomicU64; SITE_COUNT] = [ZERO; SITE_COUNT];

/// Loss-spike EMA state: (ema, observations).
static LOSS_EMA: Mutex<(f64, u64)> = Mutex::new((0.0, 0));

fn record(kind: FaultKind, site: Site, index: usize, value: f64, absmax: f64, detail: &str) {
    TRIPS.fetch_add(1, Ordering::Relaxed);
    if kind.is_fatal() {
        FATAL.store(1, Ordering::Relaxed);
    }
    let mut slot = FAULT.lock().expect("sentinel fault");
    if slot.is_some() {
        return; // first fault wins; later ones only count
    }
    *slot = Some(Fault {
        kind,
        site,
        mechanism: MECH.lock().expect("sentinel mech").clone().unwrap_or_default(),
        layer: LAYER.load(Ordering::Relaxed),
        head: HEAD.with(|h| h.get()),
        step: STEP.load(Ordering::Relaxed),
        token: TOKEN.load(Ordering::Relaxed),
        index,
        value,
        absmax,
        detail: detail.to_string(),
        ts_us: super::span::now_us(),
    });
    drop(slot);
    eprintln!(
        "psf sentinel: {} at {} (layer {}, head {}, step {}, token {}){}{}",
        kind.name(),
        site.name(),
        LAYER.load(Ordering::Relaxed),
        HEAD.with(|h| h.get()),
        STEP.load(Ordering::Relaxed),
        TOKEN.load(Ordering::Relaxed),
        if detail.is_empty() { "" } else { " — " },
        detail,
    );
    super::incident::sentinel_trip();
}

fn raise_watermark(site: Site, absmax: f32) {
    let bits = absmax.to_bits() as u64;
    let w = &WATERMARK[site.index()];
    let mut cur = w.load(Ordering::Relaxed);
    while bits > cur {
        match w.compare_exchange_weak(cur, bits, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

fn scan_slice(site: Site, detail: &str, data: &[f32]) {
    let mut absmax = 0.0f32;
    let mut bad: Option<(usize, f32)> = None;
    for (i, &x) in data.iter().enumerate() {
        let a = x.abs();
        if a > absmax {
            absmax = a;
        }
        if bad.is_none() && !x.is_finite() {
            bad = Some((i, x));
        }
    }
    raise_watermark(site, if absmax.is_finite() { absmax } else { f32::MAX });
    match bad {
        Some((i, x)) => record(FaultKind::NonFinite, site, i, x as f64, absmax as f64, detail),
        None if absmax > OVERFLOW_ABS => {
            record(FaultKind::Overflow, site, 0, absmax as f64, absmax as f64, detail)
        }
        None => {}
    }
}

#[inline]
fn due(site: Site) -> bool {
    if !site.sampled() {
        return true;
    }
    CALLS[site.index()].fetch_add(1, Ordering::Relaxed) % KERNEL_SAMPLE_STRIDE == 0
}

/// Scan one tensor slice at a site.  Off: one relaxed load.  On: absmax
/// + non-finite sweep on the site's sampling cadence.
#[inline]
pub fn scan(site: Site, data: &[f32]) {
    if !super::sentinels_on() {
        return;
    }
    if due(site) {
        scan_slice(site, "", data);
    }
}

/// [`scan`] with a free-form attribution tag (gradient section names).
#[inline]
pub fn scan_named(site: Site, detail: &str, data: &[f32]) {
    if !super::sentinels_on() {
        return;
    }
    if due(site) {
        scan_slice(site, detail, data);
    }
}

/// Scan a row-iterated tensor (strided views) as one logical slice:
/// sampling is per call, absmax and the fault index span every row.
#[inline]
pub fn scan_rows<'a, I>(site: Site, rows: I)
where
    I: IntoIterator<Item = &'a [f32]>,
{
    if !super::sentinels_on() {
        return;
    }
    if !due(site) {
        return;
    }
    let mut absmax = 0.0f32;
    let mut bad: Option<(usize, f32)> = None;
    let mut base = 0usize;
    for row in rows {
        for (i, &x) in row.iter().enumerate() {
            let a = x.abs();
            if a > absmax {
                absmax = a;
            }
            if bad.is_none() && !x.is_finite() {
                bad = Some((base + i, x));
            }
        }
        base += row.len();
    }
    raise_watermark(site, if absmax.is_finite() { absmax } else { f32::MAX });
    match bad {
        Some((i, x)) => record(FaultKind::NonFinite, site, i, x as f64, absmax as f64, ""),
        None if absmax > OVERFLOW_ABS => {
            record(FaultKind::Overflow, site, 0, absmax as f64, absmax as f64, "")
        }
        None => {}
    }
}

/// Feed the loss-spike detector: trips on non-finite loss (fatal) or a
/// loss far above its EMA after warmup (advisory).
pub fn observe_loss(step: u64, loss: f64) {
    if !super::sentinels_on() {
        return;
    }
    set_step(step);
    if !loss.is_finite() {
        record(FaultKind::NonFinite, Site::Loss, 0, loss, loss.abs(), "batch loss");
        return;
    }
    let mut ema = LOSS_EMA.lock().expect("sentinel loss ema");
    let (mean, n) = *ema;
    if n >= LOSS_WARMUP && mean > 0.0 && loss > mean * LOSS_SPIKE_FACTOR {
        record(
            FaultKind::LossSpike,
            Site::Loss,
            0,
            loss,
            loss,
            &format!("loss {loss:.4} > {LOSS_SPIKE_FACTOR}x EMA {mean:.4}"),
        );
    }
    *ema = if n == 0 { (loss, 1) } else { (0.9 * mean + 0.1 * loss, n + 1) };
}

/// Feed one section's update ratio |Δw|/|w|.  Non-finite trips (fatal);
/// finite values only raise the watermark for the flight recorder.
pub fn observe_update_ratio(step: u64, section: &str, ratio: f64) {
    if !super::sentinels_on() {
        return;
    }
    set_step(step);
    if !ratio.is_finite() {
        record(FaultKind::NonFinite, Site::UpdateRatio, 0, ratio, ratio.abs(), section);
        return;
    }
    raise_watermark(Site::UpdateRatio, ratio as f32);
}

// ------------------------------------------------------------ readouts

/// Has any fault been recorded?
pub fn tripped() -> bool {
    TRIPS.load(Ordering::Relaxed) > 0
}

/// Has a *fatal* (non-finite) fault been recorded?  One relaxed load —
/// the trainer polls this between steps.
#[inline]
pub fn tripped_fatal() -> bool {
    FATAL.load(Ordering::Relaxed) != 0
}

/// Total faults seen (first is kept, the rest only counted).
pub fn trip_count() -> u64 {
    TRIPS.load(Ordering::Relaxed)
}

/// Snapshot of the first recorded fault.
pub fn fault() -> Option<Fault> {
    FAULT.lock().expect("sentinel fault").clone()
}

/// Per-site absmax watermarks seen so far: `(site name, absmax)`,
/// nonzero sites only.  Flight-recorder gauge feed.
pub fn watermarks() -> Vec<(&'static str, f64)> {
    const SITES: [Site; SITE_COUNT] = [
        Site::FeatureMap,
        Site::ZFold,
        Site::AttnOut,
        Site::Logits,
        Site::Grad,
        Site::Loss,
        Site::UpdateRatio,
    ];
    SITES
        .iter()
        .filter_map(|s| {
            let bits = WATERMARK[s.index()].load(Ordering::Relaxed);
            (bits != 0).then(|| (s.name(), f32::from_bits(bits as u32) as f64))
        })
        .collect()
}

/// The first fault as a JSON object (`null` when no fault) — embedded
/// verbatim in incident dumps.
pub fn fault_json() -> String {
    match fault() {
        None => "null".into(),
        Some(f) => crate::metrics::Record::new()
            .str("kind", f.kind.name())
            .str("site", f.site.name())
            .str("mechanism", &f.mechanism)
            .i64("layer", f.layer)
            .i64("head", f.head)
            .i64("step", f.step)
            .i64("token", f.token)
            .i64("index", f.index as i64)
            .f64("value", f.value)
            .f64("absmax", f.absmax)
            .str("detail", &f.detail)
            .i64("ts_us", f.ts_us as i64)
            .to_json(),
    }
}

/// Clear every sentinel accumulator (tests and bench A/B sweeps).
pub fn reset() {
    *FAULT.lock().expect("sentinel fault") = None;
    TRIPS.store(0, Ordering::Relaxed);
    FATAL.store(0, Ordering::Relaxed);
    for c in &CALLS {
        c.store(0, Ordering::Relaxed);
    }
    for w in &WATERMARK {
        w.store(0, Ordering::Relaxed);
    }
    *LOSS_EMA.lock().expect("sentinel loss ema") = (0.0, 0);
    LAYER.store(-1, Ordering::Relaxed);
    STEP.store(-1, Ordering::Relaxed);
    TOKEN.store(-1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sentinel state is process-global; these tests serialize on one
    // lock so enable/reset cycles don't interleave.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn off_scan_is_inert() {
        let _g = TEST_LOCK.lock().unwrap();
        if super::super::sentinels_on() {
            return; // another test enabled sentinels; skip rather than race
        }
        reset();
        scan(Site::Logits, &[f32::NAN, 1.0]);
        assert!(!tripped(), "disabled sentinel must not record");
    }

    #[test]
    fn first_nonfinite_wins_with_attribution() {
        let _g = TEST_LOCK.lock().unwrap();
        super::super::set_sentinels(true);
        reset();
        set_mechanism("psk4_r8_b16");
        set_layer(2);
        set_head(1);
        set_token(7);
        scan(Site::Logits, &[0.5, f32::INFINITY, f32::NAN]);
        scan(Site::Logits, &[f32::NAN]); // later fault: counted, not kept
        let f = fault().expect("fault recorded");
        assert_eq!(f.kind, FaultKind::NonFinite);
        assert_eq!(f.site, Site::Logits);
        assert_eq!(f.mechanism, "psk4_r8_b16");
        assert_eq!((f.layer, f.head, f.token), (2, 1, 7));
        assert_eq!(f.index, 1, "first bad element, not the later NaN");
        assert!(tripped_fatal());
        assert!(trip_count() >= 2);
        assert!(fault_json().contains("\"site\":\"logits\""));
        super::super::set_sentinels(false);
        reset();
    }

    #[test]
    fn overflow_is_advisory_not_fatal() {
        let _g = TEST_LOCK.lock().unwrap();
        super::super::set_sentinels(true);
        reset();
        scan(Site::Grad, &[1e31, 2.0]);
        let f = fault().expect("overflow recorded");
        assert_eq!(f.kind, FaultKind::Overflow);
        assert!(!tripped_fatal(), "overflow must not halt training");
        super::super::set_sentinels(false);
        reset();
    }

    #[test]
    fn kernel_sites_sample_train_sites_do_not() {
        let _g = TEST_LOCK.lock().unwrap();
        super::super::set_sentinels(true);
        reset();
        // Call 0 scans, calls 1..STRIDE-1 skip: a NaN on call 1 is missed
        // by design (sampling), but the same NaN at a train site is not.
        scan(Site::FeatureMap, &[1.0]);
        scan(Site::FeatureMap, &[f32::NAN]);
        assert!(!tripped(), "sampled site skipped the off-stride call");
        scan(Site::Grad, &[f32::NAN]);
        assert!(tripped(), "train sites scan every call");
        super::super::set_sentinels(false);
        reset();
    }

    #[test]
    fn loss_spike_detector_needs_warmup_then_fires() {
        let _g = TEST_LOCK.lock().unwrap();
        super::super::set_sentinels(true);
        reset();
        for s in 0..LOSS_WARMUP {
            observe_loss(s, 2.0);
        }
        assert!(!tripped(), "steady loss is healthy");
        observe_loss(LOSS_WARMUP, 2.0 * LOSS_SPIKE_FACTOR * 1.5);
        let f = fault().expect("spike recorded");
        assert_eq!(f.kind, FaultKind::LossSpike);
        assert!(!f.kind.is_fatal());
        super::super::set_sentinels(false);
        reset();
    }

    #[test]
    fn scan_rows_spans_rows_with_flat_index() {
        let _g = TEST_LOCK.lock().unwrap();
        super::super::set_sentinels(true);
        reset();
        let r0 = [1.0f32, 2.0];
        let r1 = [3.0f32, f32::NAN];
        scan_rows(Site::AttnOut, [&r0[..], &r1[..]]);
        let f = fault().expect("fault recorded");
        assert_eq!(f.index, 3, "flat index across rows");
        super::super::set_sentinels(false);
        reset();
    }

    #[test]
    fn watermarks_track_absmax() {
        let _g = TEST_LOCK.lock().unwrap();
        super::super::set_sentinels(true);
        reset();
        scan(Site::Grad, &[-4.0, 2.0]);
        scan(Site::Grad, &[3.0]);
        let w = watermarks();
        let grad = w.iter().find(|(n, _)| *n == "grad").expect("grad watermark");
        assert_eq!(grad.1, 4.0);
        super::super::set_sentinels(false);
        reset();
    }
}
