//! Induction Heads (Olsson et al. 2022; paper Appendix F.2).
//!
//! A sequence of random tokens contains one SPECIAL token at an arbitrary
//! position; the second-to-last token is SPECIAL again and the model must
//! output the token that followed the first SPECIAL occurrence.  Measures
//! in-context pattern completion ("[A][B] ... [A] -> [B]").
//!
//! Vocabulary layout: 0 = PAD, 1 = SPECIAL, 2.. = regular tokens.
//!
//! Only the final position carries training signal; in the flattened batch
//! every other target is negated so the AOT loss masks it (the model still
//! *sees* the full sequence as inputs — see `loss_fn` in model.py).

use super::Example;
use crate::util::rng::Pcg;

pub const SPECIAL: u32 = 1;
pub const TOKEN_BASE: u32 = 2;

#[derive(Clone, Copy, Debug)]
pub struct InductionTask {
    pub ctx: usize,
    /// Number of regular (non-special) tokens; paper uses 16.
    pub n_tokens: usize,
}

impl InductionTask {
    pub fn new(ctx: usize, n_tokens: usize) -> Self {
        assert!(ctx >= 8, "ctx too small for induction task");
        assert!(n_tokens >= 2);
        InductionTask { ctx, n_tokens }
    }

    /// Paper setup: vocabulary of 16 random tokens.
    pub fn standard(ctx: usize) -> Self {
        Self::new(ctx, 16)
    }

    pub fn vocab(&self) -> usize {
        TOKEN_BASE as usize + self.n_tokens
    }

    /// Generate one example: tokens length ctx+1.
    ///
    /// Layout (input coordinates 0..ctx):
    ///   random tokens everywhere, tokens[q] = SPECIAL for a random
    ///   q < ctx-3, tokens[ctx-1] = SPECIAL, tokens[ctx] = tokens[q+1].
    /// The single answer position (target coordinates) is ctx-1.
    pub fn sample(&self, rng: &mut Pcg) -> Example {
        let total = self.ctx + 1;
        let mut tokens: Vec<u32> = (0..total)
            .map(|_| TOKEN_BASE + rng.below(self.n_tokens as u64) as u32)
            .collect();
        // "a random position except the last 3 tokens" (Appendix F.2)
        let q = rng.below((total - 3) as u64) as usize;
        tokens[q] = SPECIAL;
        tokens[total - 2] = SPECIAL;
        tokens[total - 1] = tokens[q + 1];
        Example { tokens, answer_positions: vec![self.ctx - 1] }
    }

    /// A deterministic batch as a flat (batch, ctx+1) i32 vec with
    /// non-answer targets negated (masked-loss convention).
    pub fn batch(&self, batch: usize, rng: &mut Pcg) -> (Vec<i32>, Vec<Example>) {
        let mut flat = Vec::with_capacity(batch * (self.ctx + 1));
        let mut examples = Vec::with_capacity(batch);
        for _ in 0..batch {
            let ex = self.sample(rng);
            let answers: std::collections::HashSet<usize> =
                ex.answer_positions.iter().copied().collect();
            for (i, &t) in ex.tokens.iter().enumerate() {
                // Token at sequence index i is target index i-1; mask all
                // targets except answers. Index 0 is input-only: keep sign.
                let masked = i > 0 && !answers.contains(&(i - 1));
                flat.push(if masked { -(t as i32) } else { t as i32 });
            }
            examples.push(ex);
        }
        (flat, examples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_shape_and_answer() {
        let task = InductionTask::standard(128);
        let mut rng = Pcg::seeded(0);
        for _ in 0..32 {
            let ex = task.sample(&mut rng);
            assert_eq!(ex.tokens.len(), 129);
            assert_eq!(ex.answer_positions, vec![127]);
            // find the first SPECIAL; the last token must equal its successor
            let q = ex.tokens.iter().position(|&t| t == SPECIAL).unwrap();
            assert!(q < 126, "special must avoid the last 3 positions");
            assert_eq!(ex.tokens[128], ex.tokens[q + 1]);
            assert_eq!(ex.tokens[127], SPECIAL);
        }
    }

    #[test]
    fn answer_is_a_regular_token() {
        let task = InductionTask::standard(64);
        let mut rng = Pcg::seeded(1);
        for _ in 0..32 {
            let ex = task.sample(&mut rng);
            let ans = *ex.tokens.last().unwrap();
            assert!(ans >= TOKEN_BASE && (ans as usize) < task.vocab());
        }
    }

    #[test]
    fn batch_masks_everything_but_answer() {
        let task = InductionTask::standard(32);
        let (flat, examples) = task.batch(4, &mut Pcg::seeded(2));
        assert_eq!(flat.len(), 4 * 33);
        for (b, ex) in examples.iter().enumerate() {
            let row = &flat[b * 33..(b + 1) * 33];
            // index 0 is input-only and positive
            assert!(row[0] > 0);
            for i in 1..33 {
                let is_answer = ex.answer_positions.contains(&(i - 1));
                assert_eq!(row[i] > 0, is_answer, "row[{i}] sign");
                assert_eq!(row[i].unsigned_abs(), ex.tokens[i]);
            }
        }
    }

    #[test]
    fn batch_is_deterministic() {
        let task = InductionTask::standard(32);
        let (a, _) = task.batch(3, &mut Pcg::seeded(7));
        let (b, _) = task.batch(3, &mut Pcg::seeded(7));
        assert_eq!(a, b);
    }
}
