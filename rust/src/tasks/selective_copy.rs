//! Selective Copying (Gu & Dao 2023; paper Appendix F.1, Table 5, Fig 5).
//!
//! The context contains `n_memorize` colored tokens scattered at random
//! positions among pads; after a separator the model must reproduce the
//! colors in order.  Measures content-aware long-range memorization.
//!
//! Vocabulary layout: 0 = PAD, 1 = SEP, 2.. = colors.

use super::Example;
use crate::util::rng::Pcg;

#[derive(Clone, Copy, Debug)]
pub struct SelectiveCopyTask {
    pub ctx: usize,
    pub n_colors: usize,
    pub n_memorize: usize,
}

pub const SEP: u32 = 1;
pub const COLOR_BASE: u32 = 2;

impl SelectiveCopyTask {
    pub fn new(ctx: usize, n_colors: usize, n_memorize: usize) -> Self {
        assert!(ctx > 2 * n_memorize + 2, "ctx too small for task");
        SelectiveCopyTask { ctx, n_colors, n_memorize }
    }

    /// Paper setup scaled: 16 colors, 16 tokens to copy.
    pub fn standard(ctx: usize) -> Self {
        Self::new(ctx, 16, 16)
    }

    pub fn vocab(&self) -> usize {
        COLOR_BASE as usize + self.n_colors
    }

    /// Generate one example: tokens length ctx+1.
    ///
    /// Layout: [ scatter region (ctx - n_memorize - 1) | SEP | answers ].
    /// Targets are PAD-masked everywhere except the answer span.
    pub fn sample(&self, rng: &mut Pcg) -> Example {
        let total = self.ctx + 1;
        let scatter_len = total - self.n_memorize - 1;
        let mut tokens = vec![0u32; total];

        // choose distinct scatter positions, sorted (order defines answer)
        let mut pos: Vec<usize> = (0..scatter_len).collect();
        rng.shuffle(&mut pos);
        let mut chosen: Vec<usize> = pos[..self.n_memorize].to_vec();
        chosen.sort_unstable();

        let mut colors = Vec::with_capacity(self.n_memorize);
        for &p in &chosen {
            let c = COLOR_BASE + rng.below(self.n_colors as u64) as u32;
            tokens[p] = c;
            colors.push(c);
        }
        tokens[scatter_len] = SEP;
        tokens[scatter_len + 1..].copy_from_slice(&colors);

        // Answer positions in *target* coordinates: the answer span starts
        // at input index scatter_len (the SEP) predicting target index
        // scatter_len .. scatter_len + n_memorize.
        let answer_positions = (scatter_len..scatter_len + self.n_memorize).collect();
        Example { tokens, answer_positions }
    }

    /// A deterministic batch of examples as a flat (batch, ctx+1) i32 vec.
    pub fn batch(&self, batch: usize, rng: &mut Pcg) -> (Vec<i32>, Vec<Example>) {
        let mut flat = Vec::with_capacity(batch * (self.ctx + 1));
        let mut examples = Vec::with_capacity(batch);
        for _ in 0..batch {
            let ex = self.sample(rng);
            flat.extend(ex.tokens.iter().map(|&t| t as i32));
            examples.push(ex);
        }
        (flat, examples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_shape_and_alignment() {
        let task = SelectiveCopyTask::standard(256);
        let mut rng = Pcg::seeded(0);
        let ex = task.sample(&mut rng);
        assert_eq!(ex.tokens.len(), 257);
        assert_eq!(ex.answer_positions.len(), 16);

        // The colors in the scatter region, in order, equal the answers.
        let scatter_len = 257 - 16 - 1;
        let scattered: Vec<u32> = ex.tokens[..scatter_len]
            .iter()
            .copied()
            .filter(|&t| t >= COLOR_BASE)
            .collect();
        let answers: Vec<u32> = ex.tokens[scatter_len + 1..].to_vec();
        assert_eq!(scattered, answers);
        assert_eq!(ex.tokens[scatter_len], SEP);
    }

    #[test]
    fn answer_positions_index_answers() {
        let task = SelectiveCopyTask::standard(128);
        let mut rng = Pcg::seeded(1);
        let ex = task.sample(&mut rng);
        let targets = ex.targets();
        for &p in &ex.answer_positions {
            assert!(targets[p] >= COLOR_BASE, "target at {p} = {}", targets[p]);
        }
    }

    #[test]
    fn nonanswer_targets_are_pad_or_sep() {
        let task = SelectiveCopyTask::standard(128);
        let mut rng = Pcg::seeded(2);
        let ex = task.sample(&mut rng);
        let answers: std::collections::HashSet<_> =
            ex.answer_positions.iter().copied().collect();
        // Targets outside answers may be pad, sep, or scattered colors;
        // crucially the *masked loss* counts colors only where target != 0.
        // Check at least: nothing out of vocab.
        for (i, &t) in ex.targets().iter().enumerate() {
            assert!((t as usize) < task.vocab());
            if answers.contains(&i) {
                assert!(t >= COLOR_BASE);
            }
        }
    }

    #[test]
    fn batch_is_flat_and_deterministic() {
        let task = SelectiveCopyTask::standard(64);
        let (a, _) = task.batch(4, &mut Pcg::seeded(3));
        let (b, _) = task.batch(4, &mut Pcg::seeded(3));
        assert_eq!(a, b);
        assert_eq!(a.len(), 4 * 65);
    }

    #[test]
    #[should_panic]
    fn tiny_ctx_rejected() {
        SelectiveCopyTask::standard(16);
    }
}
