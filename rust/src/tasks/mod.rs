//! Synthetic tasks from Appendix F: Selective Copying and Induction Heads.
//!
//! Both are emitted in the (B, ctx+1) next-token format the train artifact
//! consumes, with PAD (0) masking every position except the answers — so
//! the masked loss trains exactly the task signal, and accuracy evaluation
//! reads only answer positions.

pub mod induction;
pub mod selective_copy;

/// One task example: a full sequence (ctx + 1 tokens; inputs are [..ctx],
/// targets are [1..]) and the positions (in target coordinates) that count
/// for accuracy.
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<u32>,
    /// Indices into the target sequence (0-based) holding answers.
    pub answer_positions: Vec<usize>,
}

impl Example {
    /// Targets slice (length ctx).
    pub fn targets(&self) -> &[u32] {
        &self.tokens[1..]
    }

    /// Inputs slice (length ctx).
    pub fn inputs(&self) -> &[u32] {
        &self.tokens[..self.tokens.len() - 1]
    }
}

/// Number of answer positions where the greedy prediction matches.
/// `logits`: (ctx, vocab) row-major for this example's inputs.
pub fn answers_correct(ex: &Example, logits: &[f32], vocab: usize) -> usize {
    let targets = ex.targets();
    let mut correct = 0;
    for &pos in &ex.answer_positions {
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        if best as u32 == targets[pos] {
            correct += 1;
        }
    }
    correct
}

/// Exact match: every answer position greedily correct (the paper's
/// Table 5 metric).
pub fn example_correct(ex: &Example, logits: &[f32], vocab: usize) -> bool {
    answers_correct(ex, logits, vocab) == ex.answer_positions.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_correct_checks_only_answers() {
        let ex = Example { tokens: vec![5, 6, 7, 8], answer_positions: vec![2] };
        // targets = [6,7,8]; answer position 2 -> target 8.
        let vocab = 10;
        let mut logits = vec![0.0f32; 3 * vocab];
        logits[2 * vocab + 8] = 5.0; // argmax at answer = 8 ✓
        logits[0 * vocab + 1] = 9.0; // wrong elsewhere, ignored
        assert!(example_correct(&ex, &logits, vocab));
        logits[2 * vocab + 3] = 9.0; // now argmax at answer = 3 ✗
        assert!(!example_correct(&ex, &logits, vocab));
    }
}
