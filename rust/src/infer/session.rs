//! Decode sessions: prompt prefill, then token-by-token stepping.
//!
//! A session owns its per-layer [`LayerState`]s, its sampling policy, and
//! its private RNG stream — sessions over the same (immutable) model are
//! fully independent, which is what lets the scheduler interleave them in
//! any order without changing any session's output.

use std::time::Instant;

use crate::infer::model::{LayerState, NativeLm};
use crate::infer::sampler::SamplePolicy;
use crate::util::rng::Pcg;

/// Byte-level prompt encoding: BOS (0) + each byte as id 1..=256.
pub fn encode_prompt(text: &str) -> Vec<u32> {
    std::iter::once(0u32).chain(text.bytes().map(|b| b as u32 + 1)).collect()
}

/// Inverse of [`encode_prompt`] over generated ids (lossy UTF-8).
pub fn decode_text(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (1..=256).contains(&t))
        .map(|&t| (t - 1) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// A generation request submitted to the scheduler.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub policy: SamplePolicy,
    /// Sampling seed — with the same seed, prompt, and policy the output
    /// token sequence is identical regardless of scheduling.
    pub seed: u64,
}

/// Frozen copy of a [`DecodeSession`]: decode states, sampler RNG stream,
/// and token history.  Restoring resumes generation byte-identically to an
/// uninterrupted run — the primitive the serving gateway's prompt-prefix
/// cache (`serve::cache`) and any future migration/checkpointing are built
/// on.  Timing fields are observations, not state, and are not captured.
#[derive(Clone)]
pub struct SessionSnapshot {
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    pub states: Vec<LayerState>,
    pub last_logits: Vec<f32>,
    pub policy: SamplePolicy,
    pub rng: Pcg,
    pub max_new: usize,
    pub finished: bool,
}

impl SessionSnapshot {
    /// Tokens generated beyond the prompt at capture time.
    pub fn new_tokens(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }
}

/// One in-flight decode session.
pub struct DecodeSession {
    pub id: usize,
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    states: Vec<LayerState>,
    last_logits: Vec<f32>,
    policy: SamplePolicy,
    rng: Pcg,
    max_new: usize,
    pub finished: bool,
    /// Wall time of the prompt prefill.
    pub prefill_secs: f64,
    /// Accumulated wall time of decode steps.
    pub decode_secs: f64,
    /// Per-token decode latencies (seconds), one per generated token.
    pub step_secs: Vec<f64>,
}

impl DecodeSession {
    /// Prefill the prompt through the full-context path and stand ready to
    /// decode. Panics on an empty prompt (encode_prompt always emits BOS).
    pub fn new(model: &NativeLm, id: usize, req: GenRequest) -> DecodeSession {
        assert!(!req.prompt.is_empty(), "prompt must contain at least BOS");
        let mut states = model.new_states();
        let t0 = Instant::now();
        let logits = model.prefill(&req.prompt, &mut states);
        let prefill_secs = t0.elapsed().as_secs_f64();
        let last = logits.row(req.prompt.len() - 1).to_vec();
        DecodeSession {
            id,
            prompt_len: req.prompt.len(),
            tokens: req.prompt,
            states,
            last_logits: last,
            policy: req.policy,
            rng: Pcg::seeded(req.seed),
            max_new: req.max_new_tokens,
            finished: req.max_new_tokens == 0,
            prefill_secs,
            decode_secs: 0.0,
            step_secs: Vec::new(),
        }
    }

    /// Build a session from a cached prompt-prefix state, skipping the
    /// prefill entirely: `states`/`last_logits` must be a snapshot taken
    /// right after prefilling exactly `req.prompt` (no decode steps), as
    /// the serving cache stores them.  Sampling seed/policy/budget come
    /// from `req`, so one cached prefix serves any request shape over the
    /// same prompt.
    pub fn from_prefix(
        id: usize,
        req: GenRequest,
        states: Vec<LayerState>,
        last_logits: Vec<f32>,
    ) -> DecodeSession {
        assert!(!req.prompt.is_empty(), "prompt must contain at least BOS");
        if let Some(head) = states.first().and_then(|l| l.heads.first()) {
            assert_eq!(
                head.tokens_seen(),
                req.prompt.len(),
                "prefix snapshot does not match the prompt length"
            );
        }
        DecodeSession {
            id,
            prompt_len: req.prompt.len(),
            tokens: req.prompt,
            states,
            last_logits,
            policy: req.policy,
            rng: Pcg::seeded(req.seed),
            max_new: req.max_new_tokens,
            finished: req.max_new_tokens == 0,
            prefill_secs: 0.0,
            decode_secs: 0.0,
            step_secs: Vec::new(),
        }
    }

    /// Freeze this session's full state (deep copy).
    pub fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot {
            tokens: self.tokens.clone(),
            prompt_len: self.prompt_len,
            states: self.states.clone(),
            last_logits: self.last_logits.clone(),
            policy: self.policy.clone(),
            rng: self.rng.clone(),
            max_new: self.max_new,
            finished: self.finished,
        }
    }

    /// Resume from a snapshot; continuation is byte-identical to the
    /// session the snapshot was taken from (timing counters restart).
    pub fn restore(id: usize, snap: SessionSnapshot) -> DecodeSession {
        DecodeSession {
            id,
            tokens: snap.tokens,
            prompt_len: snap.prompt_len,
            states: snap.states,
            last_logits: snap.last_logits,
            policy: snap.policy,
            rng: snap.rng,
            max_new: snap.max_new,
            finished: snap.finished,
            prefill_secs: 0.0,
            decode_secs: 0.0,
            step_secs: Vec::new(),
        }
    }

    /// Tokens generated so far.
    pub fn new_tokens(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// Steps this session can still take before finishing — `step`
    /// returns `None` exactly when this is 0.  Lets the scheduler compute
    /// its token allocation arithmetically (and therefore identically at
    /// every thread count) before stepping sessions in parallel.
    pub fn remaining_budget(&self) -> usize {
        if self.finished {
            0
        } else {
            self.max_new - self.new_tokens()
        }
    }

    /// Sample one token and advance the decode states to produce the next
    /// logits. Returns the token, or `None` if the session is already
    /// finished.  The model advances even on the final token, so every
    /// generated token costs exactly one sample + one model step (honest
    /// per-token timing) and the states stay consistent with `tokens` —
    /// a retired session could be resumed with a larger budget.
    pub fn step(&mut self, model: &NativeLm) -> Option<u32> {
        if self.finished {
            return None;
        }
        let t0 = Instant::now();
        let tok = self.policy.sample(&self.last_logits, &mut self.rng) as u32;
        self.tokens.push(tok);
        let pos = self.tokens.len() - 1;
        self.last_logits = model.step(tok, pos, &mut self.states);
        if self.new_tokens() >= self.max_new {
            self.finished = true;
        }
        let dt = t0.elapsed().as_secs_f64();
        self.decode_secs += dt;
        self.step_secs.push(dt);
        Some(tok)
    }

    /// Run the whole request to completion (no scheduler involved).
    pub fn run_to_completion(&mut self, model: &NativeLm) {
        while self.step(model).is_some() {}
    }

    /// Generated suffix (excluding the prompt).
    pub fn generated(&self) -> &[u32] {
        &self.tokens[self.prompt_len..]
    }

    /// Per-layer decode states (read-only; the cache freezer walks these
    /// without the deep copy a [`DecodeSession::snapshot`] would make).
    pub fn states(&self) -> &[LayerState] {
        &self.states
    }

    /// Next-token logits produced by the last prefill/step.
    pub fn last_logits(&self) -> &[f32] {
        &self.last_logits
    }

    /// Decode-state footprint right now, in f32 words.
    pub fn state_memory_floats(&self) -> usize {
        NativeLm::state_memory_floats(&self.states)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::model::LmConfig;

    fn model() -> NativeLm {
        let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 3 };
        NativeLm::new(cfg, Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true })
    }

    #[test]
    fn prompt_roundtrip() {
        let toks = encode_prompt("hi!");
        assert_eq!(toks, vec![0, b'h' as u32 + 1, b'i' as u32 + 1, b'!' as u32 + 1]);
        assert_eq!(decode_text(&toks[1..]), "hi!");
    }

    #[test]
    fn session_generates_exactly_max_new() {
        let m = model();
        let req = GenRequest {
            prompt: vec![0, 5, 9],
            max_new_tokens: 7,
            policy: SamplePolicy::Greedy,
            seed: 0,
        };
        let mut s = DecodeSession::new(&m, 0, req);
        s.run_to_completion(&m);
        assert!(s.finished);
        assert_eq!(s.new_tokens(), 7);
        assert_eq!(s.step_secs.len(), 7);
        assert!(s.generated().iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn restored_session_continues_byte_identically() {
        // The cache/migration primitive: snapshot mid-decode, keep stepping
        // the original, then restore the snapshot — the restored session
        // must emit the exact same remaining tokens (and land on the exact
        // same logits) as the uninterrupted run.
        use crate::attn::Mechanism;
        let mechs = [
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 16, block: 8 },
        ];
        for mech in mechs {
            let cfg =
                LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 3 };
            let m = NativeLm::new(cfg, mech.clone());
            let req = GenRequest {
                prompt: vec![0, 5, 9, 21, 2],
                max_new_tokens: 12,
                policy: SamplePolicy::Temperature(0.8),
                seed: 99,
            };
            let mut uninterrupted = DecodeSession::new(&m, 0, req);
            for _ in 0..5 {
                uninterrupted.step(&m);
            }
            let snap = uninterrupted.snapshot();
            assert_eq!(snap.new_tokens(), 5);
            uninterrupted.run_to_completion(&m);

            let mut restored = DecodeSession::restore(1, snap);
            restored.run_to_completion(&m);
            assert_eq!(
                restored.tokens,
                uninterrupted.tokens,
                "{}: restored tokens diverged",
                mech.label()
            );
            // Byte-identical down to the final logits, not just the argmaxes.
            assert_eq!(
                restored.snapshot().last_logits,
                uninterrupted.snapshot().last_logits,
                "{}: restored logits diverged",
                mech.label()
            );
        }
    }

    #[test]
    fn from_prefix_matches_fresh_prefill() {
        // A prompt-prefix snapshot (states + last logits of a session that
        // has not decoded yet) must serve any request over the same prompt
        // exactly as a cold prefill would.
        let m = model();
        let prompt = vec![0u32, 7, 13, 2, 40, 11];
        let policies =
            [SamplePolicy::Greedy, SamplePolicy::TopP { p: 0.9, temperature: 0.8 }];
        let cold = DecodeSession::new(
            &m,
            0,
            GenRequest {
                prompt: prompt.clone(),
                max_new_tokens: 0,
                policy: SamplePolicy::Greedy,
                seed: 0,
            },
        );
        let prefix = cold.snapshot();
        for (i, policy) in policies.into_iter().enumerate() {
            let req = |seed| GenRequest {
                prompt: prompt.clone(),
                max_new_tokens: 9,
                policy: policy.clone(),
                seed,
            };
            let mut fresh = DecodeSession::new(&m, 0, req(5 + i as u64));
            fresh.run_to_completion(&m);
            let mut cached = DecodeSession::from_prefix(
                1,
                req(5 + i as u64),
                prefix.states.clone(),
                prefix.last_logits.clone(),
            );
            assert_eq!(cached.prefill_secs, 0.0);
            cached.run_to_completion(&m);
            assert_eq!(fresh.tokens, cached.tokens);
        }
    }

    #[test]
    fn same_seed_same_output() {
        let m = model();
        let req = |seed| GenRequest {
            prompt: vec![0, 1, 2, 3, 4],
            max_new_tokens: 12,
            policy: SamplePolicy::Temperature(0.9),
            seed,
        };
        let mut a = DecodeSession::new(&m, 0, req(42));
        let mut b = DecodeSession::new(&m, 1, req(42));
        let mut c = DecodeSession::new(&m, 2, req(43));
        a.run_to_completion(&m);
        b.run_to_completion(&m);
        c.run_to_completion(&m);
        assert_eq!(a.generated(), b.generated());
        assert_ne!(a.generated(), c.generated());
    }
}
