//! Native decoder-only transformer LM over the kernel core.
//!
//! The PJRT model path (`runtime::ModelRuntime`) executes fixed-shape AOT
//! artifacts and cannot step one token at a time; this model is its
//! native-rust twin for the serving path, mirroring the paper recipe the
//! JAX model uses (python/compile/model.py): sinusoidal absolute position
//! embeddings on the token embedding, pre-LN blocks, RoPE on q/k, GEGLU
//! feed-forward, final LN + readout.  Weights are deterministic in the
//! config seed (this repo has no host-side checkpoint import — the
//! serving subsystem's correctness story is prefill/decode parity, which
//! is weight-independent).
//!
//! Attention is entirely behind [`CausalKernel`]: each (layer, head)
//! holds one `Arc<dyn CausalKernel>` built by `Mechanism::build_kernel`
//! (the single dispatch point), and this file never learns which engine
//! is behind a head.  Two execution paths over the *same* weights:
//!
//! * [`NativeLm::prefill`] — full-context forward; each head consumes
//!   strided views of the fused q/k/v projections and writes its output
//!   stripe in place (`kernel::prefill_heads` — no per-head copies, no
//!   zero-padding, no concat), leaving the decode states exactly as if
//!   every position had been stepped;
//! * [`NativeLm::step`] — one token through the per-head
//!   [`KernelState`]s: O(1) per token for the linear engine, O(n) for
//!   the KV engine.

use std::sync::Arc;

use crate::attn::kernel::{self, CausalKernel, KernelState};
use crate::attn::Mechanism;
use crate::tensor::{layernorm_rows, ln_row, Tensor};
use crate::util::rng::Pcg;

/// Native LM hyperparameters.
#[derive(Clone, Debug)]
pub struct LmConfig {
    /// Vocabulary size; the `generate` path uses byte-level tokens
    /// (id 0 = BOS, ids 1..=256 = bytes), so 257 is the natural floor.
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    /// GEGLU hidden width = `ff_mult * d_model`.
    pub ff_mult: usize,
    /// Weight seed (deterministic init).
    pub seed: u64,
}

impl Default for LmConfig {
    fn default() -> Self {
        LmConfig { vocab: 257, d_model: 64, layers: 2, heads: 4, ff_mult: 2, seed: 0 }
    }
}

struct Layer {
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    ffn_gate: Tensor,
    ffn_up: Tensor,
    ffn_down: Tensor,
    /// One instantiated kernel (engine + sketches/features) per head.
    heads: Vec<Arc<dyn CausalKernel>>,
}

/// Decode state of one layer: one [`KernelState`] per head.
#[derive(Clone)]
pub struct LayerState {
    pub heads: Vec<KernelState>,
}

/// Native autoregressive LM (one per served mechanism).
pub struct NativeLm {
    pub cfg: LmConfig,
    pub mech: Mechanism,
    embed: Tensor,
    readout: Tensor,
    layers: Vec<Layer>,
}

impl NativeLm {
    pub fn new(cfg: LmConfig, mech: Mechanism) -> NativeLm {
        assert!(cfg.d_model % cfg.heads == 0, "d_model must divide into heads");
        let hd = cfg.d_model / cfg.heads;
        assert!(hd % 2 == 0, "head_dim must be even (RoPE pairs)");
        let mut rng = Pcg::seeded(cfg.seed ^ 0x1fe7);
        let d = cfg.d_model;
        let f = cfg.ff_mult * d;
        let sd = 1.0 / (d as f32).sqrt();
        let sf = 1.0 / (f as f32).sqrt();
        let embed = Tensor::gaussian(&mut rng, &[cfg.vocab, d]).scale(0.02);
        let readout = Tensor::gaussian(&mut rng, &[d, cfg.vocab]).scale(0.02);
        let layers = (0..cfg.layers)
            .map(|_| Layer {
                wq: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wk: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wv: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wo: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                ffn_gate: Tensor::gaussian(&mut rng, &[d, f]).scale(sd),
                ffn_up: Tensor::gaussian(&mut rng, &[d, f]).scale(sd),
                ffn_down: Tensor::gaussian(&mut rng, &[f, d]).scale(sf),
                heads: (0..cfg.heads).map(|_| mech.build_kernel(hd, &mut rng)).collect(),
            })
            .collect();
        NativeLm { cfg, mech, embed, readout, layers }
    }

    pub fn head_dim(&self) -> usize {
        self.cfg.d_model / self.cfg.heads
    }

    /// Fresh per-layer decode states matching this model's kernels.
    pub fn new_states(&self) -> Vec<LayerState> {
        self.layers
            .iter()
            .map(|l| LayerState { heads: l.heads.iter().map(|k| k.new_state()).collect() })
            .collect()
    }

    /// Total decode-state footprint in f32 words (all layers and heads).
    pub fn state_memory_floats(states: &[LayerState]) -> usize {
        states
            .iter()
            .flat_map(|l| l.heads.iter())
            .map(KernelState::memory_floats)
            .sum()
    }

    /// Full-context forward: (n,) tokens -> (n, vocab) logits.
    pub fn forward(&self, tokens: &[u32]) -> Tensor {
        self.forward_capture(tokens, None)
    }

    /// Prefill: full-context forward that additionally leaves `states`
    /// holding every position's per-layer/head decode state, ready for
    /// token-by-token [`NativeLm::step`]s at positions `n..`.
    pub fn prefill(&self, tokens: &[u32], states: &mut [LayerState]) -> Tensor {
        self.forward_capture(tokens, Some(states))
    }

    fn forward_capture(&self, tokens: &[u32], mut states: Option<&mut [LayerState]>) -> Tensor {
        let n = tokens.len();
        assert!(n > 0, "empty token sequence");
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        let mut x = Tensor::zeros(&[n, d]);
        for (i, &t) in tokens.iter().enumerate() {
            let row = x.row_mut(i);
            row.copy_from_slice(self.embed.row(t as usize));
            add_sinusoidal(row, i);
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let xn = layernorm_rows(&x);
            let mut q = xn.matmul(&layer.wq);
            let mut k = xn.matmul(&layer.wk);
            let v = xn.matmul(&layer.wv);
            // RoPE on the fused projections, per head segment (rows are
            // independent — deterministic row-parallel).
            rope_heads(&mut q, hd);
            rope_heads(&mut k, hd);
            // Heads are embarrassingly parallel: each one reads its own
            // strided column stripe of q/k/v, owns its own decode state,
            // and writes its own output stripe — no shared mutable state,
            // no copies, so the bytes cannot depend on scheduling.
            let mut attn_out = Tensor::zeros(&[n, d]);
            kernel::prefill_heads(
                &layer.heads,
                &q,
                &k,
                &v,
                states.as_deref_mut().map(|s| s[li].heads.as_mut_slice()),
                &mut attn_out,
            );
            x = x.add(&attn_out.matmul(&layer.wo));
            let xn2 = layernorm_rows(&x);
            let g = xn2.matmul(&layer.ffn_gate).map(gelu);
            let u = xn2.matmul(&layer.ffn_up);
            x = x.add(&g.hadamard(&u).matmul(&layer.ffn_down));
        }
        layernorm_rows(&x).matmul(&self.readout)
    }

    /// One decode step: fold `token` (at absolute position `pos`) into the
    /// states and return the next-token logits (vocab,).
    pub fn step(&self, token: u32, pos: usize, states: &mut [LayerState]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        let mut x = self.embed.row(token as usize).to_vec();
        add_sinusoidal(&mut x, pos);
        for (li, layer) in self.layers.iter().enumerate() {
            let xn = Tensor::from_vec(&[1, d], ln_row(&x));
            let q = xn.matmul(&layer.wq);
            let k = xn.matmul(&layer.wk);
            let v = xn.matmul(&layer.wv);
            let mut concat = vec![0.0f32; d];
            for hi in 0..self.cfg.heads {
                let mut qh = q.row(0)[hi * hd..(hi + 1) * hd].to_vec();
                let mut kh = k.row(0)[hi * hd..(hi + 1) * hd].to_vec();
                let vh = &v.row(0)[hi * hd..(hi + 1) * hd];
                rope_row(&mut qh, pos);
                rope_row(&mut kh, pos);
                let oh = layer.heads[hi].step(&qh, &kh, vh, &mut states[li].heads[hi]);
                concat[hi * hd..(hi + 1) * hd].copy_from_slice(&oh);
            }
            let attn_out = Tensor::from_vec(&[1, d], concat).matmul(&layer.wo);
            for (xi, a) in x.iter_mut().zip(attn_out.data()) {
                *xi += a;
            }
            let xn2 = Tensor::from_vec(&[1, d], ln_row(&x));
            let g = xn2.matmul(&layer.ffn_gate).map(gelu);
            let u = xn2.matmul(&layer.ffn_up);
            let ffn = g.hadamard(&u).matmul(&layer.ffn_down);
            for (xi, a) in x.iter_mut().zip(ffn.data()) {
                *xi += a;
            }
        }
        Tensor::from_vec(&[1, d], ln_row(&x)).matmul(&self.readout).into_vec()
    }
}

/// Apply RoPE to every head segment of every row of a fused (n, H·hd)
/// projection, in place.  Row-parallel on the deterministic backend.
fn rope_heads(t: &mut Tensor, hd: usize) {
    use crate::exec::pool;
    let d = t.cols();
    debug_assert_eq!(d % hd, 0);
    pool::par_row_chunks(t.data_mut(), d, 16, |row0, chunk| {
        for (r, row) in chunk.chunks_mut(d).enumerate() {
            let pos = row0 + r;
            for seg in row.chunks_mut(hd) {
                rope_row(seg, pos);
            }
        }
    });
}

/// Add the sinusoidal absolute position embedding for `pos` in place —
/// the half-split layout of python/compile/model.py::sinusoidal_table.
fn add_sinusoidal(row: &mut [f32], pos: usize) {
    let d = row.len();
    let half = d / 2;
    for j in 0..half {
        let angle = pos as f64 / 10000f64.powf(2.0 * j as f64 / d as f64);
        row[j] += angle.sin() as f32;
        row[half + j] += angle.cos() as f32;
    }
}

/// Rotary position embedding of one head row (half-split pairing, matching
/// python/compile/model.py::_rope).
fn rope_row(x: &mut [f32], pos: usize) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let theta = pos as f64 / 10000f64.powf(2.0 * i as f64 / hd as f64);
        let (c, s) = (theta.cos() as f32, theta.sin() as f32);
        let (x1, x2) = (x[i], x[half + i]);
        x[i] = x1 * c - x2 * s;
        x[half + i] = x1 * s + x2 * c;
    }
}

/// Tanh-approximation GELU (python/compile/common.py's activation).
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((2.0 / std::f32::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(mech: Mechanism) -> NativeLm {
        let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 7 };
        NativeLm::new(cfg, mech)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let lm = tiny(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let tokens: Vec<u32> = (0..13).map(|i| (i * 5) % 64).collect();
        let logits = lm.forward(&tokens);
        assert_eq!(logits.shape(), &[13, 64]);
        assert!(logits.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_is_deterministic_in_seed() {
        let mech = Mechanism::Performer { m: 8, block: 8 };
        let a = tiny(mech.clone());
        let b = tiny(mech);
        let tokens: Vec<u32> = (0..9).collect();
        assert_eq!(a.forward(&tokens), b.forward(&tokens));
    }

    #[test]
    fn forward_is_causal() {
        let lm = tiny(Mechanism::Softmax);
        let t1: Vec<u32> = (0..12).collect();
        let mut t2 = t1.clone();
        t2[11] = 63;
        let a = lm.forward(&t1);
        let b = lm.forward(&t2);
        for i in 0..11 {
            assert_eq!(a.row(i), b.row(i), "row {i} depends on a future token");
        }
        assert_ne!(a.row(11), b.row(11));
    }

    #[test]
    fn odd_length_forward_matches_all_mechanisms() {
        // n = 13 against block 8: the ragged tail path must leave forward
        // logits finite and causal for every mechanism (the kernel-level
        // oracle comparison lives in attn::kernel::state tests).
        let mechs = [
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 16, block: 8 },
        ];
        let tokens: Vec<u32> = (0..13).map(|i| (i * 7) % 64).collect();
        for mech in mechs {
            let lm = tiny(mech.clone());
            let a = lm.forward(&tokens);
            assert!(a.data().iter().all(|x| x.is_finite()), "{}", mech.label());
            // Prefix invariance: truncating the input reproduces the
            // logits of every kept position (no tail-block leakage).
            let b = lm.forward(&tokens[..9]);
            for i in 0..9 {
                for (x, y) in a.row(i).iter().zip(b.row(i)) {
                    let tol = 1e-3 * (1.0 + x.abs().max(y.abs()));
                    assert!((x - y).abs() <= tol, "{} row {i}: {x} vs {y}", mech.label());
                }
            }
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_row(&mut x, 17);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3);
    }
}
