//! Native decoder-only transformer LM over the native attention kernels.
//!
//! The PJRT model path (`runtime::ModelRuntime`) executes fixed-shape AOT
//! artifacts and cannot step one token at a time; this model is its
//! native-rust twin for the serving path, mirroring the paper recipe the
//! JAX model uses (python/compile/model.py): sinusoidal absolute position
//! embeddings on the token embedding, pre-LN blocks, RoPE on q/k, GEGLU
//! feed-forward, final LN + readout.  Weights are deterministic in the
//! config seed (this repo has no host-side checkpoint import — the
//! serving subsystem's correctness story is prefill/decode parity, which
//! is weight-independent).
//!
//! Two execution paths over the *same* weights:
//! * [`NativeLm::prefill`] — full-context forward via `Attention::run`
//!   (the block kernels), capturing per-layer/head k,v into the decode
//!   states;
//! * [`NativeLm::step`] — one token through [`DecodeState`]s: O(1) per
//!   token for Polysketch/Performer, O(n) for the softmax family.

use crate::attn::{Attention, Mechanism};
use crate::exec::pool;
use crate::infer::state::{ln_row, DecodeState};
use crate::tensor::{layernorm_rows, Tensor};
use crate::util::rng::Pcg;

/// Native LM hyperparameters.
#[derive(Clone, Debug)]
pub struct LmConfig {
    /// Vocabulary size; the `generate` path uses byte-level tokens
    /// (id 0 = BOS, ids 1..=256 = bytes), so 257 is the natural floor.
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    /// GEGLU hidden width = `ff_mult * d_model`.
    pub ff_mult: usize,
    /// Weight seed (deterministic init).
    pub seed: u64,
}

impl Default for LmConfig {
    fn default() -> Self {
        LmConfig { vocab: 257, d_model: 64, layers: 2, heads: 4, ff_mult: 2, seed: 0 }
    }
}

struct Layer {
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    ffn_gate: Tensor,
    ffn_up: Tensor,
    ffn_down: Tensor,
    /// One instantiated mechanism (sketches/features) per head.
    heads: Vec<Attention>,
}

/// Decode state of one layer: one [`DecodeState`] per head.
#[derive(Clone)]
pub struct LayerState {
    pub heads: Vec<DecodeState>,
}

/// Native autoregressive LM (one per served mechanism).
pub struct NativeLm {
    pub cfg: LmConfig,
    pub mech: Mechanism,
    embed: Tensor,
    readout: Tensor,
    layers: Vec<Layer>,
}

impl NativeLm {
    pub fn new(cfg: LmConfig, mech: Mechanism) -> NativeLm {
        assert!(cfg.d_model % cfg.heads == 0, "d_model must divide into heads");
        let hd = cfg.d_model / cfg.heads;
        assert!(hd % 2 == 0, "head_dim must be even (RoPE pairs)");
        let mut rng = Pcg::seeded(cfg.seed ^ 0x1fe7);
        let d = cfg.d_model;
        let f = cfg.ff_mult * d;
        let sd = 1.0 / (d as f32).sqrt();
        let sf = 1.0 / (f as f32).sqrt();
        let embed = Tensor::gaussian(&mut rng, &[cfg.vocab, d]).scale(0.02);
        let readout = Tensor::gaussian(&mut rng, &[d, cfg.vocab]).scale(0.02);
        let layers = (0..cfg.layers)
            .map(|_| Layer {
                wq: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wk: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wv: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wo: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                ffn_gate: Tensor::gaussian(&mut rng, &[d, f]).scale(sd),
                ffn_up: Tensor::gaussian(&mut rng, &[d, f]).scale(sd),
                ffn_down: Tensor::gaussian(&mut rng, &[f, d]).scale(sf),
                heads: (0..cfg.heads).map(|_| Attention::new(&mech, hd, &mut rng)).collect(),
            })
            .collect();
        NativeLm { cfg, mech, embed, readout, layers }
    }

    pub fn head_dim(&self) -> usize {
        self.cfg.d_model / self.cfg.heads
    }

    /// Fresh per-layer decode states sharing this model's projections.
    pub fn new_states(&self) -> Vec<LayerState> {
        self.layers
            .iter()
            .map(|l| LayerState { heads: l.heads.iter().map(DecodeState::new).collect() })
            .collect()
    }

    /// Total decode-state footprint in f32 words (all layers and heads).
    pub fn state_memory_floats(states: &[LayerState]) -> usize {
        states
            .iter()
            .flat_map(|l| l.heads.iter())
            .map(DecodeState::memory_floats)
            .sum()
    }

    /// Full-context forward: (n,) tokens -> (n, vocab) logits.
    pub fn forward(&self, tokens: &[u32]) -> Tensor {
        self.forward_capture(tokens, None)
    }

    /// Prefill: full-context forward that additionally folds every
    /// position's per-layer/head (k, v) into `states`, leaving them ready
    /// for token-by-token [`NativeLm::step`]s at positions `n..`.
    pub fn prefill(&self, tokens: &[u32], states: &mut [LayerState]) -> Tensor {
        self.forward_capture(tokens, Some(states))
    }

    fn forward_capture(&self, tokens: &[u32], mut states: Option<&mut [LayerState]>) -> Tensor {
        let n = tokens.len();
        assert!(n > 0, "empty token sequence");
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        // Zero-pad the sequence up to the mechanism's block multiple once
        // per layer (causality makes trailing padding inert for real rows;
        // zero rows project to zero rows, so padding before the q/k/v
        // matmuls is bitwise the same as padding each head after them) so
        // decode-state block partitions line up exactly with the prefill
        // partition at any prompt length.
        let block = self.block_multiple();
        let np = n.div_ceil(block) * block;
        let mut x = Tensor::zeros(&[n, d]);
        for (i, &t) in tokens.iter().enumerate() {
            let row = x.row_mut(i);
            row.copy_from_slice(self.embed.row(t as usize));
            add_sinusoidal(row, i);
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let xn = layernorm_rows(&x);
            let xnp = if np == n { xn } else { pad_rows(&xn, np) };
            let q = xnp.matmul(&layer.wq);
            let k = xnp.matmul(&layer.wk);
            let v = xnp.matmul(&layer.wv);
            // Heads are embarrassingly parallel: each one slices its own
            // q/k/v columns, owns its own decode state, and produces its
            // own (np, hd) output — no shared mutable state, so the bytes
            // cannot depend on scheduling.
            let mut head_states: Vec<Option<&mut DecodeState>> = match states.as_deref_mut() {
                Some(s) => s[li].heads.iter_mut().map(Some).collect(),
                None => (0..self.cfg.heads).map(|_| None).collect(),
            };
            let outs: Vec<Tensor> = pool::par_map_mut(&mut head_states, 1, |hi, st| {
                let mut qh = slice_head(&q, hi, hd);
                let mut kh = slice_head(&k, hi, hd);
                let vh = slice_head(&v, hi, hd);
                for i in 0..n {
                    // Padding rows are zero and rotate to zero: skip them.
                    rope_row(qh.row_mut(i), i);
                    rope_row(kh.row_mut(i), i);
                }
                if let Some(st) = st {
                    for i in 0..n {
                        st.absorb(kh.row(i), vh.row(i));
                    }
                }
                layer.heads[hi].run(&qh, &kh, &vh)
            });
            let mut concat = Tensor::zeros(&[n, d]);
            for (hi, oh) in outs.iter().enumerate() {
                for i in 0..n {
                    concat.row_mut(i)[hi * hd..(hi + 1) * hd].copy_from_slice(oh.row(i));
                }
            }
            x = x.add(&concat.matmul(&layer.wo));
            let xn2 = layernorm_rows(&x);
            let g = xn2.matmul(&layer.ffn_gate).map(gelu);
            let u = xn2.matmul(&layer.ffn_up);
            x = x.add(&g.hadamard(&u).matmul(&layer.ffn_down));
        }
        layernorm_rows(&x).matmul(&self.readout)
    }

    /// One decode step: fold `token` (at absolute position `pos`) into the
    /// states and return the next-token logits (vocab,).
    pub fn step(&self, token: u32, pos: usize, states: &mut [LayerState]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        let mut x = self.embed.row(token as usize).to_vec();
        add_sinusoidal(&mut x, pos);
        for (li, layer) in self.layers.iter().enumerate() {
            let xn = Tensor::from_vec(&[1, d], ln_row(&x));
            let q = xn.matmul(&layer.wq);
            let k = xn.matmul(&layer.wk);
            let v = xn.matmul(&layer.wv);
            let mut concat = vec![0.0f32; d];
            for hi in 0..self.cfg.heads {
                let mut qh = q.row(0)[hi * hd..(hi + 1) * hd].to_vec();
                let mut kh = k.row(0)[hi * hd..(hi + 1) * hd].to_vec();
                let vh = &v.row(0)[hi * hd..(hi + 1) * hd];
                rope_row(&mut qh, pos);
                rope_row(&mut kh, pos);
                let oh = states[li].heads[hi].step(&qh, &kh, vh);
                concat[hi * hd..(hi + 1) * hd].copy_from_slice(&oh);
            }
            let attn_out = Tensor::from_vec(&[1, d], concat).matmul(&layer.wo);
            for (xi, a) in x.iter_mut().zip(attn_out.data()) {
                *xi += a;
            }
            let xn2 = Tensor::from_vec(&[1, d], ln_row(&x));
            let g = xn2.matmul(&layer.ffn_gate).map(gelu);
            let u = xn2.matmul(&layer.ffn_up);
            let ffn = g.hadamard(&u).matmul(&layer.ffn_down);
            for (xi, a) in x.iter_mut().zip(ffn.data()) {
                *xi += a;
            }
        }
        Tensor::from_vec(&[1, d], ln_row(&x)).matmul(&self.readout).into_vec()
    }

    /// Sequence-length multiple the mechanism's block kernels require
    /// (1 for the streaming softmax/poly paths).
    fn block_multiple(&self) -> usize {
        match &self.mech {
            Mechanism::Softmax | Mechanism::Poly { .. } => 1,
            Mechanism::Flash { block }
            | Mechanism::Polysketch { block, .. }
            | Mechanism::Performer { block, .. } => (*block).max(1),
        }
    }
}

/// Zero-pad a 2-D tensor's rows up to `np`.
fn pad_rows(t: &Tensor, np: usize) -> Tensor {
    let mut out = Tensor::zeros(&[np, t.cols()]);
    out.data_mut()[..t.len()].copy_from_slice(t.data());
    out
}

/// Column slice of one head: (n, d) -> (n, hd).
fn slice_head(t: &Tensor, head: usize, hd: usize) -> Tensor {
    let n = t.rows();
    let mut out = Tensor::zeros(&[n, hd]);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&t.row(i)[head * hd..(head + 1) * hd]);
    }
    out
}

/// Add the sinusoidal absolute position embedding for `pos` in place —
/// the half-split layout of python/compile/model.py::sinusoidal_table.
fn add_sinusoidal(row: &mut [f32], pos: usize) {
    let d = row.len();
    let half = d / 2;
    for j in 0..half {
        let angle = pos as f64 / 10000f64.powf(2.0 * j as f64 / d as f64);
        row[j] += angle.sin() as f32;
        row[half + j] += angle.cos() as f32;
    }
}

/// Rotary position embedding of one head row (half-split pairing, matching
/// python/compile/model.py::_rope).
fn rope_row(x: &mut [f32], pos: usize) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let theta = pos as f64 / 10000f64.powf(2.0 * i as f64 / hd as f64);
        let (c, s) = (theta.cos() as f32, theta.sin() as f32);
        let (x1, x2) = (x[i], x[half + i]);
        x[i] = x1 * c - x2 * s;
        x[half + i] = x1 * s + x2 * c;
    }
}

/// Tanh-approximation GELU (python/compile/common.py's activation).
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((2.0 / std::f32::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(mech: Mechanism) -> NativeLm {
        let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 7 };
        NativeLm::new(cfg, mech)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let lm = tiny(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let tokens: Vec<u32> = (0..13).map(|i| (i * 5) % 64).collect();
        let logits = lm.forward(&tokens);
        assert_eq!(logits.shape(), &[13, 64]);
        assert!(logits.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_is_deterministic_in_seed() {
        let mech = Mechanism::Performer { m: 8, block: 8 };
        let a = tiny(mech.clone());
        let b = tiny(mech);
        let tokens: Vec<u32> = (0..9).collect();
        assert_eq!(a.forward(&tokens), b.forward(&tokens));
    }

    #[test]
    fn forward_is_causal() {
        let lm = tiny(Mechanism::Softmax);
        let t1: Vec<u32> = (0..12).collect();
        let mut t2 = t1.clone();
        t2[11] = 63;
        let a = lm.forward(&t1);
        let b = lm.forward(&t2);
        for i in 0..11 {
            assert_eq!(a.row(i), b.row(i), "row {i} depends on a future token");
        }
        assert_ne!(a.row(11), b.row(11));
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_row(&mut x, 17);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3);
    }
}
