//! Native decoder-only transformer LM over the kernel core.
//!
//! The PJRT model path (`runtime::ModelRuntime`) executes fixed-shape AOT
//! artifacts and cannot step one token at a time; this model is its
//! native-rust twin for the serving *and training* paths, mirroring the
//! paper recipe the JAX model uses (python/compile/model.py): sinusoidal
//! absolute position embeddings on the token embedding, pre-LN blocks,
//! RoPE on q/k, GEGLU feed-forward, final LN + readout.
//!
//! Weights live in a shared [`Params`] struct — named-tensor iteration
//! for the optimizer and checkpoint serialization — used identically by
//! inference and by the native training subsystem (`crate::train`), and
//! round-trip bitwise through [`NativeLm::to_checkpoint`] /
//! [`NativeLm::from_checkpoint`], so weights trained with
//! `psf train-native` are directly servable by `psf generate`/`psf
//! serve`.  Fresh models are deterministic in the config seed.
//!
//! Attention is entirely behind [`CausalKernel`]: each (layer, head)
//! holds one `Arc<dyn CausalKernel>` built by `Mechanism::build_kernel`
//! (the single dispatch point), and this file never learns which engine
//! is behind a head.  Two execution paths over the *same* weights:
//!
//! * [`NativeLm::prefill`] — full-context forward; each head consumes
//!   strided views of the fused q/k/v projections and writes its output
//!   stripe in place (`kernel::prefill_heads` — no per-head copies, no
//!   zero-padding, no concat), leaving the decode states exactly as if
//!   every position had been stepped;
//! * [`NativeLm::step`] — one token through the per-head
//!   [`KernelState`]s: O(1) per token for the linear engine, O(n) for
//!   the KV engine.

use std::sync::Arc;

use crate::attn::kernel::{self, CausalKernel, KernelState};
use crate::attn::Mechanism;
use crate::checkpoint::Checkpoint;
use crate::mem::quant::{self, QuantMatrix};
use crate::obs;
use crate::obs::phase;
use crate::tensor::{micro, layernorm_rows, ln_row, Tensor};
use crate::util::rng::Pcg;

/// Checkpoint format version written into the `meta` section.
const CKPT_FORMAT: f32 = 1.0;

/// Native LM hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct LmConfig {
    /// Vocabulary size; the `generate` path uses byte-level tokens
    /// (id 0 = BOS, ids 1..=256 = bytes), so 257 is the natural floor.
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    /// GEGLU hidden width = `ff_mult * d_model`.
    pub ff_mult: usize,
    /// Weight seed (deterministic init).
    pub seed: u64,
}

impl Default for LmConfig {
    fn default() -> Self {
        LmConfig { vocab: 257, d_model: 64, layers: 2, heads: 4, ff_mult: 2, seed: 0 }
    }
}

/// One transformer block's weights.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerParams {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub ffn_gate: Tensor,
    pub ffn_up: Tensor,
    pub ffn_down: Tensor,
}

/// Every learnable tensor of a [`NativeLm`], shared between inference and
/// training.  The kernels' random state (sketches/features) is *not* a
/// parameter — it is reconstructed from the config seed — so a `Params`
/// plus an [`LmConfig`] + [`Mechanism`] fully determines a model.
#[derive(Clone, Debug, PartialEq)]
pub struct Params {
    pub embed: Tensor,
    pub readout: Tensor,
    pub layers: Vec<LayerParams>,
}

impl Params {
    /// Named-tensor iteration in a fixed, stable order — the contract the
    /// optimizer state, gradient buffers, and checkpoint sections share.
    pub fn named(&self) -> Vec<(String, &Tensor)> {
        let mut out: Vec<(String, &Tensor)> =
            vec![("embed".into(), &self.embed), ("readout".into(), &self.readout)];
        for (i, l) in self.layers.iter().enumerate() {
            out.push((format!("layer{i}.wq"), &l.wq));
            out.push((format!("layer{i}.wk"), &l.wk));
            out.push((format!("layer{i}.wv"), &l.wv));
            out.push((format!("layer{i}.wo"), &l.wo));
            out.push((format!("layer{i}.ffn_gate"), &l.ffn_gate));
            out.push((format!("layer{i}.ffn_up"), &l.ffn_up));
            out.push((format!("layer{i}.ffn_down"), &l.ffn_down));
        }
        out
    }

    /// Mutable twin of [`Params::named`], same order.
    pub fn named_mut(&mut self) -> Vec<(String, &mut Tensor)> {
        let mut out: Vec<(String, &mut Tensor)> = vec![
            ("embed".into(), &mut self.embed),
            ("readout".into(), &mut self.readout),
        ];
        for (i, l) in self.layers.iter_mut().enumerate() {
            out.push((format!("layer{i}.wq"), &mut l.wq));
            out.push((format!("layer{i}.wk"), &mut l.wk));
            out.push((format!("layer{i}.wv"), &mut l.wv));
            out.push((format!("layer{i}.wo"), &mut l.wo));
            out.push((format!("layer{i}.ffn_gate"), &mut l.ffn_gate));
            out.push((format!("layer{i}.ffn_up"), &mut l.ffn_up));
            out.push((format!("layer{i}.ffn_down"), &mut l.ffn_down));
        }
        out
    }

    /// Same-shaped all-zero buffer (gradient accumulator).
    pub fn zeros_like(&self) -> Params {
        Params {
            embed: Tensor::zeros(self.embed.shape()),
            readout: Tensor::zeros(self.readout.shape()),
            layers: self
                .layers
                .iter()
                .map(|l| LayerParams {
                    wq: Tensor::zeros(l.wq.shape()),
                    wk: Tensor::zeros(l.wk.shape()),
                    wv: Tensor::zeros(l.wv.shape()),
                    wo: Tensor::zeros(l.wo.shape()),
                    ffn_gate: Tensor::zeros(l.ffn_gate.shape()),
                    ffn_up: Tensor::zeros(l.ffn_up.shape()),
                    ffn_down: Tensor::zeros(l.ffn_down.shape()),
                })
                .collect(),
        }
    }

    /// self += other · s, tensor by tensor (fixed iteration order — the
    /// deterministic gradient reduction runs through here with s = 1).
    pub fn add_scaled(&mut self, other: &Params, s: f32) {
        let o = other.named();
        for ((_, t), (_, u)) in self.named_mut().into_iter().zip(o) {
            for (a, b) in t.data_mut().iter_mut().zip(u.data()) {
                *a += b * s;
            }
        }
    }

    /// self *= s elementwise.
    pub fn scale_in_place(&mut self, s: f32) {
        for (_, t) in self.named_mut() {
            for a in t.data_mut() {
                *a *= s;
            }
        }
    }

    pub fn num_params(&self) -> usize {
        self.named().iter().map(|(_, t)| t.len()).sum()
    }

    /// Σ x² over every tensor, accumulated in f64 (global-norm clipping).
    pub fn l2_norm_sq(&self) -> f64 {
        self.named()
            .iter()
            .flat_map(|(_, t)| t.data())
            .map(|&x| (x as f64) * (x as f64))
            .sum()
    }
}

/// Decode state of one layer: one [`KernelState`] per head.
#[derive(Clone)]
pub struct LayerState {
    pub heads: Vec<KernelState>,
}

/// Int8 twins of one transformer block's weights (per-row scales).
struct QuantLayer {
    wq: QuantMatrix,
    wk: QuantMatrix,
    wv: QuantMatrix,
    wo: QuantMatrix,
    ffn_gate: QuantMatrix,
    ffn_up: QuantMatrix,
    ffn_down: QuantMatrix,
}

/// Int8 twins of every [`Params`] tensor, built by
/// [`NativeLm::requantize`] under `PSF_QUANT=q8`.  The f32 originals
/// stay resident (training, prefill, and the sharded paths keep using
/// them); only the single-token decode step reads these.
struct QuantWeights {
    embed: QuantMatrix,
    readout: QuantMatrix,
    layers: Vec<QuantLayer>,
}

/// Native autoregressive LM (one per served mechanism).
pub struct NativeLm {
    pub cfg: LmConfig,
    pub mech: Mechanism,
    params: Params,
    /// One instantiated kernel (engine + sketches/features) per
    /// (layer, head).
    kernels: Vec<Vec<Arc<dyn CausalKernel>>>,
    /// Int8 decode weights, `Some` only under `PSF_QUANT=q8`.
    qweights: Option<QuantWeights>,
}

impl NativeLm {
    pub fn new(cfg: LmConfig, mech: Mechanism) -> NativeLm {
        assert!(cfg.d_model % cfg.heads == 0, "d_model must divide into heads");
        let hd = cfg.d_model / cfg.heads;
        assert!(hd % 2 == 0, "head_dim must be even (RoPE pairs)");
        // RNG consumption order is part of the golden-fixture contract:
        // embed, readout, then per layer the seven weight tensors followed
        // by that layer's head kernels.
        let mut rng = Pcg::seeded(cfg.seed ^ 0x1fe7);
        let d = cfg.d_model;
        let f = cfg.ff_mult * d;
        let sd = 1.0 / (d as f32).sqrt();
        let sf = 1.0 / (f as f32).sqrt();
        let embed = Tensor::gaussian(&mut rng, &[cfg.vocab, d]).scale(0.02);
        let readout = Tensor::gaussian(&mut rng, &[d, cfg.vocab]).scale(0.02);
        let mut layers = Vec::with_capacity(cfg.layers);
        let mut kernels = Vec::with_capacity(cfg.layers);
        for _ in 0..cfg.layers {
            layers.push(LayerParams {
                wq: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wk: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wv: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                wo: Tensor::gaussian(&mut rng, &[d, d]).scale(sd),
                ffn_gate: Tensor::gaussian(&mut rng, &[d, f]).scale(sd),
                ffn_up: Tensor::gaussian(&mut rng, &[d, f]).scale(sd),
                ffn_down: Tensor::gaussian(&mut rng, &[f, d]).scale(sf),
            });
            kernels.push((0..cfg.heads).map(|_| mech.build_kernel(hd, &mut rng)).collect());
        }
        let mut lm =
            NativeLm { cfg, mech, params: Params { embed, readout, layers }, kernels, qweights: None };
        // After all RNG consumption: requantize reads no randomness, so
        // the fixture contract above is unaffected by PSF_QUANT.
        lm.requantize();
        // Telemetry attribution only — which mechanism faults and
        // incident dumps should name.
        let label = lm.mech.label();
        obs::sentinel::set_mechanism(&label);
        obs::incident::set_mechanism(&label);
        lm
    }

    /// (Re)build the int8 weight twins when `PSF_QUANT=q8`; drops them
    /// otherwise.  Must be re-run after any bulk weight mutation (the
    /// optimizer step, checkpoint restore) or decode serves stale
    /// weights.  Consumes no RNG.
    pub fn requantize(&mut self) {
        if !quant::mode().q8_weights() {
            self.qweights = None;
            return;
        }
        let _t = phase::timer(phase::Phase::Quantize);
        self.qweights = Some(self.build_qweights());
    }

    fn build_qweights(&self) -> QuantWeights {
        QuantWeights {
            embed: QuantMatrix::from_tensor(&self.params.embed),
            readout: QuantMatrix::from_tensor(&self.params.readout),
            layers: self
                .params
                .layers
                .iter()
                .map(|l| QuantLayer {
                    wq: QuantMatrix::from_tensor(&l.wq),
                    wk: QuantMatrix::from_tensor(&l.wk),
                    wv: QuantMatrix::from_tensor(&l.wv),
                    wo: QuantMatrix::from_tensor(&l.wo),
                    ffn_gate: QuantMatrix::from_tensor(&l.ffn_gate),
                    ffn_up: QuantMatrix::from_tensor(&l.ffn_up),
                    ffn_down: QuantMatrix::from_tensor(&l.ffn_down),
                })
                .collect(),
        }
    }

    pub fn head_dim(&self) -> usize {
        self.cfg.d_model / self.cfg.heads
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    /// Mutable weight access (the optimizer's write path).
    pub fn params_mut(&mut self) -> &mut Params {
        &mut self.params
    }

    /// Replace the weights wholesale (checkpoint restore); shapes must
    /// match the model's config.
    pub fn set_params(&mut self, p: Params) {
        let want: Vec<_> = self.params.named().iter().map(|(n, t)| (n.clone(), t.shape().to_vec())).collect();
        let got: Vec<_> = p.named().iter().map(|(n, t)| (n.clone(), t.shape().to_vec())).collect();
        assert_eq!(want, got, "set_params: shape mismatch");
        self.params = p;
    }

    /// Per-layer head kernels (the training backward walks these).
    pub fn kernels(&self) -> &[Vec<Arc<dyn CausalKernel>>] {
        &self.kernels
    }

    /// Fresh per-layer decode states matching this model's kernels.
    pub fn new_states(&self) -> Vec<LayerState> {
        self.kernels
            .iter()
            .map(|l| LayerState { heads: l.iter().map(|k| k.new_state()).collect() })
            .collect()
    }

    /// Total decode-state footprint in f32 words (all layers and heads).
    pub fn state_memory_floats(states: &[LayerState]) -> usize {
        states
            .iter()
            .flat_map(|l| l.heads.iter())
            .map(KernelState::memory_floats)
            .sum()
    }

    /// Full-context forward: (n,) tokens -> (n, vocab) logits.
    pub fn forward(&self, tokens: &[u32]) -> Tensor {
        self.forward_capture(tokens, None)
    }

    /// Prefill: full-context forward that additionally leaves `states`
    /// holding every position's per-layer/head decode state, ready for
    /// token-by-token [`NativeLm::step`]s at positions `n..`.
    pub fn prefill(&self, tokens: &[u32], states: &mut [LayerState]) -> Tensor {
        self.forward_capture(tokens, Some(states))
    }

    fn forward_capture(&self, tokens: &[u32], mut states: Option<&mut [LayerState]>) -> Tensor {
        let n = tokens.len();
        assert!(n > 0, "empty token sequence");
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        let mut x = Tensor::zeros(&[n, d]);
        for (i, &t) in tokens.iter().enumerate() {
            let row = x.row_mut(i);
            row.copy_from_slice(self.params.embed.row(t as usize));
            add_sinusoidal(row, i);
        }
        for (li, layer) in self.params.layers.iter().enumerate() {
            obs::sentinel::set_layer(li);
            let xn = layernorm_rows(&x);
            let mut q = xn.matmul(&layer.wq);
            let mut k = xn.matmul(&layer.wk);
            let v = xn.matmul(&layer.wv);
            // RoPE on the fused projections, per head segment (rows are
            // independent — deterministic row-parallel).
            rope_heads(&mut q, hd);
            rope_heads(&mut k, hd);
            // Heads are embarrassingly parallel: each one reads its own
            // strided column stripe of q/k/v, owns its own decode state,
            // and writes its own output stripe — no shared mutable state,
            // no copies, so the bytes cannot depend on scheduling.
            let mut attn_out = Tensor::zeros(&[n, d]);
            kernel::prefill_heads(
                &self.kernels[li],
                &q,
                &k,
                &v,
                states.as_deref_mut().map(|s| s[li].heads.as_mut_slice()),
                &mut attn_out,
            );
            x = x.add(&attn_out.matmul(&layer.wo));
            let xn2 = layernorm_rows(&x);
            let mut g = xn2.matmul(&layer.ffn_gate);
            micro::gelu_rows(g.data_mut());
            let u = xn2.matmul(&layer.ffn_up);
            x = x.add(&g.hadamard(&u).matmul(&layer.ffn_down));
        }
        let logits = layernorm_rows(&x).matmul(&self.params.readout);
        obs::sentinel::scan(obs::sentinel::Site::Logits, logits.data());
        logits
    }

    /// One decode step: fold `token` (at absolute position `pos`) into the
    /// states and return the next-token logits (vocab,).
    pub fn step(&self, token: u32, pos: usize, states: &mut [LayerState]) -> Vec<f32> {
        if let Some(qw) = &self.qweights {
            return self.step_q8(qw, token, pos, states);
        }
        obs::sentinel::set_token(pos);
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        let mut x = self.params.embed.row(token as usize).to_vec();
        add_sinusoidal(&mut x, pos);
        for (li, layer) in self.params.layers.iter().enumerate() {
            obs::sentinel::set_layer(li);
            let xn = Tensor::from_vec(&[1, d], ln_row(&x));
            let q = xn.matmul(&layer.wq);
            let k = xn.matmul(&layer.wk);
            let v = xn.matmul(&layer.wv);
            let mut concat = vec![0.0f32; d];
            for hi in 0..self.cfg.heads {
                let mut qh = q.row(0)[hi * hd..(hi + 1) * hd].to_vec();
                let mut kh = k.row(0)[hi * hd..(hi + 1) * hd].to_vec();
                let vh = &v.row(0)[hi * hd..(hi + 1) * hd];
                rope_row(&mut qh, pos);
                rope_row(&mut kh, pos);
                let oh = self.kernels[li][hi].step(&qh, &kh, vh, &mut states[li].heads[hi]);
                concat[hi * hd..(hi + 1) * hd].copy_from_slice(&oh);
            }
            let attn_out = Tensor::from_vec(&[1, d], concat).matmul(&layer.wo);
            for (xi, a) in x.iter_mut().zip(attn_out.data()) {
                *xi += a;
            }
            let xn2 = Tensor::from_vec(&[1, d], ln_row(&x));
            let mut g = xn2.matmul(&layer.ffn_gate);
            micro::gelu_rows(g.data_mut());
            let u = xn2.matmul(&layer.ffn_up);
            let ffn = g.hadamard(&u).matmul(&layer.ffn_down);
            for (xi, a) in x.iter_mut().zip(ffn.data()) {
                *xi += a;
            }
        }
        let logits = Tensor::from_vec(&[1, d], ln_row(&x)).matmul(&self.params.readout).into_vec();
        obs::sentinel::scan(obs::sentinel::Site::Logits, &logits);
        logits
    }

    /// Quantized twin of [`NativeLm::step`]: identical control flow, but
    /// every per-token matvec (the seven layer matrices, the embedding
    /// row, the readout) reads the int8 twins through the micro layer's
    /// fused q8 primitives with f32 accumulation.  A deliberate
    /// near-copy rather than a parameterization of `step` — that body
    /// carries the bitwise contract for `PSF_QUANT=off` and must not
    /// change shape (see the sharded-twins note below).  Prefill and the
    /// sharded paths stay f32: q8 targets the decode step, where weight
    /// bandwidth dominates.
    fn step_q8(&self, qw: &QuantWeights, token: u32, pos: usize, states: &mut [LayerState]) -> Vec<f32> {
        obs::sentinel::set_token(pos);
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        let mut x = vec![0.0f32; d];
        micro::dequant_row(&mut x, qw.embed.qrow(token as usize), qw.embed.scales[token as usize]);
        add_sinusoidal(&mut x, pos);
        for (li, qlayer) in qw.layers.iter().enumerate() {
            obs::sentinel::set_layer(li);
            let xn = ln_row(&x);
            let q = q8_vecmat(&xn, &qlayer.wq);
            let k = q8_vecmat(&xn, &qlayer.wk);
            let v = q8_vecmat(&xn, &qlayer.wv);
            let mut concat = vec![0.0f32; d];
            for hi in 0..self.cfg.heads {
                let mut qh = q[hi * hd..(hi + 1) * hd].to_vec();
                let mut kh = k[hi * hd..(hi + 1) * hd].to_vec();
                let vh = &v[hi * hd..(hi + 1) * hd];
                rope_row(&mut qh, pos);
                rope_row(&mut kh, pos);
                let oh = self.kernels[li][hi].step(&qh, &kh, vh, &mut states[li].heads[hi]);
                concat[hi * hd..(hi + 1) * hd].copy_from_slice(&oh);
            }
            let attn_out = q8_vecmat(&concat, &qlayer.wo);
            for (xi, a) in x.iter_mut().zip(&attn_out) {
                *xi += a;
            }
            let xn2 = ln_row(&x);
            let mut g = q8_vecmat(&xn2, &qlayer.ffn_gate);
            micro::gelu_rows(&mut g);
            let u = q8_vecmat(&xn2, &qlayer.ffn_up);
            for (gi, ui) in g.iter_mut().zip(&u) {
                *gi *= ui;
            }
            let ffn = q8_vecmat(&g, &qlayer.ffn_down);
            for (xi, a) in x.iter_mut().zip(&ffn) {
                *xi += a;
            }
        }
        let logits = q8_vecmat(&ln_row(&x), &qw.readout);
        obs::sentinel::scan(obs::sentinel::Site::Logits, &logits);
        logits
    }

    // ---------------------------------------- head-sharded (TP) twins
    //
    // Deliberate near-copies of `forward_capture`/`step` rather than a
    // refactor: those two bodies carry the bitwise-determinism contract
    // for every existing test and cache snapshot, and the sharded path
    // differs in kind (fallible, combine hook in the middle of each
    // layer), not just in head range.
    //
    // Partition: each shard computes heads `range` of every layer's
    // attention (its stripes of the masked concat, so `concat · wo` is a
    // *partial* attention output), hands that partial to `combine`, and
    // receives the world sum; everything outside attention (embeddings,
    // layernorms, FFN, readout) is replicated bit-identically on every
    // shard.  Because all shards add the *same* combined bytes into the
    // same replicated residual, their logits — and hence sampled tokens
    // — are identical, which is what lets any one shard own the token
    // stream.  The world sum must be formed in shard-index order on
    // every shard: f32 addition does not commute bitwise.

    /// Sharded prefill: like [`NativeLm::prefill`], but runs only heads
    /// `range` of each layer and routes each layer's partial attention
    /// output (length `n·d_model`, row-major) through `combine`, which
    /// must return the shard-order world sum of the same length.
    pub fn prefill_sharded(
        &self,
        tokens: &[u32],
        mut states: Option<&mut [LayerState]>,
        range: std::ops::Range<usize>,
        combine: &mut dyn FnMut(usize, Vec<f32>) -> anyhow::Result<Vec<f32>>,
    ) -> anyhow::Result<Tensor> {
        let n = tokens.len();
        anyhow::ensure!(n > 0, "empty token sequence");
        anyhow::ensure!(
            range.start < range.end && range.end <= self.cfg.heads,
            "bad head range {}..{} of {}",
            range.start,
            range.end,
            self.cfg.heads
        );
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        let mut x = Tensor::zeros(&[n, d]);
        for (i, &t) in tokens.iter().enumerate() {
            let row = x.row_mut(i);
            row.copy_from_slice(self.params.embed.row(t as usize));
            add_sinusoidal(row, i);
        }
        for (li, layer) in self.params.layers.iter().enumerate() {
            obs::sentinel::set_layer(li);
            let xn = layernorm_rows(&x);
            let mut q = xn.matmul(&layer.wq);
            let mut k = xn.matmul(&layer.wk);
            let v = xn.matmul(&layer.wv);
            rope_heads(&mut q, hd);
            rope_heads(&mut k, hd);
            let mut attn_out = Tensor::zeros(&[n, d]);
            kernel::prefill_head_range(
                &self.kernels[li],
                range.clone(),
                &q,
                &k,
                &v,
                states.as_deref_mut().map(|s| s[li].heads.as_mut_slice()),
                &mut attn_out,
            );
            // Stripes outside `range` are zero, so this is the shard's
            // partial contribution to the full attention output.
            let partial = attn_out.matmul(&layer.wo);
            let combined = combine(li, partial.into_vec())?;
            anyhow::ensure!(
                combined.len() == n * d,
                "combine returned {} floats for layer {li}, expected {}",
                combined.len(),
                n * d
            );
            x = x.add(&Tensor::from_vec(&[n, d], combined));
            let xn2 = layernorm_rows(&x);
            let mut g = xn2.matmul(&layer.ffn_gate);
            micro::gelu_rows(g.data_mut());
            let u = xn2.matmul(&layer.ffn_up);
            x = x.add(&g.hadamard(&u).matmul(&layer.ffn_down));
        }
        let logits = layernorm_rows(&x).matmul(&self.params.readout);
        obs::sentinel::scan(obs::sentinel::Site::Logits, logits.data());
        Ok(logits)
    }

    /// Sharded decode step: like [`NativeLm::step`], but runs only heads
    /// `range` and routes each layer's partial attention output (length
    /// `d_model`) through `combine`.  Only this shard's `states[..][range]`
    /// entries advance; the others stay untouched.
    pub fn step_sharded(
        &self,
        token: u32,
        pos: usize,
        states: &mut [LayerState],
        range: std::ops::Range<usize>,
        combine: &mut dyn FnMut(usize, Vec<f32>) -> anyhow::Result<Vec<f32>>,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            range.start < range.end && range.end <= self.cfg.heads,
            "bad head range {}..{} of {}",
            range.start,
            range.end,
            self.cfg.heads
        );
        obs::sentinel::set_token(pos);
        let d = self.cfg.d_model;
        let hd = self.head_dim();
        let mut x = self.params.embed.row(token as usize).to_vec();
        add_sinusoidal(&mut x, pos);
        for (li, layer) in self.params.layers.iter().enumerate() {
            obs::sentinel::set_layer(li);
            let xn = Tensor::from_vec(&[1, d], ln_row(&x));
            let q = xn.matmul(&layer.wq);
            let k = xn.matmul(&layer.wk);
            let v = xn.matmul(&layer.wv);
            let mut concat = vec![0.0f32; d];
            for hi in range.clone() {
                let mut qh = q.row(0)[hi * hd..(hi + 1) * hd].to_vec();
                let mut kh = k.row(0)[hi * hd..(hi + 1) * hd].to_vec();
                let vh = &v.row(0)[hi * hd..(hi + 1) * hd];
                rope_row(&mut qh, pos);
                rope_row(&mut kh, pos);
                let oh = self.kernels[li][hi].step(&qh, &kh, vh, &mut states[li].heads[hi]);
                concat[hi * hd..(hi + 1) * hd].copy_from_slice(&oh);
            }
            let partial = Tensor::from_vec(&[1, d], concat).matmul(&layer.wo);
            let combined = combine(li, partial.into_vec())?;
            anyhow::ensure!(
                combined.len() == d,
                "combine returned {} floats for layer {li}, expected {d}",
                combined.len()
            );
            for (xi, a) in x.iter_mut().zip(&combined) {
                *xi += a;
            }
            let xn2 = Tensor::from_vec(&[1, d], ln_row(&x));
            let mut g = xn2.matmul(&layer.ffn_gate);
            micro::gelu_rows(g.data_mut());
            let u = xn2.matmul(&layer.ffn_up);
            let ffn = g.hadamard(&u).matmul(&layer.ffn_down);
            for (xi, a) in x.iter_mut().zip(ffn.data()) {
                *xi += a;
            }
        }
        let logits = Tensor::from_vec(&[1, d], ln_row(&x)).matmul(&self.params.readout).into_vec();
        obs::sentinel::scan(obs::sentinel::Site::Logits, &logits);
        Ok(logits)
    }

    // ------------------------------------------------- checkpoint bridge

    /// Serialize config, mechanism, and weights into a [`Checkpoint`]
    /// (sections `meta`, `mech`, `param.<name>`); the trainer layers its
    /// optimizer sections on top before saving.  Values are stored as raw
    /// little-endian f32, so a save/load round-trip is bitwise exact.
    pub fn to_checkpoint(&self, step: u64) -> Checkpoint {
        let mut ck = Checkpoint::new(step);
        let mut meta = vec![
            CKPT_FORMAT,
            self.cfg.vocab as f32,
            self.cfg.d_model as f32,
            self.cfg.layers as f32,
            self.cfg.heads as f32,
            self.cfg.ff_mult as f32,
        ];
        // The seed round-trips byte by byte (f32 holds 0..=255 exactly).
        meta.extend(self.cfg.seed.to_le_bytes().iter().map(|&b| b as f32));
        ck.sections.insert("meta".into(), meta);
        ck.sections.insert(
            "mech".into(),
            self.mech.label().bytes().map(|b| b as f32).collect(),
        );
        for (name, t) in self.params.named() {
            ck.sections.insert(format!("param.{name}"), t.data().to_vec());
        }
        ck
    }

    /// Rebuild a model from a checkpoint written by
    /// [`NativeLm::to_checkpoint`]: config + mechanism from the `meta` /
    /// `mech` sections (the kernels' sketches re-derive from the stored
    /// seed), then the weights loaded bitwise from the `param.*`
    /// sections.
    pub fn from_checkpoint(ck: &Checkpoint) -> anyhow::Result<NativeLm> {
        let meta = ck.get("meta").ok_or_else(|| anyhow::anyhow!("checkpoint has no meta section"))?;
        anyhow::ensure!(meta.len() == 6 + 8, "meta section has {} entries, want 14", meta.len());
        anyhow::ensure!(
            meta[0] == CKPT_FORMAT,
            "unsupported checkpoint format {} (want {})",
            meta[0],
            CKPT_FORMAT
        );
        let mut seed_bytes = [0u8; 8];
        for (b, &v) in seed_bytes.iter_mut().zip(&meta[6..]) {
            *b = v as u8;
        }
        let cfg = LmConfig {
            vocab: meta[1] as usize,
            d_model: meta[2] as usize,
            layers: meta[3] as usize,
            heads: meta[4] as usize,
            ff_mult: meta[5] as usize,
            seed: u64::from_le_bytes(seed_bytes),
        };
        // Validate here so a malformed (but CRC-valid) checkpoint yields
        // a clean error instead of tripping NativeLm::new's asserts.
        anyhow::ensure!(
            cfg.vocab >= 1
                && cfg.layers >= 1
                && cfg.heads >= 1
                && cfg.ff_mult >= 1
                && cfg.d_model % cfg.heads == 0
                && (cfg.d_model / cfg.heads) % 2 == 0,
            "checkpoint meta is degenerate: vocab {} d_model {} layers {} heads {} ff_mult {} \
             (need d_model divisible into heads with an even head_dim)",
            cfg.vocab,
            cfg.d_model,
            cfg.layers,
            cfg.heads,
            cfg.ff_mult
        );
        let label: String = ck
            .get("mech")
            .ok_or_else(|| anyhow::anyhow!("checkpoint has no mech section"))?
            .iter()
            .map(|&v| v as u8 as char)
            .collect();
        let mech = Mechanism::parse(&label).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut lm = NativeLm::new(cfg, mech);
        for (name, t) in lm.params.named_mut() {
            let key = format!("param.{name}");
            let data = ck
                .get(&key)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing section {key}"))?;
            anyhow::ensure!(
                data.len() == t.len(),
                "section {key}: {} values, want {}",
                data.len(),
                t.len()
            );
            t.data_mut().copy_from_slice(data);
        }
        // The int8 twins built by `new` quantized the random init;
        // rebuild them from the restored weights.
        lm.requantize();
        Ok(lm)
    }

    /// Load a model from a checkpoint file; returns the model and the
    /// training step it was saved at.
    pub fn load_checkpoint(path: &std::path::Path) -> anyhow::Result<(NativeLm, u64)> {
        let ck = Checkpoint::load(path).map_err(|e| anyhow::anyhow!("{e}"))?;
        let lm = NativeLm::from_checkpoint(&ck)?;
        Ok((lm, ck.step))
    }
}

/// Row-vector × per-row-quantized matrix with f32 accumulation:
/// `out[c] = Σ_r a[r] · (q[r·cols+c] as f32 · scales[r])`, weights
/// staying int8 in memory end to end.
fn q8_vecmat(a: &[f32], m: &QuantMatrix) -> Vec<f32> {
    debug_assert_eq!(a.len(), m.rows);
    let mut out = vec![0.0f32; m.cols];
    micro::gemm_row_q8(&mut out, a, &m.q, &m.scales);
    out
}

/// Apply RoPE to every head segment of every row of a fused (n, H·hd)
/// projection, in place.  Row-parallel on the deterministic backend.
pub(crate) fn rope_heads(t: &mut Tensor, hd: usize) {
    use crate::exec::pool;
    let d = t.cols();
    debug_assert_eq!(d % hd, 0);
    pool::par_row_chunks(t.data_mut(), d, 16, |row0, chunk| {
        for (r, row) in chunk.chunks_mut(d).enumerate() {
            let pos = row0 + r;
            for seg in row.chunks_mut(hd) {
                rope_row(seg, pos);
            }
        }
    });
}

/// Add the sinusoidal absolute position embedding for `pos` in place —
/// the half-split layout of python/compile/model.py::sinusoidal_table.
pub(crate) fn add_sinusoidal(row: &mut [f32], pos: usize) {
    let d = row.len();
    let half = d / 2;
    for j in 0..half {
        let angle = pos as f64 / 10000f64.powf(2.0 * j as f64 / d as f64);
        row[j] += angle.sin() as f32;
        row[half + j] += angle.cos() as f32;
    }
}

/// Rotary position embedding of one head row (half-split pairing, matching
/// python/compile/model.py::_rope).
pub(crate) fn rope_row(x: &mut [f32], pos: usize) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let theta = pos as f64 / 10000f64.powf(2.0 * i as f64 / hd as f64);
        let (c, s) = (theta.cos() as f32, theta.sin() as f32);
        let (x1, x2) = (x[i], x[half + i]);
        x[i] = x1 * c - x2 * s;
        x[half + i] = x1 * s + x2 * c;
    }
}

/// Inverse (transpose) rotation of [`rope_row`] — RoPE is orthogonal, so
/// the backward pass pulls gradients through with the adjoint rotation.
pub(crate) fn rope_row_inv(x: &mut [f32], pos: usize) {
    let hd = x.len();
    let half = hd / 2;
    for i in 0..half {
        let theta = pos as f64 / 10000f64.powf(2.0 * i as f64 / hd as f64);
        let (c, s) = (theta.cos() as f32, theta.sin() as f32);
        let (x1, x2) = (x[i], x[half + i]);
        x[i] = x1 * c + x2 * s;
        x[half + i] = -x1 * s + x2 * c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(mech: Mechanism) -> NativeLm {
        let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 7 };
        NativeLm::new(cfg, mech)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let lm = tiny(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let tokens: Vec<u32> = (0..13).map(|i| (i * 5) % 64).collect();
        let logits = lm.forward(&tokens);
        assert_eq!(logits.shape(), &[13, 64]);
        assert!(logits.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_is_deterministic_in_seed() {
        let mech = Mechanism::Performer { m: 8, block: 8 };
        let a = tiny(mech.clone());
        let b = tiny(mech);
        let tokens: Vec<u32> = (0..9).collect();
        assert_eq!(a.forward(&tokens), b.forward(&tokens));
    }

    #[test]
    fn forward_is_causal() {
        let lm = tiny(Mechanism::Softmax);
        let t1: Vec<u32> = (0..12).collect();
        let mut t2 = t1.clone();
        t2[11] = 63;
        let a = lm.forward(&t1);
        let b = lm.forward(&t2);
        for i in 0..11 {
            assert_eq!(a.row(i), b.row(i), "row {i} depends on a future token");
        }
        assert_ne!(a.row(11), b.row(11));
    }

    #[test]
    fn odd_length_forward_matches_all_mechanisms() {
        // n = 13 against block 8: the ragged tail path must leave forward
        // logits finite and causal for every mechanism (the kernel-level
        // oracle comparison lives in attn::kernel::state tests).
        let mechs = [
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 16, block: 8 },
        ];
        let tokens: Vec<u32> = (0..13).map(|i| (i * 7) % 64).collect();
        for mech in mechs {
            let lm = tiny(mech.clone());
            let a = lm.forward(&tokens);
            assert!(a.data().iter().all(|x| x.is_finite()), "{}", mech.label());
            // Prefix invariance: truncating the input reproduces the
            // logits of every kept position (no tail-block leakage).
            let b = lm.forward(&tokens[..9]);
            for i in 0..9 {
                for (x, y) in a.row(i).iter().zip(b.row(i)) {
                    let tol = 1e-3 * (1.0 + x.abs().max(y.abs()));
                    assert!((x - y).abs() <= tol, "{} row {i}: {x} vs {y}", mech.label());
                }
            }
        }
    }

    #[test]
    fn sharded_full_range_identity_combine_is_bitwise() {
        // One shard owning every head with a pass-through combine must
        // reproduce the unsharded path exactly — prefill logits, decode
        // logits, and the states they leave behind.
        let lm = tiny(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let tokens: Vec<u32> = (0..11).map(|i| (i * 3) % 64).collect();
        let mut ident = |_li: usize, partial: Vec<f32>| Ok(partial);
        let mut plain = lm.new_states();
        let want = lm.prefill(&tokens, &mut plain);
        let mut sharded = lm.new_states();
        let got = lm
            .prefill_sharded(&tokens, Some(&mut sharded), 0..lm.cfg.heads, &mut ident)
            .unwrap();
        assert_eq!(got, want);
        let mut pos = tokens.len();
        for t in [5u32, 9, 17] {
            let la = lm.step(t, pos, &mut plain);
            let lb = lm.step_sharded(t, pos, &mut sharded, 0..lm.cfg.heads, &mut ident).unwrap();
            let la_bits: Vec<u32> = la.iter().map(|v| v.to_bits()).collect();
            let lb_bits: Vec<u32> = lb.iter().map(|v| v.to_bits()).collect();
            assert_eq!(la_bits, lb_bits, "step at pos {pos} diverged");
            pos += 1;
        }
    }

    #[test]
    fn sharded_combine_error_propagates() {
        let lm = tiny(Mechanism::Softmax);
        let mut fail = |_li: usize, _p: Vec<f32>| anyhow::bail!("peer lost");
        assert!(lm.prefill_sharded(&[1, 2, 3], None, 0..1, &mut fail).is_err());
        let mut states = lm.new_states();
        assert!(lm.step_sharded(1, 0, &mut states, 0..1, &mut fail).is_err());
    }

    #[test]
    fn step_q8_tracks_f32_step_closely() {
        // Direct call (no PSF_QUANT global): the int8 decode path is an
        // approximation of step(), so compare in normalized L2, not
        // bitwise — per-row quantization bounds each weight's relative
        // error by ~1/254.
        let lm = tiny(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let qw = lm.build_qweights();
        let tokens: Vec<u32> = (0..9).map(|i| (i * 5) % 64).collect();
        let mut sf = lm.new_states();
        let mut sq = lm.new_states();
        lm.prefill(&tokens, &mut sf);
        lm.prefill(&tokens, &mut sq);
        let mut pos = tokens.len();
        for t in [3u32, 11, 40] {
            let a = lm.step(t, pos, &mut sf);
            let b = lm.step_q8(&qw, t, pos, &mut sq);
            let norm: f32 = a.iter().map(|v| v * v).sum::<f32>().sqrt();
            let dist: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt();
            assert!(dist <= 0.15 * norm + 0.05, "pos {pos}: |a-b| {dist} vs |a| {norm}");
            pos += 1;
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_row(&mut x, 17);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3);
    }

    #[test]
    fn rope_inv_round_trips_bit_close() {
        let orig: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.7).collect();
        let mut x = orig.clone();
        rope_row(&mut x, 23);
        rope_row_inv(&mut x, 23);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn named_params_cover_everything_in_stable_order() {
        let lm = tiny(Mechanism::Softmax);
        let names: Vec<String> = lm.params().named().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "readout");
        assert_eq!(names[2], "layer0.wq");
        assert_eq!(names.len(), 2 + 7 * lm.cfg.layers);
        let total = lm.params().num_params();
        let d = lm.cfg.d_model;
        let f = lm.cfg.ff_mult * d;
        assert_eq!(total, 2 * 64 * d + lm.cfg.layers * (4 * d * d + 3 * d * f));
    }

    #[test]
    fn checkpoint_round_trip_is_bitwise() {
        let dir = std::env::temp_dir().join("psf_model_ckpt_test");
        let path = dir.join("roundtrip.ckpt");
        let mech = Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true };
        let lm = tiny(mech);
        lm.to_checkpoint(123).save(&path).unwrap();
        let (back, step) = NativeLm::load_checkpoint(&path).unwrap();
        assert_eq!(step, 123);
        assert_eq!(back.cfg, lm.cfg);
        assert_eq!(back.mech, lm.mech);
        for ((an, at), (bn, bt)) in lm.params().named().iter().zip(back.params().named()) {
            assert_eq!(an, &bn);
            let a_bits: Vec<u32> = at.data().iter().map(|v| v.to_bits()).collect();
            let b_bits: Vec<u32> = bt.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(a_bits, b_bits, "{an}: payload not bitwise identical");
        }
        // The restored model serves the same bytes.
        let tokens: Vec<u32> = (0..13).collect();
        assert_eq!(lm.forward(&tokens), back.forward(&tokens));
    }

    #[test]
    fn mutated_params_change_forward_and_round_trip() {
        let dir = std::env::temp_dir().join("psf_model_ckpt_test");
        let path = dir.join("mutated.ckpt");
        let mut lm = tiny(Mechanism::Flash { block: 8 });
        let tokens: Vec<u32> = (0..9).collect();
        let before = lm.forward(&tokens);
        lm.params_mut().embed.data_mut()[0] += 1.0;
        let after = lm.forward(&tokens);
        assert_ne!(before, after, "params_mut must feed the forward path");
        lm.to_checkpoint(1).save(&path).unwrap();
        let (back, _) = NativeLm::load_checkpoint(&path).unwrap();
        assert_eq!(back.forward(&tokens), after);
    }
}
