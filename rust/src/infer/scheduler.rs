//! Continuous-batching scheduler for concurrent decode sessions.
//!
//! Serving shape: requests queue up, at most `max_concurrent` sessions are
//! resident (each holds decode state — constant-size for the linear
//! mechanisms, O(context) for the softmax family), and each scheduling
//! tick hands out up to `tick_tokens` single-token steps round-robin
//! across resident sessions.  Finished sessions retire immediately and
//! free their slot for the queue — the continuous-batching discipline.
//!
//! Parallelism without nondeterminism: a tick first computes the
//! round-robin token allocation *arithmetically* (sessions finish exactly
//! when `new_tokens == max_new`, so the walk needs no stepping), then
//! steps the sessions on the shared compute pool (`exec::pool`) — each
//! session is private state plus a private RNG, so cross-session
//! scheduling can never leak into a token stream, and the allocation
//! itself is identical at every thread count.  Prefill inside admission
//! additionally fans out per head / per matmul tile through the same
//! backend.
//!
//! Per-session latency and aggregate throughput flow through `metrics`:
//! one JSONL record per retired session plus a closing aggregate record.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::time::Instant;

use crate::exec::pool;
use crate::infer::model::NativeLm;
use crate::infer::session::{DecodeSession, GenRequest};
use crate::metrics::{JsonlWriter, Record};
use crate::util::stats::percentile;

/// Scheduler knobs.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Maximum resident (admitted, unfinished) sessions.
    pub max_concurrent: usize,
    /// Decode-token budget handed out per scheduling tick.
    pub tick_tokens: usize,
    /// Optional JSONL sink for per-session + aggregate records.
    pub log_path: Option<PathBuf>,
    /// Echo per-session completion lines to stderr.
    pub echo: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_concurrent: 4, tick_tokens: 16, log_path: None, echo: false }
    }
}

/// What one retired session looked like.
pub struct SessionReport {
    pub id: usize,
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    /// Queue-to-retire wall time (includes time spent waiting on peers).
    pub wall_secs: f64,
    pub state_memory_floats: usize,
    pub tokens: Vec<u32>,
    /// Per-token decode latencies (seconds), one per generated token.
    pub step_secs: Vec<f64>,
}

impl SessionReport {
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_secs > 0.0 {
            self.new_tokens as f64 / self.decode_secs
        } else {
            0.0
        }
    }
}

/// Aggregate result of draining the queue.
pub struct ServeSummary {
    pub reports: Vec<SessionReport>,
    pub wall_secs: f64,
    pub total_new_tokens: usize,
    /// Aggregate decode throughput: generated tokens / total wall time.
    pub tokens_per_sec: f64,
    pub p50_step_ms: f64,
    pub p95_step_ms: f64,
}

/// Continuous-batching scheduler over one shared immutable model.
pub struct Scheduler<'m> {
    model: &'m NativeLm,
    cfg: SchedulerConfig,
    queue: VecDeque<(usize, GenRequest, Instant)>,
    next_id: usize,
    /// Resident (admitted, unfinished) sessions with their enqueue times.
    active: Vec<(DecodeSession, Instant)>,
    /// Round-robin cursor, persistent across ticks so a small token budget
    /// rotates over sessions instead of favoring active[0].
    cursor: usize,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m NativeLm, cfg: SchedulerConfig) -> Scheduler<'m> {
        Scheduler { model, cfg, queue: VecDeque::new(), next_id: 0, active: Vec::new(), cursor: 0 }
    }

    /// Enqueue a request; returns its session id.
    pub fn submit(&mut self, req: GenRequest) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, req, Instant::now()));
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Resident (admitted, unfinished) session count.
    pub fn resident(&self) -> usize {
        self.active.len()
    }

    /// Nothing queued and nothing resident?
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// One scheduling tick: admit from the queue into free slots (the
    /// expensive full-context prefill happens here), hand out up to
    /// `tick_tokens` single-token steps round-robin across resident
    /// sessions, then retire finished sessions.  Returns the sessions
    /// retired during this tick — callers ([`Scheduler::run`], the serve
    /// workers' tests, and anything that needs incremental scheduling)
    /// decide what to do with them.
    pub fn tick(&mut self) -> Vec<SessionReport> {
        // Admission: fill free slots from the queue.
        while self.active.len() < self.cfg.max_concurrent.max(1) {
            let Some((id, req, queued)) = self.queue.pop_front() else { break };
            self.active.push((DecodeSession::new(self.model, id, req), queued));
        }
        // Round-robin allocation under the budget, computed without
        // stepping: a session leaves the rotation exactly when its
        // allocation reaches its remaining budget, which replicates the
        // sequential step-and-check loop token for token.
        let len = self.active.len();
        let mut alloc = vec![0usize; len];
        if len > 0 {
            let rem: Vec<usize> = self.active.iter().map(|(s, _)| s.remaining_budget()).collect();
            let mut budget = self.cfg.tick_tokens.max(1);
            while budget > 0 {
                let Some(idx) = (0..len)
                    .map(|off| (self.cursor + off) % len)
                    .find(|&i| alloc[i] < rem[i])
                else {
                    break;
                };
                alloc[idx] += 1;
                self.cursor = (idx + 1) % len;
                budget -= 1;
            }
            // Execute the allocation: sessions are independent (private
            // states, private RNG), so stepping them on pool threads
            // yields the same streams as any sequential interleaving.
            let model = self.model;
            pool::par_map_mut(&mut self.active, 1, |i, (session, _)| {
                for _ in 0..alloc[i] {
                    session.step(model);
                }
            });
        }
        // Retirement: free slots, hand reports to the caller.
        let mut retired = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if !self.active[i].0.finished {
                i += 1;
                continue;
            }
            let (s, queued) = self.active.swap_remove(i);
            retired.push(SessionReport {
                id: s.id,
                prompt_len: s.prompt_len,
                new_tokens: s.new_tokens(),
                prefill_secs: s.prefill_secs,
                decode_secs: s.decode_secs,
                wall_secs: queued.elapsed().as_secs_f64(),
                state_memory_floats: s.state_memory_floats(),
                tokens: s.tokens,
                step_secs: s.step_secs,
            });
        }
        retired
    }

    /// Drain the queue to completion under the admission/budget discipline:
    /// a thin loop over [`Scheduler::tick`] plus JSONL/echo reporting.
    pub fn run(&mut self) -> anyhow::Result<ServeSummary> {
        let mut log = match &self.cfg.log_path {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        let t0 = Instant::now();
        let mut reports: Vec<SessionReport> = Vec::new();
        let mut step_secs: Vec<f64> = Vec::new();

        while !self.idle() {
            for report in self.tick() {
                step_secs.extend_from_slice(&report.step_secs);
                if let Some(w) = &mut log {
                    w.write(&session_record(self.model, &report))?;
                }
                if self.cfg.echo {
                    eprintln!(
                        "session {:>3} done: {} prompt + {} new tokens, prefill {:.1}ms, \
                         {:.2}ms/token decode",
                        report.id,
                        report.prompt_len,
                        report.new_tokens,
                        report.prefill_secs * 1e3,
                        report.decode_secs * 1e3 / report.new_tokens.max(1) as f64,
                    );
                }
                reports.push(report);
            }
        }

        reports.sort_by_key(|r| r.id);
        let wall_secs = t0.elapsed().as_secs_f64();
        let total_new_tokens: usize = reports.iter().map(|r| r.new_tokens).sum();
        step_secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (p50, p95) = if step_secs.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&step_secs, 50.0) * 1e3, percentile(&step_secs, 95.0) * 1e3)
        };
        let summary = ServeSummary {
            wall_secs,
            total_new_tokens,
            tokens_per_sec: if wall_secs > 0.0 { total_new_tokens as f64 / wall_secs } else { 0.0 },
            p50_step_ms: p50,
            p95_step_ms: p95,
            reports,
        };
        if let Some(w) = &mut log {
            w.write(
                &Record::new()
                    .str("kind", "serve_summary")
                    .str("mech", self.model.mech.label())
                    .i64("sessions", summary.reports.len() as i64)
                    .i64("new_tokens", summary.total_new_tokens as i64)
                    .f64("wall_secs", summary.wall_secs)
                    .f64("tokens_per_sec", summary.tokens_per_sec)
                    .f64("p50_step_ms", summary.p50_step_ms)
                    .f64("p95_step_ms", summary.p95_step_ms),
            )?;
            w.flush()?;
        }
        Ok(summary)
    }
}

fn session_record(model: &NativeLm, r: &SessionReport) -> Record {
    Record::new()
        .str("kind", "session")
        .str("mech", model.mech.label())
        .i64("id", r.id as i64)
        .i64("prompt_len", r.prompt_len as i64)
        .i64("new_tokens", r.new_tokens as i64)
        .f64("prefill_ms", r.prefill_secs * 1e3)
        .f64("decode_ms", r.decode_secs * 1e3)
        .f64("decode_tokens_per_sec", r.decode_tokens_per_sec())
        .f64("wall_ms", r.wall_secs * 1e3)
        .i64("state_memory_floats", r.state_memory_floats as i64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::model::LmConfig;
    use crate::infer::sampler::SamplePolicy;

    fn model(mech: Mechanism) -> NativeLm {
        let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 9 };
        NativeLm::new(cfg, mech)
    }

    fn req(seed: u64, max_new: usize) -> GenRequest {
        GenRequest {
            prompt: vec![0, 7, 3, 9],
            max_new_tokens: max_new,
            policy: SamplePolicy::Temperature(0.8),
            seed,
        }
    }

    #[test]
    fn drains_all_sessions_under_tight_budget() {
        let m = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false });
        let cfg = SchedulerConfig { max_concurrent: 2, tick_tokens: 3, ..Default::default() };
        let mut sched = Scheduler::new(&m, cfg);
        for i in 0..5 {
            sched.submit(req(i, 4 + i as usize));
        }
        let summary = sched.run().unwrap();
        assert_eq!(summary.reports.len(), 5);
        assert_eq!(summary.total_new_tokens, 4 + 5 + 6 + 7 + 8);
        for (i, r) in summary.reports.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.new_tokens, 4 + i);
        }
    }

    #[test]
    fn output_independent_of_batching_discipline() {
        // The determinism contract: scheduling order must not leak into
        // any session's token stream.
        let m = model(Mechanism::Performer { m: 8, block: 8 });
        let run = |max_concurrent, tick_tokens| {
            let cfg = SchedulerConfig { max_concurrent, tick_tokens, ..Default::default() };
            let mut sched = Scheduler::new(&m, cfg);
            for i in 0..4 {
                sched.submit(req(100 + i, 10));
            }
            let mut out: Vec<Vec<u32>> =
                sched.run().unwrap().reports.into_iter().map(|r| r.tokens).collect();
            out.sort();
            out
        };
        assert_eq!(run(1, 1), run(4, 32));
        assert_eq!(run(2, 5), run(3, 7));
    }

    #[test]
    fn manual_ticks_match_run() {
        // tick() is the public increment run() loops over: driving it by
        // hand must produce the same completions and respect the admission
        // cap at every point.
        let mech = Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true };
        let cfg = SchedulerConfig { max_concurrent: 2, tick_tokens: 5, ..Default::default() };
        let collect = |mut sched: Scheduler| -> Vec<Vec<u32>> {
            let mut out: Vec<Vec<u32>> = Vec::new();
            let mut ticks = 0;
            while !sched.idle() {
                out.extend(sched.tick().into_iter().map(|r| r.tokens));
                assert!(sched.resident() <= 2);
                ticks += 1;
                assert!(ticks < 1000, "tick loop did not terminate");
            }
            out.sort();
            out
        };
        let m = model(mech);
        let mut a = Scheduler::new(&m, cfg.clone());
        let mut b = Scheduler::new(&m, cfg);
        for i in 0..4 {
            a.submit(req(i, 6));
            b.submit(req(i, 6));
        }
        let manual = collect(a);
        let mut ran: Vec<Vec<u32>> =
            b.run().unwrap().reports.into_iter().map(|r| r.tokens).collect();
        ran.sort();
        assert_eq!(manual, ran);
    }

    #[test]
    fn writes_jsonl_records() {
        let dir = std::env::temp_dir().join("psf_sched_test");
        let path = dir.join("serve.jsonl");
        let _ = std::fs::remove_file(&path);
        let m = model(Mechanism::Softmax);
        let cfg = SchedulerConfig { log_path: Some(path.clone()), ..Default::default() };
        let mut sched = Scheduler::new(&m, cfg);
        sched.submit(req(0, 3));
        sched.submit(req(1, 3));
        let summary = sched.run().unwrap();
        assert_eq!(summary.reports.len(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3); // 2 sessions + 1 aggregate
        assert!(text.contains("\"kind\":\"session\""));
        assert!(text.contains("\"kind\":\"serve_summary\""));
    }
}
