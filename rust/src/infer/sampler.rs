//! Token sampling policies over next-token logits.
//!
//! All policies draw from a caller-owned [`Pcg`] stream, so a (seed,
//! prompt, policy) triple replays the exact same token sequence no matter
//! how the scheduler interleaves sessions — the determinism contract the
//! serving path is tested against.

use crate::util::rng::Pcg;

/// How to turn logits into a token.
#[derive(Clone, Debug, PartialEq)]
pub enum SamplePolicy {
    /// Argmax (ties broken toward the lowest id). Ignores the RNG.
    Greedy,
    /// Softmax at the given temperature.
    Temperature(f32),
    /// Keep the `k` highest logits, then temperature-softmax among them.
    TopK { k: usize, temperature: f32 },
    /// Nucleus sampling: smallest probability mass >= `p`.
    TopP { p: f32, temperature: f32 },
}

impl SamplePolicy {
    /// Build from the CLI surface: a policy name plus the shared knobs.
    pub fn from_flags(name: &str, temperature: f32, k: usize, p: f32) -> Result<SamplePolicy, String> {
        match name {
            "greedy" => Ok(SamplePolicy::Greedy),
            "temperature" => Ok(SamplePolicy::Temperature(temperature)),
            "top-k" => Ok(SamplePolicy::TopK { k, temperature }),
            "top-p" => Ok(SamplePolicy::TopP { p, temperature }),
            other => Err(format!("unknown sampling policy `{other}` (want greedy | temperature | top-k | top-p)")),
        }
    }

    /// Sample a token id from `logits`.
    pub fn sample(&self, logits: &[f32], rng: &mut Pcg) -> usize {
        assert!(!logits.is_empty());
        match self {
            SamplePolicy::Greedy => argmax(logits),
            SamplePolicy::Temperature(t) => rng.categorical(&softmax_t(logits, *t)),
            SamplePolicy::TopK { k, temperature } => {
                // Clamp so k = 0 and k > vocab are well-defined instead of
                // indexing out of bounds below.
                let k = (*k).clamp(1, logits.len());
                // k-th highest logit is the inclusion threshold.
                let mut sorted: Vec<f32> = logits.to_vec();
                sorted.sort_by(|a, b| b.total_cmp(a));
                let thresh = sorted[k - 1];
                let mut probs = softmax_t(logits, *temperature);
                // Mask below-threshold entries; keep at most k at ties by
                // zeroing extras from the high ids down.
                let mut kept = logits.iter().filter(|&&l| l >= thresh).count();
                for (i, &l) in logits.iter().enumerate().rev() {
                    if l < thresh {
                        probs[i] = 0.0;
                    } else if l == thresh && kept > k {
                        probs[i] = 0.0;
                        kept -= 1;
                    }
                }
                rng.categorical(&probs)
            }
            SamplePolicy::TopP { p, temperature } => {
                let probs = softmax_t(logits, *temperature);
                // total_cmp (not partial_cmp-with-fallback): a total order
                // keeps the sort — and therefore the nucleus — one fixed
                // permutation for any input, ties resolved by index (the
                // sort is stable).
                let mut order: Vec<usize> = (0..probs.len()).collect();
                order.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]));
                let target = p.clamp(0.0, 1.0);
                let mut mass = 0.0f32;
                let mut nucleus = vec![0.0f32; probs.len()];
                for &i in &order {
                    nucleus[i] = probs[i];
                    mass += probs[i];
                    if mass >= target {
                        break;
                    }
                }
                rng.categorical(&nucleus)
            }
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Temperature softmax (stable); t <= 0 degrades to a one-hot argmax.
fn softmax_t(logits: &[f32], t: f32) -> Vec<f32> {
    if t <= 0.0 {
        let mut out = vec![0.0; logits.len()];
        out[argmax(logits)] = 1.0;
        return out;
    }
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| ((l - mx) / t).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = [0.1f32, 2.0, -1.0, 1.9];
        let mut rng = Pcg::seeded(0);
        for _ in 0..10 {
            assert_eq!(SamplePolicy::Greedy.sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let pol = SamplePolicy::Temperature(0.8);
        let run = |seed| {
            let mut rng = Pcg::seeded(seed);
            (0..32).map(|_| pol.sample(&logits, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [5.0f32, 4.0, 3.0, -10.0, -10.0, -10.0];
        let pol = SamplePolicy::TopK { k: 3, temperature: 1.0 };
        let mut rng = Pcg::seeded(1);
        for _ in 0..200 {
            assert!(pol.sample(&logits, &mut rng) < 3);
        }
    }

    #[test]
    fn top_k_with_k_beyond_vocab_does_not_panic_and_keeps_full_support() {
        // Regression: k > logits.len() used to index sorted[k - 1] out of
        // bounds; clamped it must behave exactly like k = vocab.
        let logits = [0.0f32, 0.1, 0.2, 0.3];
        let pol = SamplePolicy::TopK { k: 1000, temperature: 1.0 };
        let mut rng = Pcg::seeded(8);
        let mut seen = [false; 4];
        for _ in 0..400 {
            seen[pol.sample(&logits, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "support must cover the whole vocab: {seen:?}");
        // k = 0 clamps to 1: degenerates to the single best logit.
        let pol0 = SamplePolicy::TopK { k: 0, temperature: 1.0 };
        for _ in 0..50 {
            assert_eq!(pol0.sample(&logits, &mut rng), 3);
        }
    }

    #[test]
    fn top_k_breaks_ties_deterministically_by_lowest_index() {
        // Regression: tied logits at the threshold must admit exactly k
        // tokens, keeping the lowest ids — never more than k.
        let logits = [1.0f32, 1.0, 1.0, 1.0, -5.0];
        let pol = SamplePolicy::TopK { k: 2, temperature: 1.0 };
        let mut rng = Pcg::seeded(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            let t = pol.sample(&logits, &mut rng);
            assert!(t < 2, "tied logits admitted token {t} beyond k=2");
            seen[t] = true;
        }
        assert!(seen[0] && seen[1], "both lowest-index ties must stay in support");
    }

    #[test]
    fn top_p_restricts_to_nucleus() {
        // One dominant token: a tight nucleus must always pick it.
        let logits = [10.0f32, 0.0, 0.0, 0.0];
        let pol = SamplePolicy::TopP { p: 0.5, temperature: 1.0 };
        let mut rng = Pcg::seeded(2);
        for _ in 0..100 {
            assert_eq!(pol.sample(&logits, &mut rng), 0);
        }
    }

    #[test]
    fn zero_temperature_degrades_to_greedy() {
        let logits = [0.0f32, 3.0, 1.0];
        let mut rng = Pcg::seeded(3);
        assert_eq!(SamplePolicy::Temperature(0.0).sample(&logits, &mut rng), 1);
    }

    #[test]
    fn frequencies_follow_weights() {
        let logits = [0.0f32, 2.0f32.ln() + 0.0]; // p1 = 2 * p0
        let pol = SamplePolicy::Temperature(1.0);
        let mut rng = Pcg::seeded(4);
        let mut counts = [0usize; 2];
        for _ in 0..6000 {
            counts[pol.sample(&logits, &mut rng)] += 1;
        }
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((1.6..2.5).contains(&ratio), "ratio {ratio}");
    }
}
