//! Per-mechanism decode state: the recurrent view of causal attention.
//!
//! Linear attention admits an O(1)-per-token recurrence
//! (`S_t = S_{t-1} + phi(k_t) v_t^T`, `z_t = z_{t-1} + phi(k_t)`), so
//! generating a token costs the same at context 512 and context 8k; the
//! softmax family has no such sufficient statistic and must keep a KV
//! cache that is rescanned per token (O(n)).  One [`DecodeState`] variant
//! per [`Mechanism`](crate::attn::Mechanism):
//!
//! * `Softmax` — growing KV cache, exact softmax row (serves `Softmax`
//!   *and* `Flash`: blocked streaming is a prefill-side layout, the math
//!   is identical);
//! * `Poly` — growing cache of layernormed keys, degree-p weights;
//! * `Sketch` — Polysketch recurrent state: prefix feature moments
//!   `Z in R^{r^2 x (h+1)}` plus the current diagonal block's half-sketch
//!   rows, reproducing `block_lt::polysketch_attention_block`'s exact
//!   prefix/diagonal split (including Section 3.2 local-exact blocks);
//! * `Feature` — Performer recurrent state `S in R^{m x (h+1)}`.
//!
//! Every variant's `step` is numerically consistent with the full-context
//! prefill path (`Attention::run` over the same partition) — the
//! prefill/decode parity tests in `tests/integration_infer.rs` are the
//! correctness anchor for the whole serving subsystem.

use std::sync::Arc;

use crate::attn::block_lt::self_tensor_row;
use crate::attn::performer::PerformerFeatures;
use crate::attn::poly::powi;
use crate::attn::sketch::{HalfRowScratch, PolySketch};
use crate::attn::Attention;
use crate::tensor::{axpy, dot};

/// Attention state of one (layer, head) during autoregressive decoding.
///
/// `Clone` is load-bearing: the serving gateway's prompt-prefix cache
/// (`serve::cache`) stores cloned states, so a clone must be a deep,
/// independent copy — O(r²h) for the recurrent variants, O(n·h) for the
/// KV-cache family.
#[derive(Clone)]
pub enum DecodeState {
    /// Exact softmax over a growing KV cache (also the Flash fallback).
    Softmax(KvCache),
    /// Degree-p polynomial weights over a growing cache of LN'd keys.
    Poly { p: u32, cache: KvCache },
    /// Polysketch recurrent state — O(1)/token, constant memory.
    Sketch(SketchState),
    /// Performer recurrent state — O(1)/token, constant memory.
    Feature(FeatureState),
}

impl DecodeState {
    /// Build the decode state matching an instantiated [`Attention`],
    /// sharing its sketch/feature projections (required for prefill/decode
    /// consistency — never resample).
    pub fn new(attn: &Attention) -> DecodeState {
        match attn {
            Attention::Softmax | Attention::Flash { .. } => DecodeState::Softmax(KvCache::new()),
            Attention::Poly { p } => DecodeState::Poly { p: *p, cache: KvCache::new() },
            Attention::Polysketch { sk, block, local } => DecodeState::Sketch(SketchState {
                sk: Arc::clone(sk),
                block: (*block).max(1),
                local: *local,
                h: 0,
                z: Vec::new(),
                buf_rh: Vec::new(),
                buf_kn: Vec::new(),
                buf_v: Vec::new(),
                phi: Vec::new(),
                sketch_scratch: HalfRowScratch::default(),
                tokens: 0,
            }),
            Attention::Performer { feats, .. } => DecodeState::Feature(FeatureState {
                feats: Arc::clone(feats),
                h: 0,
                s: Vec::new(),
                tokens: 0,
            }),
        }
    }

    /// One decode step: fold `(k, v)` into the state and return this
    /// position's attention output for query `q` (all `head_dim`-length
    /// rows; the output has `v`'s length).
    pub fn step(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        match self {
            DecodeState::Softmax(cache) => {
                cache.push(k, v);
                cache.softmax_row(q)
            }
            DecodeState::Poly { p, cache } => {
                cache.push(&ln_row(k), v);
                cache.poly_row(&ln_row(q), *p)
            }
            DecodeState::Sketch(st) => st.step(q, k, v),
            DecodeState::Feature(st) => st.step(q, k, v),
        }
    }

    /// Fold a key/value pair into the state without producing an output —
    /// the prefill path (the full-context forward already computed the
    /// outputs; this seeds the state for subsequent `step`s).
    pub fn absorb(&mut self, k: &[f32], v: &[f32]) {
        match self {
            DecodeState::Softmax(cache) => cache.push(k, v),
            DecodeState::Poly { cache, .. } => cache.push(&ln_row(k), v),
            DecodeState::Sketch(st) => st.absorb(k, v),
            DecodeState::Feature(st) => st.absorb(k, v),
        }
    }

    /// Number of tokens folded in so far.
    pub fn tokens_seen(&self) -> usize {
        match self {
            DecodeState::Softmax(cache) | DecodeState::Poly { cache, .. } => cache.len,
            DecodeState::Sketch(st) => st.tokens,
            DecodeState::Feature(st) => st.tokens,
        }
    }

    /// O(1)-per-token state (true for the linear mechanisms)?
    pub fn is_recurrent(&self) -> bool {
        matches!(self, DecodeState::Sketch(_) | DecodeState::Feature(_))
    }

    /// Current state footprint in f32 words — constant in context length
    /// for recurrent states, linear for KV caches.
    pub fn memory_floats(&self) -> usize {
        match self {
            DecodeState::Softmax(cache) | DecodeState::Poly { cache, .. } => {
                cache.k.len() + cache.v.len()
            }
            DecodeState::Sketch(st) => {
                st.z.len()
                    + st.buf_rh.iter().map(Vec::len).sum::<usize>()
                    + st.buf_kn.iter().map(Vec::len).sum::<usize>()
                    + st.buf_v.iter().map(Vec::len).sum::<usize>()
            }
            DecodeState::Feature(st) => st.s.len(),
        }
    }
}

// ------------------------------------------------------------- KV cache

/// Growing key/value cache (flat row-major storage).
#[derive(Clone)]
pub struct KvCache {
    k: Vec<f32>,
    v: Vec<f32>,
    kd: usize,
    vd: usize,
    len: usize,
}

impl KvCache {
    fn new() -> KvCache {
        KvCache { k: Vec::new(), v: Vec::new(), kd: 0, vd: 0, len: 0 }
    }

    fn push(&mut self, k: &[f32], v: &[f32]) {
        if self.len == 0 {
            self.kd = k.len();
            self.vd = v.len();
        }
        debug_assert_eq!(k.len(), self.kd);
        debug_assert_eq!(v.len(), self.vd);
        self.k.extend_from_slice(k);
        self.v.extend_from_slice(v);
        self.len += 1;
    }

    fn krow(&self, j: usize) -> &[f32] {
        &self.k[j * self.kd..(j + 1) * self.kd]
    }

    fn vrow(&self, j: usize) -> &[f32] {
        &self.v[j * self.vd..(j + 1) * self.vd]
    }

    /// Stable softmax attention of one query over the cache — the same
    /// operation order as `softmax::softmax_attention`'s row loop.
    fn softmax_row(&self, q: &[f32]) -> Vec<f32> {
        let scale = 1.0 / (q.len() as f32).sqrt();
        let mut scores = vec![0.0f32; self.len];
        let mut mx = f32::NEG_INFINITY;
        for j in 0..self.len {
            scores[j] = dot(q, self.krow(j)) * scale;
            mx = mx.max(scores[j]);
        }
        let mut sum = 0.0;
        for s in scores.iter_mut() {
            *s = (*s - mx).exp();
            sum += *s;
        }
        let mut out = vec![0.0f32; self.vd];
        for j in 0..self.len {
            axpy(&mut out, self.vrow(j), scores[j] / sum);
        }
        out
    }

    /// Degree-p polynomial attention of one (LN'd) query over the cache of
    /// LN'd keys, with the paper's `1 +` denominator — mirrors
    /// `poly::poly_attention_prenormed`'s row loop.
    fn poly_row(&self, qn: &[f32], p: u32) -> Vec<f32> {
        let mut out = vec![0.0f32; self.vd];
        let mut denom = 1.0f32;
        for j in 0..self.len {
            let w = powi(dot(qn, self.krow(j)), p);
            denom += w;
            axpy(&mut out, self.vrow(j), w);
        }
        let inv = 1.0 / denom;
        for o in out.iter_mut() {
            *o *= inv;
        }
        out
    }
}

// -------------------------------------------------- polysketch recurrence

/// Polysketch decode state: prefix moments + current diagonal block.
///
/// Mirrors `polysketch_attention_block`'s decomposition exactly: keys in
/// completed blocks live only as `Z += phi'(r_j)^T [v_j | 1]` (constant
/// memory); keys of the in-progress block are buffered so the diagonal
/// uses the squared half-sketch scores — or, with `local`, the exact
/// degree-p polynomial scores of Section 3.2.  Work per token is
/// O(r^2 h + b r): independent of context length.
#[derive(Clone)]
pub struct SketchState {
    /// Shared with the instantiating [`Attention`] (and every clone of
    /// this state): the projections are immutable model data, not
    /// per-session state, so cloning a state — or caching a thousand
    /// prompt prefixes — never duplicates them.
    sk: Arc<PolySketch>,
    block: usize,
    local: bool,
    /// Value dim (+1 normalizer column); set on first token.
    h: usize,
    /// Prefix state Z: (r*r) x (h+1), row-major by feature index.
    z: Vec<f32>,
    /// In-progress block: key half-sketch rows (r,).
    buf_rh: Vec<Vec<f32>>,
    /// In-progress block: layernormed raw keys (only kept when `local`).
    buf_kn: Vec<Vec<f32>>,
    /// In-progress block: value rows (h,).
    buf_v: Vec<Vec<f32>>,
    /// Scratch for one phi' feature row (r*r) — reused every token so the
    /// per-token hot path does not hit the allocator for it.
    phi: Vec<f32>,
    /// Scratch for the half-sketch row recursion, same rationale: the
    /// token × layer × head hot path must not rebuild 1-row tensors or
    /// per-level temporaries on every call.
    sketch_scratch: HalfRowScratch,
    tokens: usize,
}

impl SketchState {
    fn ensure_init(&mut self, v: &[f32]) {
        if self.h == 0 {
            self.h = v.len();
            let f = self.sk.r * self.sk.r;
            self.z = vec![0.0; f * (self.h + 1)];
            self.phi = vec![0.0; f];
        }
    }

    /// Append a key to the in-progress block (no flush: the current
    /// position's output must still see this block as the diagonal).
    fn buffer_key(&mut self, k: &[f32], v: &[f32]) {
        self.ensure_init(v);
        let kn = ln_row(k);
        self.buf_rh.push(self.sk.half_row_scratch(&kn, &mut self.sketch_scratch));
        if self.local {
            self.buf_kn.push(kn);
        }
        self.buf_v.push(v.to_vec());
        self.tokens += 1;
    }

    /// Flush the block into Z once it reaches the partition boundary — the
    /// same `block`-aligned partition the full-context block path uses.
    fn maybe_flush(&mut self) {
        if self.buf_rh.len() == self.block {
            self.flush();
        }
    }

    /// Z += phi'(r_j)^T [v_j | 1] for every buffered key, then clear.
    fn flush(&mut self) {
        let hc = self.h + 1;
        for (rh, v) in self.buf_rh.iter().zip(&self.buf_v) {
            self_tensor_row(rh, &mut self.phi);
            for (c, &kc) in self.phi.iter().enumerate() {
                if kc == 0.0 {
                    continue;
                }
                let zrow = &mut self.z[c * hc..(c + 1) * hc];
                axpy(&mut zrow[..self.h], v, kc);
                zrow[self.h] += kc;
            }
        }
        self.buf_rh.clear();
        self.buf_kn.clear();
        self.buf_v.clear();
    }

    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        self.buffer_key(k, v);
        self.maybe_flush();
    }

    fn step(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        self.buffer_key(k, v);
        let qn = ln_row(q);
        let lq = self.sk.half_row_scratch(&qn, &mut self.sketch_scratch);
        let hc = self.h + 1;
        // Prefix contribution phi'(l_q) . Z — same feature-order
        // accumulation as the block kernel's matmul_into_rows.
        self_tensor_row(&lq, &mut self.phi);
        let mut acc = vec![0.0f32; hc];
        for (c, &qv) in self.phi.iter().enumerate() {
            if qv == 0.0 {
                continue;
            }
            axpy(&mut acc, &self.z[c * hc..(c + 1) * hc], qv);
        }
        // Diagonal block: exact-local or squared half-sketch scores.
        for j in 0..self.buf_rh.len() {
            let w = if self.local {
                powi(dot(&qn, &self.buf_kn[j]), self.sk.p as u32)
            } else {
                let s = dot(&lq, &self.buf_rh[j]);
                s * s
            };
            axpy(&mut acc[..self.h], &self.buf_v[j], w);
            acc[self.h] += w;
        }
        let inv = 1.0 / (1.0 + acc[self.h]);
        acc.truncate(self.h);
        for o in acc.iter_mut() {
            *o *= inv;
        }
        self.maybe_flush();
        acc
    }
}

// --------------------------------------------------- performer recurrence

/// Performer decode state: `S += phi(k_t)^T [v_t | 1]`, O(m h) per token.
#[derive(Clone)]
pub struct FeatureState {
    /// Shared, immutable (see [`SketchState::sk`]).
    feats: Arc<PerformerFeatures>,
    h: usize,
    /// S: m x (h+1), row-major by feature index.
    s: Vec<f32>,
    tokens: usize,
}

impl FeatureState {
    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        if self.h == 0 {
            self.h = v.len();
            self.s = vec![0.0; self.feats.w.cols() * (self.h + 1)];
        }
        let hc = self.h + 1;
        let pk = self.feats.apply_row(k);
        for (c, &kc) in pk.iter().enumerate() {
            if kc == 0.0 {
                continue;
            }
            let srow = &mut self.s[c * hc..(c + 1) * hc];
            axpy(&mut srow[..self.h], v, kc);
            srow[self.h] += kc;
        }
        self.tokens += 1;
    }

    fn step(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        self.absorb(k, v);
        let hc = self.h + 1;
        let pq = self.feats.apply_row(q);
        let mut acc = vec![0.0f32; hc];
        for (c, &qv) in pq.iter().enumerate() {
            if qv == 0.0 {
                continue;
            }
            axpy(&mut acc, &self.s[c * hc..(c + 1) * hc], qv);
        }
        let inv = 1.0 / (1.0 + acc[self.h]);
        acc.truncate(self.h);
        for o in acc.iter_mut() {
            *o *= inv;
        }
        acc
    }
}

/// Parameter-free layer normalization of one row — identical arithmetic to
/// `tensor::layernorm_rows` (eps 1e-6), applied per token.
pub fn ln_row(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    let mean: f32 = x.iter().sum::<f32>() / n as f32;
    let var: f32 = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
    let inv = 1.0 / (var + 1e-6).sqrt();
    x.iter().map(|v| (v - mean) * inv).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::{Attention, Mechanism};
    use crate::tensor::{layernorm_rows, Tensor};
    use crate::util::rng::Pcg;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    /// Pad rows to a multiple of `block` with zeros (causality makes the
    /// padding inert for the first n rows), run, truncate — the same
    /// helper contract `infer::model` uses for prefill.
    fn run_ref(attn: &Attention, q: &Tensor, k: &Tensor, v: &Tensor, block: usize) -> Tensor {
        let n = q.rows();
        let np = n.div_ceil(block) * block;
        if np == n {
            return attn.run(q, k, v);
        }
        let pad = |t: &Tensor| {
            let mut out = Tensor::zeros(&[np, t.cols()]);
            out.data_mut()[..t.len()].copy_from_slice(t.data());
            out
        };
        let full = attn.run(&pad(q), &pad(k), &pad(v));
        Tensor::from_vec(&[n, v.cols()], full.data()[..n * v.cols()].to_vec())
    }

    fn mechs() -> Vec<Mechanism> {
        vec![
            Mechanism::Softmax,
            Mechanism::Flash { block: 8 },
            Mechanism::Poly { p: 4 },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false },
            Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true },
            Mechanism::Performer { m: 16, block: 8 },
        ]
    }

    /// Per-row causal oracle with NO padding anywhere: softmax math for
    /// the softmax family, exact poly weights for poly, hybrid
    /// local/sketched weights (respecting the block partition) for
    /// polysketch, feature dots for performer.
    fn naive_oracle(attn: &Attention, q: &Tensor, k: &Tensor, v: &Tensor, block: usize) -> Tensor {
        use crate::attn::poly::poly_attention;
        use crate::attn::softmax::softmax_attention;
        let linear = |wf: &dyn Fn(usize, usize) -> f32| -> Tensor {
            let (n, hv) = (q.rows(), v.cols());
            let mut out = Tensor::zeros(&[n, hv]);
            for i in 0..n {
                let mut denom = 1.0f32;
                let mut acc = vec![0.0f32; hv];
                for j in 0..=i {
                    let w = wf(i, j);
                    denom += w;
                    axpy(&mut acc, v.row(j), w);
                }
                for (o, a) in out.row_mut(i).iter_mut().zip(&acc) {
                    *o = a / denom;
                }
            }
            out
        };
        match attn {
            Attention::Softmax | Attention::Flash { .. } => softmax_attention(q, k, v),
            Attention::Poly { p } => poly_attention(q, k, v, *p),
            Attention::Polysketch { sk, local, .. } => {
                let qn = layernorm_rows(q);
                let kn = layernorm_rows(k);
                let lq = sk.half(&qn);
                let lk = sk.half(&kn);
                linear(&|i, j| {
                    if *local && i / block == j / block {
                        powi(dot(qn.row(i), kn.row(j)), sk.p as u32)
                    } else {
                        let s = dot(lq.row(i), lk.row(j));
                        s * s
                    }
                })
            }
            Attention::Performer { feats, .. } => {
                let pq = feats.apply(q);
                let pk = feats.apply(k);
                linear(&|i, j| dot(pq.row(i), pk.row(j)))
            }
        }
    }

    #[test]
    fn padded_prefill_matches_unpadded_oracle_at_odd_length() {
        // n = 13 against block 8: the prefill path zero-pads to 16, and
        // trailing padding must be inert — every real row must match an
        // oracle computed with no padding at all, for every mechanism.
        let mut rng = Pcg::seeded(11);
        let (n, h, block) = (13usize, 8, 8usize);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        for mech in mechs() {
            let attn = Attention::new(&mech, h, &mut Pcg::seeded(17));
            let got = run_ref(&attn, &q, &k, &v, block);
            let want = naive_oracle(&attn, &q, &k, &v, block);
            for i in 0..n {
                for (g, w) in got.row(i).iter().zip(want.row(i)) {
                    assert!(close(*g, *w, 2e-3), "{} row {i}: {g} vs {w}", mech.label());
                }
            }
        }
    }

    #[test]
    fn step_matches_full_context_attention() {
        // The parity anchor at the attention level: token-by-token decode
        // must reproduce the full-context kernel row by row, including at
        // lengths that straddle block boundaries.
        let mut rng = Pcg::seeded(0);
        let h = 8;
        for n in [5usize, 8, 13, 24] {
            let q = Tensor::gaussian(&mut rng, &[n, h]);
            let k = Tensor::gaussian(&mut rng, &[n, h]);
            let v = Tensor::gaussian(&mut rng, &[n, h]);
            for mech in mechs() {
                let attn = Attention::new(&mech, h, &mut Pcg::seeded(11));
                let want = run_ref(&attn, &q, &k, &v, 8);
                let mut st = DecodeState::new(&attn);
                for i in 0..n {
                    let got = st.step(q.row(i), k.row(i), v.row(i));
                    for (g, w) in got.iter().zip(want.row(i)) {
                        assert!(
                            close(*g, *w, 2e-3),
                            "{} n={n} row {i}: {g} vs {w}",
                            mech.label()
                        );
                    }
                }
                assert_eq!(st.tokens_seen(), n);
            }
        }
    }

    #[test]
    fn absorb_then_step_matches_pure_stepping() {
        // Prefill (absorb) must leave the state exactly where stepping
        // token-by-token would have.
        let mut rng = Pcg::seeded(1);
        let (n, h, split) = (19usize, 8, 11usize);
        let q = Tensor::gaussian(&mut rng, &[n, h]);
        let k = Tensor::gaussian(&mut rng, &[n, h]);
        let v = Tensor::gaussian(&mut rng, &[n, h]);
        for mech in mechs() {
            let attn = Attention::new(&mech, h, &mut Pcg::seeded(3));
            let mut stepped = DecodeState::new(&attn);
            let mut absorbed = DecodeState::new(&attn);
            for i in 0..split {
                stepped.step(q.row(i), k.row(i), v.row(i));
                absorbed.absorb(k.row(i), v.row(i));
            }
            for i in split..n {
                let a = stepped.step(q.row(i), k.row(i), v.row(i));
                let b = absorbed.step(q.row(i), k.row(i), v.row(i));
                assert_eq!(a, b, "{} row {i}", mech.label());
            }
        }
    }

    #[test]
    fn recurrent_states_have_constant_memory() {
        let mut rng = Pcg::seeded(2);
        let h = 8;
        for mech in mechs() {
            let attn = Attention::new(&mech, h, &mut rng);
            let mut st = DecodeState::new(&attn);
            let probe = |st: &mut DecodeState, rng: &mut Pcg, n: usize| {
                for _ in 0..n {
                    let q: Vec<f32> = rng.gaussians(h);
                    let k: Vec<f32> = rng.gaussians(h);
                    let v: Vec<f32> = rng.gaussians(h);
                    st.step(&q, &k, &v);
                }
                st.memory_floats()
            };
            let m64 = probe(&mut st, &mut rng, 64);
            let m256 = probe(&mut st, &mut rng, 192);
            if st.is_recurrent() {
                // Buffer occupancy wobbles within a block; totals must not
                // grow with tokens. 64 and 256 are both block multiples.
                assert_eq!(m64, m256, "{}", mech.label());
            } else {
                assert!(m256 > m64, "{}", mech.label());
            }
        }
    }

    #[test]
    fn cloned_state_is_deep_and_continues_identically() {
        // The cache primitive: a cloned state must be an independent deep
        // copy — identical continuation under identical inputs, and no
        // aliasing (stepping one must not perturb the other).
        let mut rng = Pcg::seeded(7);
        let h = 8;
        for mech in mechs() {
            let attn = Attention::new(&mech, h, &mut Pcg::seeded(5));
            let mut orig = DecodeState::new(&attn);
            for _ in 0..13 {
                let (q, k, v) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
                orig.step(&q, &k, &v);
            }
            let mut copy = orig.clone();
            assert_eq!(copy.tokens_seen(), orig.tokens_seen());
            // Divergent input on the copy leaves the original untouched...
            let (dq, dk, dv) = (rng.gaussians(h), rng.gaussians(h), rng.gaussians(h));
            copy.step(&dq, &dk, &dv);
            // ...so a fresh clone of the original still replays the copy's
            // step bit-for-bit.
            let mut copy2 = orig.clone();
            let a = copy2.step(&dq, &dk, &dv);
            let mut copy3 = orig.clone();
            let b = copy3.step(&dq, &dk, &dv);
            assert_eq!(a, b, "{}", mech.label());
            assert_eq!(orig.tokens_seen(), 13, "{}", mech.label());
        }
    }

    #[test]
    fn ln_row_matches_layernorm_rows() {
        let mut rng = Pcg::seeded(3);
        let x = Tensor::gaussian(&mut rng, &[4, 16]).scale(2.5);
        let want = layernorm_rows(&x);
        for i in 0..4 {
            assert_eq!(ln_row(x.row(i)).as_slice(), want.row(i));
        }
    }
}
