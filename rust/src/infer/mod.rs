//! Inference subsystem: linear-time autoregressive decoding and serving.
//!
//! The paper's training-side result — polysketch attention is linear in
//! context length — implies a stronger serving-side property: linear
//! attention has a recurrent view (`S_t = S_{t-1} + phi(k_t) v_t^T`), so
//! each generated token costs O(1) state update and constant memory,
//! where softmax attention must rescan an O(n) KV cache.  This module is
//! that serving path, end to end:
//!
//! * [`model`] — [`NativeLm`](model::NativeLm): the native transformer LM
//!   (paper recipe) whose attention lives entirely behind the kernel
//!   core (`attn::kernel`): per-head `Arc<dyn CausalKernel>` objects
//!   with one [`KernelState`](crate::attn::KernelState) each — a
//!   recurrent state for the linear engine, a KV cache for the
//!   quadratic engine — consistent by construction between the
//!   full-context prefill path and per-token stepping;
//! * [`sampler`] — greedy / temperature / top-k / nucleus policies on a
//!   deterministic [`Pcg`](crate::util::rng::Pcg) stream;
//! * [`session`] — one request's lifecycle: prefill, step, retire;
//! * [`scheduler`] — continuous batching of concurrent sessions against a
//!   token budget, emitting latency/throughput metrics.
//!
//! `benches/decode_throughput.rs` sweeps context per mechanism and shows
//! the payoff: flat µs/token for Polysketch/Performer, linear growth for
//! the softmax family.

pub mod model;
pub mod sampler;
pub mod scheduler;
pub mod session;

pub use crate::attn::KernelState;
pub use model::{LayerParams, LayerState, LmConfig, NativeLm, Params};
pub use sampler::SamplePolicy;
pub use scheduler::{Scheduler, SchedulerConfig, ServeSummary, SessionReport};
pub use session::{decode_text, encode_prompt, DecodeSession, GenRequest, SessionSnapshot};
