//! The model-runner process: one `NativeLm` (full replica or a head
//! shard), a private prompt cache, and a `serve::WorkerPool`, driven
//! entirely by frames from the gateway connection.
//!
//! A runner is launched by the supervisor as `psf runner --socket ...`
//! (a hidden subcommand), connects back over the Unix socket, announces
//! itself with a `Hello`, then serves multiplexed streams until the
//! connection dies or a `Shutdown` frame arrives.  It never binds a
//! port and never outlives its gateway: the gateway exiting closes the
//! socket, the inbound channel disconnects, and the runner drains and
//! exits — no orphan processes to reap.
//!
//! Two execution modes, chosen by the head range in [`RunnerConfig`]:
//!
//! * **replica** (full head range): `Generate` frames go through the
//!   same `WorkerPool` continuous-batching path as single-process
//!   serving, so a routed request is byte-identical to one served by
//!   `psf serve` without `--runners`;
//! * **head shard** (partial range): `TpGenerate` frames run the
//!   lock-step [`run_tp_session`] loop, exchanging per-layer partials
//!   with the gateway via `TpPartial`/`TpCombined` frames; the leader
//!   shard (head 0) additionally owns the token stream.

use std::collections::HashMap;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::infer::{decode_text, NativeLm};
use crate::metrics::ServeCounters;
use crate::obs;
use crate::serve::cache::PromptCache;
use crate::serve::worker::{RequestStats, ServeJob, TokenEvent, WorkerConfig, WorkerPool};

use super::mux::Mux;
use super::proto::{
    decode_generate, encode_done, encode_error, encode_hello, encode_token, Frame, FrameKind,
    Hello,
};
use super::tp::{run_tp_session, IpcCombine};

/// How long a runner keeps retrying the supervisor's socket on startup.
const CONNECT_WINDOW: Duration = Duration::from_secs(5);
/// Ceiling on one TP combine round-trip before the shard gives up.
const TP_COMBINE_TIMEOUT: Duration = Duration::from_secs(60);

#[derive(Clone, Debug)]
pub struct RunnerConfig {
    /// Supervisor's listening socket to connect back to.
    pub socket: PathBuf,
    pub runner_id: u32,
    pub worker: WorkerConfig,
    /// Admission-queue cap for this runner's pool.
    pub queue_cap: usize,
    /// Prompt-prefix cache byte budget (per runner — each shard caches
    /// only its keyslice, which is why routing keeps the slices stable).
    pub cache_bytes: usize,
    /// Head range `[head_start, head_end)` for TP mode; `head_end == 0`
    /// means full replica.
    pub head_start: usize,
    pub head_end: usize,
}

/// Process body of `psf runner`.  Returns when the gateway shuts the
/// connection down (or told us to), after draining in-flight work.
pub fn run_runner(model: NativeLm, cfg: RunnerConfig) -> anyhow::Result<()> {
    let heads = model.cfg.heads;
    let (range, tp) = if cfg.head_end > cfg.head_start {
        anyhow::ensure!(cfg.head_end <= heads, "head range end {} > {heads} heads", cfg.head_end);
        let full = cfg.head_start == 0 && cfg.head_end == heads;
        (cfg.head_start..cfg.head_end, !full)
    } else {
        (0..heads, false)
    };

    // The supervisor binds the listener before spawning us, but give the
    // accept loop a grace window anyway.
    let deadline = Instant::now() + CONNECT_WINDOW;
    let conn = loop {
        match UnixStream::connect(&cfg.socket) {
            Ok(c) => break c,
            Err(e) if Instant::now() < deadline => {
                let _ = e;
                thread::sleep(Duration::from_millis(25));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("connecting to {}", cfg.socket.display()))
            }
        }
    };

    let (inbound_tx, inbound_rx) = channel();
    let mux = Mux::start(conn, inbound_tx).context("starting runner mux")?;
    let hello = Hello {
        runner_id: cfg.runner_id,
        mech: model.mech.label(),
        head_start: range.start as u32,
        head_end: range.end as u32,
    };
    mux.send(&Frame::new(FrameKind::Hello, 0, encode_hello(&hello)))
        .context("sending hello")?;
    eprintln!(
        "psf runner {}: connected (mech {}, heads {}..{}{})",
        cfg.runner_id,
        hello.mech,
        range.start,
        range.end,
        if tp { ", tensor-parallel" } else { "" },
    );

    let model = Arc::new(model);
    let cache = Arc::new(PromptCache::new(cfg.cache_bytes));
    let counters = Arc::new(ServeCounters::new());
    // Gauges for this runner's flight recorder (inert unless started via
    // `--incident`); a crashing runner dumps its own incident file.
    counters.register_recorder_gauges();
    // TP shards run requests lock-step on dedicated threads; only
    // replicas need the continuous-batching pool.
    let pool = if tp {
        None
    } else {
        Some(WorkerPool::new(
            Arc::clone(&model),
            Arc::clone(&cache),
            Arc::clone(&counters),
            cfg.worker.clone(),
        ))
    };
    let cancels: Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>> = Arc::new(Mutex::new(HashMap::new()));

    while let Ok(frame) = inbound_rx.recv() {
        match frame.kind {
            FrameKind::Generate => {
                let stream = frame.stream;
                let (req, trace_id) = match decode_generate(&frame.payload) {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = mux.send(&Frame::new(
                            FrameKind::Error,
                            stream,
                            encode_error(false, &format!("bad generate payload: {e}")),
                        ));
                        continue;
                    }
                };
                let pool = match pool.as_ref() {
                    Some(p) => p,
                    None => {
                        let _ = mux.send(&Frame::new(
                            FrameKind::Error,
                            stream,
                            encode_error(false, "head-shard runner cannot serve Generate"),
                        ));
                        continue;
                    }
                };
                let (tx, rx) = channel();
                let job = ServeJob {
                    id: stream,
                    req,
                    events: tx,
                    queued: Instant::now(),
                    trace: trace_id,
                };
                match pool.try_submit(job, cfg.queue_cap) {
                    Ok(()) => {
                        counters.admitted.fetch_add(1, Ordering::Relaxed);
                        let flag = Arc::new(AtomicBool::new(false));
                        cancels.lock().unwrap().insert(stream, Arc::clone(&flag));
                        let mux = Arc::clone(&mux);
                        let cancels = Arc::clone(&cancels);
                        thread::spawn(move || {
                            for ev in rx.iter() {
                                // Dropping `rx` mid-stream is how the pool
                                // learns the request is cancelled.
                                if flag.load(Ordering::Relaxed) {
                                    break;
                                }
                                let out = match ev {
                                    TokenEvent::Token { token, text } => Frame::new(
                                        FrameKind::Token,
                                        stream,
                                        encode_token(token, &text),
                                    ),
                                    TokenEvent::Done(stats) => {
                                        Frame::new(FrameKind::Done, stream, encode_done(&stats))
                                    }
                                };
                                if mux.send(&out).is_err() {
                                    break;
                                }
                            }
                            cancels.lock().unwrap().remove(&stream);
                        });
                    }
                    Err(_job) => {
                        counters.rejected.fetch_add(1, Ordering::Relaxed);
                        let _ = mux.send(&Frame::new(
                            FrameKind::Error,
                            stream,
                            encode_error(true, "runner admission queue full, retry later"),
                        ));
                    }
                }
            }
            FrameKind::TpGenerate => {
                let stream = frame.stream;
                let (req, trace_id) = match decode_generate(&frame.payload) {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = mux.send(&Frame::new(
                            FrameKind::Error,
                            stream,
                            encode_error(false, &format!("bad generate payload: {e}")),
                        ));
                        continue;
                    }
                };
                // Register before the first TpPartial goes out: the
                // gateway only addresses this stream in response.
                let rx = mux.register_stream(stream);
                let mux = Arc::clone(&mux);
                let model = Arc::clone(&model);
                let range = range.clone();
                let counters = Arc::clone(&counters);
                thread::spawn(move || {
                    obs::set_trace_id(trace_id);
                    let _span = obs::span("tp_session", "shard");
                    let leader = range.start == 0;
                    let t0 = Instant::now();
                    let mut combine =
                        IpcCombine { mux: &mux, rx: &rx, stream, timeout: TP_COMBINE_TIMEOUT };
                    let mut on_token = |tok: u32| -> anyhow::Result<()> {
                        if leader {
                            mux.send(&Frame::new(
                                FrameKind::Token,
                                stream,
                                encode_token(tok, &decode_text(&[tok])),
                            ))?;
                        }
                        Ok(())
                    };
                    match run_tp_session(&model, range, &req, &mut combine, &mut on_token) {
                        Ok(run) => {
                            counters.completed.fetch_add(1, Ordering::Relaxed);
                            counters
                                .tokens_generated
                                .fetch_add(run.generated.len() as u64, Ordering::Relaxed);
                            if leader {
                                let stats = RequestStats {
                                    id: stream,
                                    prompt_len: run.prompt_len,
                                    new_tokens: run.generated.len(),
                                    cache_hit: false,
                                    ttft_secs: run.ttft_secs,
                                    prefill_secs: run.prefill_secs,
                                    decode_secs: run.decode_secs,
                                    wall_secs: t0.elapsed().as_secs_f64(),
                                    generated: run.generated,
                                };
                                let _ = mux.send(&Frame::new(
                                    FrameKind::Done,
                                    stream,
                                    encode_done(&stats),
                                ));
                            }
                        }
                        Err(e) => {
                            let _ = mux.send(&Frame::new(
                                FrameKind::Error,
                                stream,
                                encode_error(true, &e.to_string()),
                            ));
                        }
                    }
                    mux.close_stream(stream);
                });
            }
            FrameKind::Ping => {
                let _ = mux.send(&Frame::control(FrameKind::Pong));
            }
            FrameKind::Cancel => {
                if let Some(flag) = cancels.lock().unwrap().get(&frame.stream) {
                    flag.store(true, Ordering::Relaxed);
                }
            }
            FrameKind::MetricsReq => {
                let json = metrics_json(&cfg, &counters, &cache, pool.as_ref());
                let _ = mux.send(&Frame::new(
                    FrameKind::MetricsReply,
                    frame.stream,
                    json.into_bytes(),
                ));
            }
            FrameKind::Shutdown => break,
            // Hello/Token/Done/Error/Pong/Tp* are things *we* send (or
            // gateway-side answers to registered streams) — ignore.
            _ => {}
        }
    }

    if let Some(pool) = pool {
        pool.drain();
    }
    // Export this process's spans before exiting — the gateway merges
    // the per-runner files into its own trace after shutdown.
    match obs::flush() {
        Ok(Some(path)) => {
            eprintln!("psf runner {}: trace written to {}", cfg.runner_id, path.display())
        }
        Ok(None) => {}
        Err(e) => eprintln!("psf runner {}: trace flush failed: {e}", cfg.runner_id),
    }
    eprintln!("psf runner {}: drained, exiting", cfg.runner_id);
    Ok(())
}

/// Runner-local serve counters as a JSON object (a `serve_metrics`
/// record plus runner identity and pool gauges) — the payload of
/// `MetricsReply`, spliced verbatim into the gateway's `/metrics`.
fn metrics_json(
    cfg: &RunnerConfig,
    counters: &ServeCounters,
    cache: &PromptCache,
    pool: Option<&WorkerPool>,
) -> String {
    let stats = cache.stats();
    counters.cache_bytes.store(stats.bytes as u64, Ordering::Relaxed);
    // Arena gauges come from this runner's private cache arena — each
    // shard reports only the pages backing its own keyslice.
    counters.record_arena(&cache.arena_stats());
    counters
        .record()
        .i64("runner_id", cfg.runner_id as i64)
        .i64("cache_entries", stats.entries as i64)
        .i64("cache_evictions", stats.evictions as i64)
        .i64("queue_depth", pool.map_or(0, |p| p.queued()) as i64)
        .i64("resident", pool.map_or(0, |p| p.resident()) as i64)
        .to_json()
}
