//! Multi-process sharded serving: a gateway process routing over
//! model-runner worker processes, std-only (Unix sockets + threads).
//!
//! One `psf serve --runners N` invocation becomes N+1 processes: the
//! gateway (HTTP front-end, supervisor, consistent-hash router) and N
//! runners, each launched as the hidden `psf runner` subcommand of the
//! same binary and owning either a full `NativeLm` replica
//! (data-parallel, the default) or a contiguous head range of one model
//! (`--tp`, head-sharded tensor parallelism over the strided head views
//! the kernel core already exposes).
//!
//! The pieces, bottom-up:
//!
//! * [`proto`] — the versioned IPC frame codec: length-prefixed binary
//!   frames (magic, version, kind, stream id, payload) plus the
//!   payload codecs for requests, tokens, stats, and TP partials.
//!   Version mismatch is a hard connection error by design: gateway
//!   and runners ship in one binary, so disagreement means a stale
//!   process, not a peer to negotiate with.
//! * [`mux`] — stream multiplexing over one `UnixStream` per runner:
//!   a reader thread routes frames to per-stream channels; peer death
//!   is a *channel property* (every receiver disconnects at once), so
//!   in-flight requests fail fast instead of timing out.
//! * [`ring`] — consistent hashing (FNV-1a, 64 vnodes/runner) of the
//!   prompt-cache key, so each runner's prompt cache stays hot and a
//!   dead runner's keys move without reshuffling the rest.
//! * [`runner`] — the worker process body: replicas serve `Generate`
//!   through the same `serve::WorkerPool` continuous-batching path as
//!   single-process serving; head shards run the lock-step TP loop.
//! * [`supervisor`] — spawns runners, detects death (EOF, exit,
//!   heartbeat staleness), respawns and rebalances; the gateway
//!   degrades, it never dies with a runner.
//! * [`tp`] — head-range session driver shared by the in-process
//!   `LocalCombine` harness (tests) and the IPC combine path.
//! * [`gateway`] — the HTTP front-end: same request language, chunk
//!   format, and metrics shape as `serve::Gateway`, plus per-runner
//!   attribution, `/healthz` degradation reporting, and crash-retry
//!   error lines.
//!
//! Determinism contract, extended across process boundaries: a (seed,
//! prompt, policy) triple yields the same token stream whether served
//! by `psf serve` single-process, by any replica runner, or (world=1)
//! by the TP path — `tests/integration_shard.rs` pins this against the
//! in-process `DecodeSession` oracle.

pub mod gateway;
pub mod mux;
pub mod proto;
pub mod ring;
pub mod runner;
pub mod supervisor;
pub mod tp;

pub use gateway::{collect_shard_stream, ShardConfig, ShardEvent, ShardGateway, ShardReply};
pub use mux::Mux;
pub use proto::{Frame, FrameKind, Hello, ProtoError, VERSION};
pub use ring::{hash_key, HashRing};
pub use runner::{run_runner, RunnerConfig};
pub use supervisor::{OpenStream, Supervisor, SupervisorConfig};
pub use tp::{partition_heads, run_tp_session, LocalCombine, TpCombine, TpRun};
