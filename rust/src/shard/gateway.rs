//! The sharded serving gateway: an HTTP router over runner processes.
//!
//! Same request language and stream format as the single-process
//! `serve::Gateway` (the parser and chunk formatters are shared —
//! `serve::gateway::{parse_generate_body, token_chunk, done_chunk}`),
//! but the model lives in runner processes: the gateway holds no
//! weights, no decode states, and no prompt cache.  Each request's
//! cache key (mech label + prompt tokens) is consistent-hashed onto the
//! ring, so repeats land on the runner whose cache already holds the
//! prefix snapshot.
//!
//! Failure semantics: a request in flight on a runner that dies gets a
//! terminal `{"error":...,"retriable":true}` stream line — fast, from
//! the mux disconnect, not a timeout — while the supervisor respawns
//! the runner.  The gateway itself never dies with a runner; `/healthz`
//! reports `degraded` until the world is whole again.

use std::io;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::attn::Mechanism;
use crate::infer::GenRequest;
use crate::metrics::{json_escape, JsonlWriter, Record, ServeCounters};
use crate::obs;
use crate::serve::gateway::{
    done_chunk, parse_generate_body, request_record, token_chunk, GenDefaults,
};
use crate::serve::http::{Handler, HttpRequest, HttpServer, Responder};
use crate::serve::worker::RequestStats;
use crate::serve::Rejected;

use super::proto::{
    decode_done, decode_error, decode_token, decode_tp_vec, encode_tp_vec, Frame, FrameKind,
};
use super::ring::hash_key;
use super::supervisor::{OpenStream, Supervisor};

/// How long the gateway waits for the next frame of a replica stream.
/// A dead runner disconnects instantly (mux EOF); this limit only fires
/// on a wedged-but-alive runner.
const STREAM_TIMEOUT: Duration = Duration::from_secs(120);
/// Per-frame wait inside a TP exchange (lock-step, so much tighter).
const TP_TIMEOUT: Duration = Duration::from_secs(60);
/// Budget for collecting one runner's live counters into `/metrics`.
const METRICS_TIMEOUT: Duration = Duration::from_millis(250);

#[derive(Clone, Debug)]
pub struct ShardConfig {
    pub addr: String,
    pub default_max_tokens: usize,
    pub max_tokens_cap: usize,
    pub log_path: Option<PathBuf>,
    /// Stop after this many completed requests (0 = run forever).
    pub max_requests: u64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            addr: "127.0.0.1:0".into(),
            default_max_tokens: 64,
            max_tokens_cap: 512,
            log_path: None,
            max_requests: 0,
        }
    }
}

/// Events of one routed request (the sharded analogue of
/// `serve::TokenEvent`, with runner attribution and explicit failure).
#[derive(Clone, Debug)]
pub enum ShardEvent {
    Token { token: u32, text: String },
    Done { stats: RequestStats, runner: u32 },
    Failed { retriable: bool, msg: String, runner: Option<u32> },
}

/// Collected outcome of one request (bench/test client loop).
pub struct ShardReply {
    pub tokens: Vec<u32>,
    pub done: Option<(RequestStats, u32)>,
    pub error: Option<(bool, String)>,
}

/// Drain a [`ShardGateway::submit`] receiver to its terminal event.
pub fn collect_shard_stream(rx: Receiver<ShardEvent>) -> ShardReply {
    let mut reply = ShardReply { tokens: Vec::new(), done: None, error: None };
    for ev in rx.iter() {
        match ev {
            ShardEvent::Token { token, .. } => reply.tokens.push(token),
            ShardEvent::Done { stats, runner } => reply.done = Some((stats, runner)),
            ShardEvent::Failed { retriable, msg, .. } => reply.error = Some((retriable, msg)),
        }
    }
    reply
}

/// Gateway-side per-runner tallies.  The runner's own counters are
/// fetched live over IPC for `/metrics`; these survive runner deaths.
#[derive(Default)]
struct RunnerTally {
    routed: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
}

pub struct ShardGateway {
    sup: Arc<Supervisor>,
    cfg: ShardConfig,
    mech: Mechanism,
    pub counters: Arc<ServeCounters>,
    tally: Vec<RunnerTally>,
    next_trace: AtomicU64,
    stop: Arc<AtomicBool>,
    log: Mutex<Option<JsonlWriter>>,
    bound: Mutex<Option<std::net::SocketAddr>>,
    /// TP requests run the whole world lock-step; one at a time.
    tp_serial: Mutex<()>,
}

impl ShardGateway {
    pub fn new(
        sup: Arc<Supervisor>,
        mech: Mechanism,
        cfg: ShardConfig,
    ) -> anyhow::Result<ShardGateway> {
        let log = match &cfg.log_path {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        let tally = (0..sup.runners()).map(|_| RunnerTally::default()).collect();
        let counters = Arc::new(ServeCounters::new());
        // The supervisor's heartbeat feeds the IPC round-trip histogram.
        sup.set_counters(Arc::clone(&counters));
        // Serve gauges become flight-recorder time series (inert unless
        // the recorder is started).
        counters.register_recorder_gauges();
        Ok(ShardGateway {
            sup,
            cfg,
            mech,
            counters,
            tally,
            next_trace: AtomicU64::new(0),
            stop: Arc::new(AtomicBool::new(false)),
            log: Mutex::new(log),
            bound: Mutex::new(None),
            tp_serial: Mutex::new(()),
        })
    }

    pub fn http_addr(&self) -> Option<std::net::SocketAddr> {
        *self.bound.lock().expect("bound lock poisoned")
    }

    pub fn mech_label(&self) -> String {
        self.mech.label()
    }

    /// Flip this to stop `run_http` (what the SIGTERM watcher holds).
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    pub fn supervisor(&self) -> &Arc<Supervisor> {
        &self.sup
    }

    /// Route and run one request, streaming events to the receiver — the
    /// in-process analogue of `Gateway::submit` for benches and tests.
    /// (HTTP connections instead run `drive` on the connection thread.)
    pub fn submit(self: &Arc<Self>, req: GenRequest) -> Result<Receiver<ShardEvent>, Rejected> {
        if self.stop.load(Ordering::SeqCst) {
            self.counters.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(Rejected::Draining);
        }
        self.counters.admitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let gw = Arc::clone(self);
        thread::spawn(move || {
            gw.drive(req, &mut |ev| drop(tx.send(ev)));
        });
        Ok(rx)
    }

    /// Run one admitted request to its terminal event, synchronously.
    /// Mints the request's trace id here so both entry points (the
    /// `submit` thread and the HTTP connection thread) get one; the id
    /// rides the Generate frame so runner-side spans stitch to ours.
    fn drive(&self, req: GenRequest, emit: &mut dyn FnMut(ShardEvent)) {
        let trace = obs::mint_trace_id(self.next_trace.fetch_add(1, Ordering::Relaxed));
        obs::set_trace_id(trace);
        let _span = obs::span("serve_request", "gateway");
        if self.sup.is_tp() {
            self.drive_tp(req, trace, emit);
        } else {
            self.drive_replica(req, trace, emit);
        }
    }

    /// One replica-routed request: hash -> runner -> relay frames.
    fn drive_replica(&self, req: GenRequest, trace: u64, emit: &mut dyn FnMut(ShardEvent)) {
        let hash = hash_key(&self.mech.label(), &req.prompt);
        let runner = match self.sup.route(hash) {
            Some(r) => r,
            None => {
                emit(ShardEvent::Failed {
                    retriable: true,
                    msg: "no healthy runner (all shards down, respawning)".into(),
                    runner: None,
                });
                return;
            }
        };
        self.tally[runner as usize].routed.fetch_add(1, Ordering::Relaxed);
        let open = match self.sup.open_generate(runner, &req, trace) {
            Ok(o) => o,
            Err(e) => {
                self.fail(emit, runner, true, &format!("runner {runner} unavailable: {e}"));
                return;
            }
        };
        loop {
            let frame = match open.rx.recv_timeout(STREAM_TIMEOUT) {
                Ok(f) => f,
                Err(_) => {
                    // Disconnected (runner died — the usual case) or wedged.
                    self.fail(emit, runner, true, "runner connection lost mid-stream, retry");
                    return;
                }
            };
            match frame.kind {
                FrameKind::Token => match decode_token(&frame.payload) {
                    Ok((token, text)) => emit(ShardEvent::Token { token, text }),
                    Err(e) => {
                        self.fail(emit, runner, true, &format!("bad token frame: {e}"));
                        return;
                    }
                },
                FrameKind::Done => match decode_done(&frame.payload) {
                    Ok(stats) => {
                        self.complete(runner, &stats);
                        emit(ShardEvent::Done { stats, runner });
                        return;
                    }
                    Err(e) => {
                        self.fail(emit, runner, true, &format!("bad done frame: {e}"));
                        return;
                    }
                },
                FrameKind::Error => {
                    let (retriable, msg) = decode_error(&frame.payload)
                        .unwrap_or((true, "undecodable runner error".into()));
                    self.fail(emit, runner, retriable, &msg);
                    return;
                }
                _ => {} // stray frame kinds on a request stream: ignore
            }
        }
    }

    /// One tensor-parallel request: every runner steps the same request
    /// lock-step; the gateway is the combine hub (sum partials in shard
    /// order, broadcast the result) and relays the leader's tokens.
    fn drive_tp(&self, req: GenRequest, trace: u64, emit: &mut dyn FnMut(ShardEvent)) {
        let _serial = self.tp_serial.lock().expect("tp lock poisoned");
        let streams: Vec<OpenStream> = match self.sup.tp_streams(&req, trace) {
            Ok(s) => s,
            Err(e) => {
                emit(ShardEvent::Failed {
                    retriable: true,
                    msg: format!("TP world incomplete: {e}"),
                    runner: None,
                });
                return;
            }
        };
        let cancel_all = |streams: &[OpenStream]| {
            for s in streams {
                s.cancel();
            }
        };
        'rounds: loop {
            // Gather one TpPartial per shard.  Shard 0 is the leader and
            // is polled first: its interleaved Token frames are relayed,
            // and its Done — sent only after every shard has made its
            // final combine call — ends the run before we wait on
            // followers (who send nothing after their last partial).
            let mut partials: Vec<Option<(u32, Vec<f32>)>> =
                (0..streams.len()).map(|_| None).collect();
            for (i, open) in streams.iter().enumerate() {
                while partials[i].is_none() {
                    let frame = match open.rx.recv_timeout(TP_TIMEOUT) {
                        Ok(f) => f,
                        Err(_) => {
                            cancel_all(&streams);
                            self.fail(emit, open.runner, true, "TP shard lost mid-request, retry");
                            return;
                        }
                    };
                    match frame.kind {
                        FrameKind::TpPartial => match decode_tp_vec(&frame.payload) {
                            Ok(p) => partials[i] = Some(p),
                            Err(e) => {
                                cancel_all(&streams);
                                self.fail(emit, open.runner, true, &format!("bad TpPartial: {e}"));
                                return;
                            }
                        },
                        FrameKind::Token => {
                            if let Ok((token, text)) = decode_token(&frame.payload) {
                                emit(ShardEvent::Token { token, text });
                            }
                        }
                        FrameKind::Done => {
                            if let Ok(stats) = decode_done(&frame.payload) {
                                self.complete(open.runner, &stats);
                                emit(ShardEvent::Done { stats, runner: open.runner });
                            }
                            break 'rounds;
                        }
                        FrameKind::Error => {
                            let (retriable, msg) = decode_error(&frame.payload)
                                .unwrap_or((true, "undecodable runner error".into()));
                            cancel_all(&streams);
                            self.fail(emit, open.runner, retriable, &msg);
                            return;
                        }
                        _ => {}
                    }
                }
            }
            // Shard-index-order sum — the bitwise contract every shard's
            // residual depends on (f32 addition is order-sensitive).
            let (layer, mut sum) = partials[0].take().expect("leader partial gathered");
            for p in partials.iter_mut().skip(1) {
                let (l, data) = p.take().expect("follower partial gathered");
                if l != layer || data.len() != sum.len() {
                    cancel_all(&streams);
                    self.fail(emit, streams[0].runner, true, "TP shards out of step");
                    return;
                }
                for (s, v) in sum.iter_mut().zip(&data) {
                    *s += v;
                }
            }
            let combined = encode_tp_vec(layer, &sum);
            for open in &streams {
                let frame = Frame::new(FrameKind::TpCombined, open.stream, combined.clone());
                if open.send(&frame).is_err() {
                    cancel_all(&streams);
                    self.fail(emit, open.runner, true, "TP shard lost during broadcast, retry");
                    return;
                }
            }
        }
    }

    fn complete(&self, runner: u32, stats: &RequestStats) {
        self.tally[runner as usize].completed.fetch_add(1, Ordering::Relaxed);
        self.counters.completed.fetch_add(1, Ordering::Relaxed);
        self.counters.tokens_generated.fetch_add(stats.new_tokens as u64, Ordering::Relaxed);
        self.counters.record_ttft(stats.ttft_secs);
        if stats.cache_hit {
            self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.counters.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        self.on_done(runner, stats);
    }

    fn fail(&self, emit: &mut dyn FnMut(ShardEvent), runner: u32, retriable: bool, msg: &str) {
        self.tally[runner as usize].failed.fetch_add(1, Ordering::Relaxed);
        emit(ShardEvent::Failed { retriable, msg: msg.to_string(), runner: Some(runner) });
    }

    /// Per-request JSONL record + the `max_requests` stop condition.
    fn on_done(&self, runner: u32, stats: &RequestStats) {
        if let Some(w) = self.log.lock().expect("log lock poisoned").as_mut() {
            let _ = w.write(&request_record(&self.mech.label(), stats).i64("runner", runner as i64));
            let _ = w.flush();
        }
        if self.cfg.max_requests > 0
            && self.counters.completed.load(Ordering::Relaxed) >= self.cfg.max_requests
        {
            self.stop.store(true, Ordering::SeqCst);
        }
    }

    /// Aggregate serve counters (same `serve_metrics` shape as the
    /// single-process gateway, plus fleet gauges).
    pub fn metrics_record(&self) -> Record {
        let (total, healthy) = self.sup.health();
        self.counters
            .record()
            .str("mech", self.mech.label())
            .i64("runners", total as i64)
            .i64("healthy_runners", healthy as i64)
            .i64("respawns", self.sup.respawn_count() as i64)
    }

    /// `/metrics` body: the aggregate record with a `"runners":[..]`
    /// array spliced in — per-runner gateway tallies plus each live
    /// runner's own counters (`null` for a dead or unresponsive runner).
    pub fn metrics_json(&self) -> String {
        let base = self.metrics_record().to_json();
        let states = self.sup.runner_states();
        let mut runners = String::from("[");
        for (i, (healthy, respawns)) in states.iter().enumerate() {
            if i > 0 {
                runners.push(',');
            }
            let live = if *healthy {
                self.sup
                    .fetch_runner_metrics(i as u32, METRICS_TIMEOUT)
                    .unwrap_or_else(|| "null".into())
            } else {
                "null".into()
            };
            runners.push_str(&format!(
                "{{\"runner\":{i},\"healthy\":{healthy},\"respawns\":{respawns},\
                 \"routed\":{},\"completed\":{},\"failed\":{},\"live\":{live}}}",
                self.tally[i].routed.load(Ordering::Relaxed),
                self.tally[i].completed.load(Ordering::Relaxed),
                self.tally[i].failed.load(Ordering::Relaxed),
            ));
        }
        runners.push(']');
        format!("{},\"runners\":{}}}", &base[..base.len() - 1], runners)
    }

    /// Serve HTTP until stopped, then shut the runner fleet down and
    /// flush the closing metrics record.  The first banner line matches
    /// the single-process gateway (the CI smoke scrapes the addr off it).
    pub fn run_http(self: Arc<ShardGateway>) -> anyhow::Result<()> {
        let server = HttpServer::bind(&self.cfg.addr)?;
        let addr = server.local_addr()?;
        *self.bound.lock().expect("bound lock poisoned") = Some(addr);
        println!("psf serve: listening on http://{addr} (mech {})", self.mech_label());
        println!(
            "psf serve: {} runner processes ({})",
            self.sup.runners(),
            if self.sup.is_tp() {
                "head-sharded tensor parallel"
            } else {
                "data-parallel replicas"
            },
        );
        let stop = Arc::clone(&self.stop);
        let handler: Arc<dyn Handler> = Arc::clone(&self) as Arc<dyn Handler>;
        server.serve(handler, stop)?;
        self.finish()
    }

    /// Stop accepting, shut down the fleet, flush the closing record.
    pub fn finish(&self) -> anyhow::Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        self.sup.shutdown();
        let record = self.metrics_record();
        if let Some(w) = self.log.lock().expect("log lock poisoned").as_mut() {
            w.write(&record)?;
            w.flush()?;
        }
        eprintln!("psf serve: drained — {}", record.to_json());
        Ok(())
    }

    /// Stream one request out as chunked JSON lines (the connection
    /// thread blocks in `drive` while tokens relay through it).
    fn stream_response(&self, req: GenRequest, resp: &mut Responder<'_>) -> io::Result<()> {
        resp.start_chunked(200, "application/json")?;
        let mut io_err: Option<io::Error> = None;
        self.drive(req, &mut |ev| {
            if io_err.is_some() {
                return; // client went away; let the drive finish quietly
            }
            let result = match ev {
                ShardEvent::Token { token, text } => resp.chunk(&token_chunk(token, &text)),
                ShardEvent::Done { stats, runner } => {
                    resp.chunk(&done_chunk(&stats, &format!(",\"runner\":{runner}")))
                }
                ShardEvent::Failed { retriable, msg, runner } => resp.chunk(&format!(
                    "{{\"error\":{},\"retriable\":{},\"runner\":{}}}\n",
                    json_escape(&msg),
                    retriable,
                    runner.map_or("null".to_string(), |r| r.to_string()),
                )),
            };
            if let Err(e) = result {
                io_err = Some(e);
            }
        });
        match io_err {
            Some(e) => Err(e),
            None => resp.finish(),
        }
    }
}

impl Handler for ShardGateway {
    fn handle(&self, req: HttpRequest, resp: &mut Responder<'_>) -> io::Result<()> {
        let (path, query) = match req.path.split_once('?') {
            Some((p, q)) => (p, q),
            None => (req.path.as_str(), ""),
        };
        match (req.method.as_str(), path) {
            ("GET", "/healthz") => {
                let (total, healthy) = self.sup.health();
                resp.simple(
                    200,
                    "application/json",
                    &format!(
                        "{{\"ok\":true,\"mech\":{},\"linear\":{},\"simd\":{},\"quant\":{},\
                         \"uptime_seconds\":{:.1},\
                         \"runners\":{},\"healthy\":{},\"degraded\":{},\"respawns\":{}}}",
                        json_escape(&self.mech.label()),
                        self.mech.is_linear(),
                        json_escape(crate::tensor::micro::backend_label()),
                        json_escape(crate::mem::quant::mode().label()),
                        crate::obs::uptime_secs(),
                        total,
                        healthy,
                        healthy < total,
                        self.sup.respawn_count(),
                    ),
                )
            }
            ("GET", "/metrics") if query.split('&').any(|kv| kv == "format=prometheus") => resp
                .simple(200, "text/plain; version=0.0.4", &self.counters.prometheus_text()),
            ("GET", "/metrics") => resp.simple(200, "application/json", &self.metrics_json()),
            ("POST", "/v1/generate") => {
                let defaults = GenDefaults {
                    default_max_tokens: self.cfg.default_max_tokens,
                    max_tokens_cap: self.cfg.max_tokens_cap,
                };
                let gen_req = match parse_generate_body(&req.body_str(), &defaults) {
                    Ok(r) => r,
                    Err(msg) => {
                        return resp.simple(
                            400,
                            "application/json",
                            &format!("{{\"error\":{}}}", json_escape(&msg)),
                        );
                    }
                };
                if self.stop.load(Ordering::SeqCst) {
                    self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                    return resp.simple(
                        503,
                        "application/json",
                        "{\"error\":\"gateway is draining\"}",
                    );
                }
                self.counters.admitted.fetch_add(1, Ordering::Relaxed);
                self.stream_response(gen_req, resp)
            }
            (_, "/healthz" | "/metrics" | "/v1/generate") => {
                resp.simple(405, "application/json", "{\"error\":\"method not allowed\"}")
            }
            _ => resp.simple(404, "application/json", "{\"error\":\"no such route\"}"),
        }
    }
}
