//! Consistent-hash ring routing requests to runners.
//!
//! Routing key = the prompt-cache key (mech label + prompt tokens), so a
//! repeated prompt always lands on the runner whose `serve::cache`
//! already holds its prefix snapshot.  Consistent hashing (rather than
//! `hash % runners`) means removing a crashed runner only remaps the
//! keys that lived on it — every other runner's cache stays hot, which
//! is the whole point of sharding the keyspace.
//!
//! Each runner owns [`VNODES`] virtual points on a `u64` ring; a key
//! routes to the first point clockwise from its hash.  Rebalance
//! stability is pinned by a property test in `tests/properties.rs`.

use std::collections::BTreeMap;

/// Virtual points per runner: enough to keep the keyspace split within a
/// few percent of even for single-digit runner counts.
pub const VNODES: u32 = 64;

/// FNV-1a, 64-bit.  Stable across platforms and releases — ring layout
/// is part of the cache-locality contract, so no `DefaultHasher`.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Hash of a prompt-cache key: mech label, a separator that cannot occur
/// in a label, then the token ids little-endian.
pub fn hash_key(mech: &str, prompt: &[u32]) -> u64 {
    let mut buf = Vec::with_capacity(mech.len() + 1 + prompt.len() * 4);
    buf.extend_from_slice(mech.as_bytes());
    buf.push(0xff);
    for &t in prompt {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    fnv1a(&buf)
}

/// The ring: hash point -> runner id.
#[derive(Clone, Debug, Default)]
pub struct HashRing {
    points: BTreeMap<u64, u32>,
}

impl HashRing {
    pub fn new() -> HashRing {
        HashRing::default()
    }

    fn vnode_hash(runner: u32, vnode: u32) -> u64 {
        let mut buf = [0u8; 16];
        buf[..4].copy_from_slice(&runner.to_le_bytes());
        buf[4..8].copy_from_slice(&vnode.to_le_bytes());
        buf[8..16].copy_from_slice(b"psf-ring");
        fnv1a(&buf)
    }

    pub fn add(&mut self, runner: u32) {
        for v in 0..VNODES {
            self.points.insert(Self::vnode_hash(runner, v), runner);
        }
    }

    pub fn remove(&mut self, runner: u32) {
        for v in 0..VNODES {
            let h = Self::vnode_hash(runner, v);
            // Only remove a point we own: two runners' vnodes could in
            // principle collide, and the survivor must keep its point.
            if self.points.get(&h) == Some(&runner) {
                self.points.remove(&h);
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn len_runners(&self) -> usize {
        let mut ids: Vec<u32> = self.points.values().copied().collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    /// First point clockwise from `hash`, wrapping at the top of the
    /// keyspace.  `None` only when the ring is empty (all runners down).
    pub fn route(&self, hash: u64) -> Option<u32> {
        self.points
            .range(hash..)
            .next()
            .or_else(|| self.points.iter().next())
            .map(|(_, &r)| r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_spread_across_runners() {
        let mut ring = HashRing::new();
        for r in 0..4 {
            ring.add(r);
        }
        let mut counts = [0usize; 4];
        for i in 0..4000u32 {
            let h = hash_key("psk4_r4_b8_local", &[i, i * 7 + 1]);
            counts[ring.route(h).unwrap() as usize] += 1;
        }
        for (r, &c) in counts.iter().enumerate() {
            assert!(c > 400, "runner {r} got only {c}/4000 keys — vnode spread too lumpy");
        }
    }

    #[test]
    fn removal_only_moves_victims_keys() {
        let mut ring = HashRing::new();
        for r in 0..3 {
            ring.add(r);
        }
        let keys: Vec<u64> = (0..2000u32).map(|i| hash_key("softmax", &[i])).collect();
        let before: Vec<u32> = keys.iter().map(|&h| ring.route(h).unwrap()).collect();
        ring.remove(1);
        for (&h, &owner) in keys.iter().zip(&before) {
            let after = ring.route(h).unwrap();
            if owner != 1 {
                assert_eq!(after, owner, "key moved off a surviving runner");
            } else {
                assert_ne!(after, 1);
            }
        }
        // Re-adding restores the exact original layout (vnode hashes are
        // deterministic).
        ring.add(1);
        let restored: Vec<u32> = keys.iter().map(|&h| ring.route(h).unwrap()).collect();
        assert_eq!(restored, before);
    }

    #[test]
    fn empty_ring_routes_nowhere() {
        let mut ring = HashRing::new();
        assert!(ring.route(123).is_none());
        ring.add(0);
        assert_eq!(ring.route(123), Some(0));
        ring.remove(0);
        assert!(ring.route(123).is_none());
    }
}
